"""RG-LRU linear recurrence (RecurrentGemma) as a Pallas TPU kernel.

    h_t = a_t * h_{t-1} + b_t,   b_t = sqrt(1 - a_t^2) * x_t

TPU adaptation: the recurrence is feature-parallel, so the grid tiles
(batch, features) — each program owns a [S, block_d] VMEM tile and carries
the hidden state in a VMEM scratch row across a fori_loop over time.  The
gate precomputation (sqrt(1-a²)·x) is vectorized outside the kernel where
the VPU is fully utilised.  (A production variant would run a chunked
associative scan per tile for log-depth; the sequential form is the
validation target and matches Griffin's own TPU reference.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rglru_scan"]


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, hlast_ref, h_scr, *,
                  seq_len: int):
    h_scr[...] = h0_ref[0].astype(jnp.float32)       # [1, bd]

    def body(t, _):
        a_t = a_ref[0, t, :].astype(jnp.float32)     # [bd]
        b_t = b_ref[0, t, :].astype(jnp.float32)
        h = a_t * h_scr[0, :] + b_t
        h_scr[0, :] = h
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, seq_len, body, 0)
    hlast_ref[0] = h_scr[...].astype(hlast_ref.dtype)


def rglru_scan(x: jax.Array, a: jax.Array,
               h0: jax.Array | None = None,
               block_d: int = 128,
               interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """x, a: [B, S, D]; returns (h [B, S, D], h_last [B, D])."""
    B, S, D = x.shape
    if h0 is None:
        h0 = jnp.zeros((B, D), jnp.float32)
    af = a.astype(jnp.float32)
    b = jnp.sqrt(jnp.clip(1.0 - af * af, 0.0, 1.0)) * x.astype(jnp.float32)

    block_d = min(block_d, D)
    nd = pl.cdiv(D, block_d)
    kernel = functools.partial(_rglru_kernel, seq_len=S)
    out, h_last = pl.pallas_call(
        kernel,
        grid=(B, nd),
        in_specs=[
            pl.BlockSpec((1, S, block_d), lambda bi, di: (bi, 0, di)),
            pl.BlockSpec((1, S, block_d), lambda bi, di: (bi, 0, di)),
            pl.BlockSpec((1, 1, block_d), lambda bi, di: (bi, 0, di)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, block_d), lambda bi, di: (bi, 0, di)),
            pl.BlockSpec((1, 1, block_d), lambda bi, di: (bi, 0, di)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), x.dtype),
            jax.ShapeDtypeStruct((B, 1, D), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
    )(af, b, h0.reshape(B, 1, D))
    return out, h_last.reshape(B, D)
