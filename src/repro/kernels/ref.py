"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth (tests sweep shapes/dtypes and
assert_allclose against them) and the CPU execution path (the Pallas
kernels target TPU; on CPU they run in interpret mode or fall back here).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref", "rglru_ref", "rwkv6_ref", "rwkv6_chunked"]


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: int | None = None,
                  softcap: float | None = None,
                  scale: float | None = None,
                  q_offset: int = 0,
                  kv_len: jax.Array | None = None) -> jax.Array:
    """Multi-head attention with GQA, sliding window and logit softcap.

    Shapes: q [B, Sq, Hq, D], k/v [B, Sk, Hkv, D] with Hq % Hkv == 0.
    `q_offset` is the absolute position of q[:, 0] (decode: Sq=1,
    q_offset=pos).  `kv_len` optionally masks cache positions >= kv_len.
    Computation in float32, result cast back to q.dtype.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    groups = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads for GQA
    kf = jnp.repeat(kf, groups, axis=2)
    vf = jnp.repeat(vf, groups, axis=2)

    logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap

    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    if kv_len is not None:
        mask &= k_pos[None, :] < kv_len
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.astype(q.dtype)


def rglru_ref(x: jax.Array, a: jax.Array, reset: jax.Array | None = None,
              h0: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """RG-LRU linear recurrence (Griffin / RecurrentGemma):

        h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * x_t

    Shapes: x, a [B, S, D] (a in (0,1), already gated); returns
    (h [B, S, D], h_last [B, D]).  float32 internally.
    """
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    gated = jnp.sqrt(jnp.clip(1.0 - af * af, 0.0, 1.0)) * xf
    if h0 is None:
        h0 = jnp.zeros(x.shape[:1] + x.shape[2:], jnp.float32)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    h_last, hs = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (af.swapaxes(0, 1), gated.swapaxes(0, 1)))
    return hs.swapaxes(0, 1).astype(x.dtype), h_last.astype(x.dtype)


def rwkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
              u: jax.Array, s0: jax.Array | None = None
              ) -> tuple[jax.Array, jax.Array]:
    """RWKV6 (Finch) WKV recurrence with data-dependent decay.

    Per head with state S [D_k, D_v]:

        out_t = r_t @ (S + u^T ⊙ (k_t^T v_t))
        S    <- diag(w_t) S + k_t^T v_t

    Shapes: r/k/w [B, S, H, Dk], v [B, S, H, Dv], u [H, Dk].
    Returns (out [B, S, H, Dv], S_last [B, H, Dk, Dv]).
    """
    B, S, H, Dk = r.shape
    Dv = v.shape[-1]
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    uf = u.astype(jnp.float32)
    if s0 is None:
        s0 = jnp.zeros((B, H, Dk, Dv), jnp.float32)

    def step(S, rkvw):
        r_t, k_t, v_t, w_t = rkvw          # [B,H,Dk],[B,H,Dk],[B,H,Dv],[B,H,Dk]
        kv = k_t[..., :, None] * v_t[..., None, :]      # [B,H,Dk,Dv]
        out = jnp.einsum("bhk,bhkv->bhv", r_t,
                         S + uf[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, out

    s_last, outs = jax.lax.scan(
        step, s0.astype(jnp.float32),
        (rf.transpose(1, 0, 2, 3), kf.transpose(1, 0, 2, 3),
         vf.transpose(1, 0, 2, 3), wf.transpose(1, 0, 2, 3)))
    out = outs.transpose(1, 0, 2, 3)       # [B,S,H,Dv]
    return out.astype(r.dtype), s_last.astype(jnp.float32)


def rwkv6_chunked(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                  u: jax.Array, s0: jax.Array | None = None,
                  chunk: int = 64, subchunk: int = 8
                  ) -> tuple[jax.Array, jax.Array]:
    """Chunk-parallel WKV6, exact w.r.t. `rwkv6_ref` (float32 rounding).

    The per-timestep scan round-trips the Dk×Dv state through HBM every
    step; this form carries state once per `chunk` steps (the lax.scan
    carry) and handles the inside of a chunk with `chunk/subchunk`
    unrolled sub-blocks that stay inside one fusion: within a sub-block
    the pairwise decay is computed in a numerically safe factorised form
    (exponent range bounded by subchunk·|log w| <= ~88), across
    sub-blocks the state is passed in registers.  MXU-friendly masked
    matmuls replace the rank-1 VPU updates — this is the production
    training path (EXPERIMENTS §Perf) and mirrors the Pallas kernel's
    VMEM-resident-state algorithm.
    """
    B, S, H, Dk = r.shape
    Dv = v.shape[-1]
    L = min(chunk, S)
    q = min(subchunk, L)
    assert S % L == 0 and L % q == 0, (S, L, q)
    n_chunks = S // L
    n_sub = L // q
    # keep the bulk arrays in their storage dtype (bf16 on the training
    # path) — per-subchunk tiles are upcast inside sub_block, which cuts
    # four full-sequence f32 copies per layer (EXPERIMENTS §Perf iter 2)
    rf = r.reshape(B, n_chunks, L, H, Dk)
    kf = k.reshape(B, n_chunks, L, H, Dk)
    vf = v.reshape(B, n_chunks, L, H, Dv)
    logw = w.reshape(B, n_chunks, L, H, Dk)
    uf = u.astype(jnp.float32)
    if s0 is None:
        s0 = jnp.zeros((B, H, Dk, Dv), jnp.float32)

    # chunks on the scan axis: [n_chunks, B, L, H, *]
    rf, kf, vf, logw = (x.swapaxes(0, 1) for x in (rf, kf, vf, logw))
    tri = jnp.tril(jnp.ones((q, q), jnp.float32), k=-1)  # strict lower

    def sub_block(S_state, rc, kc, vc, lw):
        """One q-length sub-block: exact factorised pairwise decays."""
        out_dtype = rc.dtype
        rc = rc.astype(jnp.float32)
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        lw = jnp.log(jnp.clip(lw.astype(jnp.float32), 1e-30, 1.0))
        Lc = jnp.cumsum(lw, axis=1)              # inclusive prefix [B,q,H,D]
        Lprev = Lc - lw                          # exclusive prefix
        rd = rc * jnp.exp(Lprev)                 # <= rc (decays)
        ki = kc * jnp.exp(-Lc)                   # bounded: q*|log w| <= ~88
        sc = jnp.einsum("bthd,bihd->bhti", rd, ki) * tri[None, None]
        diag = jnp.einsum("bthd,bthd->bth", rc, uf[None, None] * kc)
        out = jnp.einsum("bhti,bihd->bthd", sc, vc)
        out = out + diag[..., None] * vc
        out = out + jnp.einsum("bthk,bhkv->bthv", rd, S_state)
        decay_all = jnp.exp(Lc[:, -1])           # [B,H,Dk]
        kd = kc * jnp.exp(Lc[:, -1][:, None] - Lc)
        S_new = (decay_all[..., None] * S_state
                 + jnp.einsum("bthk,bthv->bhkv", kd, vc))
        # emit storage dtype per tile: halves the stacked chunk outputs
        # and their gradients (EXPERIMENTS §Perf rwkv iter 3)
        return S_new, out.astype(out_dtype)

    def per_chunk(S_state, xs):
        rc, kc, vc, lw = xs                      # [B, L, H, *]
        outs = []
        for j in range(n_sub):                   # unrolled: in-fusion state
            sl = slice(j * q, (j + 1) * q)
            S_state, o = sub_block(S_state, rc[:, sl], kc[:, sl],
                                   vc[:, sl], lw[:, sl])
            outs.append(o)
        return S_state, jnp.concatenate(outs, axis=1)

    s_last, outs = jax.lax.scan(per_chunk, s0.astype(jnp.float32),
                                (rf, kf, vf, logw))
    out = outs.swapaxes(0, 1).reshape(B, S, H, Dv)
    return out.astype(r.dtype), s_last
