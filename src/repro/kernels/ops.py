"""jit-ready wrappers around the Pallas kernels with platform dispatch.

Three implementations per op:
  * "pallas"  — the TPU kernel (interpret-mode on CPU, compiled on TPU);
  * "ref"     — the pure-jnp oracle (differentiable, used for training on
                CPU and as the ground truth in tests);
  * "chunked" — flash-semantics pure-jnp attention: lax.scan over kv
                blocks with online softmax.  This is what long-context
                paths lower in the dry-run, so `cost_analysis()` reports
                flash-like memory traffic instead of a materialised
                [B,H,S,S] logit tensor.

`impl="auto"` picks: pallas on TPU; on CPU, ref for short sequences and
chunked once Sk exceeds `CHUNK_THRESHOLD`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref as _ref
from .flash_attention import flash_attention as _flash
from .rglru import rglru_scan as _rglru_pallas
from .rwkv6 import rwkv6_scan as _rwkv6_pallas

__all__ = ["attention", "rglru", "rwkv6", "on_tpu"]

CHUNK_THRESHOLD = 1024
_KV_BLOCK = 512


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------- #
# attention
# ---------------------------------------------------------------------- #
def _attention_chunked(q, k, v, *, causal, window, softcap, scale,
                       q_offset=0, kv_len=None, kv_block=_KV_BLOCK):
    """Online-softmax attention, scanned over kv blocks (flash semantics).
    Supports distinct qk and v head dims (MLA: 192 vs 128)."""
    B, Sq, Hq, Dk = q.shape
    _, Sk, Hkv, _ = k.shape
    Dv = v.shape[-1]
    groups = Hq // Hkv
    scale = scale if scale is not None else Dk ** -0.5
    nblocks = -(-Sk // kv_block)
    pad = nblocks * kv_block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qf = q.astype(jnp.float32) * scale
    kb = k.reshape(B, nblocks, kv_block, Hkv, Dk).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblocks, kv_block, Hkv, Dv).transpose(1, 0, 2, 3, 4)
    q_pos = jnp.arange(Sq) + q_offset

    def step(carry, blk):
        m, lsum, acc, bi = carry
        kblk, vblk = blk                              # [B, bk, Hkv, D]
        kblk = jnp.repeat(kblk.astype(jnp.float32), groups, axis=2)
        vblk = jnp.repeat(vblk.astype(jnp.float32), groups, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kblk)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        k_pos = bi * kv_block + jnp.arange(kv_block)
        mask = k_pos[None, :] < Sk
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        if kv_len is not None:
            mask &= k_pos[None, :] < kv_len
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        lsum = alpha * lsum + p.sum(-1, keepdims=True)
        acc = acc * alpha.swapaxes(1, 2) + jnp.einsum(
            "bhqk,bkhd->bqhd", p, vblk)
        return (m_new, lsum, acc, bi + 1), None

    m0 = jnp.full((B, Hq, Sq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hq, Sq, 1), jnp.float32)
    acc0 = jnp.zeros((B, Sq, Hq, Dv), jnp.float32)
    (m, lsum, acc, _), _ = jax.lax.scan(step, (m0, l0, acc0, 0), (kb, vb))
    lsum = jnp.where(lsum == 0.0, 1.0, lsum).swapaxes(1, 2)  # [B, Sq, Hq, 1]
    return (acc / lsum).astype(q.dtype)


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              softcap: float | None = None, scale: float | None = None,
              q_offset=0, kv_len=None, impl: str = "auto") -> jax.Array:
    """Unified attention entry point used by every model."""
    if impl == "auto":
        if on_tpu():
            impl = "pallas"
        elif k.shape[1] > CHUNK_THRESHOLD:
            impl = "chunked"
        else:
            impl = "ref"
    if impl == "pallas":
        # static offsets only in the kernel path; fall back otherwise
        if isinstance(q_offset, int) and kv_len is None:
            return _flash(q, k, v, causal=causal, window=window,
                          softcap=softcap, scale=scale,
                          interpret=not on_tpu())
        impl = "chunked"
    if impl == "chunked":
        return _attention_chunked(q, k, v, causal=causal, window=window,
                                  softcap=softcap, scale=scale,
                                  q_offset=q_offset, kv_len=kv_len)
    if impl == "ref":
        return _ref.attention_ref(q, k, v, causal=causal, window=window,
                                  softcap=softcap, scale=scale,
                                  q_offset=q_offset, kv_len=kv_len)
    raise ValueError(f"unknown impl {impl!r}")


# ---------------------------------------------------------------------- #
# recurrences
# ---------------------------------------------------------------------- #
def rglru(x, a, h0=None, impl: str = "auto"):
    """RG-LRU scan; returns (h, h_last)."""
    if impl == "auto":
        impl = "pallas" if on_tpu() else "ref"
    if impl == "pallas":
        return _rglru_pallas(x, a, h0, interpret=not on_tpu())
    return _ref.rglru_ref(x, a, h0=h0)


def rwkv6(r, k, v, w, u, s0=None, impl: str = "auto"):
    """RWKV6 WKV scan; returns (out, state_last).

    "auto" uses the chunk-parallel formulation for sequences (state
    carried once per 64 steps; MXU matmuls — EXPERIMENTS §Perf iteration
    on rwkv6-7b/train_4k) and the per-step form for single-token decode.
    """
    if impl == "auto":
        if on_tpu():
            impl = "pallas"
        elif r.shape[1] > 1:
            impl = "chunked"
        else:
            impl = "ref"
    if impl == "pallas":
        return _rwkv6_pallas(r, k, v, w, u, s0, interpret=not on_tpu())
    if impl == "chunked":
        S = r.shape[1]
        chunk = 64 if S % 64 == 0 else (S if S <= 64 else 1)
        if chunk > 1:
            sub = 8 if chunk % 8 == 0 else chunk
            return _ref.rwkv6_chunked(r, k, v, w, u, s0=s0, chunk=chunk,
                                      subchunk=sub)
        impl = "ref"
    return _ref.rwkv6_ref(r, k, v, w, u, s0=s0)
