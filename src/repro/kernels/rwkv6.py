"""RWKV6 (Finch) WKV recurrence as a Pallas TPU kernel.

Per head, with state S ∈ R^{Dk×Dv}:

    out_t = r_t (S + u ⊙ k_t^T v_t)
    S    <- diag(w_t) S + k_t^T v_t        (data-dependent decay w_t)

TPU adaptation: grid tiles (batch, heads); each program owns the full
[S, Dk]/[S, Dv] stripes of one head in VMEM and carries the Dk×Dv state
matrix in VMEM scratch across a fori_loop over time.  Head dims are 64
(rwkv6-7b), so the state tile (64×64 fp32 = 16 KB) sits comfortably in
VMEM and each step is a rank-1 update + matvec on the VPU.  A production
variant chunks time and uses the MXU for the intra-chunk parallel form;
the sequential form is the validated reference target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rwkv6_scan"]


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                  o_ref, slast_ref, s_scr, *, seq_len: int):
    s_scr[...] = s0_ref[0, 0].astype(jnp.float32)     # [Dk, Dv]
    u = u_ref[0].astype(jnp.float32)                  # [1?, Dk] -> [Dk]

    def body(t, _):
        r_t = r_ref[0, 0, t, :].astype(jnp.float32)   # [Dk]
        k_t = k_ref[0, 0, t, :].astype(jnp.float32)   # [Dk]
        v_t = v_ref[0, 0, t, :].astype(jnp.float32)   # [Dv]
        w_t = w_ref[0, 0, t, :].astype(jnp.float32)   # [Dk]
        kv = k_t[:, None] * v_t[None, :]              # [Dk, Dv]
        s = s_scr[...]
        out = jnp.sum((s + u[0][:, None] * kv) * r_t[:, None], axis=0)
        s_scr[...] = w_t[:, None] * s + kv
        o_ref[0, 0, t, :] = out.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, seq_len, body, 0)
    slast_ref[0, 0] = s_scr[...]


def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, s0: jax.Array | None = None,
               interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """r/k/w [B, S, H, Dk], v [B, S, H, Dv], u [H, Dk].

    Returns (out [B, S, H, Dv], S_last [B, H, Dk, Dv]).
    """
    B, S, H, Dk = r.shape
    Dv = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((B, H, Dk, Dv), jnp.float32)

    # [B, H, S, D] stripes per (batch, head) program
    rt = r.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    wt = w.transpose(0, 2, 1, 3)

    kernel = functools.partial(_rwkv6_kernel, seq_len=S)
    out, s_last = pl.pallas_call(
        kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1, 1, S, Dk), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, S, Dk), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, S, Dv), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, S, Dk), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Dk), lambda b, h: (0, h, 0)),
            pl.BlockSpec((1, 1, Dk, Dv), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, S, Dv), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Dk, Dv), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, Dv), r.dtype),
            jax.ShapeDtypeStruct((B, H, Dk, Dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Dk, Dv), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, u.reshape(1, H, Dk), s0)
    return out.transpose(0, 2, 1, 3), s_last
