"""Flash attention (forward) as a Pallas TPU kernel.

TPU-native adaptation (DESIGN.md §2): the online-softmax streaming
algorithm is re-tiled for the TPU memory hierarchy — q/k/v blocks live in
VMEM via BlockSpec, the (block_q × head_dim) accumulator stays in VMEM
scratch across the k-grid, and block shapes are multiples of 128 so the
MXU sees aligned matmuls.  Supports causal masking, sliding windows
(gemma2/recurrentgemma local layers), logit softcapping (gemma2) and GQA
(kv heads broadcast via the index map, so no repeated kv in HBM).

Grid: (batch, q_heads, Sq/block_q, Sk/block_k) — the k axis is the
innermost (sequential) dimension; softmax state (m, l) and the output
accumulator are carried in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

_NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int | None,
               softcap: float | None, block_q: int, block_k: int,
               seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale      # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)              # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)              # [bk, d]
    # zero padded kv rows (non-divisible seq): 0 * garbage would be NaN
    kv_valid = (ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_k, 1), 0)) < seq_k
    k = jnp.where(kv_valid, k, 0.0)
    v = jnp.where(kv_valid, v, 0.0)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = k_pos < seq_k
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]                              # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                           # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)                  # [bq, 1]
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _done():
        lsum = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.where(lsum == 0.0, 1.0, lsum)
                       ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    softcap: float | None = None,
                    scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q [B, Sq, Hq, D], k/v [B, Sk, Hkv, D] -> [B, Sq, Hq, D].

    `interpret=True` executes the kernel body in Python on CPU (the
    container target); on TPU pass interpret=False.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    groups = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 8))
    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Sk, block_k)

    # [B, H, S, D] layout so blocks are (seq, head_dim) tiles in VMEM
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, seq_k=Sk)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, g=groups: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, g=groups: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum l
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
