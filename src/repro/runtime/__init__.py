from .fault_tolerance import ElasticMesh, StragglerDetector, TrainSupervisor
__all__ = ["ElasticMesh", "StragglerDetector", "TrainSupervisor"]
