"""Fault tolerance + elasticity for 1000+-node operation (DESIGN.md §5).

Components:
  StragglerDetector — per-step EWMA of step time; flags hosts whose step
      latency exceeds mean + k·σ (at pod scale the right reaction is to
      drop the host from the next elastic re-mesh, not to block).
  ElasticMesh — recompute (pod, data, model) mesh shape when the healthy
      device count changes; model-parallel degree is pinned (weights are
      sharded over it), the data axes absorb the change, and global batch
      is re-divided — callers re-lower the step on the new mesh and
      restore from the latest checkpoint.
  TrainSupervisor — crash-isolation loop: run_step is retried through
      checkpoint restore on failure, with simulated-failure hooks for
      tests (this is the unit under test on CPU; on a real pod the same
      logic runs per-host around jax.distributed).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable

__all__ = ["StragglerDetector", "ElasticMesh", "TrainSupervisor"]


class StragglerDetector:
    """EWMA step-time tracker with z-score flagging."""

    def __init__(self, alpha: float = 0.1, threshold_sigma: float = 3.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.k = threshold_sigma
        self.warmup = warmup
        self.mean: float | None = None
        self.var = 0.0
        self.n = 0
        self.flagged: list[int] = []

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self.n += 1
        if self.mean is None:
            self.mean = dt
            return False
        is_straggler = False
        if self.n > self.warmup:
            sigma = math.sqrt(self.var) if self.var > 0 else self.mean * 0.1
            if dt > self.mean + self.k * sigma:
                is_straggler = True
                self.flagged.append(step)
        # EWMA update (straggler samples still update, damped)
        a = self.alpha * (0.25 if is_straggler else 1.0)
        delta = dt - self.mean
        self.mean += a * delta
        self.var = (1 - a) * (self.var + a * delta * delta)
        return is_straggler


@dataclasses.dataclass
class ElasticMesh:
    """Recompute mesh shape as devices come and go."""

    model_parallel: int = 16       # pinned: weights are sharded over it
    min_data: int = 1

    def plan(self, n_devices: int) -> dict:
        """Largest (pod, data, model) grid usable with n_devices."""
        if n_devices < self.model_parallel * self.min_data:
            raise RuntimeError(
                f"{n_devices} devices cannot host model_parallel="
                f"{self.model_parallel}")
        usable_rows = n_devices // self.model_parallel
        # prefer 2 pods when enough rows survive, else single pod
        if usable_rows >= 32:
            pods, data = 2, usable_rows // 2
        else:
            pods, data = 1, usable_rows
        used = pods * data * self.model_parallel
        return {"pod": pods, "data": data, "model": self.model_parallel,
                "devices_used": used, "devices_idle": n_devices - used}

    def rebatch(self, global_batch: int, old_data: int, new_data: int
                ) -> int:
        """Keep per-shard batch constant; global batch scales with the
        surviving data parallelism (elastic batch scaling)."""
        per_shard = max(1, global_batch // old_data)
        return per_shard * new_data


class TrainSupervisor:
    """Checkpoint/restart supervision around a step function."""

    def __init__(self, ckpt_manager, save_every: int = 50,
                 max_restarts: int = 10, save_blocking: bool = True):
        self.ckpt = ckpt_manager
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.save_blocking = save_blocking
        self.restarts = 0
        self.straggler = StragglerDetector()

    def run(self, state, run_step: Callable, n_steps: int,
            fail_hook: Callable | None = None,
            meta: dict | None = None):
        """Run n_steps with checkpoint/restart.  `run_step(state, step)
        -> state`.  `fail_hook(step)` may raise to simulate failures."""
        start = 0
        latest = self.ckpt.latest_step()
        if latest is not None:
            state, m = self.ckpt.restore(state)
            start = m["step"]
        step = start
        while step < n_steps:
            try:
                if fail_hook is not None:
                    fail_hook(step)
                t0 = time.time()
                state = run_step(state, step)
                self.straggler.observe(step, time.time() - t0)
                step += 1
                if step % self.save_every == 0 or step == n_steps:
                    # with save_blocking=False a failed async write
                    # surfaces at the NEXT save's wait() — still inside
                    # this try, so it takes the restart path below
                    self.ckpt.save(step, state, meta or {},
                                   blocking=self.save_blocking)
                    if step == n_steps:
                        self.ckpt.wait()
            except Exception:  # noqa: BLE001 — restart from checkpoint
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                latest = self.ckpt.latest_step()
                if latest is None:
                    step = 0
                    continue
                state, m = self.ckpt.restore(state)
                step = m["step"]
        return state, step
