"""repro.obs — structured telemetry (spans, counters, Perfetto export).

Quick start::

    from repro import obs

    with obs.scoped() as col:
        with obs.span("my.phase", lane="main", k=3):
            ...
    from repro.obs.export import write_profile
    write_profile("out.json", col)           # open in ui.perfetto.dev

Or set ``REPRO_PROFILE=out.json`` in the environment to profile a whole
process, then ``python -m repro.obs summarize out.json``.

See docs/observability.md for the full API and event taxonomy.
"""

from .core import (
    PROFILE_ENV,
    Collector,
    complete,
    counter,
    current,
    disable,
    enable,
    enabled,
    event,
    gauge,
    observe,
    profiled,
    scoped,
    span,
)
from .metrics import DEFAULT_BUCKETS_US, Histogram, MetricsRegistry

__all__ = [
    "Collector",
    "DEFAULT_BUCKETS_US",
    "Histogram",
    "MetricsRegistry",
    "PROFILE_ENV",
    "complete",
    "counter",
    "current",
    "disable",
    "enable",
    "enabled",
    "event",
    "gauge",
    "observe",
    "profiled",
    "scoped",
    "span",
]
