"""Profile exporters: Chrome trace-event / Perfetto JSON + flat summary.

The profile file is a standard Chrome trace-event object —
``{"traceEvents": [...], ...}`` — which https://ui.perfetto.dev and
``chrome://tracing`` open directly.  Extra top-level keys carry the
repro-specific scalars:

* ``repro.counters`` / ``repro.gauges`` — flat metrics summary.
* ``repro.phases`` — per-phase totals (also derivable from the events).
* ``repro.metrics`` — the collector's :class:`MetricsRegistry` snapshot
  (histograms with bucket arrays and p50/p99; see `repro.obs.metrics`).

Every span becomes a ``ph:"X"`` complete event.  Lanes map to ``tid``s
in order of first appearance, each named via a ``ph:"M"``
``thread_name`` metadata event, so Perfetto shows one labelled track
per worker.  Timestamps are rebased to the earliest event and events
are sorted by ``ts``, which makes per-lane timestamps monotone.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List

from .core import Collector

PID = 1

__all__ = ["chrome_trace", "events_from_chrome", "load_profile",
           "timeline_trace", "write_profile"]


def _phase_totals(events: List[Dict[str, Any]]) -> Dict[str, Dict[str, float]]:
    phases: Dict[str, Dict[str, float]] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        ph = phases.setdefault(ev["name"], {"count": 0, "total_us": 0.0})
        ph["count"] += 1
        ph["total_us"] += ev.get("dur", 0.0)
    return phases


def chrome_trace(col: Collector) -> Dict[str, Any]:
    """Render a collector as a Perfetto-loadable trace-event object."""
    events = sorted(col.events, key=lambda ev: ev["ts"])
    base = events[0]["ts"] if events else 0.0
    lanes: Dict[str, int] = {}
    out: List[Dict[str, Any]] = []
    for ev in events:
        lane = ev.get("lane", "main")
        tid = lanes.get(lane)
        if tid is None:
            tid = lanes[lane] = len(lanes)
        rec: Dict[str, Any] = {
            "name": ev["name"],
            "ph": ev["ph"],
            "pid": PID,
            "tid": tid,
            "ts": round(ev["ts"] - base, 3),
            "cat": ev.get("cat", "op"),
        }
        if ev["ph"] == "X":
            rec["dur"] = round(ev.get("dur", 0.0), 3)
        if ev["ph"] == "i":
            rec["s"] = "t"  # instant scope: thread
        if "args" in ev:
            rec["args"] = ev["args"]
        out.append(rec)
    meta = [
        {
            "name": "thread_name",
            "ph": "M",
            "pid": PID,
            "tid": tid,
            "args": {"name": lane},
        }
        for lane, tid in lanes.items()
    ]
    return {
        "traceEvents": meta + out,
        "displayTimeUnit": "ms",
        "repro": {
            "counters": dict(col.counters),
            "gauges": dict(col.gauges),
            "phases": _phase_totals(col.events),
            "metrics": col.metrics.snapshot(),
        },
    }


def events_from_chrome(doc: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Recover normalized events (name/ts/dur/lane/cat) from a profile
    file, resolving ``tid`` back to lane names via the metadata events."""
    raw = doc.get("traceEvents", [])
    names: Dict[Any, str] = {}
    for ev in raw:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            names[ev.get("tid")] = ev.get("args", {}).get("name", str(ev.get("tid")))
    out: List[Dict[str, Any]] = []
    for ev in raw:
        if ev.get("ph") not in ("X", "i"):
            continue
        out.append(
            {
                "name": ev.get("name", "?"),
                "ph": ev["ph"],
                "ts": float(ev.get("ts", 0.0)),
                "dur": float(ev.get("dur", 0.0)),
                "lane": names.get(ev.get("tid"), str(ev.get("tid"))),
                "cat": ev.get("cat", "op"),
                "args": ev.get("args", {}),
            }
        )
    return out


def timeline_trace(timeline: Dict[str, Any]) -> Dict[str, Any]:
    """Reconstruct a Perfetto-loadable trace from a dist *round
    timeline* (the ``timeline=`` dict `dist_vertex_cut` fills, also
    persisted in ``BENCH_dist_scaling.json`` meta).

    The timeline records durations, not wall-clock timestamps, so the
    tracks are synthetic: each round lays ``parse_wait`` then ``merge``
    on the ``coord`` lane and the per-worker ``cut`` spans in parallel
    on ``cut/wN`` lanes, advancing a cumulative clock by the round's
    critical path (parse_wait + max cut + merge) — the idealized
    dataflow the recorded durations imply.  A trailing ``finalize``
    span closes the coord lane when the timeline carries
    ``finalize_us``.
    """
    col = Collector()
    t = 0.0                                     # seconds, rebased at 0
    for rnd in timeline.get("rounds") or []:
        r = rnd.get("round", 0)
        pw = float(rnd.get("parse_wait_us", 0.0)) / 1e6
        if pw > 0:
            col.complete("dist.parse_wait", t, t + pw, lane="coord",
                         cat="wait", round=r)
        t += pw
        cuts = [float(u) / 1e6 for u in rnd.get("cut_us", [])]
        for w, cu in enumerate(cuts):
            col.complete("dist.cut", t, t + cu, lane=f"cut/w{w}",
                         cat="op", round=r,
                         edges=rnd.get("edges"))
        t += max(cuts, default=0.0)
        mu = float(rnd.get("merge_us", 0.0)) / 1e6
        if mu > 0:
            col.complete("dist.merge", t, t + mu, lane="coord", cat="op",
                         round=r, full=bool(rnd.get("full_merge")))
        t += mu
    fu = float(timeline.get("finalize_us") or 0.0) / 1e6
    if fu > 0:
        col.complete("dist.finalize", t, t + fu, lane="coord", cat="op")
    for key in ("workers", "merge_period", "full_merges", "round_merges"):
        if isinstance(timeline.get(key), (int, float)):
            col.set_gauge(f"timeline.{key}", timeline[key])
    return chrome_trace(col)


def write_profile(path: str, col: Collector) -> None:
    doc = chrome_trace(col)
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, separators=(",", ":"))
        fh.write("\n")


def load_profile(path: str) -> Dict[str, Any]:
    with open(path, "r", encoding="utf-8") as fh:
        return json.load(fh)
