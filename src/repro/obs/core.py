"""Structured telemetry: spans, counters, gauges.

Design contract (see docs/observability.md):

* **Zero-cost when disabled.**  ``span()`` / ``counter()`` / ``event()``
  check one module global and return immediately; the disabled ``span()``
  hands back a shared no-op context manager, so instrumented hot loops
  pay a dict lookup and nothing else.
* **Thread-safe.**  A :class:`Collector` guards its event list with a
  lock; spans measure time outside the lock and append once.
* **Process-safe by construction.**  Worker processes never talk to the
  coordinator's collector.  They time their own work with
  ``time.perf_counter()`` — CLOCK_MONOTONIC, system-wide on Linux, so
  timestamps from forked/spawned children are directly comparable — and
  ship ``(t0, t1)`` pairs home over the existing result channels; the
  coordinator records them with :func:`complete` at merge time.

Timestamps are absolute ``perf_counter()`` microseconds.  Exporters
rebase to the earliest event (``repro.obs.export``).

Event categories steer the summarizer's concurrency sweep
(``repro.obs.summarize``):

* ``"op"`` (default) — real work attributed to a lane.
* ``"wait"`` — a lane blocking on someone else (e.g. the dist
  coordinator waiting for the parse pool); excluded from busy time.
* ``"section"`` — an orchestration envelope around finer-grained ops
  (e.g. ``pipeline.partition`` around the dist engine's rounds);
  excluded from busy time so nesting never fakes parallelism.
"""

from __future__ import annotations

import atexit
import os
import sys
import threading
from contextlib import contextmanager
from time import perf_counter
from typing import Any, Dict, Iterator, List, Optional

from .metrics import MetricsRegistry

PROFILE_ENV = "REPRO_PROFILE"

__all__ = [
    "Collector",
    "PROFILE_ENV",
    "complete",
    "counter",
    "current",
    "disable",
    "enable",
    "enabled",
    "event",
    "gauge",
    "observe",
    "profiled",
    "scoped",
    "span",
]


class Collector:
    """Thread-safe sink for spans, instants, counters, gauges and the
    metrics registry (histograms — see `repro.obs.metrics`)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.events: List[Dict[str, Any]] = []
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.metrics = MetricsRegistry()

    # -- events ---------------------------------------------------------
    def complete(
        self,
        name: str,
        t0: float,
        t1: float,
        lane: str = "main",
        cat: str = "op",
        **args: Any,
    ) -> None:
        """Record a finished span from absolute perf_counter seconds."""
        ev: Dict[str, Any] = {
            "name": name,
            "ph": "X",
            "ts": t0 * 1e6,
            "dur": max(t1 - t0, 0.0) * 1e6,
            "lane": lane,
            "cat": cat,
        }
        if args:
            ev["args"] = args
        with self._lock:
            self.events.append(ev)

    def instant(self, name: str, lane: str = "main", **args: Any) -> None:
        ev: Dict[str, Any] = {
            "name": name,
            "ph": "i",
            "ts": perf_counter() * 1e6,
            "lane": lane,
            "cat": "instant",
        }
        if args:
            ev["args"] = args
        with self._lock:
            self.events.append(ev)

    # -- scalars --------------------------------------------------------
    def add(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0.0) + value

    def set_gauge(self, name: str, value: float) -> None:
        with self._lock:
            self.gauges[name] = value

    # -- merging --------------------------------------------------------
    def absorb_events(self, events: List[Dict[str, Any]]) -> None:
        with self._lock:
            self.events.extend(events)

    def absorb(self, other: "Collector") -> None:
        """Merge another collector (a scoped child) into this one."""
        with self._lock:
            self.events.extend(other.events)
            for k, v in other.counters.items():
                self.counters[k] = self.counters.get(k, 0.0) + v
            self.gauges.update(other.gauges)
        self.metrics.merge(other.metrics)  # registry has its own lock


class _Span:
    """Context manager recording one complete event on exit."""

    __slots__ = ("_col", "_name", "_lane", "_cat", "_args", "_t0")

    def __init__(self, col: Collector, name: str, lane: str, cat: str, args: dict):
        self._col = col
        self._name = name
        self._lane = lane
        self._cat = cat
        self._args = args

    def set(self, **kw: Any) -> None:
        """Attach args discovered mid-span (e.g. ``sp.set(full=True)``)."""
        self._args.update(kw)

    def __enter__(self) -> "_Span":
        self._t0 = perf_counter()
        return self

    def __exit__(self, *exc: Any) -> bool:
        self._col.complete(
            self._name, self._t0, perf_counter(), self._lane, self._cat, **self._args
        )
        return False


class _NoopSpan:
    __slots__ = ()

    def set(self, **kw: Any) -> None:
        pass

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False


_NOOP = _NoopSpan()

_active: Optional[Collector] = None


def current() -> Optional[Collector]:
    """The active collector, or None when telemetry is disabled."""
    return _active


def enabled() -> bool:
    return _active is not None


def enable(collector: Optional[Collector] = None) -> Collector:
    """Install ``collector`` (or a fresh one) as the active sink."""
    global _active
    _active = collector if collector is not None else Collector()
    return _active


def disable() -> Optional[Collector]:
    """Deactivate telemetry; returns the collector that was active."""
    global _active
    col, _active = _active, None
    return col


def span(name: str, lane: str = "main", cat: str = "op", **args: Any):
    """``with obs.span("dist.round", lane="cut/w0", round=3): ...``

    Returns a shared no-op when telemetry is disabled.
    """
    col = _active
    if col is None:
        return _NOOP
    return _Span(col, name, lane, cat, args)


def complete(
    name: str, t0: float, t1: float, lane: str = "main", cat: str = "op", **args: Any
) -> None:
    """Record an externally-timed span (absolute perf_counter seconds)."""
    col = _active
    if col is not None:
        col.complete(name, t0, t1, lane, cat, **args)


def event(name: str, lane: str = "main", **args: Any) -> None:
    """Record an instant event (e.g. a fallback reason)."""
    col = _active
    if col is not None:
        col.instant(name, lane, **args)


def counter(name: str, value: float = 1.0) -> None:
    col = _active
    if col is not None:
        col.add(name, value)


def gauge(name: str, value: float) -> None:
    col = _active
    if col is not None:
        col.set_gauge(name, value)


def observe(name: str, value: float) -> None:
    """Record one sample into the active collector's named histogram
    (microseconds by repo convention).  No-op when telemetry is off —
    same zero-cost contract as `span()`."""
    col = _active
    if col is not None:
        col.metrics.observe(name, value)


@contextmanager
def scoped(merge: bool = True) -> Iterator[Collector]:
    """Activate a fresh collector for the block; restore the outer one.

    With ``merge=True`` (default) the outer collector, if any, absorbs
    the child's events and counters on exit, so a scoped measurement
    still contributes to a surrounding ``REPRO_PROFILE`` dump.
    """
    global _active
    outer = _active
    col = Collector()
    _active = col
    try:
        yield col
    finally:
        _active = outer
        if merge and outer is not None:
            outer.absorb(col)


@contextmanager
def profiled(path: str) -> Iterator[Collector]:
    """Scoped collection that writes a profile JSON to ``path`` on exit."""
    from .export import write_profile

    with scoped() as col:
        try:
            yield col
        finally:
            write_profile(path, col)


def _install_env_profile() -> None:
    """``REPRO_PROFILE=out.json`` enables collection for the whole
    process and dumps the profile at interpreter exit."""
    path = os.environ.get(PROFILE_ENV)
    if not path:
        return
    col = enable()
    pid = os.getpid()

    def _dump() -> None:
        if os.getpid() != pid:  # forked child: not our profile
            return
        try:
            from .export import write_profile

            write_profile(path, col)
        except OSError as e:  # pragma: no cover - disk-full etc.
            print(f"repro.obs: could not write {path}: {e}", file=sys.stderr)

    atexit.register(_dump)


_install_env_profile()
