"""``python -m repro.obs summarize out.json`` — render a profile.

Prints the phase table (count, total, mean, % wall, critical-path
contribution), the wall-time decomposition into parallel / serial /
idle, per-lane utilization, and the measured serial fraction with its
Amdahl speedup bound.
"""

from __future__ import annotations

import argparse
import sys

from .export import events_from_chrome, load_profile
from .summarize import render_summary, summarize_events


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = ap.add_subparsers(dest="cmd", required=True)
    s = sub.add_parser("summarize", help="render a profile JSON as a phase table")
    s.add_argument("profile", help="path written by REPRO_PROFILE / profile=")
    args = ap.parse_args(argv)

    doc = load_profile(args.profile)
    events = events_from_chrome(doc)
    if not events:
        print(f"{args.profile}: no events", file=sys.stderr)
        return 1
    counters = doc.get("repro", {}).get("counters", {})
    print(f"profile: {args.profile}  ({len(events)} events)")
    print(render_summary(summarize_events(events), counters))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
