"""``python -m repro.obs summarize out.json`` — render a profile.

Prints the phase table (count, total, mean, % wall, critical-path
contribution), the wall-time decomposition into parallel / serial /
idle, per-lane utilization, and the measured serial fraction with its
Amdahl speedup bound.

``python -m repro.obs timeline BENCH_dist_scaling.json -o tl.json``
reconstructs a Perfetto-loadable trace from the dist round timeline
persisted in a bench JSON's meta (``meta.timeline_w4`` by default) —
one labelled track per cut worker plus the coordinator lane.
"""

from __future__ import annotations

import argparse
import json
import sys

from .export import events_from_chrome, load_profile, timeline_trace
from .summarize import render_summary, summarize_events


def _timeline(args) -> int:
    with open(args.source, "r", encoding="utf-8") as fh:
        doc = json.load(fh)
    tl = doc
    if "rounds" not in tl:                  # a bench JSON, not a raw dict
        tl = doc.get("meta", {}).get(args.key)
    if not tl or not tl.get("rounds"):
        print(f"{args.source}: no round timeline under meta.{args.key}",
              file=sys.stderr)
        return 1
    trace = timeline_trace(tl)
    with open(args.out, "w", encoding="utf-8") as fh:
        json.dump(trace, fh, separators=(",", ":"))
        fh.write("\n")
    n = sum(1 for ev in trace["traceEvents"] if ev.get("ph") == "X")
    print(f"{args.out}: {n} spans over {len(tl['rounds'])} rounds "
          f"(open in ui.perfetto.dev)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = ap.add_subparsers(dest="cmd", required=True)
    s = sub.add_parser("summarize", help="render a profile JSON as a phase table")
    s.add_argument("profile", help="path written by REPRO_PROFILE / profile=")
    t = sub.add_parser(
        "timeline", help="dist round timeline -> Perfetto trace JSON")
    t.add_argument("source", help="BENCH_dist_scaling.json or a raw "
                                  "timeline dict")
    t.add_argument("-o", "--out", default="timeline_trace.json")
    t.add_argument("--key", default="timeline_w4",
                   help="meta key holding the timeline (default "
                        "timeline_w4)")
    args = ap.parse_args(argv)

    if args.cmd == "timeline":
        return _timeline(args)

    doc = load_profile(args.profile)
    events = events_from_chrome(doc)
    if not events:
        print(f"{args.profile}: no events", file=sys.stderr)
        return 1
    counters = doc.get("repro", {}).get("counters", {})
    print(f"profile: {args.profile}  ({len(events)} events)")
    print(render_summary(summarize_events(events), counters))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
