"""Process-wide metrics registry: counters, gauges, latency histograms.

This is the scalar half of `repro.obs` (spans/events are the temporal
half — see `core`).  A :class:`MetricsRegistry` holds *named
instruments*:

* **counters** — monotone sums (`cache_hit`, `evictions`);
* **gauges** — last-write-wins levels (`hot_entries`);
* **histograms** — fixed-bucket latency distributions with
  `percentile()` estimation, built for merging: two histograms over the
  same bucket bounds combine by adding bucket counts, so per-worker
  recordings fold into one distribution without keeping raw samples.

Design contract (mirrors the span layer, docs/observability.md):

* **Zero-cost when disabled.**  The module-level helpers in
  `repro.obs.core` (`obs.observe(...)`) check the active-collector
  global and return immediately; a disabled process pays one attribute
  load per call site.  A registry owned directly (e.g. by
  `PlanService.metrics`) is always on — live serving metrics must not
  depend on profiling being enabled.
* **Lock-guarded.**  One registry lock covers every instrument; the
  critical sections are a few float ops, so contention is bounded by
  the caller's own throughput.
* **Process-safe by construction.**  Worker processes never touch the
  coordinator's registry.  They ship durations home over the existing
  dist result channels (the same `(t0, us)` pairs the span layer
  records) and the coordinator observes them at merge time — so the
  "merged" histogram is recorded in one process and needs no shared
  memory.  `merge()` exists for the scoped-collector path
  (`obs.scoped()` absorbing a child registry) and for folding snapshot
  dicts that did cross a process boundary.

Histogram buckets are upper bounds in the observed unit (the repo
convention is **microseconds**); the default covers 1 µs .. 100 s on a
1-2.5-5 grid, with an implicit +inf overflow bucket.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, Iterable, List, Optional

__all__ = ["DEFAULT_BUCKETS_US", "Histogram", "MetricsRegistry"]

# 1-2.5-5 per decade, 1 µs .. 100 s; +inf overflow is implicit
DEFAULT_BUCKETS_US = tuple(
    base * scale
    for scale in (1.0, 1e1, 1e2, 1e3, 1e4, 1e5, 1e6, 1e7)
    for base in (1.0, 2.5, 5.0)
)


class Histogram:
    """Fixed-bucket histogram with exact count/sum/min/max sidecars.

    Not thread-safe on its own — the owning :class:`MetricsRegistry`
    serialises access under its lock.
    """

    __slots__ = ("bounds", "counts", "count", "sum", "min", "max")

    def __init__(self, bounds: Iterable[float] = DEFAULT_BUCKETS_US):
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(self.bounds) or not self.bounds:
            raise ValueError("histogram bounds must be sorted and non-empty")
        self.counts = [0] * (len(self.bounds) + 1)  # +1: overflow
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, value: float) -> None:
        # linear scan beats bisect at these bucket counts for typical
        # (small) latencies, and keeps this file dependency-free
        i = 0
        bounds = self.bounds
        n = len(bounds)
        while i < n and value > bounds[i]:
            i += 1
        self.counts[i] += 1
        self.count += 1
        self.sum += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value

    def percentile(self, q: float) -> float:
        """Estimate the q-th percentile (q in [0, 100]) by linear
        interpolation inside the covering bucket, clamped to the exact
        observed min/max so single-sample histograms report the sample."""
        if self.count == 0:
            return 0.0
        rank = q / 100.0 * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= rank:
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                frac = (rank - seen) / c
                est = lo + (hi - lo) * max(0.0, min(1.0, frac))
                return min(max(est, self.min), self.max)
            seen += c
        return self.max

    def merge(self, other: "Histogram") -> None:
        if other.bounds != self.bounds:
            raise ValueError("cannot merge histograms with different buckets")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    def snapshot(self) -> Dict[str, Any]:
        return {
            "bounds": list(self.bounds),
            "counts": list(self.counts),
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "p50": self.percentile(50),
            "p99": self.percentile(99),
        }

    @classmethod
    def from_snapshot(cls, snap: Dict[str, Any]) -> "Histogram":
        h = cls(snap["bounds"])
        h.counts = [int(c) for c in snap["counts"]]
        h.count = int(snap["count"])
        h.sum = float(snap["sum"])
        if h.count:
            h.min = float(snap["min"])
            h.max = float(snap["max"])
        return h


class MetricsRegistry:
    """Named counters, gauges and histograms behind one lock."""

    def __init__(self, buckets: Iterable[float] = DEFAULT_BUCKETS_US):
        self._lock = threading.Lock()
        self._buckets = tuple(float(b) for b in buckets)
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, Histogram] = {}

    # -- recording ------------------------------------------------------
    def counter(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0.0) + value

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self.gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        """Record one sample into the named histogram (created with the
        registry's default buckets on first use)."""
        with self._lock:
            h = self.histograms.get(name)
            if h is None:
                h = self.histograms[name] = Histogram(self._buckets)
            h.observe(value)

    def histogram(self, name: str,
                  buckets: Optional[Iterable[float]] = None) -> Histogram:
        """Get-or-create the named histogram (optionally with explicit
        bucket bounds — only honoured at creation)."""
        with self._lock:
            h = self.histograms.get(name)
            if h is None:
                h = self.histograms[name] = Histogram(
                    buckets if buckets is not None else self._buckets)
            return h

    # -- summarising ----------------------------------------------------
    def percentile(self, name: str, q: float) -> float:
        with self._lock:
            h = self.histograms.get(name)
            return h.percentile(q) if h is not None else 0.0

    def snapshot(self) -> Dict[str, Any]:
        """A JSON-able view: counters/gauges flat, histograms with
        bucket arrays and p50/p99 summaries."""
        with self._lock:
            return {
                "counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "histograms": {k: h.snapshot()
                               for k, h in self.histograms.items()},
            }

    def reset(self) -> None:
        with self._lock:
            self.counters.clear()
            self.gauges.clear()
            self.histograms.clear()

    # -- merging --------------------------------------------------------
    def merge(self, other: "MetricsRegistry | Dict[str, Any]") -> None:
        """Fold another registry (or a `snapshot()` dict that crossed a
        process boundary) into this one."""
        if isinstance(other, MetricsRegistry):
            other = other.snapshot()
        with self._lock:
            for k, v in other.get("counters", {}).items():
                self.counters[k] = self.counters.get(k, 0.0) + v
            self.gauges.update(other.get("gauges", {}))
            for k, snap in other.get("histograms", {}).items():
                h = self.histograms.get(k)
                if h is None:
                    self.histograms[k] = Histogram.from_snapshot(snap)
                else:
                    h.merge(Histogram.from_snapshot(snap))

    def __len__(self) -> int:
        with self._lock:
            return (len(self.counters) + len(self.gauges)
                    + len(self.histograms))
