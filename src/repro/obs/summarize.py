"""Profile analysis: phase table, lane utilization, serial fraction.

Works on normalized events (``name``/``ts``/``dur``/``lane``/``cat``),
either straight from a :class:`~repro.obs.core.Collector` or recovered
from a profile file via :func:`repro.obs.export.events_from_chrome`.

The concurrency sweep considers only **leaf** spans (``cat == "op"``) —
``wait`` and ``section`` envelopes never count as busy time, so nested
orchestration spans cannot fake parallelism.  It decomposes wall time
exactly into:

* ``parallel_us`` — at least two lanes doing real work at once,
* ``serial_us``  — exactly one lane busy (this time is on the critical
  path by definition; the phase table attributes it to the innermost
  span that owns it),
* ``idle_us``    — no lane busy (scheduling gaps, uninstrumented code).

``serial_fraction = 1 - parallel_us / wall_us`` is the measured
non-parallel share, i.e. the *s* in Amdahl's bound ``1/(s + (1-s)/W)``.
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

__all__ = ["render_summary", "summarize_events"]


def _leaf_spans(events: List[Dict[str, Any]]) -> List[Tuple[float, float, str, str]]:
    out = []
    for ev in events:
        if ev.get("ph", "X") != "X" or ev.get("cat", "op") != "op":
            continue
        t0 = float(ev["ts"])
        out.append((t0, t0 + float(ev.get("dur", 0.0)), ev.get("lane", "main"), ev["name"]))
    return out


def _sweep(spans: List[Tuple[float, float, str, str]]) -> Dict[str, Any]:
    """Single pass over span endpoints; O(S log S)."""
    points: List[Tuple[float, int, int]] = []  # (time, +1/-1, span index)
    for i, (t0, t1, _lane, _name) in enumerate(spans):
        if t1 > t0:
            points.append((t0, 1, i))
            points.append((t1, -1, i))
    points.sort(key=lambda p: (p[0], -p[1]))

    active_by_lane: Dict[str, Dict[int, Tuple[float, str]]] = {}
    busy_lanes = 0
    serial = parallel = 0.0
    lane_busy: Dict[str, float] = {}
    phase_serial: Dict[str, float] = {}

    prev_t = points[0][0] if points else 0.0
    for t, kind, i in points:
        dt = t - prev_t
        if dt > 0:
            if busy_lanes == 1:
                serial += dt
                # attribute to the innermost active span on the busy lane
                for lane, active in active_by_lane.items():
                    if active:
                        _t0, name = max(active.values(), key=lambda v: v[0])
                        phase_serial[name] = phase_serial.get(name, 0.0) + dt
                        lane_busy[lane] = lane_busy.get(lane, 0.0) + dt
                        break
            elif busy_lanes >= 2:
                parallel += dt
                for lane, active in active_by_lane.items():
                    if active:
                        lane_busy[lane] = lane_busy.get(lane, 0.0) + dt
        prev_t = t
        t0, t1, lane, name = spans[i]
        active = active_by_lane.setdefault(lane, {})
        if kind == 1:
            if not active:
                busy_lanes += 1
            active[i] = (t0, name)
        else:
            active.pop(i, None)
            if not active:
                busy_lanes -= 1
    return {
        "serial_us": serial,
        "parallel_us": parallel,
        "lane_busy": lane_busy,
        "phase_serial": phase_serial,
    }


def summarize_events(events: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Compute the summary dict the CLI renders (see module docstring)."""
    xs = [ev for ev in events if ev.get("ph", "X") == "X"]
    if not xs:
        return {
            "wall_us": 0.0,
            "serial_us": 0.0,
            "parallel_us": 0.0,
            "idle_us": 0.0,
            "serial_fraction": 1.0,
            "amdahl_bound": 1.0,
            "phases": {},
            "lanes": {},
            "instants": {},
        }
    start = min(float(ev["ts"]) for ev in xs)
    end = max(float(ev["ts"]) + float(ev.get("dur", 0.0)) for ev in xs)
    wall = max(end - start, 1e-9)

    spans = _leaf_spans(events)
    sw = _sweep(spans)
    serial, parallel = sw["serial_us"], sw["parallel_us"]
    idle = max(wall - serial - parallel, 0.0)
    s = max(min(1.0 - parallel / wall, 1.0), 0.0)

    phases: Dict[str, Dict[str, float]] = {}
    for ev in xs:
        ph = phases.setdefault(
            ev["name"], {"count": 0, "total_us": 0.0, "serial_us": 0.0}
        )
        ph["count"] += 1
        ph["total_us"] += float(ev.get("dur", 0.0))
    for name, us in sw["phase_serial"].items():
        if name in phases:
            phases[name]["serial_us"] = us

    lanes = {
        lane: {"busy_us": busy, "utilization": busy / wall}
        for lane, busy in sorted(sw["lane_busy"].items())
    }
    nlanes = max(len(lanes), 1)
    amdahl = 1.0 / (s + (1.0 - s) / nlanes) if nlanes > 1 else 1.0

    instants: Dict[str, int] = {}
    for ev in events:
        if ev.get("ph") == "i":
            key = ev["name"]
            reason = (ev.get("args") or {}).get("reason")
            if reason:
                key = f"{key}[{reason}]"
            instants[key] = instants.get(key, 0) + 1

    return {
        "wall_us": wall,
        "serial_us": serial,
        "parallel_us": parallel,
        "idle_us": idle,
        "serial_fraction": s,
        "amdahl_bound": amdahl,
        "phases": phases,
        "lanes": lanes,
        "instants": instants,
    }


def _ms(us: float) -> str:
    return f"{us / 1e3:10.2f}"


def render_summary(summary: Dict[str, Any], counters: Dict[str, float] | None = None) -> str:
    """Human-readable phase table + concurrency decomposition."""
    wall = summary["wall_us"]
    lines = [
        f"wall {wall / 1e3:.2f} ms   "
        f"parallel {_pct(summary['parallel_us'], wall)}   "
        f"serial {_pct(summary['serial_us'], wall)}   "
        f"idle {_pct(summary['idle_us'], wall)}",
        f"serial fraction s = {summary['serial_fraction']:.3f}   "
        f"Amdahl speedup bound @ {len(summary['lanes'])} lanes: "
        f"{summary['amdahl_bound']:.2f}x",
        "",
        f"{'phase':<24} {'count':>6} {'total ms':>10} {'mean ms':>9} "
        f"{'% wall':>7} {'critical ms':>12}",
    ]
    for name, ph in sorted(
        summary["phases"].items(), key=lambda kv: -kv[1]["total_us"]
    ):
        mean = ph["total_us"] / max(ph["count"], 1)
        lines.append(
            f"{name:<24} {ph['count']:>6} {_ms(ph['total_us'])} "
            f"{mean / 1e3:>9.2f} {100 * ph['total_us'] / wall:>6.1f}% "
            f"{ph['serial_us'] / 1e3:>12.2f}"
        )
    if summary["lanes"]:
        lines += ["", f"{'lane':<24} {'busy ms':>10} {'util':>7}"]
        for lane, st in summary["lanes"].items():
            lines.append(
                f"{lane:<24} {_ms(st['busy_us'])} {100 * st['utilization']:>6.1f}%"
            )
    if summary["instants"]:
        lines += ["", "instant events:"]
        for key, n in sorted(summary["instants"].items()):
            lines.append(f"  {key:<38} x{n}")
    if counters:
        lines += ["", "counters:"]
        for key, v in sorted(counters.items()):
            lines.append(f"  {key:<38} {v:g}")
    return "\n".join(lines)


def _pct(us: float, wall: float) -> str:
    return f"{us / 1e3:.2f} ms ({100 * us / max(wall, 1e-9):.1f}%)"
