"""recurrentgemma-9b — hybrid: RG-LRU recurrent blocks + local attention
in a 2:1 pattern (two recurrent blocks per local-attention block).
[arXiv:2402.19427; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab_size=256_000, head_dim=256,
    layer_pattern=("rec", "rec", "attn"), local_window=2048,
    hidden_act="gelu", embed_scale=True,
    rglru_width=4096, conv1d_width=4,
)
