"""smollm-360m — llama-architecture small dense GQA model.
[hf:HuggingFaceTB/SmolLM-360M; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
    d_ff=2560, vocab_size=49152,
    hidden_act="silu", rope_theta=10_000.0,
)
