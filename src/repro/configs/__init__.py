"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from .base import SHAPES, ModelConfig, ParallelConfig, ShapeConfig
from .granite_3_2b import CONFIG as granite_3_2b
from .gemma2_27b import CONFIG as gemma2_27b
from .gemma_2b import CONFIG as gemma_2b
from .smollm_360m import CONFIG as smollm_360m
from .qwen2_vl_2b import CONFIG as qwen2_vl_2b
from .recurrentgemma_9b import CONFIG as recurrentgemma_9b
from .seamless_m4t_large_v2 import CONFIG as seamless_m4t_large_v2
from .deepseek_v3_671b import CONFIG as deepseek_v3_671b
from .dbrx_132b import CONFIG as dbrx_132b
from .rwkv6_7b import CONFIG as rwkv6_7b

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in (
        granite_3_2b, gemma2_27b, gemma_2b, smollm_360m, qwen2_vl_2b,
        recurrentgemma_9b, seamless_m4t_large_v2, deepseek_v3_671b,
        dbrx_132b, rwkv6_7b,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Small same-family config for CPU smoke tests (assignment: reduced
    layers/width/experts/vocab, same structure)."""
    import dataclasses
    pattern = list(cfg.layer_pattern)
    small = dict(
        n_layers=max(len(pattern) * 2, 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
    )
    if cfg.is_moe:
        small.update(n_experts=4, experts_per_token=2,
                     moe_d_ff=64,
                     n_shared_experts=min(cfg.n_shared_experts, 1))
    if cfg.use_mla:
        small.update(q_lora_rank=32 if cfg.q_lora_rank else 0,
                     kv_lora_rank=32, qk_rope_head_dim=8,
                     qk_nope_head_dim=16, v_head_dim=16, head_dim=16)
    if cfg.rglru_width:
        small.update(rglru_width=64)
    if cfg.n_encoder_layers:
        small.update(n_encoder_layers=2)
    if cfg.local_window:
        small.update(local_window=32)
    if cfg.mrope_sections:
        # sections must sum to head_dim // 2
        hd = small.get("head_dim", 16)
        small.update(mrope_sections=(hd // 2 - 2 * (hd // 8),
                                     hd // 8, hd // 8))
    if cfg.mtp_depth:
        small.update(mtp_depth=1)
    small.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-reduced", **small)


__all__ = ["ARCHS", "get_config", "reduced_config", "ModelConfig",
           "ParallelConfig", "ShapeConfig", "SHAPES"]
