"""seamless-m4t-large-v2 — encoder-decoder multimodal (speech/text)
backbone.  The audio frontend is a STUB: `input_specs()` supplies
precomputed frame embeddings (assignment note).  [arXiv:2308.11596; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, n_encoder_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256_206,
    hidden_act="gelu", frontend="audio", tie_embeddings=False,
)
