"""deepseek-v3-671b — MoE with Multi-head Latent Attention (MLA),
1 shared + 256 routed experts (top-8), multi-token prediction.
All 61 layers are MoE here (the real model\'s first 3 layers are dense
d_ff=18432 — recorded as a simplification in DESIGN.md).
[arXiv:2412.19437; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432, vocab_size=129_280,
    n_experts=256, n_shared_experts=1, experts_per_token=8,
    moe_d_ff=2048,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_rope_head_dim=64, qk_nope_head_dim=128, v_head_dim=128,
    mtp_depth=1, hidden_act="silu", tie_embeddings=False,
)
