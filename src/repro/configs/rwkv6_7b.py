"""rwkv6-7b (Finch) — attention-free RNN with data-dependent decay
(time-mix WKV6 + channel-mix).  [arXiv:2404.05892; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,
    d_ff=14336, vocab_size=65_536, head_dim=64,
    layer_pattern=("rwkv",), hidden_act="relu",
    tie_embeddings=False,
)
