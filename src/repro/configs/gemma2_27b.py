"""gemma2-27b — dense GQA, local+global alternating attention with logit
softcapping and GeGLU.  [arXiv:2408.00118; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16,
    d_ff=36864, vocab_size=256_000, head_dim=128,
    layer_pattern=("local", "global"), local_window=4096,
    attn_softcap=50.0, final_softcap=30.0,
    hidden_act="gelu", embed_scale=True, rope_theta=10_000.0,
)
