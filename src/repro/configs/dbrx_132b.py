"""dbrx-132b — fine-grained MoE, 16 experts top-4, GQA.
[hf:databricks/dbrx-base; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab_size=100_352,
    n_experts=16, n_shared_experts=0, experts_per_token=4,
    moe_d_ff=10752, hidden_act="silu", tie_embeddings=False,
)
