"""qwen2-vl-2b — VLM backbone with M-RoPE (temporal/height/width rotary
sections).  The vision frontend is a STUB: `input_specs()` supplies
precomputed patch embeddings (assignment note).  [arXiv:2409.12191; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab_size=151_936,
    hidden_act="silu", rope_theta=1_000_000.0,
    frontend="vision", mrope_sections=(16, 24, 24),
)
