"""Model/run configuration schema for all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Sequence

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "ParallelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture.  Field values come verbatim from the assignment
    table (public configs); family selects the block structure."""

    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None      # default d_model // n_heads

    # attention features
    rope_theta: float = 10_000.0
    attn_softcap: float | None = None     # gemma2 logit softcapping
    final_softcap: float | None = None
    local_window: int | None = None       # sliding-window size (local attn)
    layer_pattern: Sequence[str] = ("attn",)   # repeating block pattern
    hidden_act: str = "silu"              # silu | gelu (geglu == gated gelu)
    embed_scale: bool = False             # gemma: scale embeddings by sqrt(d)

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int | None = None
    capacity_factor: float = 1.25

    # MLA (DeepSeek-V3)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 512
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    # multi-token prediction (DeepSeek-V3)
    mtp_depth: int = 0

    # recurrent (RG-LRU) / ssm (RWKV6)
    rglru_width: int | None = None        # recurrence width (d_model default)
    conv1d_width: int = 4

    # encoder-decoder
    n_encoder_layers: int = 0

    # modality frontend stub: None | "vision" | "audio"
    frontend: str | None = None
    mrope_sections: Sequence[int] | None = None   # qwen2-vl M-RoPE

    tie_embeddings: bool = True
    norm_eps: float = 1e-6

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.n_heads)

    # -- derived sizes -------------------------------------------------- #
    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode at 500k context? (SSM state or bounded
        local window — no full-context attention anywhere.)"""
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            return all(b != "attn" or self.local_window for b in
                       self.layer_pattern) or "global" not in \
                self.layer_pattern
        return False

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), used for
        MODEL_FLOPS = 6·N·D in the roofline analysis."""
        d, hd = self.d_model, self.head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = {}

        if self.use_mla:
            q = (d * self.q_lora_rank + self.q_lora_rank * n_q *
                 (self.qk_nope_head_dim + self.qk_rope_head_dim)) \
                if self.q_lora_rank else \
                d * n_q * (self.qk_nope_head_dim + self.qk_rope_head_dim)
            kv = (d * (self.kv_lora_rank + self.qk_rope_head_dim)
                  + self.kv_lora_rank * n_q *
                  (self.qk_nope_head_dim + self.v_head_dim))
            o = n_q * self.v_head_dim * d
            per_layer["attn"] = q + kv + o
        else:
            per_layer["attn"] = d * hd * (n_q + 2 * n_kv) + n_q * hd * d

        gate_mult = 3  # gated MLP: in, gate, out
        per_layer["mlp"] = gate_mult * d * self.d_ff
        if self.is_moe:
            eff = self.moe_d_ff or self.d_ff
            per_layer["moe"] = (self.n_experts + self.n_shared_experts) \
                * gate_mult * d * eff + d * self.n_experts  # + router
        rw = self.rglru_width or d
        per_layer["rec"] = (2 * d * rw            # in/gate projections
                           + self.conv1d_width * rw + 3 * rw  # conv + lru
                           + rw * d)              # out projection
        per_layer["rwkv"] = 6 * d * d + 2 * d * (int(3.5 * d))
        # encoder/decoder cross attention
        per_layer["xattn"] = d * hd * (n_q + 2 * n_kv) + n_q * hd * d

        total = emb
        pattern = list(self.layer_pattern)
        for i in range(self.n_layers):
            block = pattern[i % len(pattern)]
            if block in ("attn", "local", "global"):
                total += per_layer["attn"] + per_layer[
                    "moe" if self.is_moe else "mlp"]
            elif block == "rec":
                total += per_layer["rec"] + per_layer["mlp"]
            elif block == "rwkv":
                total += per_layer["rwkv"]
        for _ in range(self.n_encoder_layers):
            total += per_layer["attn"] + per_layer["mlp"]
        if self.n_encoder_layers:  # decoder cross-attn
            total += self.n_layers * per_layer["xattn"]
        total += self.mtp_depth * (per_layer["attn"] + per_layer[
            "moe" if self.is_moe else "mlp"])
        return int(total)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k + shared only)."""
        if not self.is_moe:
            return self.param_count()
        eff = self.moe_d_ff or self.d_ff
        inactive = (self.n_experts - self.experts_per_token) \
            * 3 * self.d_model * eff * self.n_layers
        return int(self.param_count() - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How a (model × mesh) cell is sharded — DESIGN.md §5."""

    fsdp: bool = True          # shard params/opt-state over 'data'
    tp: bool = True            # tensor parallel over 'model'
    ep: bool = False           # experts over 'model' instead of TP inside
    sp: bool = False           # shard sequence over 'model' (long context)
    pod_dp: bool = True        # 'pod' axis is pure data parallel
    # expert-weight layout: "2d" = [E/model, d/data, ff] (ZeRO-3 style,
    # re-gathered per use) | "ep_pod" = [E/(pod*model)] fully resident
    # (multi-pod only; weights never gathered, MoE a2a crosses DCN)
    expert_layout: str = "2d"
    remat: str = "none"        # none | block | full
    microbatches: int = 1      # gradient accumulation steps
    expert_placement: str = "contiguous"  # contiguous | vertex_cut
