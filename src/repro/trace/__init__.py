"""Dynamic-trace ingestion front end (NDJSON traces -> streaming IRGraphs).

The paper's pipeline starts from instrumented dynamic LLVM traces (§3:
basic-block execution order + per-memory-op timing).  This package is
that front end for the reproduction: it adopts the ct-publicness NDJSON
TRACE/CFG schemas (v0) as the interchange format, streams million-line
traces into `IRGraph`s with constant per-chunk memory (`ingest.py`),
replays static listings along CFG paths (`replay_trace`), derives edge
weights through pluggable models (`weights.py`), and writes the same
schema back out from jaxpr traces (`record.py`) — giving a round-trip
oracle against `core.jaxpr_graph.jaxpr_to_graph`.

Two fast paths sit in front of the sequential interpreter (see
docs/trace-format.md for the formats and guarantees):

  * `scan.py` — a vectorized structural-index NDJSON scanner that
    parses compact machine-written traces with numpy byte passes and
    falls back to the sequential path on anything outside its subset,
    or past the size budget where its batch passes stop winning
    (``REPRO_TRACE_SCAN_MAX_MB``, default 24; ``REPRO_TRACE_SCANNER=0``
    disables it, ``=1`` forces it at any size);
  * `binfmt.py` — the `.rtb` binary columnar trace container v1 written
    by ``python -m repro.trace convert``; `.rtb` paths are accepted
    everywhere NDJSON paths are and load at memory speed.

CLI: ``python -m repro.trace {inspect,convert,partition,record,synth}``.
"""
from .schema import SCHEMA_VERSION, TraceFormatError, type_bytes
from .weights import (WEIGHT_MODELS, register_weight_model,
                      resolve_weight_model)
from .ingest import (CFG, TraceStats, ingest_trace, ingest_trace_with_stats,
                     load_cfg, load_graph, replay_trace)
from .binfmt import (BINARY_MAGIC, BINARY_VERSION, BinaryFormatError,
                     is_binary_trace_path, iter_trace_bin_chunks,
                     read_trace_bin, read_trace_bin_header, write_trace_bin)
from .scan import (SCAN_MAX_MB_ENV, SCANNER_ENV, scanner_enabled,
                   scanner_mode, try_scan_ingest)
from .record import (DEMO_PROGRAMS, demo_program, record_fn, record_graph,
                     record_jaxpr)
from .synth import iter_synthetic_trace, synthesize_trace

__all__ = [
    "SCHEMA_VERSION", "TraceFormatError", "type_bytes",
    "WEIGHT_MODELS", "register_weight_model", "resolve_weight_model",
    "CFG", "TraceStats", "ingest_trace", "ingest_trace_with_stats",
    "load_cfg", "load_graph", "replay_trace",
    "BINARY_MAGIC", "BINARY_VERSION", "BinaryFormatError",
    "is_binary_trace_path", "iter_trace_bin_chunks", "read_trace_bin",
    "read_trace_bin_header", "write_trace_bin",
    "SCAN_MAX_MB_ENV", "SCANNER_ENV", "scanner_enabled", "scanner_mode",
    "try_scan_ingest",
    "DEMO_PROGRAMS", "demo_program", "record_fn", "record_graph",
    "record_jaxpr",
    "iter_synthetic_trace", "synthesize_trace",
]
