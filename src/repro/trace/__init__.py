"""Dynamic-trace ingestion front end (NDJSON traces -> streaming IRGraphs).

The paper's pipeline starts from instrumented dynamic LLVM traces (§3:
basic-block execution order + per-memory-op timing).  This package is
that front end for the reproduction: it adopts the ct-publicness NDJSON
TRACE/CFG schemas (v0) as the interchange format, streams million-line
traces into `IRGraph`s with constant per-chunk memory (`ingest.py`),
replays static listings along CFG paths (`replay_trace`), derives edge
weights through pluggable models (`weights.py`), and writes the same
schema back out from jaxpr traces (`record.py`) — giving a round-trip
oracle against `core.jaxpr_graph.jaxpr_to_graph`.

CLI: ``python -m repro.trace {inspect,convert,partition,record,synth}``.
"""
from .schema import SCHEMA_VERSION, TraceFormatError, type_bytes
from .weights import (WEIGHT_MODELS, register_weight_model,
                      resolve_weight_model)
from .ingest import (CFG, TraceStats, ingest_trace, ingest_trace_with_stats,
                     load_cfg, load_graph, replay_trace)
from .record import (DEMO_PROGRAMS, demo_program, record_fn, record_graph,
                     record_jaxpr)
from .synth import iter_synthetic_trace, synthesize_trace

__all__ = [
    "SCHEMA_VERSION", "TraceFormatError", "type_bytes",
    "WEIGHT_MODELS", "register_weight_model", "resolve_weight_model",
    "CFG", "TraceStats", "ingest_trace", "ingest_trace_with_stats",
    "load_cfg", "load_graph", "replay_trace",
    "DEMO_PROGRAMS", "demo_program", "record_fn", "record_graph",
    "record_jaxpr",
    "iter_synthetic_trace", "synthesize_trace",
]
