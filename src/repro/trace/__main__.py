"""Trace tooling CLI.

    python -m repro.trace inspect  examples/traces/toy_loop.ndjson
    python -m repro.trace convert  trace.ndjson graph.npz --weight-model bytes
    python -m repro.trace partition trace.ndjson -p 64 --method wb_libra
    python -m repro.trace record   mlp.ndjson --program mlp
    python -m repro.trace synth    big.ndjson --lines 1000000 --seed 0

`inspect` prints ingestion stats + graph stats as JSON; `convert` writes
an `.npz` IRGraph snapshot; `partition` runs the full partition -> map
-> simulate pipeline on the ingested graph and prints the plan summary;
`record` serializes a built-in JAX demo program's dynamic trace;
`synth` writes a deterministic synthetic trace (benchmark input).
"""
from __future__ import annotations

import argparse
import json
import sys

from .ingest import ingest_trace_with_stats, replay_trace
from .record import DEMO_PROGRAMS, demo_program, record_fn
from .synth import synthesize_trace
from .weights import WEIGHT_MODELS


def _add_ingest_args(sp) -> None:
    sp.add_argument("trace",
                    help="NDJSON trace file (.gz / .zst paths are "
                         "decompressed transparently; no flag needed) or "
                         "a .rtb binary trace from `convert`")
    sp.add_argument("--weight-model", default="bytes",
                    choices=sorted(WEIGHT_MODELS))
    sp.add_argument("--on-error", default="raise",
                    choices=("raise", "skip"))
    sp.add_argument("--chunk-edges", type=int, default=1 << 16)
    sp.add_argument("--cfg", default=None,
                    help="CFG NDJSON side file (block/edge/path records)")
    sp.add_argument("--replay", action="store_true",
                    help="treat the trace as a static listing and replay "
                         "it along the CFG's path records")
    sp.add_argument("--repeat", type=int, default=1,
                    help="replay each path this many times")
    sp.add_argument("--workers", type=int, default=1,
                    help="parse (and for `partition`, also cut) the trace "
                         "on this many sharded workers (repro.dist); 1 = "
                         "the sequential streaming ingester")


def _ingest(args, keep_labels: bool = False):
    kw = dict(weight_model=args.weight_model, on_error=args.on_error,
              chunk_edges=args.chunk_edges, keep_labels=keep_labels)
    if args.replay:
        if args.cfg is None:
            sys.exit("--replay needs --cfg (path records)")
        return replay_trace(args.trace, args.cfg, repeat=args.repeat, **kw)
    if args.workers > 1:
        from ..dist import dist_ingest_with_stats
        return dist_ingest_with_stats(args.trace, workers=args.workers,
                                      cfg=args.cfg, **kw)
    return ingest_trace_with_stats(args.trace, cfg=args.cfg, **kw)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.trace",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    sp = sub.add_parser("inspect", help="ingest + print stats JSON")
    _add_ingest_args(sp)

    sp = sub.add_parser("convert",
                        help="ingest + save a .rtb binary trace or .npz "
                             "IRGraph snapshot (picked by suffix)")
    _add_ingest_args(sp)
    sp.add_argument("out", help="output path: .rtb[.gz|.zst] writes the "
                                "binary columnar trace container v1; "
                                ".npz writes an IRGraph snapshot")

    sp = sub.add_parser("partition",
                        help="ingest + partition/map/simulate summary")
    _add_ingest_args(sp)
    sp.add_argument("-p", "--clusters", type=int, default=8)
    sp.add_argument("--method", default="wb_libra")
    sp.add_argument("--lam", type=float, default=1.0)
    sp.add_argument("--backend", default="fast",
                    help="pipeline backend; --workers > 1 implies 'dist'")
    sp.add_argument("--divergence", type=float, default=None,
                    help="adaptive merge trigger for the dist backend: "
                         "defer full state merges until the per-cluster "
                         "load drift exceeds this fraction of the mean "
                         "cluster load (default: merge every round)")
    sp.add_argument("--profile", default=None, metavar="OUT.json",
                    help="write a Perfetto-loadable telemetry profile of "
                         "the ingest+partition run (render with `python "
                         "-m repro.obs summarize OUT.json`)")

    sp = sub.add_parser("record",
                        help="write a JAX demo program's trace as NDJSON")
    sp.add_argument("out", help="output .ndjson path")
    sp.add_argument("--program", default="mlp",
                    choices=sorted(DEMO_PROGRAMS))

    sp = sub.add_parser("synth", help="write a synthetic NDJSON trace")
    sp.add_argument("out", help="output .ndjson path")
    sp.add_argument("--lines", type=int, default=100_000)
    sp.add_argument("--seed", type=int, default=0)
    sp.add_argument("--fns", type=int, default=4)

    args = ap.parse_args(argv)

    if args.cmd == "inspect":
        g, stats = _ingest(args, keep_labels=False)
        print(json.dumps({"stats": stats.summary(), "graph": g.stats()},
                         indent=2, default=float))
    elif args.cmd == "convert":
        from .binfmt import is_binary_trace_path, write_trace_bin
        g, stats = _ingest(args)
        if is_binary_trace_path(args.out):
            write_trace_bin(args.out, g, stats)
        else:
            g.save_npz(args.out)
        print(f"wrote {args.out}: {g.num_vertices} vertices, "
              f"{g.num_edges} edges ({stats.records} records)")
    elif args.cmd == "partition":
        import contextlib

        from .. import obs
        from ..core.planner import plan_graph
        prof = (obs.profiled(args.profile) if args.profile
                else contextlib.nullcontext())
        with prof:
            g, _ = _ingest(args)
            backend = "dist" if args.workers > 1 else args.backend
            report = plan_graph(g, args.clusters, method=args.method,
                                lam=args.lam, backend=backend,
                                workers=args.workers,
                                divergence=args.divergence)
        print(json.dumps(report.summary(), indent=2, default=float))
        if args.profile:
            print(f"profile: {args.profile} (python -m repro.obs "
                  f"summarize {args.profile})", file=sys.stderr)
    elif args.cmd == "record":
        fn, fargs = demo_program(args.program)
        lines = record_fn(fn, *fargs, out=args.out, name=args.program)
        print(f"wrote {args.out}: {lines} trace lines ({args.program})")
    elif args.cmd == "synth":
        lines = synthesize_trace(args.out, args.lines, seed=args.seed,
                                 n_fns=args.fns)
        print(f"wrote {args.out}: {lines} synthetic trace lines "
              f"(seed {args.seed})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
