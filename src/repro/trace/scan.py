"""Vectorized structural-index NDJSON scanner (the fast JSON path).

Instead of `json.loads` per line, the scanner treats the whole byte
stream as data: numpy passes locate every quote, newline, and structural
byte in bulk, token spans become integer arrays, and the rolling
def-table semantics of `ingest._StreamBuilder` are replayed with one
stable lexsort over (function, symbol, time) events — a use binds to
the latest def event before it in its group, a group-leading use of a
non-`const:` symbol materialises (and registers) a live-in, and
`const:` uses with no preceding def materialise fresh vertices.  Edge
weights are evaluated once per unique `(op, use_ty, producer_bytes)`
triple and gathered, so float results are bit-identical to calling the
weight model per edge.

The scanner is *strict and partial*: it accepts only the compact,
machine-written TRACE_SCHEMA v0 subset (no escapes, no whitespace
outside strings, every record carrying fn/bb/pp/op/def/uses, tokens
within fixed width bounds) and proves the input is in that subset with
structural byte accounting before trusting its own parse.  Anything
else — CFG `kind` lines, `on_error="skip"`, iterable/file-like sources,
pretty-printed JSON, unknown keys, a malformed byte — falls back to the
sequential interpreter, which is the semantic reference and owns all
error reporting.  Fallback is whole-file, so diagnostics (line numbers,
messages) are exactly the sequential path's.

The scanner's whole-file batch passes win on small and medium traces
but lose to the streaming interpreter once the file outgrows the
page/CPU caches (the structural index is several full-size temporary
arrays), so by default it only engages for files up to
``REPRO_TRACE_SCAN_MAX_MB`` megabytes on disk (default 24; compressed
inputs are judged by their on-disk size).  Set ``REPRO_TRACE_SCANNER=0``
(or ``off``) to disable the scanner everywhere, or ``=1`` (``on``,
``force``) to engage it regardless of file size.
"""
from __future__ import annotations

import os
from time import perf_counter

import numpy as np

from .. import obs
from ..core.graph import IRGraph
from .schema import type_bytes
from .weights import resolve_weight_model

__all__ = ["SCANNER_ENV", "SCAN_MAX_MB_ENV", "scanner_enabled",
           "scanner_mode", "try_scan_ingest"]

SCANNER_ENV = "REPRO_TRACE_SCANNER"
SCAN_MAX_MB_ENV = "REPRO_TRACE_SCAN_MAX_MB"
DEFAULT_SCAN_MAX_MB = 24.0

_BLOCK = 1 << 24                # structural pass block: 16 MiB
_SYM_W = 24                     # max bytes for ids/ops/types
_PP_W = 48                      # max bytes for pp tokens
_MAX_UNIQUE_PP = 1 << 17

# key classes by (token length, first byte); full bytes verified after
_KEYS = {(2, ord("f")): (0, b"fn"), (2, ord("b")): (1, b"bb"),
         (2, ord("p")): (2, b"pp"), (2, ord("o")): (3, b"op"),
         (3, ord("d")): (4, b"def"), (4, ord("u")): (5, b"uses"),
         (6, ord("d")): (6, b"def_ty"), (7, ord("u")): (7, b"use_tys")}
_NKEYS = 8

_ALLOWED = np.zeros(256, np.bool_)
_ALLOWED[[ord(c) for c in '{}[]:,"nul']] = True
_ALLOWED[10] = True


class _Fallback(Exception):
    """Input outside the scanner's subset — use the sequential path."""


def scanner_mode() -> str:
    """Scanner policy from the environment: "off", "force" or "auto".

    "auto" (the default) engages the scanner only for files whose
    on-disk size is within the `REPRO_TRACE_SCAN_MAX_MB` budget — the
    batch structural passes materialise several full-size temporaries,
    so past the cache-friendly regime the streaming interpreter is
    faster despite parsing line by line.
    """
    v = os.environ.get(SCANNER_ENV, "").lower()
    if v in ("0", "off", "false", "no"):
        return "off"
    if v in ("1", "on", "force", "yes"):
        return "force"
    return "auto"


def scanner_enabled() -> bool:
    return scanner_mode() != "off"


def _scan_size_ok(path: str) -> bool:
    try:
        limit = float(os.environ.get(SCAN_MAX_MB_ENV,
                                     DEFAULT_SCAN_MAX_MB))
    except ValueError:
        limit = DEFAULT_SCAN_MAX_MB
    try:
        return os.path.getsize(path) <= limit * (1 << 20)
    except OSError:
        return True       # let _read_all surface (or fall back on) it


def try_scan_ingest(source, *, weight_model="bytes", on_error="raise",
                    cfg=None, name=None, keep_labels=False):
    """Scan `source` if eligible; return `(IRGraph, TraceStats)` or None.

    None means "not handled" — the caller runs the sequential ingester,
    which reproduces both the result and any error diagnostics.
    """
    mode = scanner_mode()
    if mode == "off":
        return None
    if cfg is not None or on_error != "raise":
        obs.event("trace.scan_fallback", reason="cfg_or_on_error")
        return None
    if not isinstance(weight_model, str):
        # user callables may be stateful; the scanner evaluates weights
        # per unique triple, which is only sound for pure models
        obs.event("trace.scan_fallback", reason="weight_model_callable")
        return None
    if not isinstance(source, (str, os.PathLike)):
        obs.event("trace.scan_fallback", reason="not_a_path")
        return None
    path = os.fspath(source)
    if mode == "auto" and not _scan_size_ok(path):
        obs.event("trace.scan_fallback", reason="size_budget")
        return None
    try:
        data = _read_all(path)
    except (_Fallback, OSError):
        obs.event("trace.scan_fallback", reason="read_error")
        return None
    from .ingest import _source_name
    t0 = perf_counter()
    try:
        out = _scan_bytes(data, resolve_weight_model(weight_model),
                          keep_labels, _source_name(source, name))
    except _Fallback:
        obs.event("trace.scan_fallback", reason="structure")
        return None
    if obs.enabled():
        t1 = perf_counter()
        m = int(out[0].num_edges)
        obs.complete("trace.ingest", t0, t1, engine="scan",
                     bytes=len(data), edges=m,
                     edges_per_s=round(m / max(t1 - t0, 1e-9)))
    return out


def _read_all(path: str) -> bytes:
    if path.endswith(".gz"):
        import gzip
        with gzip.open(path, "rb") as f:
            return f.read()
    if path.endswith((".zst", ".zstd")):
        try:
            import zstandard
        except ImportError:
            raise _Fallback from None      # sequential raises the real error
        with open(path, "rb") as fh:
            return zstandard.ZstdDecompressor().stream_reader(fh).read()
    with open(path, "rb") as f:
        return f.read()


# ---------------------------------------------------------------------- #
# structural pass (blocked so every temporary stays small)
# ---------------------------------------------------------------------- #
def _structural_scan(mv: np.ndarray):
    """One blocked pass: quote/newline positions, string-interior
    residue validation, and residue byte counts for the structural
    accounting checks.  Raises `_Fallback` on any byte outside the
    compact subset (escapes, whitespace, digits outside strings, ...).
    """
    quotes, newlines = [], []
    counts = np.zeros(256, np.int64)
    parity = 0
    for lo in range(0, mv.shape[0], _BLOCK):
        blk = mv[lo:lo + _BLOCK]
        if np.count_nonzero(blk == 92):
            raise _Fallback                 # escapes break quote pairing
        qmask = blk == 34
        q = np.flatnonzero(qmask)
        if q.size:
            quotes.append(q.astype(np.int32) + np.int32(lo))
        nlmask = blk == 10
        nl = np.flatnonzero(nlmask)
        if nl.size:
            newlines.append(nl.astype(np.int32) + np.int32(lo))
        # control bytes are invalid JSON inside strings and must all be
        # the newlines that terminate lines
        if np.count_nonzero(blk < 32) != nl.size:
            raise _Fallback
        # parity of preceding quotes -> inside-string mask (uint8 cumsum
        # wraps mod 256, which preserves the parity bit)
        qm8 = qmask.view(np.uint8)
        cq = np.cumsum(qm8, dtype=np.uint8)
        inside = ((cq - qm8 + np.uint8(parity)) & np.uint8(1)).view(np.bool_)
        parity = (parity + int(cq[-1])) & 1 if blk.size else parity
        if inside[nlmask].any():
            raise _Fallback                 # newline inside a string
        counts += np.bincount(blk[~inside], minlength=256)
    if parity:
        raise _Fallback                     # unterminated string
    # disallowed residue bytes (escapes, whitespace, digits, ...) show up
    # as nonzero counts outside the allowed set — one check, no gathers
    if int(counts[~_ALLOWED].sum()) or int(counts[92]):
        raise _Fallback
    cat = (np.concatenate(quotes) if quotes else np.zeros(0, np.int32),
           np.concatenate(newlines) if newlines else np.zeros(0, np.int32))
    return cat[0], cat[1], counts


def _pack_tokens(mv, starts, lens, width):
    """Zero-padded (k, width) uint8 matrix of token bytes (longer tokens
    truncate — callers bound the lengths of the tokens they care about),
    gathered in bounded slices so no temporary exceeds ~40 MB."""
    k = starts.shape[0]
    out = np.zeros((k, width), np.uint8)
    if not k:
        return out
    step = max(1, (1 << 22) // width)
    col = np.arange(width, dtype=np.int64)
    for lo in range(0, k, step):
        s = slice(lo, min(lo + step, k))
        offs = starts[s, None] + col[None, :]
        valid = col[None, :] < np.minimum(lens[s, None], width)
        out[s] = np.take(mv, np.minimum(offs, mv.shape[0] - 1)) * valid
    return out


def _pack_cols(mv, tok_ids, starts, lens, width, presence=False):
    """u64 column arrays over the given tokens' packed bytes; `tok_ids`
    may contain -1 (absent field) -> all-zero rows, distinguished from
    real empty-string tokens by the optional presence column."""
    ids = np.maximum(tok_ids, 0)
    present = tok_ids >= 0
    s = starts[ids].astype(np.int64)
    ln = np.where(present, lens[ids], 0).astype(np.int64)
    # shrink to the smallest 8-byte multiple that holds every token —
    # identity is preserved within one call, and most id/op/type tokens
    # are far below the 24-byte bound
    wmax = int(ln.max()) if ln.size else 0
    width = min(width, max(8, -(-wmax // 8) * 8))
    mat = _pack_tokens(mv, s, ln, width)
    cols = [np.ascontiguousarray(mat[:, 8 * i:8 * i + 8]).view("<u8").ravel()
            for i in range(width // 8)]
    if presence:
        return [present.astype(np.int8)] + cols
    return cols


def _unique_rows(cols):
    """(sort_order_repr, inverse, n_unique) for rows given as equal-length
    integer column arrays — a lexsort-based np.unique(axis=0)."""
    k = cols[0].shape[0]
    if k == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64), 0
    order = np.lexsort(tuple(reversed(cols)))
    new = np.zeros(k, np.bool_)
    new[0] = True
    for c in cols:
        cs = c[order]
        new[1:] |= cs[1:] != cs[:-1]
    uid_sorted = np.cumsum(new) - 1
    inverse = np.empty(k, np.int64)
    inverse[order] = uid_sorted
    repr_idx = order[new]
    return repr_idx, inverse, int(uid_sorted[-1]) + 1


def _decode(mv, start, length) -> str:
    return bytes(mv[start:start + length]).decode("utf-8")


# ---------------------------------------------------------------------- #
# the scan
# ---------------------------------------------------------------------- #
def _scan_bytes(data: bytes, weight_fn, keep_labels: bool, name: str):
    from .ingest import TraceStats
    mv = np.frombuffer(data, np.uint8)
    nbytes = mv.shape[0]
    if nbytes == 0:
        g = IRGraph(n=0, src=np.zeros(0, np.int32), dst=np.zeros(0, np.int32),
                    w=np.zeros(0, np.float64), name=name,
                    node_labels=[] if keep_labels else None)
        return g, TraceStats(engine="scan")

    quotes, newlines, res_counts = _structural_scan(mv)
    if quotes.shape[0] % 2:
        raise _Fallback
    has_final_nl = nbytes and mv[-1] == 10
    if not has_final_nl:
        newlines = np.append(newlines, np.int32(nbytes))
    L = newlines.shape[0]                   # total lines (blank included)

    starts = quotes[0::2] + 1
    ends = quotes[1::2]                     # position of closing quote
    lens = ends - starts
    T = starts.shape[0]
    if T == 0:
        raise _Fallback                     # only blank lines? let seq decide
    if int(ends[-1]) + 1 >= nbytes or int(starts[0]) < 2:
        raise _Fallback
    after = mv[ends + 1]
    before = mv[starts - 2]                 # byte before the opening quote
    is_key = after == 58                    # ':'

    # ---- key classification (verified byte-exact) -------------------- #
    kcls = np.full(T, -1, np.int8)
    kstarts, klens = starts[is_key], lens[is_key]
    kfirst = mv[np.minimum(kstarts, nbytes - 1)]
    kc = np.full(kstarts.shape[0], -1, np.int8)
    for (length, first), (cls, full) in _KEYS.items():
        m = (klens == length) & (kfirst == first)
        if not m.any():
            continue
        sel = kstarts[m]
        ok = np.ones(sel.shape[0], np.bool_)
        for j, ch in enumerate(full):
            ok &= mv[sel + j] == ch
        if not ok.all():
            raise _Fallback                 # unknown key (incl. "kind")
        kc[m] = cls
    if (kc < 0).any():
        raise _Fallback
    kcls[is_key] = kc

    # ---- adjacency checks -------------------------------------------- #
    kb, vb, va = before[is_key], before[~is_key], after[~is_key]
    if not (((kb == 123) | (kb == 44)).all()
            and ((vb == 58) | (vb == 91) | (vb == 44)).all()
            and ((va == 44) | (va == 125) | (va == 93)).all()):
        raise _Fallback

    # ---- token -> line / record mapping ------------------------------ #
    # L binary searches into T tokens beats T searches into L newlines
    cum = np.searchsorted(starts, newlines, side="left")
    tok_per_line = np.diff(np.concatenate((np.zeros(1, np.int64), cum)))
    line_of = np.repeat(np.arange(L, dtype=np.int32), tok_per_line)
    nonempty = tok_per_line > 0
    # token-less lines must be zero-length (true blank lines)
    line_begin = np.concatenate((np.zeros(1, np.int32), newlines[:-1] + 1))
    line_len = newlines - line_begin
    if (line_len[~nonempty] != 0).any():
        raise _Fallback
    R = int(np.count_nonzero(nonempty))
    rec_of_line = np.cumsum(nonempty) - 1   # valid on nonempty lines
    # every nonempty line is "{...}"
    if not ((mv[line_begin[nonempty]] == 123).all()
            and (mv[np.minimum(newlines[nonempty], nbytes) - 1] == 125).all()):
        raise _Fallback

    # ---- owner key for every value token ----------------------------- #
    tidx = np.arange(T, dtype=np.int32)
    key_pos = np.where(is_key, tidx, np.int32(-1))
    owner = np.maximum.accumulate(key_pos)
    vmask = ~is_key
    vowner = owner[vmask]
    if (vowner < 0).any() or (line_of[vmask] != line_of[vowner]).any():
        raise _Fallback
    vcls = kcls[vowner]
    vline = line_of[vmask]
    vlens = lens[vmask]

    # ---- per-record key/value count grammar -------------------------- #
    rec_of_key = rec_of_line[line_of[is_key]]
    rec_of_val = rec_of_line[vline]
    kcount = np.bincount(rec_of_key * _NKEYS + kcls[is_key],
                         minlength=R * _NKEYS).reshape(R, _NKEYS)
    vcount = np.bincount(rec_of_val * _NKEYS + vcls,
                         minlength=R * _NKEYS).reshape(R, _NKEYS)
    if (kcount[:, :6] != 1).any() or (kcount[:, 6:] > 1).any():
        raise _Fallback
    if (vcount[:, :4] != 1).any() or (vcount[:, 4] > 1).any():
        raise _Fallback
    if (vcount[:, 6] != kcount[:, 6]).any():
        raise _Fallback
    has_use_tys = kcount[:, 7] == 1
    n_uses = vcount[:, 5]
    if (vcount[:, 7] != np.where(has_use_tys, n_uses, 0)).any():
        raise _Fallback

    # ---- "null" accounting (def: null is the only legal null) -------- #
    null_def = vcount[:, 4] == 0
    n_null = int(np.count_nonzero(null_def))
    if (int(res_counts[ord("n")]) != n_null
            or int(res_counts[ord("u")]) != n_null
            or int(res_counts[ord("l")]) != 2 * n_null):
        raise _Fallback
    def_key_end = np.full(R, -1, np.int64)
    dk = kcls[is_key] == 4
    def_key_end[rec_of_key[dk]] = ends[is_key][dk]
    if n_null:
        e = def_key_end[null_def]
        if (e + 6 > nbytes).any():
            raise _Fallback
        for j, ch in enumerate(b"null"):
            if not (mv[e + 2 + j] == ch).all():
                raise _Fallback

    # ---- global structural counts ------------------------------------ #
    total_keys = int(np.count_nonzero(is_key))
    n_arrays = int(kcount[:, 5].sum() + kcount[:, 7].sum())
    exp_commas = (total_keys - R
                  + int(np.maximum(n_uses - 1, 0).sum())
                  + int(np.maximum(vcount[:, 7] - 1, 0).sum()))
    if (int(res_counts[123]) != R or int(res_counts[125]) != R
            or int(res_counts[91]) != n_arrays
            or int(res_counts[93]) != n_arrays
            or int(res_counts[58]) != total_keys
            or int(res_counts[44]) != exp_commas):
        raise _Fallback

    # ---- field extraction -------------------------------------------- #
    vtok = np.flatnonzero(vmask).astype(np.int32)   # token id per value
    # every packed-width-bound token (ids, ops, types — everything but
    # pp) must fit in _SYM_W bytes, else identity packing is lossy
    if int(np.max(vlens[vcls != 2], initial=0)) > _SYM_W:
        raise _Fallback

    def field_tok(cls):
        m = vcls == cls
        out = np.full(R, -1, np.int64)
        out[rec_of_val[m]] = vtok[m]
        return out

    fn_tok = field_tok(0)
    bb_tok = field_tok(1)
    pp_tok = field_tok(2)
    op_tok = field_tok(3)
    def_tok = field_tok(4)                  # -1 where def: null
    defty_tok = field_tok(6)                # -1 where absent
    use_m = vcls == 5
    use_tok = vtok[use_m]                   # token ids, in use order
    rec_of_use = rec_of_val[use_m]
    E = use_tok.shape[0]
    use_start = np.concatenate(([0], np.cumsum(n_uses)))[:-1]
    uty_m = vcls == 7
    uty_tok_ids = vtok[uty_m]
    uty_rec = rec_of_val[uty_m]
    use_ty_tok = np.full(E, -1, np.int64)
    if uty_tok_ids.size:
        grp_new = np.ones(uty_rec.shape[0], np.bool_)
        grp_new[1:] = uty_rec[1:] != uty_rec[:-1]
        ordinal = np.arange(uty_rec.shape[0]) - np.maximum.accumulate(
            np.where(grp_new, np.arange(uty_rec.shape[0]), 0))
        use_ty_tok[use_start[uty_rec] + ordinal] = uty_tok_ids

    # ---- interning --------------------------------------------------- #
    def pack(tok_ids, presence=False):
        return _pack_cols(mv, tok_ids, starts, lens, _SYM_W,
                          presence=presence)

    fn_repr, fn_uid, nF = _unique_rows(pack(fn_tok))
    bb_repr, fb_uid, nB = _unique_rows([fn_uid] + pack(bb_tok))
    op_repr, op_uid, nO = _unique_rows(pack(op_tok))
    has_defty = defty_tok >= 0
    ty_tok_all = np.concatenate((defty_tok, use_ty_tok))
    ty_repr, ty_uid_all, nTy = _unique_rows(pack(ty_tok_all, presence=True))
    defty_uid, use_ty_uid = ty_uid_all[:R], ty_uid_all[R:]

    fn_strs = [_decode(mv, starts[fn_tok[i]], lens[fn_tok[i]])
               for i in fn_repr]
    op_strs = [_decode(mv, starts[op_tok[i]], lens[op_tok[i]])
               for i in op_repr]
    ty_strs = []
    for i in ty_repr:
        t = ty_tok_all[i]
        ty_strs.append(None if t < 0 else _decode(mv, starts[t], lens[t]))
    ty_bytes = np.array([-1.0 if s is None else type_bytes(s)
                         for s in ty_strs])

    # ---- pp validation + ordering ------------------------------------ #
    # pp_repr entries are record indices (one pp token per record), so
    # validating each *unique* pp against its representative record's
    # own fn/bb, then checking all records share that (fn, bb) via the
    # interned uids, proves pp == f"{fn}:{bb}:i{idx}" for every record.
    if int(lens[pp_tok].max(initial=0)) > _PP_W:
        raise _Fallback
    pp_packed = _pack_tokens(mv, starts[pp_tok], lens[pp_tok], _PP_W)
    ppk = [pp_packed[:, 8 * i:8 * i + 8].copy().view("<u8").ravel()
           for i in range(_PP_W // 8)]
    pp_repr, pp_uid, nP = _unique_rows(ppk)
    if nP > _MAX_UNIQUE_PP:
        raise _Fallback
    exp_fn = np.empty(nP, np.int64)
    exp_fb = np.empty(nP, np.int64)
    idx_of_pp = np.empty(nP, np.int64)
    for u, r in enumerate(pp_repr.tolist()):
        s = _decode(mv, starts[pp_tok[r]], lens[pp_tok[r]])
        head, sep, tail = s.rpartition(":i")
        if not sep or not tail.isdigit():
            raise _Fallback
        fnp, sep2, bbp = head.partition(":")
        if not sep2 or fnp != fn_strs[int(fn_uid[r])] \
                or bbp != _decode(mv, starts[bb_tok[r]], lens[bb_tok[r]]):
            raise _Fallback                 # seq path would reject this pp
        exp_fn[u] = fn_uid[r]
        exp_fb[u] = fb_uid[r]
        idx_of_pp[u] = int(tail)
    if (exp_fn[pp_uid] != fn_uid).any() or (exp_fb[pp_uid] != fb_uid).any():
        raise _Fallback
    idx = idx_of_pp[pp_uid]

    same = np.zeros(R, np.bool_)
    if R > 1:
        same[1:] = (fn_uid[1:] == fn_uid[:-1]) & (fb_uid[1:] == fb_uid[:-1])
    viol = np.flatnonzero(same & np.concatenate(
        ([False], idx[1:] <= idx[:-1])) if R > 1 else np.zeros(0, np.bool_))
    if viol.size:
        run_id = np.cumsum(~same) - 1
        run_start = np.flatnonzero(~same)
        latest_first = {}
        for j in viol.tolist():
            rid = int(run_id[j])
            first = latest_first.get(rid, int(idx[run_start[rid]]))
            if int(idx[j]) <= first:
                latest_first[rid] = int(idx[j])     # block re-entry
            else:
                raise _Fallback                     # out-of-order pp

    # ---- event binding ----------------------------------------------- #
    has_def = def_tok >= 0
    def_recs = np.flatnonzero(has_def)
    D = def_recs.shape[0]
    sym_tok = np.concatenate((use_tok, def_tok[def_recs]))
    sym_fn = np.concatenate((fn_uid[rec_of_use], fn_uid[def_recs]))
    sym_cols = pack(sym_tok)
    _, ssym, nS = _unique_rows([sym_fn] + sym_cols)
    # const flag per scoped symbol (first 6 bytes == b"const:")
    CONST6 = int.from_bytes(b"const:", "little")
    is_const_ev_src = (sym_cols[0] & 0xFFFFFFFFFFFF) == CONST6
    sym_is_const = np.zeros(nS, np.bool_)
    sym_is_const[ssym] = is_const_ev_src    # consistent across the group

    ev_time = np.concatenate((2 * rec_of_use, 2 * def_recs + 1))
    ev_isdef = np.concatenate((np.zeros(E, np.bool_), np.ones(D, np.bool_)))
    ev_use = np.concatenate((np.arange(E), np.full(D, -1)))
    ev_rec = np.concatenate((rec_of_use, def_recs))
    order = np.lexsort((ev_time, ssym))
    s_sym = ssym[order]
    s_isdef = ev_isdef[order]
    s_use = ev_use[order]
    s_rec = ev_rec[order]
    N = order.shape[0]
    gs = np.ones(N, np.bool_)
    if N > 1:
        gs[1:] = s_sym[1:] != s_sym[:-1]
    s_const = sym_is_const[s_sym]
    eff = s_isdef | (gs & ~s_isdef & ~s_const)
    j = np.arange(N)
    P = np.maximum.accumulate(np.where(eff, j, -1))
    S = np.maximum.accumulate(np.where(gs, j, -1))
    is_use_ev = ~s_isdef
    bound = is_use_ev & ~eff & (P >= S)
    creator = is_use_ev & eff
    const_fresh = is_use_ev & ~eff & ~bound
    if (const_fresh & ~s_const).any():
        raise _Fallback                     # unreachable by construction

    fresh_sorted = creator | const_fresh
    fresh = np.zeros(E, np.bool_)
    fresh[s_use[fresh_sorted]] = True

    # ---- vertex numbering (record, then fresh uses, interleaved) ----- #
    cfx = np.concatenate(([0], np.cumsum(fresh)))   # exclusive prefix
    rec_vertex = np.arange(R) + cfx[use_start]
    fresh_slot = (rec_vertex[rec_of_use] + 1
                  + (cfx[np.arange(E)] - cfx[use_start[rec_of_use]]))
    n_total = R + int(cfx[-1])

    # ---- producers, pbytes, src/dst ---------------------------------- #
    def_bytes = np.full(R, -1.0)
    def_bytes[has_defty] = ty_bytes[defty_uid[has_defty]]
    prod = P[np.flatnonzero(bound)]
    bpos = np.flatnonzero(bound)
    prod_vert = np.where(s_isdef[prod], rec_vertex[s_rec[prod]],
                         fresh_slot[np.maximum(s_use[prod], 0)])
    prod_bytes = np.where(s_isdef[prod] & (def_bytes[s_rec[prod]] >= 0),
                          def_bytes[s_rec[prod]], -1.0)
    src = np.empty(E, np.int64)
    src[s_use[bpos]] = prod_vert
    src[fresh] = fresh_slot[fresh]
    pb = np.full(E, -1.0)
    pb[s_use[bpos]] = prod_bytes
    dst = rec_vertex[rec_of_use]

    # ---- weights: one call per unique (op, use_ty, pbytes) ----------- #
    op_of_use = op_uid[rec_of_use]
    w_repr, w_inv, nW = _unique_rows([op_of_use, use_ty_uid,
                                      np.ascontiguousarray(pb).view(np.int64)])
    w_uniq = np.empty(nW)
    for u, i in enumerate(w_repr):
        p = pb[i]
        w_uniq[u] = weight_fn(op_strs[int(op_of_use[i])],
                              ty_strs[int(use_ty_uid[i])],
                              None if p < 0 else float(p))
    w = w_uniq[w_inv]

    # ---- labels ------------------------------------------------------ #
    labels = None
    if keep_labels:
        lab = np.empty(n_total, object)
        lab[rec_vertex] = np.array(op_strs, object)[op_uid]
        cf_use = np.zeros(E, np.bool_)
        cf_use[s_use[const_fresh]] = True
        li_use = np.zeros(E, np.bool_)
        li_use[s_use[creator]] = True
        lab[fresh_slot[cf_use]] = "const"
        li_idx = np.flatnonzero(li_use)
        for e in li_idx.tolist():
            t = use_tok[e]
            lab[fresh_slot[e]] = _decode(mv, starts[t], lens[t])
        labels = list(lab)

    stats = TraceStats(
        lines=int(L), records=R,
        const_uses=int(np.count_nonzero(const_fresh)),
        livein_uses=int(np.count_nonzero(creator)),
        void_defs=n_null, functions=nF, blocks=nB, engine="scan")
    g = IRGraph(n=n_total, src=src.astype(np.int32),
                dst=dst.astype(np.int32), w=w, name=name,
                node_labels=labels)
    return g, stats
