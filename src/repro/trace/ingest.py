"""Streaming NDJSON trace -> IRGraph (the paper's §3 graph constructor).

The ingester reconstructs the weighted dynamic dependence graph from a
TRACE_SCHEMA v0 stream while holding only O(chunk) Python state:

  * one vertex per instruction record, ids assigned in stream order —
    trace order *is* program order, which the streaming partitioner's
    greedy quality depends on (DESIGN §2 edge-order finding);
  * SSA value ids are interned through **rolling def-tables** (one plain
    dict per function: id -> (vertex, def bytes)); a re-executed block
    overwrites its defs, so loop-carried uses bind to the previous
    iteration, and a use of a never-defined id materialises a live-in
    vertex;
  * every use of a `const:*` id materialises a fresh vertex (constants
    are per-use in an SSA trace, like jaxpr literals);
  * edges are buffered in flat Python lists only up to `chunk_edges`,
    then frozen into numpy batches and concatenated once at the end —
    million-line traces never hold per-edge Python objects.

`replay_trace` expands a *static* per-block listing into a dynamic trace
by walking CFG `path` records (basic-block execution order), which is
how the paper's instrumentation-side traces are serialized compactly.

The record loop is deliberately hand-tuned (local bindings, a
``"".join`` type probe, a cached program-point prefix): `json.loads` is
the unavoidable floor, and everything else is kept within its budget so
million-line traces ingest in seconds — see the `trace_ingest` bench.
"""
from __future__ import annotations

import dataclasses
import json
import os
from time import perf_counter

import numpy as np

from .. import obs
from ..core.graph import IRGraph
from .schema import CFG_KINDS, TraceFormatError, type_bytes
from .weights import resolve_weight_model

try:                                    # optional accelerator, never required
    from orjson import loads as _json_loads    # pragma: no cover
except ImportError:
    _json_loads = json.loads

__all__ = ["TraceStats", "CFG", "TraceSession", "ingest_trace",
           "ingest_trace_with_stats", "replay_trace", "load_cfg",
           "load_graph"]

DEFAULT_CHUNK_EDGES = 1 << 16


@dataclasses.dataclass
class TraceStats:
    """Counters from one ingestion pass (CLI `inspect`, tests, benches)."""

    lines: int = 0              # lines read (blank lines included)
    records: int = 0            # instruction records turned into vertices
    cfg_records: int = 0        # kind-tagged records (skipped or routed)
    skipped: int = 0            # malformed records dropped (on_error=skip)
    const_uses: int = 0         # fresh vertices from const:* uses
    livein_uses: int = 0        # fresh vertices from never-defined ids
    void_defs: int = 0          # instructions with def: null
    cfg_violations: int = 0     # bb transitions absent from the CFG
    peak_chunk_edges: int = 0   # high-water mark of the Python edge buffer
    functions: int = 0
    blocks: int = 0
    # which ingestion engine produced the graph: "stream" (the Python
    # record loop below), "scan" (the vectorized scanner, trace.scan),
    # or "binary" (a .rtb container, trace.binfmt)
    engine: str = "stream"

    def summary(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class CFG:
    """Static control-flow side-channel (CFG_SCHEMA v0 block/edge/path)."""

    succs: dict                 # (fn, bb) -> set of successor bb labels
    paths: list                 # dicts: {fn, path_id, bbs}

    @property
    def has_blocks(self) -> bool:
        return bool(self.succs)


def _open_lines(source):
    """(line iterable, closer) for a path, file-like, or iterable of lines.

    A `.gz` path is decompressed transparently (instrumentation runs
    usually gzip their NDJSON streams on the fly; text-mode `gzip.open`
    streams line-by-line, so the O(chunk) memory bound still holds), and
    a `.zst`/`.zstd` path likewise through the optional `zstandard`
    package (`pip install repro[zstd]`) — zstd is what long-running
    instrumentation favours for its compression speed.  Lines are passed
    through raw — `json.loads` tolerates surrounding whitespace, and
    blank lines are dropped in `parse_line`'s error path, so the hot
    loop never strips."""
    if isinstance(source, (str, os.PathLike)):
        path = os.fspath(source)
        if path.endswith(".gz"):
            import gzip
            f = gzip.open(source, "rt", encoding="utf-8")
        elif path.endswith((".zst", ".zstd")):
            f = _open_zstd(source)
        else:
            f = open(source, "r", encoding="utf-8")
        return f, f.close
    return source, (lambda: None)


def _open_zstd(source):
    """Text-mode streaming reader over a zstd-compressed path.

    Soft dependency: `zstandard` is only imported when a `.zst` path is
    actually opened, so the core package stays dependency-free."""
    try:
        import zstandard
    except ImportError as e:                # pragma: no cover - soft dep
        raise ImportError(
            "reading .zst/.zstd traces needs the optional 'zstandard' "
            "package (pip install zstandard, or repro[zstd])") from e
    import io
    fh = open(source, "rb")
    reader = zstandard.ZstdDecompressor().stream_reader(fh)
    # closefd semantics: closing the text wrapper closes the stream
    # reader, which closes the underlying file handle
    return io.TextIOWrapper(reader, encoding="utf-8")


def _source_name(source, name):
    if name is not None:
        return name
    if isinstance(source, (str, os.PathLike)):
        base = os.path.basename(os.fspath(source))
        return base.rsplit(".", 1)[0] if "." in base else base
    return "trace"


# ---------------------------------------------------------------------- #
# the streaming builder
# ---------------------------------------------------------------------- #
class _StreamBuilder:
    def __init__(self, weight_fn, chunk_edges: int, keep_labels: bool,
                 cfg: "CFG | None", on_error: str):
        if on_error not in ("raise", "skip"):
            raise ValueError("on_error must be 'raise' or 'skip'")
        self.weight_fn = weight_fn
        self.chunk_edges = max(int(chunk_edges), 1)
        self.keep_labels = keep_labels
        self.cfg = cfg
        self.on_error = on_error

        # rolling def-tables, one per function (SSA ids are stable only
        # within a function): id -> (vertex, def bytes)
        self._defs_by_fn: dict = {}
        self._cur_fn = None
        self.defs: dict = {}            # the current function's table
        self.n = 0
        self.labels: list = [] if keep_labels else None
        self._batches: list = []
        self._src: list = []
        self._dst: list = []
        self._w: list = []
        # current (fn, bb, pp-index) run for ordering validation;
        # _run_first is the run's starting index (block re-entry detector)
        self._run = (None, None, -1)
        self._run_first = -1
        self._run_prefix = ""
        self._bbs: set = set()
        # counters (folded into TraceStats at finalize)
        self._lines = 0
        self._records = 0
        self._cfg_records = 0
        self._skipped = 0
        self._const_uses = 0
        self._livein_uses = 0
        self._void_defs = 0
        self._cfg_violations = 0
        self._peak = 0

    # -- node/edge plumbing -------------------------------------------- #
    def _flush(self) -> None:
        buffered = len(self._src)
        if buffered > self._peak:
            self._peak = buffered
        if buffered:
            self._batches.append((np.asarray(self._src, np.int32),
                                  np.asarray(self._dst, np.int32),
                                  np.asarray(self._w, np.float64)))
            self._src, self._dst, self._w = [], [], []

    def new_block_run(self) -> None:
        """Reset pp-ordering state at a replayed block boundary."""
        self._run = (None, None, -1)
        self._run_first = -1

    def _fail(self, lineno: int, msg: str) -> bool:
        if self.on_error == "raise":
            raise TraceFormatError(lineno, msg)
        self._skipped += 1
        return False

    # -- record processing --------------------------------------------- #
    def parse_line(self, lineno: int, line: str) -> "dict | None":
        """json-decode one line; returns the record dict, or None when it
        was blank/malformed/CFG and consumed (counted) instead."""
        self._lines += 1
        try:
            rec = _json_loads(line)
        except ValueError:
            if line.strip():
                self._fail(lineno, f"not valid JSON: {line.strip()[:60]!r}")
            return None                 # blank line
        if type(rec) is not dict:
            self._fail(lineno, "record is not a JSON object")
            return None
        kind = rec.get("kind")
        if kind is not None:
            if kind in CFG_KINDS:
                self._cfg_records += 1
                return None             # CFG side-channel, not an instruction
            self._fail(lineno, f"unknown record kind {kind!r}")
            return None
        return rec

    def add_record(self, lineno: int, rec: dict) -> bool:
        """Validate + apply one instruction record (atomically: a record
        rejected under on_error='skip' leaves no vertices, edges, or
        def-table entries behind).

        The validation/ordering prologue and the def registration are
        shared with the sharded parser (`repro.dist`), which subclasses
        this builder and overrides only `_add_use_edges` — keeping the
        dist-vs-sequential equality contract mechanical rather than a
        matter of two hand-synced copies of this hot loop.
        """
        op = rec.get("op")
        if type(op) is not str:
            return self._fail(lineno, "missing/non-string 'op'")
        uses = rec.get("uses")
        if uses is None:
            uses = ()
        elif type(uses) is not list:
            return self._fail(lineno, "'uses' must be a list of value ids")
        else:
            try:                        # C-speed all-strings probe
                "".join(uses)
            except TypeError:
                return self._fail(lineno,
                                  "'uses' must be a list of value ids")
        def_id = rec.get("def")
        if def_id is not None and type(def_id) is not str:
            return self._fail(lineno, "'def' must be a value id or null")
        use_tys = rec.get("use_tys")
        if use_tys is not None:
            if type(use_tys) is not list or len(use_tys) != len(uses):
                return self._fail(lineno, "'use_tys' not parallel to 'uses'")
            try:                        # elements: type strings (or null)
                "".join(t for t in use_tys if t is not None)
            except TypeError:
                return self._fail(lineno,
                                  "'use_tys' must be type strings or null")
        fn = rec.get("fn", "?")
        bb = rec.get("bb", "?")

        # program-point ordering: inside one contiguous (fn, bb) run the
        # instruction index must strictly increase; block changes reset
        # it, and a rewind to the run's *first* index is block re-entry
        # (a self-looping block executed back-to-back), not disorder
        run_fn, run_bb, run_idx = self._run
        same_run = fn == run_fn and bb == run_bb
        pp = rec.get("pp")
        idx = None
        reentry = False
        if pp is not None:
            if type(pp) is not str:
                return self._fail(lineno, "'pp' must be a string")
            prefix = self._run_prefix if same_run else f"{fn}:{bb}:i"
            tail = pp[len(prefix):]
            if not pp.startswith(prefix) or not tail.isdigit():
                return self._fail(
                    lineno, f"pp {pp!r} does not match fn={fn!r} bb={bb!r}")
            idx = int(tail)
            if same_run and idx <= run_idx:
                if idx <= self._run_first:
                    reentry = True
                else:
                    return self._fail(
                        lineno,
                        f"out-of-order pp {pp!r} (last index {run_idx})")

        if not same_run or reentry:
            # CFG check: a same-function block transition (including a
            # self-loop re-entry) must follow a known successor edge
            # when block records were supplied
            cfg = self.cfg
            if cfg is not None and fn == run_fn and cfg.has_blocks:
                succs = cfg.succs.get((fn, run_bb))
                if succs is not None and bb not in succs:
                    self._cfg_violations += 1
                    return self._fail(
                        lineno, f"bb transition {run_bb!r} -> {bb!r} "
                                f"not a CFG edge in {fn!r}")

        # ---- validation done; mutate ---------------------------------- #
        if not same_run or reentry:
            self._run_prefix = f"{fn}:{bb}:i"
            self._bbs.add((fn, bb))
            self._run_first = idx if idx is not None else -1
            if fn != self._cur_fn:
                self._cur_fn = fn
                self.defs = self._defs_by_fn.setdefault(fn, {})
        if idx is None:
            idx = run_idx if same_run else -1
        self._run = (fn, bb, idx)
        self._records += 1

        nid = self.n
        n = nid + 1
        if self.labels is not None:
            self.labels.append(op)
        if uses:
            n = self._add_use_edges(nid, n, op, uses, use_tys)
        self.n = n
        if len(self._src) >= self.chunk_edges:
            self._flush()

        if def_id is None:
            self._void_defs += 1
        else:
            def_ty = rec.get("def_ty")
            self.defs[def_id] = (
                nid, type_bytes(def_ty) if type(def_ty) is str else None)
        return True

    def _add_use_edges(self, nid: int, n: int, op: str, uses,
                       use_tys) -> int:
        """Operand scan: intern each use, append its edge, return the
        next fresh vertex id.  The single override point of the sharded
        parser (`repro.dist.parse._ShardBuilder`)."""
        defs_get = self.defs.get
        weight_fn = self.weight_fn
        src_append = self._src.append
        dst_append = self._dst.append
        w_append = self._w.append
        labels = self.labels
        for i, u in enumerate(uses):
            entry = defs_get(u)
            if entry is not None:
                pid, pbytes = entry
            elif u.startswith("const:"):
                pid, pbytes = n, None
                n += 1
                self._const_uses += 1
                if labels is not None:
                    labels.append("const")
            else:
                pid, pbytes = n, None
                n += 1
                self.defs[u] = (pid, None)
                self._livein_uses += 1
                if labels is not None:
                    labels.append(u)
            src_append(pid)
            dst_append(nid)
            w_append(weight_fn(
                op, use_tys[i] if use_tys is not None else None, pbytes))
        return n

    def finalize(self, name: str):
        self._flush()
        stats = TraceStats(
            lines=self._lines, records=self._records,
            cfg_records=self._cfg_records, skipped=self._skipped,
            const_uses=self._const_uses, livein_uses=self._livein_uses,
            void_defs=self._void_defs, cfg_violations=self._cfg_violations,
            peak_chunk_edges=self._peak,
            functions=len(self._defs_by_fn), blocks=len(self._bbs))
        if self._batches:
            src = np.concatenate([b[0] for b in self._batches])
            dst = np.concatenate([b[1] for b in self._batches])
            w = np.concatenate([b[2] for b in self._batches])
        else:
            src = np.zeros(0, np.int32)
            dst = np.zeros(0, np.int32)
            w = np.zeros(0, np.float64)
        g = IRGraph(n=self.n, src=src, dst=dst, w=w, name=name,
                    node_labels=self.labels)
        return g, stats


# ---------------------------------------------------------------------- #
# incremental multi-window sessions
# ---------------------------------------------------------------------- #
class TraceSession:
    """Incremental NDJSON parsing: feed trace *windows*, keep one graph.

    Each `feed(source)` call streams another window of the same logical
    trace through the rolling def-tables of a single `_StreamBuilder`,
    so vertex ids, loop-carried bindings, and edge order are exactly
    those of one uninterrupted parse of the concatenated windows —
    window boundaries never change the graph (the invariant the
    incremental repartitioner's bit-identity contract rests on).

    `feed` returns only the edges the window added (trace order), which
    is what `repro.serve.IncrementalPlanner` streams into its resumable
    cut state; `graph()` materialises the full concatenated graph.
    """

    def __init__(self, *, weight_model="bytes",
                 chunk_edges: int = DEFAULT_CHUNK_EDGES,
                 on_error: str = "raise", keep_labels: bool = False):
        self._b = _StreamBuilder(resolve_weight_model(weight_model),
                                 chunk_edges, keep_labels, None, on_error)
        self._cursor = 0            # batches already handed out by feed()
        self.windows = 0

    @property
    def n(self) -> int:
        """Vertices discovered so far."""
        return self._b.n

    def feed(self, source) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Parse one window; returns its (src, dst, w) edge arrays."""
        b = self._b
        lines, close = _open_lines(source)
        try:
            parse_line, add_record = b.parse_line, b.add_record
            for lineno, line in enumerate(lines, start=1):
                rec = parse_line(lineno, line)
                if rec is not None:
                    add_record(lineno, rec)
        finally:
            close()
        b._flush()
        new = b._batches[self._cursor:]
        self._cursor = len(b._batches)
        self.windows += 1
        if not new:
            return (np.zeros(0, np.int32), np.zeros(0, np.int32),
                    np.zeros(0, np.float64))
        if len(new) == 1:
            return new[0]
        return (np.concatenate([x[0] for x in new]),
                np.concatenate([x[1] for x in new]),
                np.concatenate([x[2] for x in new]))

    def graph(self, name: str = "session") -> IRGraph:
        """The concatenated graph over every window fed so far."""
        b = self._b
        b._flush()
        if b._batches:
            src = np.concatenate([x[0] for x in b._batches])
            dst = np.concatenate([x[1] for x in b._batches])
            w = np.concatenate([x[2] for x in b._batches])
        else:
            src = np.zeros(0, np.int32)
            dst = np.zeros(0, np.int32)
            w = np.zeros(0, np.float64)
        return IRGraph(n=b.n, src=src, dst=dst, w=w, name=name,
                       node_labels=b.labels)


# ---------------------------------------------------------------------- #
# public entry points
# ---------------------------------------------------------------------- #
def ingest_trace_with_stats(source, *, weight_model="bytes",
                            chunk_edges: int = DEFAULT_CHUNK_EDGES,
                            on_error: str = "raise",
                            cfg=None, name: str | None = None,
                            keep_labels: bool = False):
    """Stream a TRACE_SCHEMA v0 NDJSON source into an `IRGraph`.

    Args:
      source: path, file-like object, or iterable of NDJSON lines.
      weight_model: name in `WEIGHT_MODELS` ("bytes", "memop-latency") or
        a callable `(op, use_ty, producer_def_bytes) -> float`.
      chunk_edges: Python edge-buffer bound; memory per chunk is
        O(chunk_edges), independent of trace length.
      on_error: "raise" — abort with `TraceFormatError` (line number
        included); "skip" — drop the malformed record atomically and
        count it in `stats.skipped`.
      cfg: optional CFG (object or path) used to validate basic-block
        ordering against `block` records.
      keep_labels: retain per-vertex opcode labels (O(n) strings; off by
        default so huge traces stay array-only).

    Two transparent fast paths sit in front of the streaming
    interpreter (docs/trace-format.md documents both):

    * Binary `.rtb` paths (see `repro.trace.binfmt`) load directly —
      `weight_model` is baked in at conversion time and ignored here,
      and `cfg` validation is not applicable (the trace is already a
      validated graph).
    * Eligible NDJSON path sources run through the vectorized scanner
      (`repro.trace.scan`), bit-identical to the interpreter; anything
      outside its strict subset — or past the size budget where its
      batch passes stop beating the streaming interpreter
      (`REPRO_TRACE_SCAN_MAX_MB`, default 24) — falls back whole-file,
      so results and diagnostics never change.  `REPRO_TRACE_SCANNER=0`
      disables the scanner; `=1` forces it at any size.

    `stats.engine` records which engine produced the graph ("stream",
    "scan", or "binary").

    Returns:
      (IRGraph, TraceStats)
    """
    from .binfmt import is_binary_trace_path, read_trace_bin
    if is_binary_trace_path(source):
        if cfg is not None:
            raise ValueError(
                "cfg validation applies to NDJSON traces; a .rtb binary "
                "trace is already a validated graph")
        g, stats = read_trace_bin(source, keep_labels=keep_labels)
        if name is not None:
            g = dataclasses.replace(g, name=name)
        return g, stats
    if cfg is not None and not isinstance(cfg, CFG):
        cfg = load_cfg(cfg)
    from .scan import try_scan_ingest
    scanned = try_scan_ingest(source, weight_model=weight_model,
                              on_error=on_error, cfg=cfg, name=name,
                              keep_labels=keep_labels)
    if scanned is not None:
        return scanned
    b = _StreamBuilder(resolve_weight_model(weight_model), chunk_edges,
                       keep_labels, cfg, on_error)
    t0 = perf_counter()
    lines, close = _open_lines(source)
    try:
        parse_line, add_record = b.parse_line, b.add_record
        for lineno, line in enumerate(lines, start=1):
            rec = parse_line(lineno, line)
            if rec is not None:
                add_record(lineno, rec)
    finally:
        close()
    out = b.finalize(_source_name(source, name))
    if obs.enabled():
        t1 = perf_counter()
        m = int(out[0].num_edges)
        try:
            nbytes = (os.path.getsize(source)
                      if isinstance(source, (str, os.PathLike)) else 0)
        except OSError:
            nbytes = 0
        obs.complete("trace.ingest", t0, t1, engine="stream",
                     bytes=int(nbytes), edges=m,
                     edges_per_s=round(m / max(t1 - t0, 1e-9)))
    return out


def ingest_trace(source, **kw) -> IRGraph:
    """`ingest_trace_with_stats` without the stats (the common call)."""
    return ingest_trace_with_stats(source, **kw)[0]


def replay_trace(source, cfg, *, fn: str | None = None,
                 path_ids=None, repeat: int = 1,
                 weight_model="bytes",
                 chunk_edges: int = DEFAULT_CHUNK_EDGES,
                 on_error: str = "raise", name: str | None = None,
                 keep_labels: bool = False):
    """Expand a *static* per-block listing into a dynamic graph.

    The trace source holds each block's instructions once (static order);
    the CFG's `path` records give the executed basic-block sequence.
    Each visited block re-emits its instructions as fresh vertices and
    overwrites its defs in the rolling def-table, so loop-carried
    dependencies resolve to the previous iteration — the paper's dynamic
    trace reconstructed from (static listing, path) pairs.

    Args:
      fn: restrict to one function's paths (default: all).
      path_ids: iterable of path_id values to replay (default: all).
      repeat: replay each selected path this many times (load scaling).

    Returns:
      (IRGraph, TraceStats)
    """
    if not isinstance(cfg, CFG):
        cfg = load_cfg(cfg)
    b = _StreamBuilder(resolve_weight_model(weight_model), chunk_edges,
                       keep_labels, None, on_error)
    # static listing: (fn, bb) -> [(lineno, record), ...] in block order
    blocks: dict = {}
    lines, close = _open_lines(source)
    try:
        for lineno, line in enumerate(lines, start=1):
            rec = b.parse_line(lineno, line)
            if rec is not None:
                key = (rec.get("fn", "?"), rec.get("bb", "?"))
                blocks.setdefault(key, []).append((lineno, rec))
    finally:
        close()
    wanted = set(path_ids) if path_ids is not None else None
    for path in cfg.paths:
        if fn is not None and path["fn"] != fn:
            continue
        if wanted is not None and path["path_id"] not in wanted:
            continue
        for _ in range(max(1, repeat)):
            for bb in path["bbs"]:
                b.new_block_run()
                for lineno, rec in blocks.get((path["fn"], bb), ()):
                    b.add_record(lineno, rec)
    return b.finalize(_source_name(source, name))


def load_cfg(source) -> CFG:
    """Parse CFG_SCHEMA v0 `block`/`edge`/`path` records from NDJSON."""
    succs: dict = {}
    paths: list = []
    lines, close = _open_lines(source)
    try:
        for lineno, line in enumerate(lines, start=1):
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except ValueError:
                raise TraceFormatError(lineno,
                                       f"not valid JSON: {line[:60]!r}")
            if not isinstance(rec, dict):
                raise TraceFormatError(lineno, "record is not a JSON object")
            kind = rec.get("kind")
            try:
                if kind == "block":
                    succs.setdefault((rec["fn"], rec["bb"]),
                                     set()).update(rec.get("succs", []))
                elif kind == "edge":
                    succs.setdefault((rec["fn"], rec["from"]),
                                     set()).add(rec["to"])
                elif kind == "path":
                    paths.append({"fn": rec["fn"],
                                  "path_id": rec.get("path_id", len(paths)),
                                  "bbs": list(rec.get("bbs", []))})
                # other kinds (summaries, coverage, trace records) ignored
            except KeyError as e:
                raise TraceFormatError(
                    lineno, f"{kind!r} record missing field {e}") from None
    finally:
        close()
    return CFG(succs=succs, paths=paths)


def load_graph(source, **kw) -> IRGraph:
    """Load an `IRGraph` from a path, whatever the serialization.

    Dispatches on suffix: `.npz` snapshots load via `IRGraph.load_npz`,
    `.rtb` (+ `.gz`/`.zst`) binary traces via `repro.trace.binfmt`, and
    everything else ingests as a TRACE_SCHEMA v0 NDJSON trace (any
    keyword accepted by `ingest_trace` passes through).  This is the
    dispatch behind `coerce_graph` / `run_pipeline(path, ...)`.
    """
    path = os.fspath(source)
    if path.endswith(".npz"):
        return IRGraph.load_npz(path)
    return ingest_trace(path, **kw)
