"""jaxpr -> NDJSON trace exporter (the round-trip oracle's write side).

`record_graph` serializes a trace-ordered `IRGraph` (as built by
`core.jaxpr_graph.jaxpr_to_graph`) into TRACE_SCHEMA v0 NDJSON such that
re-ingesting the file reproduces the graph **bit-identically** — same
vertex ids, same `src`/`dst` edge stream, same weights under the
`bytes` model.  That gives the trace front end a machine-checkable
oracle: any jaxpr is also an NDJSON trace, and
`ingest_trace(record(...))` must equal `jaxpr_to_graph(...)` exactly
(tests/test_trace_roundtrip.py enforces it in tier-1).

Exactness hinges on reproducing the graph builder's vertex *creation
order*.  The ingester creates, per record: the instruction vertex, then
one fresh vertex per `const:*` use and per first-use of an undefined id
(registered).  `jaxpr_to_graph` creates, per eqn: the eqn vertex, then
literal/free vertices inside its operand-resolution loop — the same
order.  So every vertex serializes as its own record in id order,
*except* an in-degree-0 vertex whose first consumer precedes it in id
order (it was created inside that consumer's operand loop): it is
rendered inline — as a `const:*` use when it has a single consumer (a
jaxpr literal), or as a plain undefined id when shared (a free/boundary
variable), which the rolling def-table registers on first use.

Weights are carried in `use_tys` as `[N x i8]` byte types, so any v0
consumer reads them back with plain type parsing.
"""
from __future__ import annotations

import json
import os

from ..core.graph import IRGraph
from ..core.jaxpr_graph import jaxpr_to_graph, trace_to_graph
from .schema import encode_bytes_type

__all__ = ["record_graph", "record_jaxpr", "record_fn", "demo_program",
           "DEMO_PROGRAMS"]


def _json_str(s: str) -> str:
    return json.dumps(s, ensure_ascii=True)


def record_graph(g: IRGraph, out) -> int:
    """Write `g` as TRACE_SCHEMA v0 NDJSON; returns lines written.

    `g` must carry `node_labels` and be in trace order (consumers never
    precede their producers' records) — true of `jaxpr_to_graph` output.
    Raises ValueError when the edge stream cannot be serialized
    id-exactly (e.g. a hand-built graph with forward dependencies).
    """
    if isinstance(out, (str, os.PathLike)):
        with open(out, "w", encoding="utf-8") as f:
            return record_graph(g, f)
    if g.node_labels is None:
        raise ValueError("record_graph needs node_labels "
                         "(use jaxpr_to_graph / keep_labels=True)")
    n = g.num_vertices
    src = g.src.tolist()
    dst = g.dst.tolist()
    w = g.w.tolist()
    in_edges: list = [[] for _ in range(n)]
    out_deg = [0] * n
    first_consumer = [None] * n
    first_out_w = [8.0] * n
    for e in range(len(src)):
        s, d = src[e], dst[e]
        in_edges[d].append(e)
        if out_deg[s] == 0:
            first_consumer[s] = d
            first_out_w[s] = w[e]
        out_deg[s] += 1

    # vertices created inside an earlier consumer's operand loop
    inline_const = set()        # single-use literals -> const:* operand
    forward_reg = set()         # shared free/boundary vars -> undefined id
    for k in range(n):
        if (not in_edges[k] and first_consumer[k] is not None
                and first_consumer[k] < k):
            (inline_const if out_deg[k] == 1 else forward_reg).add(k)

    fn = str(g.name).replace(":", "_") or "trace"
    fn_j = _json_str(fn)
    lines = 0
    for k in range(n):
        if k in inline_const or k in forward_reg:
            continue
        uses, use_tys = [], []
        for e in in_edges[k]:
            s = src[e]
            if s in inline_const:
                uses.append(f"const:i64:{s}")
            elif s < k or s in forward_reg:
                # forward_reg ids are undefined at their first (earlier)
                # consumer, which makes the ingester materialise them at
                # exactly the original creation point
                uses.append(f"v{s}")
            else:
                raise ValueError(
                    f"edge {s}->{k} runs against trace order; graph is "
                    "not id-exactly serializable")
            use_tys.append(encode_bytes_type(w[e]))
        parts = [f'"fn":{fn_j},"bb":"bb0","pp":{_json_str(f"{fn}:bb0:i{lines}")}',
                 f'"op":{_json_str(g.node_labels[k])}',
                 f'"def":"v{k}"',
                 '"uses":[' + ",".join(_json_str(u) for u in uses) + "]"]
        if use_tys:
            parts.append(
                '"use_tys":[' + ",".join(_json_str(t) for t in use_tys) + "]")
        if out_deg[k]:
            parts.append(
                f'"def_ty":{_json_str(encode_bytes_type(first_out_w[k]))}')
        out.write("{" + ",".join(parts) + "}\n")
        lines += 1
    return lines


def record_jaxpr(closed_jaxpr, out, name: str = "jaxpr", **graph_kw) -> int:
    """`jaxpr_to_graph` + `record_graph` in one call; returns lines."""
    g = jaxpr_to_graph(closed_jaxpr, name=name, **graph_kw)
    return record_graph(g, out)


def record_fn(fn, *args, out, name: str | None = None, **kw) -> int:
    """Trace a JAX function and write its dynamic trace as NDJSON."""
    g = trace_to_graph(fn, *args, name=name, **kw)
    return record_graph(g, out)


# ---------------------------------------------------------------------- #
# small built-in programs (CLI `record`, examples, round-trip tests)
# ---------------------------------------------------------------------- #
def _mlp():
    import jax.numpy as jnp

    def mlp(x, w1, w2):
        h = jnp.tanh(x @ w1)
        return jnp.sum(h @ w2)

    return mlp, (jnp_ones((4, 8)), jnp_ones((8, 16)), jnp_ones((16, 4)))


def _attention():
    import jax
    import jax.numpy as jnp

    def attn(q, k, v):
        s = q @ k.T / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
        return jax.nn.softmax(s, axis=-1) @ v

    return attn, (jnp_ones((6, 8)), jnp_ones((6, 8)), jnp_ones((6, 8)))


def _scan_rnn():
    import jax
    import jax.numpy as jnp

    def rnn(xs, w):
        def step(h, x):
            h = jnp.tanh(h @ w + x)
            return h, h
        h0 = jnp.zeros((xs.shape[1],), xs.dtype)
        _, ys = jax.lax.scan(step, h0, xs)
        return ys.sum()

    return rnn, (jnp_ones((5, 4)), jnp_ones((4, 4)))


def jnp_ones(shape):
    import jax.numpy as jnp
    return jnp.ones(shape, jnp.float32)


DEMO_PROGRAMS = {"mlp": _mlp, "attention": _attention, "scan_rnn": _scan_rnn}


def demo_program(name: str):
    """Return (fn, args) for a named built-in demo program."""
    try:
        return DEMO_PROGRAMS[name]()
    except KeyError:
        raise ValueError(f"unknown demo program {name!r}; choose from "
                         f"{sorted(DEMO_PROGRAMS)}") from None
