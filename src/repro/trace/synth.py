"""Deterministic synthetic TRACE_SCHEMA v0 generator.

Emits an NDJSON dynamic trace of a plausible SSA program: sequential
functions, blocks revisited in a loop pattern (so defs roll over in the
def-table), a hub/recency operand mix that yields the paper's power-law
degree skew (early values act like arguments/globals and become hubs),
`const:*` operands, void stores, and a realistic opcode/type palette.

Used by the `trace_ingest` benchmark to build >=1M-line inputs without
shipping megabytes of fixture data, and by tests as a property source.
Everything is a pure function of (n_lines, seed, shape params).
"""
from __future__ import annotations

import os
from typing import Iterator

import numpy as np

__all__ = ["iter_synthetic_trace", "synthesize_trace"]

# (op, defines a value?) with sampling weights
_OPS = ("add", "mul", "load", "store", "getelementptr", "icmp", "call",
        "xor", "shl", "phi")
_OP_DEFS = (True, True, True, False, True, True, True, True, True, True)
_OP_P = (0.22, 0.15, 0.20, 0.10, 0.10, 0.06, 0.05, 0.05, 0.04, 0.03)

_TYS = ("i32", "i64", "double", "float", "<4 x float>", "[16 x i8]", "ptr")

_CHUNK = 1 << 14
_HUBS = 8           # first defs per fn act as hubs (args/globals)
_WINDOW = 64        # recency window for non-hub operands


def iter_synthetic_trace(n_lines: int, seed: int = 0, n_fns: int = 4,
                         bbs_per_fn: int = 6, block_len: int = 16,
                         max_uses: int = 3) -> Iterator[str]:
    """Yield `n_lines` NDJSON instruction lines (see module docstring)."""
    rng = np.random.default_rng(seed)
    fn_idx = -1
    k = 0                      # values defined in the current function
    emitted = 0
    while emitted < n_lines:
        m = min(_CHUNK, n_lines - emitted)
        op_i = rng.choice(len(_OPS), size=m, p=_OP_P)
        n_uses = rng.choice(max_uses, size=m,
                            p=_np_uses_p(max_uses)) + 1
        r_kind = rng.random((m, max_uses))      # const / hub / recent
        pick_hub = rng.integers(0, _HUBS, (m, max_uses))
        pick_rec = rng.integers(0, _WINDOW, (m, max_uses))
        const_v = rng.integers(0, 256, (m, max_uses))
        ty_i = rng.integers(0, len(_TYS), (m, max_uses))
        def_ty_i = rng.integers(0, len(_TYS), m)
        redefine = rng.random(m) < 0.03
        with_tys = rng.random(m) < 0.9
        for j in range(m):
            i = emitted + j
            new_fn = i * n_fns // n_lines
            if new_fn != fn_idx:
                fn_idx, k = new_fn, 0
            fn = f"fn{fn_idx}"
            local = i - fn_idx * n_lines // n_fns
            bb = f"bb{(local // block_len) % bbs_per_fn}"
            pp_i = local % block_len
            op = _OPS[op_i[j]]
            uses, use_tys = [], []
            for u in range(n_uses[j]):
                r = r_kind[j, u]
                if r < 0.08:
                    uses.append(f"const:i32:{const_v[j, u]}")
                elif k == 0:
                    uses.append(f"arg{u}")       # live-in before any def
                elif r < 0.30:
                    uses.append(f"v{pick_hub[j, u] % k}")
                else:
                    uses.append(f"v{k - 1 - (pick_rec[j, u] % min(k, _WINDOW))}")
                use_tys.append(_TYS[ty_i[j, u]])
            if _OP_DEFS[op_i[j]]:
                d = (k - 1 - (pick_rec[j, 0] % min(k, _WINDOW))
                     if redefine[j] and k else k)
                def_part = f'"def":"v{d}","def_ty":"{_TYS[def_ty_i[j]]}"'
                if d == k:
                    k += 1
            else:
                def_part = '"def":null'
            tys_part = (',"use_tys":[' + ",".join(
                f'"{t}"' for t in use_tys) + "]") if with_tys[j] else ""
            yield (f'{{"fn":"{fn}","bb":"{bb}","pp":"{fn}:{bb}:i{pp_i}",'
                   f'"op":"{op}",{def_part},'
                   '"uses":[' + ",".join(f'"{u}"' for u in uses) + "]"
                   + tys_part + "}")
        emitted += m


def _np_uses_p(max_uses: int):
    base = [0.35, 0.45, 0.20]
    if max_uses >= 3:
        p = base + [0.0] * (max_uses - 3)
    else:
        p = base[:max_uses]
    s = sum(p)
    return [x / s for x in p]


def synthesize_trace(out, n_lines: int, seed: int = 0, **kw) -> int:
    """Write a synthetic trace to `out` (path or file-like); returns
    the number of lines written."""
    if isinstance(out, (str, os.PathLike)):
        with open(out, "w", encoding="utf-8") as f:
            return synthesize_trace(f, n_lines, seed=seed, **kw)
    lines = 0
    for line in iter_synthetic_trace(n_lines, seed=seed, **kw):
        out.write(line + "\n")
        lines += 1
    return lines
