"""Pluggable edge-weight models for trace ingestion.

The paper weights each dynamic dependence edge with the measured time of
the memory operation behind it (§3, rdtsc instrumentation).  Real traces
rarely ship timings, so ingestion derives weights from what the schema
does carry:

  bytes          — bytes of the value moved, from `use_tys[i]` (falling
                   back to the producer's `def_ty`, then 8).  This is the
                   same cost stand-in `jaxpr_to_graph` uses, which is
                   what makes the record->ingest round trip exact.
  memop-latency  — classify the *consuming* opcode into the paper's
                   measured memory-op classes and charge every incoming
                   edge that class's latency in cycles (loads/stores/
                   RMWs dominate; ALU ops get the 1-cycle floor).

Both models clamp to >= 1.0, matching `jaxpr_graph.add_edge`.  Register
new models with `register_weight_model`, or pass any callable with the
same signature straight to `ingest_trace`.
"""
from __future__ import annotations

from typing import Callable

from .schema import type_bytes

__all__ = ["WEIGHT_MODELS", "resolve_weight_model", "register_weight_model"]

# weight_fn(op, use_ty, producer_def_bytes) -> float
WeightFn = Callable[[str, "str | None", "float | None"], float]

_DEFAULT_BYTES = 8.0

# cycles per memory-op class (paper Table 2 machine: 2.4 GHz OoO cores,
# NUMA mesh; values are the usual measured orders: L2/remote-latency
# loads, store-buffer drains, call overhead incl. spills)
MEMOP_LATENCY_CYCLES = {
    "load": 200.0,
    "store": 100.0,
    "atomicrmw": 300.0,
    "cmpxchg": 300.0,
    "fence": 100.0,
    "call": 250.0,
    "invoke": 250.0,
    "getelementptr": 4.0,
    "alloca": 20.0,
}
_ALU_LATENCY = 1.0


def _bytes_model(op: str, use_ty: str | None,
                 producer_bytes: float | None) -> float:
    if use_ty is not None:
        return max(type_bytes(use_ty), 1.0)
    if producer_bytes is not None:
        return max(producer_bytes, 1.0)
    return _DEFAULT_BYTES


def _memop_latency_model(op: str, use_ty: str | None,
                         producer_bytes: float | None) -> float:
    return MEMOP_LATENCY_CYCLES.get(op, _ALU_LATENCY)


WEIGHT_MODELS: dict[str, WeightFn] = {
    "bytes": _bytes_model,
    "memop-latency": _memop_latency_model,
}


def register_weight_model(name: str, fn: WeightFn) -> None:
    WEIGHT_MODELS[name] = fn


def resolve_weight_model(model: "str | WeightFn") -> WeightFn:
    if callable(model):
        return model
    try:
        return WEIGHT_MODELS[model]
    except KeyError:
        raise ValueError(
            f"unknown weight model {model!r}; choose from "
            f"{sorted(WEIGHT_MODELS)} or pass a callable") from None
