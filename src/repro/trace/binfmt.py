"""Binary columnar trace container v1 (`.rtb`) — convert once, ingest fast.

NDJSON is the interchange format; this is the *ingest-once* format the
ROADMAP's "break the ingestion wall" item calls for: after one
`python -m repro.trace convert trace.ndjson trace.rtb`, every later run
(partition sweeps, dist sharding, benchmarks) loads the exact IRGraph
the NDJSON path would have built, at memory bandwidth instead of JSON
parse speed.

Container layout (all integers little-endian; see docs/trace-format.md
for the normative spec):

    offset  size  field
    0       8     magic  b"REPROTB\\x00"
    8       2     format version (u16, currently 1)
    10      4     header length H (u32)
    14      H     header JSON (utf-8)
    14+H    ...   chunk payloads, then optional per-vertex label ids

The header records graph shape (`n`, `edges`, `name`), the column dtypes
(`src`/`dst` = "<i4", `w` = "<f8"), a chunk table (edge counts in file
order), the ingestion `stats` captured at conversion time, and an
optional label string table.  Each chunk payload is the raw bytes of its
`src`, `dst`, and `w` column slices, concatenated in that order —
`np.frombuffer`-able with zero parsing.

`.rtb.gz` and `.rtb.zst`/`.rtb.zstd` paths wrap the same byte stream in
gzip / zstandard (the latter via the optional `zstandard` package),
mirroring the NDJSON reader's transparent decompression.

Malformed containers raise `BinaryFormatError` with the same
debuggability contract as the NDJSON path's `TraceFormatError`: the
message names the file and the first structural problem found (bad
magic, unsupported version, dtype mismatch, truncated chunk, ...).
"""
from __future__ import annotations

import io
import json
import os
import struct

import numpy as np

from ..core.graph import IRGraph

__all__ = ["BINARY_MAGIC", "BINARY_VERSION", "BinaryFormatError",
           "is_binary_trace_path", "write_trace_bin", "read_trace_bin",
           "read_trace_bin_header", "iter_trace_bin_chunks"]

BINARY_MAGIC = b"REPROTB\x00"
BINARY_VERSION = 1
DEFAULT_BIN_CHUNK_EDGES = 1 << 20

_DTYPES = {"src": "<i4", "dst": "<i4", "w": "<f8"}
_BIN_SUFFIXES = (".rtb", ".rtb.gz", ".rtb.zst", ".rtb.zstd")


class BinaryFormatError(ValueError):
    """A malformed `.rtb` container (binary sibling of TraceFormatError)."""

    def __init__(self, path, message: str):
        super().__init__(f"binary trace {os.fspath(path)!s}: {message}")
        self.path = os.fspath(path)


def is_binary_trace_path(source) -> bool:
    """True for paths the `.rtb` reader owns (incl. compressed)."""
    if not isinstance(source, (str, os.PathLike)):
        return False
    return os.fspath(source).endswith(_BIN_SUFFIXES)


def _open_bin(path, mode: str):
    p = os.fspath(path)
    if p.endswith(".gz"):
        import gzip
        return gzip.open(p, mode)
    if p.endswith((".zst", ".zstd")):
        try:
            import zstandard
        except ImportError as e:            # pragma: no cover - soft dep
            raise ImportError(
                "reading/writing .rtb.zst traces needs the optional "
                "'zstandard' package (pip install zstandard)") from e
        if "r" in mode:
            fh = open(p, "rb")
            return io.BufferedReader(
                zstandard.ZstdDecompressor().stream_reader(fh))
        fh = open(p, "wb")
        return zstandard.ZstdCompressor().stream_writer(fh, closefd=True)
    return open(p, mode)


# ---------------------------------------------------------------------- #
# writer
# ---------------------------------------------------------------------- #
def write_trace_bin(path, g: IRGraph, stats=None,
                    chunk_edges: int = DEFAULT_BIN_CHUNK_EDGES) -> int:
    """Serialize `g` (plus optional ingestion `stats`) to `path`.

    The graph's edge arrays are split into `chunk_edges`-sized chunks so
    readers (notably `repro.dist`) can shard work without re-splitting
    lines.  Returns the number of chunks written.
    """
    chunk_edges = max(int(chunk_edges), 1)
    src = np.ascontiguousarray(g.src, dtype=np.dtype(_DTYPES["src"]))
    dst = np.ascontiguousarray(g.dst, dtype=np.dtype(_DTYPES["dst"]))
    w = np.ascontiguousarray(g.w, dtype=np.dtype(_DTYPES["w"]))
    m = int(src.shape[0])
    bounds = list(range(0, m, chunk_edges)) + [m]
    chunks = [{"edges": bounds[i + 1] - bounds[i]}
              for i in range(len(bounds) - 1)] if m else []
    header = {
        "schema_version": 0,
        "n": int(g.n),
        "edges": m,
        "name": g.name,
        "dtypes": dict(_DTYPES),
        "chunks": chunks,
    }
    if stats is not None:
        header["stats"] = stats.summary() if hasattr(stats, "summary") \
            else dict(stats)
    label_ids = None
    if g.node_labels is not None:
        table: dict = {}
        label_ids = np.empty(len(g.node_labels), np.int32)
        for i, lab in enumerate(g.node_labels):
            label_ids[i] = table.setdefault(lab, len(table))
        header["label_table"] = list(table)
    hdr = json.dumps(header, separators=(",", ":")).encode("utf-8")
    f = _open_bin(path, "wb")
    try:
        f.write(BINARY_MAGIC)
        f.write(struct.pack("<HI", BINARY_VERSION, len(hdr)))
        f.write(hdr)
        for i in range(len(bounds) - 1):
            lo, hi = bounds[i], bounds[i + 1]
            f.write(src[lo:hi].tobytes())
            f.write(dst[lo:hi].tobytes())
            f.write(w[lo:hi].tobytes())
        if label_ids is not None:
            f.write(label_ids.tobytes())
    finally:
        f.close()
    return max(len(chunks), 0)


# ---------------------------------------------------------------------- #
# reader
# ---------------------------------------------------------------------- #
def _read_exact(f, n: int, path, what: str) -> bytes:
    buf = f.read(n)
    if len(buf) != n:
        raise BinaryFormatError(
            path, f"truncated {what}: wanted {n} bytes, got {len(buf)}")
    return buf


def _read_header(f, path) -> dict:
    magic = f.read(len(BINARY_MAGIC))
    if magic != BINARY_MAGIC:
        raise BinaryFormatError(
            path, f"bad magic {magic[:8]!r} (expected {BINARY_MAGIC!r}); "
                  "not a .rtb binary trace")
    version, hlen = struct.unpack(
        "<HI", _read_exact(f, 6, path, "version/header-length"))
    if version != BINARY_VERSION:
        raise BinaryFormatError(
            path, f"unsupported format version {version} "
                  f"(this reader handles version {BINARY_VERSION})")
    try:
        header = json.loads(_read_exact(f, hlen, path, "header"))
    except ValueError as e:
        raise BinaryFormatError(path, f"header is not valid JSON: {e}") \
            from None
    if not isinstance(header, dict):
        raise BinaryFormatError(path, "header is not a JSON object")
    for field in ("n", "edges", "dtypes", "chunks"):
        if field not in header:
            raise BinaryFormatError(path, f"header missing field {field!r}")
    dtypes = header["dtypes"]
    for col, want in _DTYPES.items():
        got = dtypes.get(col)
        if got != want:
            raise BinaryFormatError(
                path, f"dtype mismatch for column {col!r}: file says "
                      f"{got!r}, this reader requires {want!r}")
    declared = sum(int(c["edges"]) for c in header["chunks"])
    if declared != int(header["edges"]):
        raise BinaryFormatError(
            path, f"chunk table sums to {declared} edges but header "
                  f"declares {header['edges']}")
    return header


def read_trace_bin_header(path) -> dict:
    """Parse and validate just the container header (cheap inspect)."""
    f = _open_bin(path, "rb")
    try:
        return _read_header(f, path)
    finally:
        f.close()


def _chunk_cols(f, path, m: int, i: int):
    cols = []
    for col in ("src", "dst", "w"):
        dt = np.dtype(_DTYPES[col])
        raw = _read_exact(f, m * dt.itemsize, path,
                          f"chunk {i} column {col!r}")
        cols.append(np.frombuffer(raw, dtype=dt))
    return tuple(cols)


def iter_trace_bin_chunks(path):
    """Yield `(header, src, dst, w)` per chunk — the dist sharding feed.

    The header is yielded with every chunk (same object) so consumers
    can size def-free merge state without a second pass; columns are
    read-only `np.frombuffer` views over freshly-read bytes.
    """
    f = _open_bin(path, "rb")
    try:
        header = _read_header(f, path)
        for i, c in enumerate(header["chunks"]):
            m = int(c["edges"])
            if m < 0:
                raise BinaryFormatError(path, f"chunk {i} negative size")
            yield (header,) + _chunk_cols(f, path, m, i)
        if not header["chunks"]:
            yield (header, np.zeros(0, np.int32), np.zeros(0, np.int32),
                   np.zeros(0, np.float64))
    finally:
        f.close()


def read_trace_bin(path, keep_labels: bool = False):
    """Load a `.rtb` container back into `(IRGraph, TraceStats)`.

    The graph is bit-identical to the one `convert` serialized (same
    dtypes, same edge order); `stats` are the conversion-time ingestion
    stats re-tagged with `engine="binary"` (or fresh zeroed stats when
    the writer had none).
    """
    from time import perf_counter

    from .. import obs
    from .ingest import TraceStats          # local import: no cycle at load
    t0 = perf_counter()
    f = _open_bin(path, "rb")
    try:
        header = _read_header(f, path)
        m = int(header["edges"])
        srcs, dsts, ws = [], [], []
        for i, c in enumerate(header["chunks"]):
            s, d, w = _chunk_cols(f, path, int(c["edges"]), i)
            srcs.append(s)
            dsts.append(d)
            ws.append(w)
        labels = None
        table = header.get("label_table")
        if table is not None:
            n = int(header["n"])
            ids = np.frombuffer(
                _read_exact(f, 4 * n, path, "label ids"), dtype="<i4")
            bad = (ids < 0) | (ids >= len(table))
            if bad.any():
                raise BinaryFormatError(
                    path, f"label id {int(ids[bad][0])} outside string "
                          f"table of {len(table)} entries")
            if keep_labels:
                labels = [table[i] for i in ids]
    finally:
        f.close()
    src = np.concatenate(srcs) if srcs else np.zeros(0, np.int32)
    dst = np.concatenate(dsts) if dsts else np.zeros(0, np.int32)
    w = np.concatenate(ws) if ws else np.zeros(0, np.float64)
    if src.shape[0] != m:                   # unreachable if header honest
        raise BinaryFormatError(path, "edge columns shorter than header")
    n = int(header["n"])
    if m and (int(src.max()) >= n or int(dst.max()) >= n):
        raise BinaryFormatError(
            path, f"edge endpoint exceeds declared vertex count {n}")
    g = IRGraph(n=n, src=src, dst=dst, w=w,
                name=header.get("name") or "trace", node_labels=labels)
    st = header.get("stats") or {}
    known = {f.name for f in TraceStats.__dataclass_fields__.values()} \
        if hasattr(TraceStats, "__dataclass_fields__") else set()
    stats = TraceStats(**{k: v for k, v in st.items() if k in known})
    stats.engine = "binary"
    if obs.enabled():
        t1 = perf_counter()
        try:
            nbytes = os.path.getsize(path)
        except OSError:
            nbytes = 0
        obs.complete("trace.ingest", t0, t1, engine="binary",
                     bytes=int(nbytes), edges=m,
                     edges_per_s=round(m / max(t1 - t0, 1e-9)))
    return g, stats
