"""TRACE_SCHEMA v0 — NDJSON dynamic-trace interchange format.

One JSON object per line.  Instruction records (the dynamic trace; one
line per *executed* IR instruction, in program order):

  fn       function name (string)
  bb       basic-block label (string, stable per function)
  pp       program point "fn:bb:i<index>" (string; <index> is the
           instruction's position inside the block)
  op       opcode name (string; LLVM names for real traces, jaxpr
           primitive names for recorded jaxprs — ingest treats it as an
           opaque label except for weight-model classification)
  def      SSA value id defined by the instruction, or null for
           void-typed instructions (store, br, ...)
  uses     array of SSA value ids read by the instruction
  def_ty   optional type string for def (see `type_bytes`)
  use_tys  optional type strings parallel to `uses`

SSA value ids:
  const:*  constants (const:i32:7, const:fp:1.5, const:null, ...) —
           every *use* of a const id materialises a fresh graph vertex,
           mirroring how literals appear per-use in an SSA trace;
  v<N> / arg<N> / anything else — interned through a rolling def-table:
           a use binds to the most recent def of that id (re-executed
           blocks overwrite their defs, so loop-carried dependencies
           resolve to the previous iteration), and a use of a
           never-defined id materialises and registers a vertex (an
           incoming argument / live-in).

CFG records (optional, same file or a side file) carry a `kind` field
and describe the *static* control-flow graph plus enumerated paths:

  {"kind":"block","fn":..,"bb":..,"succs":[..]}
  {"kind":"edge","fn":..,"from":..,"to":..}
  {"kind":"path","fn":..,"path_id":N,"bbs":[..]}

`block`/`edge` records let the ingester check basic-block ordering of a
dynamic trace; `path` records let `replay_trace` expand a *static*
per-block instruction listing into a dynamic trace by walking the
recorded block sequence (the paper's instrumented execution order).

The schema is adopted verbatim from the ct-publicness repo's
TRACE_SCHEMA.md / CFG_SCHEMA.md (v0) so traces produced by its LLVM
instrumentation pass load unchanged.
"""
from __future__ import annotations

import functools
import re

__all__ = ["SCHEMA_VERSION", "TraceFormatError", "type_bytes",
           "encode_bytes_type", "CFG_KINDS"]

SCHEMA_VERSION = 0

# record kinds that belong to the CFG side-channel, not the instruction
# stream (CFG_SCHEMA v0)
CFG_KINDS = frozenset({"func_summary", "block", "edge", "path",
                       "pp_coverage", "path_summary", "trace_index"})

class TraceFormatError(ValueError):
    """A malformed trace/CFG record.

    Raised with the 1-based line number so a million-line trace is
    debuggable; `ingest_trace(on_error="skip")` counts these instead.
    """

    def __init__(self, lineno: int, message: str):
        super().__init__(f"trace line {lineno}: {message}")
        self.lineno = lineno


# ---------------------------------------------------------------------- #
# LLVM-ish type strings -> byte sizes (the `bytes` weight model)
# ---------------------------------------------------------------------- #
_SCALAR_BYTES = {
    "half": 2.0, "bfloat": 2.0, "float": 4.0, "double": 8.0,
    "fp128": 16.0, "x86_fp80": 16.0, "ppc_fp128": 16.0,
    "ptr": 8.0, "void": 0.0, "label": 0.0, "token": 0.0, "metadata": 0.0,
}
_VEC_OR_ARRAY = re.compile(r"^[<\[]\s*(\d+)\s+x\s+(.*?)\s*[>\]]$")


@functools.lru_cache(maxsize=4096)
def type_bytes(ty: str | None, default: float = 8.0) -> float:
    """Byte size of an LLVM-style type string.

    Handles iN integers, the floating/pointer scalars, `<N x T>` vectors
    and `[N x T]` arrays (recursively); `T*` pointer spellings map to 8.
    Unknown types (opaque structs, ...) fall back to `default` — a trace
    with exotic types still ingests, it just loses weight precision.
    """
    if ty is None:
        return default
    ty = ty.strip()
    if ty.endswith("*"):
        return 8.0
    if ty in _SCALAR_BYTES:
        return _SCALAR_BYTES[ty]
    if ty.startswith("i") and ty[1:].isdigit():
        return max(float((int(ty[1:]) + 7) // 8), 1.0)
    m = _VEC_OR_ARRAY.match(ty)
    if m:
        return float(m.group(1)) * type_bytes(m.group(2), default=default)
    return default


def encode_bytes_type(nbytes: float) -> str:
    """Inverse of `type_bytes` for integral byte counts: the recorder
    writes weights as `[N x i8]` so any NDJSON consumer reads them back
    with plain v0 type parsing (`i8` when N == 1)."""
    n = int(round(nbytes))
    return "i8" if n <= 1 else f"[{n} x i8]"
