from .pipeline import DataConfig, SyntheticLM, host_shard
__all__ = ["DataConfig", "SyntheticLM", "host_shard"]
