"""Deterministic sharded synthetic-token pipeline.

Production behaviours that matter at scale and are modelled here:
  * per-host sharding: each host materialises only its slice of the
    global batch (shard_id / num_shards);
  * deterministic resume: batch t is a pure function of (seed, step), so
    restoring step k after a failure replays the exact stream with no
    state files (the paper-style trace order stays stable too);
  * microbatch splitting for gradient accumulation;
  * a mixture of synthetic "documents" (zipf unigrams + repeated n-gram
    motifs) so the LM loss actually falls during the examples' training
    runs instead of flat-lining on uniform noise.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "host_shard"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    motif_len: int = 8
    motif_repeat: int = 4


def host_shard(global_batch: int, shard_id: int, num_shards: int
               ) -> tuple[int, int]:
    """[start, size) slice of the global batch owned by this host."""
    assert global_batch % num_shards == 0, (global_batch, num_shards)
    per = global_batch // num_shards
    return shard_id * per, per


class SyntheticLM:
    """Stateless batch generator: `batch(step)` is deterministic."""

    def __init__(self, cfg: DataConfig, shard_id: int = 0,
                 num_shards: int = 1):
        self.cfg = cfg
        self.start, self.per_host = host_shard(cfg.global_batch, shard_id,
                                               num_shards)
        # fixed unigram distribution (zipf over vocab)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._p = p / p.sum()

    def batch(self, step: int, n_micro: int = 1) -> dict:
        """Returns {"tokens": int32 [B_host, S]} (or [n_micro, B/n, S])."""
        cfg = self.cfg
        rows = []
        for b in range(self.per_host):
            rng = np.random.default_rng(
                (cfg.seed, step, self.start + b))
            toks = rng.choice(cfg.vocab_size, size=cfg.seq_len,
                              p=self._p).astype(np.int32)
            # plant motifs: repeated n-grams give the model learnable
            # structure (copy heads drive the loss down)
            mlen = min(cfg.motif_len, max(cfg.seq_len // 2, 1))
            motif = rng.integers(0, cfg.vocab_size,
                                 size=mlen).astype(np.int32)
            for r in range(cfg.motif_repeat):
                at = int(rng.integers(0, max(cfg.seq_len - mlen, 1)))
                toks[at:at + mlen] = motif
            rows.append(toks)
        tokens = np.stack(rows)
        if n_micro > 1:
            assert self.per_host % n_micro == 0
            tokens = tokens.reshape(n_micro, self.per_host // n_micro,
                                    cfg.seq_len)
        return {"tokens": tokens}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
