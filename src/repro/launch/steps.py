"""Step functions: train_step (grad accumulation + remat options) and
serve_step (greedy decode) — the functions every launcher and the
multi-pod dry-run lower."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import models
from repro.configs.base import ModelConfig, ParallelConfig
from repro.optim.adamw import AdamWConfig, adamw_update

__all__ = ["make_train_step", "make_serve_step", "make_prefill_step"]


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    par: ParallelConfig, impl: str = "auto",
                    accum_dtype=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt,
    metrics).  Gradient accumulation over `par.microbatches` splits the
    *local* batch; remat wraps the per-microbatch loss.

    `accum_dtype` controls the gradient accumulator/reduction dtype:
    bf16 for memory-class cells (halves both the accumulator residency
    and the cross-shard gradient all-reduce bytes — §Perf deepseek
    iteration); default follows opt_cfg.moment_dtype."""
    if accum_dtype is None:
        accum_dtype = opt_cfg.moment_dtype

    loss = functools.partial(models.loss_fn, cfg, impl=impl,
                             remat=(par.remat != "none"))

    def single_loss(params, batch):
        return loss(params, batch)

    def train_step(params, opt_state, batch):
        """With par.microbatches > 1 the loader supplies `batch` already
        split: leaves have a leading [n_micro] dim (keeps the sharded
        batch dim intact — no resharding reshape)."""
        n_micro = par.microbatches
        if n_micro > 1:
            micro = batch

            def accum(acc, mb):
                loss, g = jax.value_and_grad(single_loss)(params, mb)
                acc_l, acc_g = acc
                return (acc_l + loss,
                        jax.tree.map(
                            lambda a, b: (a + b.astype(accum_dtype)),
                            acc_g, g)), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (tot_l, tot_g), _ = jax.lax.scan(
                accum, (jnp.zeros((), jnp.float32), zero_g), micro)
            loss_val = tot_l / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, tot_g)
        else:
            loss_val, grads = jax.value_and_grad(single_loss)(params, batch)
        grads = jax.tree.map(lambda g: g.astype(accum_dtype), grads)
        new_params, new_opt, metrics = adamw_update(params, grads,
                                                    opt_state, opt_cfg)
        metrics["loss"] = loss_val
        return new_params, new_opt, metrics

    return train_step


def make_serve_step(cfg: ModelConfig):
    """serve_step(params, cache, tokens [B], pos) -> (next_tokens, cache).
    One decode step with a KV/state cache — what the decode_* dry-run
    cells lower."""

    def serve_step(params, cache, tokens, pos):
        logits, cache = models.decode_step(cfg, params, cache, tokens, pos)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return serve_step


def make_prefill_step(cfg: ModelConfig, impl: str = "auto"):
    """prefill_step(params, batch) -> logits — the prompt forward pass
    (what the prefill_* dry-run cells lower)."""

    def prefill_step(params, batch):
        logits, _ = models.forward(cfg, params, batch, impl=impl)
        return logits[:, -1]

    return prefill_step
