import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (architecture × input-shape)
cell on the production meshes and record memory/cost/collective analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --out results.json

The XLA_FLAGS line above MUST run before any other jax-touching import —
512 placeholder host devices stand in for the 2×16×16 production pod
slice.  Results (per cell: bytes/device, HLO FLOPs, collective bytes by
op) are appended to a JSON file consumed by benchmarks/roofline.py and
EXPERIMENTS.md.
"""
import argparse  # noqa: E402  (XLA_FLAGS must be set before anything else)
import gzip      # noqa: E402
import json      # noqa: E402
import re        # noqa: E402
import sys       # noqa: E402
import time      # noqa: E402
import traceback  # noqa: E402


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the (SPMD-partitioned)
    HLO.  Parses shapes like `bf16[2048,7168]{1,0}` from lines whose op is
    all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute."""
    dtype_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                   "f64": 8, "s8": 1, "u8": 1, "pred": 1, "s64": 8,
                   "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2}
    ops = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
           "collective-permute")
    totals = {op: 0.0 for op in ops}
    counts = {op: 0 for op in ops}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = .*? (all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)"
                     r"(?:-start|-done)?\(", stripped)
        if not m:
            continue
        op = m.group(1)
        if "-done(" in stripped:
            continue  # avoid double counting async pairs
        lhs = stripped.split(" = ", 1)[1]
        out_part = lhs.split("(", 1)[0]
        b = 0.0
        for dt, dims in shape_re.findall(out_part):
            if dt not in dtype_bytes:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            b += n * dtype_bytes[dt]
        totals[op] += b
        counts[op] += 1
    return {"bytes": totals, "counts": counts,
            "total_bytes": sum(totals.values())}


def run_cell(cell, mesh, multi_pod: bool, impl: str = "auto",
             par_override: dict | None = None,
             hlo_dir: str | None = "dryrun_hlo") -> dict:
    from repro.launch.cells import lower_cell
    from repro.launch.mesh import mesh_context
    t0 = time.time()
    with mesh_context(mesh):
        lowered, meta = lower_cell(cell, mesh, impl=impl,
                                   par_override=par_override)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    hlo = compiled.as_text()   # post-SPMD: collectives are visible here
    if hlo_dir:
        os.makedirs(hlo_dir, exist_ok=True)
        tag = cell.name.replace("/", "_") + (
            "_2x16x16" if multi_pod else "_16x16")
        with gzip.open(os.path.join(hlo_dir, tag + ".hlo.gz"), "wt") as f:
            f.write(hlo)
    coll = collective_bytes(hlo)
    from repro.analysis import analyze_hlo
    la = analyze_hlo(hlo)      # loop-aware totals (per device, per step)
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    rec = {
        **meta,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": cost.get("flops", -1.0) if cost else -1.0,
        "bytes_accessed": cost.get("bytes accessed", -1.0) if cost else -1.0,
        "hlo_flops": la.flops,
        "hlo_hbm_bytes": la.hbm_bytes,
        "hlo_collective_bytes": la.collective_bytes,
        "hlo_collective_bytes_bf16eq": la.collective_bytes_bf16eq,
        "hlo_collective_counts": la.collective_counts,
        "collectives": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
            "output_bytes": getattr(mem, "output_size_in_bytes", -1),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
            "generated_code_bytes":
                getattr(mem, "generated_code_size_in_bytes", -1),
        },
    }
    print(f"  memory_analysis: args={rec['memory']['argument_bytes']/1e9:.2f}GB "
          f"temps={rec['memory']['temp_bytes']/1e9:.2f}GB "
          f"(global, /{mesh.devices.size} devices)")
    print(f"  cost_analysis: flops={rec['flops']:.3e} "
          f"bytes={rec['bytes_accessed']:.3e}")
    print(f"  loop-aware: flops={la.flops:.3e} hbm={la.hbm_bytes:.3e} "
          f"coll={sum(la.collective_bytes.values()):.3e} "
          f"{la.collective_counts}")
    return rec


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="only this architecture")
    ap.add_argument("--shape", default=None, help="only this shape")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 (512-chip) mesh instead of 16x16")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--impl", default="auto")
    args = ap.parse_args()

    from repro.launch.cells import cell_skip_reason, enumerate_cells
    from repro.launch.mesh import make_production_mesh

    cells = enumerate_cells(include_skipped=True)
    if args.arch:
        cells = [c for c in cells if c.arch == args.arch]
    if args.shape:
        cells = [c for c in cells if c.shape == args.shape]

    mesh_flags = [args.multi_pod] if not args.both_meshes else [False, True]
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["cell"], r["mesh"]) for r in results if r.get("ok")}

    failures = 0
    for multi_pod in mesh_flags:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mname = "2x16x16" if multi_pod else "16x16"
        for cell in cells:
            if (cell.name, mname) in done:
                print(f"[skip-done] {cell.name} on {mname}")
                continue
            reason = cell_skip_reason(cell)
            if reason:
                print(f"[skip] {cell.name}: {reason}")
                results.append({"cell": cell.name, "mesh": mname,
                                "ok": None, "skip_reason": reason})
                continue
            print(f"[run ] {cell.name} on {mname} ...", flush=True)
            try:
                rec = run_cell(cell, mesh, multi_pod, impl=args.impl)
                results.append(rec)
            except Exception as e:  # noqa: BLE001 — record and continue
                failures += 1
                print(f"  FAILED: {type(e).__name__}: {e}")
                traceback.print_exc(limit=3)
                results.append({"cell": cell.name, "mesh": mname,
                                "ok": False, "error": f"{type(e).__name__}: {e}"})
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    print(f"\n{sum(1 for r in results if r.get('ok'))} ok, "
          f"{failures} failed -> {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
