# NOTE: dryrun is intentionally NOT imported here — it sets XLA_FLAGS at
# import time and must only be imported as the program entry point.
from .steps import make_prefill_step, make_serve_step, make_train_step
__all__ = ["make_train_step", "make_serve_step", "make_prefill_step"]
