"""Serving launcher: batched greedy decoding with a KV/state cache.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \\
        --batch 4 --prompt-len 32 --gen 32

The prompt is replayed through `decode_step` to populate the cache (the
decode-vs-forward equivalence is test-verified), then generation proceeds
greedily.  Requests are batched: all sequences advance in lockstep, which
is the throughput-serving regime the decode_* dry-run cells model.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs import ARCHS, get_config, reduced_config
from .steps import make_serve_step


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma-2b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    max_len = args.prompt_len + args.gen
    print(f"serving {cfg.name}: batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}")

    rng = np.random.default_rng(args.seed)
    params = models.init_params(cfg, jax.random.PRNGKey(args.seed))
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    cache = models.init_cache(cfg, args.batch, max_len)
    step = jax.jit(make_serve_step(cfg))

    # replay prompt to fill the cache
    t0 = time.time()
    for t in range(args.prompt_len):
        nxt, cache = step(params, cache, prompts[:, t], jnp.int32(t))
    prefill_s = time.time() - t0

    # greedy generation
    out = []
    tok = nxt
    t0 = time.time()
    for t in range(args.prompt_len, max_len):
        out.append(tok)
        tok, cache = step(params, cache, tok, jnp.int32(t))
    gen_s = time.time() - t0
    gen = jnp.stack(out, axis=1)
    tput = args.batch * args.gen / gen_s
    print(f"prefill {prefill_s*1e3:.0f}ms, "
          f"decode {gen_s/args.gen*1e3:.1f}ms/tok/batch, "
          f"throughput {tput:.1f} tok/s")
    print("sample generation ids:", np.asarray(gen[0][:16]))


if __name__ == "__main__":
    main()
