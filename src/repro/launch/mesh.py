"""Production mesh construction (+ Algorithm-2 device ordering).

`make_production_mesh` is a FUNCTION (importing this module never touches
jax device state).  Single-pod: 16×16 = 256 chips (data, model).
Multi-pod: 2×16×16 = 512 chips (pod, data, model) — the 'pod' axis is the
DCN boundary and carries only data-parallel gradient all-reduces.

`vertex_cut_device_order` feeds a shard-communication matrix through the
paper's memory-centric mapping (core.planner.mesh_device_order) so that
heavily-communicating model shards sit on ICI-adjacent chips.
"""
from __future__ import annotations

import numpy as np

import jax

__all__ = ["make_production_mesh", "make_mesh_with_order", "mesh_context"]


def mesh_context(mesh):
    """Context manager installing `mesh` as the ambient mesh.

    Version-compat shim: the API moved from entering the `Mesh` object
    itself, through `jax.sharding.use_mesh`, to `jax.set_mesh`.  All
    three establish the same mesh context for `jax.jit` lowering, so we
    take whichever the installed JAX provides (newest first).
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh  # Mesh is itself a context manager on older JAX


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_with_order(shard_comm: np.ndarray | None = None, *,
                         multi_pod: bool = False):
    """Mesh whose device order is chosen by the paper's Algorithm 2.

    `shard_comm[i,j]`: traffic between logical 'model' shards i and j
    (e.g. collective bytes from a dry-run).  Shards are mapped to mesh
    columns so communicating shards are ICI neighbours; identity order
    when no matrix is given."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    devices = np.array(jax.devices())
    n = int(np.prod(shape))
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    devices = devices[:n]
    if shard_comm is not None:
        from repro.core.planner import mesh_device_order
        m = shape[-1]
        order = mesh_device_order(shard_comm[:m, :m], 1, m)
        # permute the model-axis columns of every (pod, data) row
        grid = devices.reshape(-1, m)
        inv = np.argsort(order)
        grid = grid[:, inv]
        devices = grid.reshape(-1)
    from jax.sharding import Mesh
    return Mesh(devices.reshape(shape), axes)
