"""(architecture × input-shape) cells: input specs, state specs, parallel
plans, and the lowering entry used by the dry-run and the benchmarks.

Everything here is ShapeDtypeStruct-based — no device allocation — per
the assignment: full configs are exercised only via lower()/compile().
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import models
from repro.configs import ARCHS, SHAPES
from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.parallel.sharding import (batch_specs, cache_specs,
                                     param_specs, sanitize_specs)
from .steps import make_prefill_step, make_serve_step, make_train_step

__all__ = ["Cell", "enumerate_cells", "cell_skip_reason", "lower_cell",
           "parallel_plan"]

PARAM_DTYPE = jnp.bfloat16


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str

    @property
    def cfg(self) -> ModelConfig:
        return ARCHS[self.arch]

    @property
    def shape_cfg(self) -> ShapeConfig:
        return SHAPES[self.shape]

    @property
    def name(self) -> str:
        return f"{self.arch}/{self.shape}"


def cell_skip_reason(cell: Cell) -> str | None:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    cfg, sc = cell.cfg, cell.shape_cfg
    if sc.name == "long_500k" and not cfg.subquadratic:
        return ("full-attention arch: O(S^2) attention at 524k context — "
                "skipped per assignment (DESIGN.md §6)")
    return None


def enumerate_cells(include_skipped: bool = False) -> list[Cell]:
    cells = [Cell(a, s) for a in ARCHS for s in SHAPES]
    if include_skipped:
        return cells
    return [c for c in cells if cell_skip_reason(c) is None]


# ---------------------------------------------------------------------- #
# per-cell parallel plan (baseline; §Perf iterates on these)
# ---------------------------------------------------------------------- #
TOKENS_PER_SHARD_TARGET = 8_192   # activation working-set control


def parallel_plan(cell: Cell, override: dict | None = None,
                  data_shards: int = 16) -> tuple[ParallelConfig,
                                                  AdamWConfig]:
    cfg, sc = cell.cfg, cell.shape_cfg
    kw: dict[str, Any] = dict(fsdp=True, tp=True, ep=cfg.is_moe)
    opt_kw: dict[str, Any] = {}
    if sc.kind == "train":
        # microbatch so tokens/device stays bounded; remat the stage scan
        tokens_per_shard = sc.global_batch * sc.seq_len // data_shards
        micro = max(1, min(sc.global_batch // data_shards,
                           tokens_per_shard // TOKENS_PER_SHARD_TARGET))
        kw.update(microbatches=int(micro), remat="block")
        if cfg.param_count() > 100e9:
            opt_kw.update(moment_dtype=jnp.bfloat16)
    if override:
        kw.update(override)
    return ParallelConfig(**kw), AdamWConfig(**opt_kw)


# ---------------------------------------------------------------------- #
# input specs (ShapeDtypeStruct stand-ins, shard-ready)
# ---------------------------------------------------------------------- #
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg: ModelConfig, B: int, S: int,
                 n_micro: int = 1) -> dict:
    """Token batch + modality-frontend stubs (precomputed embeddings).
    With n_micro > 1 the GLOBAL batch B is split: leaves are
    [n_micro, B/n_micro, ...]."""
    lead = (n_micro,) if n_micro > 1 else ()
    if n_micro > 1:
        assert B % n_micro == 0, (B, n_micro)
        B = B // n_micro
    batch = {"tokens": _sds(lead + (B, S), jnp.int32)}
    if cfg.frontend == "vision":
        n_patch = max(min(256, S // 4), 4)
        batch["patch_embeds"] = _sds(lead + (B, n_patch, cfg.d_model),
                                     PARAM_DTYPE)
        batch["mrope_pos"] = _sds(lead + (3, B, S), jnp.int32)
    if cfg.n_encoder_layers:
        batch["frame_embeds"] = _sds(lead + (B, S, cfg.d_model),
                                     PARAM_DTYPE)
    return batch


def input_specs(cell: Cell) -> dict:
    """All abstract inputs for the cell's step function."""
    cfg, sc = cell.cfg, cell.shape_cfg
    par, opt_cfg = parallel_plan(cell)
    params = jax.eval_shape(
        functools.partial(models.init_params, cfg, dtype=PARAM_DTYPE),
        jax.random.PRNGKey(0))
    out = {"params": params, "cfg": cfg, "par": par, "opt_cfg": opt_cfg}
    if sc.kind == "train":
        out["opt_state"] = jax.eval_shape(
            functools.partial(adamw_init, cfg=opt_cfg), params)
        out["batch"] = batch_struct(cfg, sc.global_batch, sc.seq_len,
                                    n_micro=par.microbatches)
    elif sc.kind == "prefill":
        out["batch"] = batch_struct(cfg, sc.global_batch, sc.seq_len)
    else:  # decode
        out["cache"] = jax.eval_shape(
            functools.partial(models.init_cache, cfg, sc.global_batch,
                              sc.seq_len, dtype=PARAM_DTYPE))
        out["tokens"] = _sds((sc.global_batch,), jnp.int32)
        out["pos"] = _sds((), jnp.int32)
    return out


# ---------------------------------------------------------------------- #
# lowering
# ---------------------------------------------------------------------- #
def _shard(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(cell: Cell, mesh, impl: str = "auto",
               par_override: dict | None = None):
    """jit(...).lower(...) for the cell's step on `mesh`.

    Returns (lowered, meta) where meta records the step kind and plan."""
    cfg, sc = cell.cfg, cell.shape_cfg
    par, opt_cfg = parallel_plan(cell, par_override)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    spec = input_specs(cell)
    params = spec["params"]
    p_specs = sanitize_specs(param_specs(params, cfg, par), params, mesh)
    p_sh = _shard(mesh, p_specs)

    if sc.kind == "train":
        step = make_train_step(cfg, opt_cfg, par, impl=impl)
        opt_state = spec["opt_state"]
        o_specs = {"m": p_specs, "v": p_specs, "step": P()}
        o_sh = _shard(mesh, o_specs)
        b_specs = sanitize_specs(
            batch_specs(cfg, spec["batch"], data_axes,
                        micro_split=par.microbatches > 1),
            spec["batch"], mesh)
        b_sh = _shard(mesh, b_specs)
        lowered = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1),
        ).lower(params, opt_state, spec["batch"])
    elif sc.kind == "prefill":
        step = make_prefill_step(cfg, impl=impl)
        b_specs = sanitize_specs(batch_specs(cfg, spec["batch"],
                                             data_axes),
                                 spec["batch"], mesh)
        b_sh = _shard(mesh, b_specs)
        lowered = jax.jit(
            step, in_shardings=(p_sh, b_sh),
        ).lower(params, spec["batch"])
    else:
        step = make_serve_step(cfg)
        c_specs = sanitize_specs(
            cache_specs(spec["cache"], data_axes), spec["cache"], mesh)
        c_sh = _shard(mesh, c_specs)
        t_specs = sanitize_specs(P(data_axes), spec["tokens"], mesh)
        t_sh = NamedSharding(mesh, t_specs)
        lowered = jax.jit(
            step,
            in_shardings=(p_sh, c_sh, t_sh, NamedSharding(mesh, P())),
            out_shardings=(t_sh, c_sh),
            donate_argnums=(1,),
        ).lower(params, spec["cache"], spec["tokens"], spec["pos"])
    meta = {"cell": cell.name, "kind": sc.kind,
            "parallel": dataclasses.asdict(par),
            "params_b": cell.cfg.param_count()}
    return lowered, meta
