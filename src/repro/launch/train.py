"""Training launcher.

CPU-runnable end-to-end driver (reduced configs) and the production
entry (full configs lower through the same path the dry-run exercises):

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \\
        --reduced --steps 100 --batch 8 --seq 128

Features wired in: deterministic sharded data pipeline, AdamW + cosine
schedule + clipping, gradient accumulation, checkpoint/restart (resume
from the latest step automatically), straggler detection, and the
supervisor loop that restores from the last checkpoint on a step failure.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS, get_config, reduced_config
from repro.configs.base import ParallelConfig
from repro.data import DataConfig, SyntheticLM
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import StragglerDetector
from .steps import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-360m", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=None,
                    help="override width (e.g. ~100M runs)")
    ap.add_argument("--n-layers", type=int, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        over = {}
        if args.d_model:
            over.update(d_model=args.d_model,
                        head_dim=max(args.d_model // 8, 16),
                        n_heads=8,
                        n_kv_heads=4 if cfg.n_kv_heads > 1 else 1,
                        d_ff=args.d_model * 4)
        if args.n_layers:
            over.update(n_layers=args.n_layers)
        cfg = reduced_config(cfg, vocab_size=4096, **over)
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20,
                          total_steps=args.steps)
    par = ParallelConfig(fsdp=False, tp=False,
                         microbatches=args.microbatches,
                         remat="none")
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  global_batch=args.batch,
                                  seed=args.seed))

    params = models.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = adamw_init(params, opt_cfg)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, par))

    ckpt = None
    start = 0
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir, keep=3)
        latest = ckpt.latest_step()
        if latest is not None:
            (params, opt_state), meta = ckpt.restore((params, opt_state))
            start = meta["step"]
            print(f"resumed from step {start}")

    straggler = StragglerDetector()
    losses = []
    for step in range(start, args.steps):
        batch = jax.tree.map(
            jnp.asarray, data.batch(step, n_micro=args.microbatches))
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        dt = time.time() - t0
        flagged = straggler.observe(step, dt)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms"
                  + (" [straggler]" if flagged else ""))
        if ckpt and (step + 1) % args.save_every == 0:
            ckpt.save(step + 1, (params, opt_state), blocking=False)
    if ckpt:
        ckpt.save(args.steps, (params, opt_state))
        ckpt.wait()
    first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
    last = np.mean(losses[-5:])
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
