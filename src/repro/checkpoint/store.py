"""Checkpointing: per-shard npz + JSON metadata, async save thread,
keep-last-k retention, atomic rename, resume with re-sharding.

Layout:  <dir>/step_<n>/shard_<i>.npz + meta.json
A checkpoint directory is only considered complete once `COMMIT` exists
AND the directory has been renamed from its `.tmp` staging name — a
crash mid-save never corrupts the restore path (fault tolerance).
Stale `*.tmp` staging dirs (even ones containing `COMMIT`, from a crash
between the commit mark and the rename) are ignored by `all_steps()`
and garbage-collected on startup.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import numpy as np
import jax

__all__ = ["CheckpointManager"]

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(getattr(k, "key", str(getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_like(template, flat: dict):
    leaves_with_path = jax.tree_util.tree_flatten_with_path(template)
    paths, treedef = (
        [p for p, _ in leaves_with_path[0]], leaves_with_path[1])
    leaves = []
    for path, tmpl in leaves_with_path[0]:
        key = "/".join(getattr(k, "key", str(getattr(k, "idx", k)))
                       for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} "
                f"vs expected {tmpl.shape}")
        leaves.append(arr.astype(tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Save/restore train state with retention + async write."""

    def __init__(self, directory: str, keep: int = 3,
                 shard_id: int = 0, num_shards: int = 1):
        self.dir = directory
        self.keep = keep
        self.shard_id = shard_id
        self.num_shards = num_shards
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._async_exc: BaseException | None = None
        self._gc_stale_tmp()

    def _gc_stale_tmp(self) -> None:
        """Remove `.tmp` staging dirs left by a crash mid-save."""
        for name in os.listdir(self.dir):
            if name.endswith(".tmp") and _STEP_RE.match(name[:-4]):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)

    # ------------------------------------------------------------------ #
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m and os.path.exists(
                    os.path.join(self.dir, name, "COMMIT")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------ #
    def _write(self, step: int, state: dict, meta: dict) -> None:
        d = self._step_dir(step)
        tmp = d + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        flat = _flatten(state)
        np.savez_compressed(
            os.path.join(tmp, f"shard_{self.shard_id}.npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({**meta, "step": step,
                       "num_shards": self.num_shards}, f)
        open(os.path.join(tmp, "COMMIT"), "w").close()
        if os.path.exists(d):
            shutil.rmtree(d)
        os.rename(tmp, d)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def save(self, step: int, state: Any, meta: dict | None = None,
             blocking: bool = True) -> None:
        state = jax.tree.map(np.asarray, state)  # device -> host copy
        if blocking:
            self._write(step, state, meta or {})
        else:
            self.wait()

            def _run():
                try:
                    self._write(step, state, meta or {})
                except BaseException as e:  # surfaced by wait()
                    self._async_exc = e

            self._thread = threading.Thread(target=_run)
            self._thread.start()

    def wait(self) -> None:
        """Join the async writer; re-raise anything it raised."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        exc, self._async_exc = self._async_exc, None
        if exc is not None:
            raise exc

    # ------------------------------------------------------------------ #
    def restore(self, template: Any, step: int | None = None
                ) -> tuple[Any, dict]:
        """Restore into the structure/dtypes of `template`."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self._step_dir(step)
        z = np.load(os.path.join(d, f"shard_{self.shard_id}.npz"),
                    allow_pickle=False)
        flat = {k: z[k] for k in z.files}
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        return _unflatten_like(template, flat), meta

    def restore_flat(self, step: int | None = None
                     ) -> tuple[dict, dict]:
        """Restore the flat {leaf-key: array} dict without a template.

        For callers (e.g. the plan cache) whose state is already a flat
        dict of arrays and who need no dtype/shape coercion."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self._step_dir(step)
        z = np.load(os.path.join(d, f"shard_{self.shard_id}.npz"),
                    allow_pickle=False)
        flat = {k: z[k] for k in z.files}
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        return flat, meta
