"""repro.serve — the long-lived partition-plan service.

The batch pipeline (`repro.core.planner`) plans one graph per call; this
package turns it into a serving system for recurring workloads:

  * `PlanService` — batched request API over a **content-addressed plan
    cache**: requests are fingerprinted over (graph/trace content,
    planning knobs), hits return the persisted (partition, mapping,
    cost) bundle from memory or disk (`checkpoint.store`), misses plan
    cold exactly once.
  * `IncrementalPlanner` — **incremental repartitioning**: new trace
    windows stream into a resumable `ShardCutState` in round quanta and
    only dirty replica-CSR rows are re-finalized; the warm result is
    bit-identical to a cold cut over the concatenated trace.
  * `python -m repro.serve` — CLI front end (plan / batch / cache).

See docs/architecture.md §plan service for the fingerprint scheme, the
cache layout, and the incremental bit-identity contract.
"""

from .cache import PlanBundle, PlanCache
from .fingerprint import plan_fingerprint
from .incremental import (DEFAULT_QUANTUM, INCREMENTAL_METHODS,
                          IncrementalPlanner)
from .service import (DEFAULT_CACHE_DIR, PlanRequest, PlanResponse,
                      PlanService)

__all__ = [
    "DEFAULT_CACHE_DIR",
    "DEFAULT_QUANTUM",
    "INCREMENTAL_METHODS",
    "IncrementalPlanner",
    "PlanBundle",
    "PlanCache",
    "PlanRequest",
    "PlanResponse",
    "PlanService",
    "plan_fingerprint",
]
