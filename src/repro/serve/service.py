"""The long-lived partition-plan service.

`PlanService` answers plan requests for recurring graphs/traces from a
two-tier content-addressed cache (`PlanCache`): requests fingerprint
their *content* plus result-relevant knobs (`serve.fingerprint`), hits
return the persisted (partition, mapping, cost) bundle without parsing
or cutting anything, misses run the full planning pipeline once and
persist the bundle through `checkpoint.store` — so restarts are warm
and repeat traffic (the production regime: millions of users, few
distinct programs) is served at dictionary-lookup cost.

`plan_many` batches: requests are fingerprinted up front and duplicate
fingerprints inside one batch plan once.

Every phase is instrumented through `repro.obs`: cache hit/miss/store
counters, fingerprint/load/plan spans — `REPRO_PROFILE=out.json` (or
`obs.scoped()`) captures a serving profile.

Beyond the profiling-gated spans, the service owns an **always-on**
:class:`~repro.obs.metrics.MetricsRegistry` (`PlanService.registry`):
per-tier request counters, hot-map eviction counts, and per-tier plan
latency histograms, summarised live by :meth:`PlanService.metrics`
(hit rate, plans/s, latency p50/p99) and surfaced by
``python -m repro.serve metrics``.
"""
from __future__ import annotations

import dataclasses
import os
from time import perf_counter

from .. import obs
from ..obs.metrics import MetricsRegistry
from ..core.mapping import Machine
from ..core.simulator import coerce_graph
from ..core.vertex_cut import vertex_cut
from .cache import PlanBundle, PlanCache
from .fingerprint import plan_fingerprint
from .incremental import finish_plan

__all__ = ["PlanRequest", "PlanResponse", "PlanService",
           "DEFAULT_CACHE_DIR"]

DEFAULT_CACHE_DIR = ".cache/plans"


@dataclasses.dataclass(frozen=True)
class PlanRequest:
    """One planning request: a graph source plus the planning knobs.

    `source` is a path (NDJSON trace / `.rtb` / `.npz`) or an in-memory
    `IRGraph`.  Knobs beyond (p, method, lam, seed) that change the
    result — e.g. a non-default `edge_order` — go through the dedicated
    fields so the fingerprint stays canonical.
    """

    source: object
    p: int
    method: str = "wb_libra"
    lam: float = 1.0
    seed: int = 0
    edge_order: str = "auto"
    weight_model: str = "bytes"


@dataclasses.dataclass
class PlanResponse:
    fingerprint: str
    cache: str                      # "cold" | "memory" | "disk"
    bundle: PlanBundle

    def summary(self) -> dict:
        return {"fingerprint": self.fingerprint, "cache": self.cache,
                **self.bundle.summary()}


class PlanService:
    """Content-addressed plan cache over the full planning pipeline."""

    def __init__(self, cache_dir: str = DEFAULT_CACHE_DIR,
                 backend: str = "fast", machine: "Machine | None" = None,
                 use_stat_memo: bool = True,
                 max_hot_entries: "int | None" = None,
                 max_hot_bytes: "int | None" = None):
        self.registry = MetricsRegistry()   # always on, never profiling-gated
        self.cache = PlanCache(cache_dir, max_entries=max_hot_entries,
                               max_bytes=max_hot_bytes,
                               metrics=self.registry)
        self.backend = backend
        self.machine = machine
        self.use_stat_memo = use_stat_memo
        self.hits = 0
        self.misses = 0
        self._t0 = perf_counter()

    def _record(self, tier: str, us: float) -> None:
        """Per-tier request accounting into the live registry."""
        self.registry.counter(f"serve.plans.{tier}")
        self.registry.observe("serve.plan_latency_us", us)
        self.registry.observe(f"serve.plan_latency_us.{tier}", us)

    # ------------------------------------------------------------------ #
    def _fingerprint(self, req: PlanRequest) -> str:
        with obs.span("serve.fingerprint", cat="op"):
            return plan_fingerprint(
                req.source, req.p, req.method, req.lam, seed=req.seed,
                edge_order=req.edge_order, weight_model=req.weight_model,
                use_stat_memo=self.use_stat_memo)

    def _plan_cold(self, req: PlanRequest) -> PlanBundle:
        with obs.span("serve.plan_cold", cat="section", p=req.p,
                      method=req.method):
            with obs.span("plan.cut", cat="section", backend=self.backend,
                          p=req.p):
                if isinstance(req.source, (str, os.PathLike)):
                    from ..trace import load_graph
                    g = load_graph(req.source,
                                   weight_model=req.weight_model)
                else:
                    g = coerce_graph(req.source)
                cut = vertex_cut(g, req.p, method=req.method, lam=req.lam,
                                 seed=req.seed, edge_order=req.edge_order,
                                 backend=self.backend)
            mapping, rep = finish_plan(g, cut, self.machine, self.backend)
        return PlanBundle(
            assignment=cut.assignment, loads=cut.loads,
            edge_counts=cut.edge_counts,
            replica_indptr=cut.replica_indptr,
            replica_flat=cut.replica_flat,
            core_of=mapping.core_of, core_times=rep.core_times,
            exec_time=rep.exec_time, comm_bytes=rep.data_comm_bytes,
            graph_name=g.name, n_vertices=g.n,
            total_weight=g.total_weight, p=req.p, method=req.method,
            lam=req.lam)

    # ------------------------------------------------------------------ #
    def plan(self, req: PlanRequest) -> PlanResponse:
        """Serve one request: cache hit or cold plan + persist."""
        t0 = perf_counter()
        fp = self._fingerprint(req)
        in_memory = fp in self.cache._hot
        bundle = self.cache.get(fp)
        if bundle is not None:
            self.hits += 1
            tier = "memory" if in_memory else "disk"
            self._record(tier, (perf_counter() - t0) * 1e6)
            return PlanResponse(fingerprint=fp, cache=tier, bundle=bundle)
        self.misses += 1
        obs.counter("serve.cache_miss", 1)
        bundle = self._plan_cold(req)
        self.cache.put(fp, bundle)
        self._record("cold", (perf_counter() - t0) * 1e6)
        return PlanResponse(fingerprint=fp, cache="cold", bundle=bundle)

    def plan_many(self, requests) -> list:
        """Batched serving; duplicate fingerprints plan once."""
        requests = list(requests)
        with obs.span("serve.plan_many", cat="section",
                      requests=len(requests)):
            responses: list = [None] * len(requests)
            first_of: dict = {}
            for i, req in enumerate(requests):
                t0 = perf_counter()
                fp = self._fingerprint(req)
                prior = first_of.get(fp)
                if prior is not None:
                    # in-batch duplicate: by the time we got here the
                    # first occurrence has populated the hot map
                    self.hits += 1
                    self._record("memory", (perf_counter() - t0) * 1e6)
                    responses[i] = PlanResponse(
                        fingerprint=fp, cache="memory",
                        bundle=responses[prior].bundle)
                    continue
                first_of[fp] = i
                in_memory = fp in self.cache._hot
                bundle = self.cache.get(fp)
                if bundle is not None:
                    self.hits += 1
                    tier = "memory" if in_memory else "disk"
                    self._record(tier, (perf_counter() - t0) * 1e6)
                    responses[i] = PlanResponse(fingerprint=fp, cache=tier,
                                                bundle=bundle)
                    continue
                self.misses += 1
                obs.counter("serve.cache_miss", 1)
                bundle = self._plan_cold(requests[i])
                self.cache.put(fp, bundle)
                self._record("cold", (perf_counter() - t0) * 1e6)
                responses[i] = PlanResponse(fingerprint=fp, cache="cold",
                                            bundle=bundle)
        return responses

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "hot_entries": len(self.cache._hot),
                "hot_bytes": self.cache.hot_bytes,
                "evictions": self.cache.evictions,
                "disk_entries": len(self.cache.fingerprints()),
                "cache_dir": self.cache.root}

    def metrics(self) -> dict:
        """Live serving metrics from the always-on registry: request
        counts by tier, cache hit rate, sustained plans/s since service
        start, and plan-latency p50/p99 (overall and per tier)."""
        snap = self.registry.snapshot()
        total = self.hits + self.misses
        elapsed = max(perf_counter() - self._t0, 1e-9)
        lat = snap["histograms"].get("serve.plan_latency_us")
        tiers = {}
        for tier in ("memory", "disk", "cold"):
            h = snap["histograms"].get(f"serve.plan_latency_us.{tier}")
            if h is not None:
                tiers[tier] = {"count": h["count"], "p50_us": h["p50"],
                               "p99_us": h["p99"]}
        return {
            "plans": total,
            "plans_per_s": round(total / elapsed, 3),
            "uptime_s": round(elapsed, 3),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": round(self.hits / total, 4) if total else 0.0,
            "evictions": self.cache.evictions,
            "hot_entries": len(self.cache._hot),
            "hot_bytes": self.cache.hot_bytes,
            "plan_latency_p50_us": lat["p50"] if lat else 0.0,
            "plan_latency_p99_us": lat["p99"] if lat else 0.0,
            "tiers": tiers,
        }
