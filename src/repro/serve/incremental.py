"""Incremental repartitioning: stream new trace windows into a live cut.

`IncrementalPlanner` keeps a long-lived partition over a growing trace.
New windows parse into a single `TraceSession` (one id space, rolling
def-tables) and their edges stream into a durable, resumable
`ShardCutState` in **round quanta** — the same prefix-snapshot
semantics as the pipelined dist engine: round r covers global edge
offsets [r·q, (r+1)·q), and the Libra degree swap plus the λ load
bound snapshot the degrees / Σw of the edges streamed so far at the
round's end offset.  Edges past the last full quantum wait in a
backlog; `plan()` flushes them into a *clone* of the durable state, so
the committed state only ever advances by whole quanta.

**Bit-identity contract.**  Because rounds sit at fixed global offsets
and every snapshot is a pure function of the edge prefix, the output
is independent of how the trace was split into windows: appending a
new window and re-planning is bit-identical to planning a fresh
session fed the whole concatenated trace (asserted in
tests/test_serve.py and gated in the `plan_service` bench).  When the
whole trace fits in one quantum the output is additionally
bit-identical to `vertex_cut(g, ..., edge_order="trace",
backend="fast")` — a single uninterrupted stream.

**Dirty-row finalize.**  Replica sets live as bitmask limb rows inside
the cut state; a cold finalize would decode all O(n·limbs) words
(`masks_to_replica_csr`).  The planner instead keeps the decoded CSR
from the previous plan and re-decodes only the rows whose masks can
have changed — vertices touched by edges streamed since — then splices
them in with a flat ragged copy.  Decode cost tracks the appended
window, not the full trace.
"""
from __future__ import annotations

import numpy as np

from .. import obs
from ..core._arrayops import masks_to_replica_csr
from ..core.graph import IRGraph
from ..core.mapping import (Machine, cluster_interaction_graphs,
                            memory_centric_mapping, resolve_mapping_backend)
from ..core.simulator import simulate, vertex_bytes_model
from ..core.vertex_cut import ShardCutState, VertexCutResult
from ..trace.ingest import TraceSession

__all__ = ["IncrementalPlanner", "INCREMENTAL_METHODS", "DEFAULT_QUANTUM",
           "finish_plan"]

# Libra-rule methods only: the PG case-2 rule consults remaining degree,
# which is unknowable before the stream ends — same restriction as the
# pipelined dist dataflow.
INCREMENTAL_METHODS = ("libra", "w_libra", "wb_libra")
DEFAULT_QUANTUM = 1 << 16


def finish_plan(g: IRGraph, cut: VertexCutResult,
                machine: "Machine | None" = None, backend: str = "fast"):
    """Map + simulate a finished cut (the tail of `plan_graph`'s
    pipeline, returning the mapping and report the plan bundle needs)."""
    map_backend = resolve_mapping_backend(backend)
    p = cut.p
    with obs.span("plan.map", cat="section", backend=map_backend):
        comm, shared = cluster_interaction_graphs(
            cut, p, vertex_bytes_model(g), backend=map_backend)
        mapping = memory_centric_mapping(
            comm, shared, machine or Machine.for_clusters(p),
            backend=map_backend)
    with obs.span("plan.simulate", cat="section", backend=map_backend):
        rep = simulate(g, cut, mapping, backend=map_backend)
    return mapping, rep


class _Backlog:
    """FIFO of pending (src, dst, stream-weight) edge arrays."""

    def __init__(self):
        self._parts: list = []
        self.size = 0

    def push(self, src, dst, wl) -> None:
        if len(src):
            self._parts.append((src, dst, wl))
            self.size += len(src)

    def pop(self, k: int):
        """Destructively take exactly min(k, size) leading edges."""
        k = min(k, self.size)
        taken, got = [], 0
        while got < k:
            src, dst, wl = self._parts[0]
            need = k - got
            if len(src) <= need:
                taken.append(self._parts.pop(0))
                got += len(src)
            else:
                taken.append((src[:need], dst[:need], wl[:need]))
                self._parts[0] = (src[need:], dst[need:], wl[need:])
                got += need
        self.size -= got
        if len(taken) == 1:
            return taken[0]
        return tuple(np.concatenate([t[i] for t in taken])
                     for i in range(3))

    def snapshot(self):
        """The pending edges, without consuming them."""
        if not self._parts:
            return (np.zeros(0, np.int32), np.zeros(0, np.int32),
                    np.zeros(0, np.float64))
        if len(self._parts) == 1:
            return self._parts[0]
        return tuple(np.concatenate([t[i] for t in self._parts])
                     for i in range(3))


def _ragged_indices(starts: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """Flat gather indices for rows (starts[i] .. starts[i]+lens[i])."""
    total = int(lens.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    offs = starts - np.concatenate(([0], np.cumsum(lens)[:-1]))
    return np.repeat(offs, lens) + np.arange(total, dtype=np.int64)


def _splice_rows(indptr: np.ndarray, flat: np.ndarray, d: np.ndarray,
                 ip_d: np.ndarray, flat_d: np.ndarray
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Replace rows `d` of CSR (indptr, flat) with (ip_d, flat_d)."""
    n = len(indptr) - 1
    old_counts = np.diff(indptr)
    counts = old_counts.copy()
    counts[d] = np.diff(ip_d)
    new_indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=new_indptr[1:])
    new_flat = np.empty(int(new_indptr[-1]), dtype=np.int32)
    clean = np.ones(n, dtype=bool)
    clean[d] = False
    new_flat[_ragged_indices(new_indptr[:-1][clean], old_counts[clean])] = \
        flat[_ragged_indices(indptr[:-1][clean], old_counts[clean])]
    new_flat[_ragged_indices(new_indptr[:-1][d], counts[d])] = flat_d
    return new_indptr, new_flat


class IncrementalPlanner:
    """Long-lived planner over a growing trace (see module docstring)."""

    def __init__(self, p: int, method: str = "wb_libra", lam: float = 1.0,
                 quantum: int = DEFAULT_QUANTUM, backend: str = "fast",
                 weight_model: str = "bytes", name: str = "session"):
        if method not in INCREMENTAL_METHODS:
            raise ValueError(
                f"incremental repartitioning supports the Libra-rule "
                f"trace-order methods {INCREMENTAL_METHODS}, not {method!r} "
                f"(the PG case rule needs remaining degrees, which only a "
                f"finished stream knows)")
        if p < 1:
            raise ValueError("p must be >= 1")
        if lam < 1.0:
            raise ValueError("lambda must be >= 1 (paper Eq. 3)")
        if quantum < 1:
            raise ValueError("quantum must be >= 1")
        self.p = p
        self.method = method
        self.lam = lam
        self.quantum = int(quantum)
        self.backend = backend
        self.name = name
        self.weighted = method in ("w_libra", "wb_libra")
        self.balanced = method == "wb_libra"

        self.session = TraceSession(weight_model=weight_model)
        self.state = ShardCutState.create(0, p, np.zeros(0, np.int64),
                                          float("inf"), True, backend)
        self._backlog = _Backlog()
        self._deg = np.zeros(0, dtype=np.int64)   # prefix degrees, committed
        self._wsum = 0.0                          # prefix Σ stream-weight
        self._outs: list = []                     # committed round outputs
        self.committed_edges = 0
        self.rounds = 0
        # dirty-row finalize state
        self._csr: "tuple | None" = None          # durable CSR cache
        self._dirty_parts: list = []              # touched since last decode

    # ------------------------------------------------------------------ #
    def append(self, source) -> int:
        """Parse one trace window and stream every full quantum of its
        edges into the durable state.  Returns the edges added."""
        with obs.span("serve.append", cat="section"):
            src, dst, w = self.session.feed(source)
            wl = (np.ascontiguousarray(w, dtype=np.float64)
                  if self.weighted else np.ones(len(src)))
            if self.weighted and len(wl) and float(wl.min()) < 0:
                raise ValueError(
                    "edge weights must be >= 0 for the greedy cuts")
            self._backlog.push(src, dst, wl)
            while self._backlog.size >= self.quantum:
                self._commit_round(*self._backlog.pop(self.quantum))
        return len(src)

    def _grow_deg(self, deg: np.ndarray, n: int) -> np.ndarray:
        if len(deg) >= n:
            return deg
        grown = np.zeros(n, dtype=np.int64)
        grown[:len(deg)] = deg
        return grown

    def _prep_round(self, deg: np.ndarray, wsum: float, src_r, dst_r, wl_r):
        """Advance a (deg, wsum) prefix snapshot over one edge chunk and
        derive the chunk's swapped endpoints and λ bound."""
        deg = self._grow_deg(deg, self.session.n)
        deg += np.bincount(src_r, minlength=len(deg))
        deg += np.bincount(dst_r, minlength=len(deg))
        wsum += float(wl_r.sum())
        bound = self.lam * wsum / self.p if self.balanced else float("inf")
        swap = deg[src_r] > deg[dst_r]
        su = np.ascontiguousarray(np.where(swap, dst_r, src_r),
                                  dtype=np.int32)
        sv = np.ascontiguousarray(np.where(swap, src_r, dst_r),
                                  dtype=np.int32)
        return deg, wsum, bound, su, sv

    def _commit_round(self, src_r, dst_r, wl_r) -> None:
        self._deg, self._wsum, bound, su, sv = self._prep_round(
            self._deg, self._wsum, src_r, dst_r, wl_r)
        self.state.grow(self.session.n)
        self.state.bound = bound
        out = np.empty(len(su), dtype=np.int32)
        self.state.stream_chunk(su, sv, wl_r, out)
        self._outs.append(out)
        self._dirty_parts.append(np.concatenate((src_r, dst_r)))
        self.committed_edges += len(su)
        self.rounds += 1
        obs.counter("serve.incremental_rounds", 1)

    # ------------------------------------------------------------------ #
    def _durable_csr(self, n: int) -> tuple[np.ndarray, np.ndarray]:
        """CSR over the durable masks, decoding only dirty rows."""
        limbs = self.state.limbs
        if self._csr is None:
            indptr, flat = masks_to_replica_csr(
                self.state.masks, n, limbs, self.p)
            obs.counter("serve.finalize_rows_decoded", n)
        else:
            indptr, flat = self._csr
            if len(indptr) - 1 < n:        # new vertices: empty rows
                grown = np.full(n + 1, indptr[-1], dtype=np.int64)
                grown[:len(indptr)] = indptr
                indptr = grown
            if self._dirty_parts:
                d = np.unique(np.concatenate(self._dirty_parts)
                              .astype(np.int64))
                rows = self.state.masks[:len(self.state.rem) * limbs] \
                    .reshape(-1, limbs)
                ip_d, flat_d = masks_to_replica_csr(
                    np.ascontiguousarray(rows[d]).ravel(), len(d), limbs,
                    self.p)
                indptr, flat = _splice_rows(indptr, flat, d, ip_d, flat_d)
                obs.counter("serve.finalize_rows_decoded", len(d))
        self._csr = (indptr, flat)
        self._dirty_parts = []
        return indptr, flat

    def plan(self, machine: "Machine | None" = None):
        """Partition + map + simulate the full trace streamed so far.

        Returns (graph, cut, mapping, report).  Pending backlog edges
        are flushed into a clone of the durable state, so calling
        `plan()` never perturbs subsequent appends.
        """
        with obs.span("serve.plan_incremental", cat="section",
                      edges=self.committed_edges + self._backlog.size):
            g = self.session.graph(self.name)
            src_t, dst_t, wl_t = self._backlog.snapshot()
            outs = self._outs
            indptr, flat = self._durable_csr(g.n)
            if len(src_t):
                st = self.state.clone()
                _deg, _ws, bound, su, sv = self._prep_round(
                    self._deg.copy(), self._wsum, src_t, dst_t, wl_t)
                st.grow(g.n)
                st.bound = bound
                tail_out = np.empty(len(su), dtype=np.int32)
                st.stream_chunk(su, sv, wl_t, tail_out)
                outs = outs + [tail_out]
                t = np.unique(np.concatenate((src_t, dst_t))
                              .astype(np.int64))
                rows = st.masks[:len(st.rem) * st.limbs] \
                    .reshape(-1, st.limbs)
                ip_t, flat_t = masks_to_replica_csr(
                    np.ascontiguousarray(rows[t]).ravel(), len(t),
                    st.limbs, self.p)
                indptr, flat = _splice_rows(indptr, flat, t, ip_t, flat_t)
            assignment = (np.concatenate(outs) if outs
                          else np.zeros(0, dtype=np.int32))
            # full-stream bincounts: float-bit-identical to a cold
            # _finalize over the concatenated trace
            loads = np.bincount(assignment, weights=g.w,
                                minlength=self.p).astype(np.float64)
            counts = np.bincount(assignment,
                                 minlength=self.p).astype(np.int64)
            cut = VertexCutResult(
                graph_name=g.name, method=self.method, p=self.p,
                lam=self.lam, assignment=assignment, loads=loads,
                edge_counts=counts, n_vertices=g.n,
                total_weight=g.total_weight, replica_indptr=indptr,
                replica_flat=flat)
        mapping, rep = finish_plan(g, cut, machine, self.backend)
        return g, cut, mapping, rep
