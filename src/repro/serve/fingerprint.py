"""Content-addressed plan fingerprints.

A plan is a pure function of (graph content, planning knobs) — every
engine in the pipeline is deterministic and backend choice never changes
the result (the equivalence contract tested in
tests/test_backend_equivalence.py).  The fingerprint therefore hashes
exactly those two things:

  * **content digest** — blake2b over the canonical edge arrays of an
    `IRGraph` (n, src, dst, w as little-endian bytes), or over the raw
    bytes of a trace file, streamed in 1 MiB chunks.  Hashing the file
    bytes rather than the parsed graph means a cache hit never pays the
    parse — which is what makes hits ~free on multi-hundred-MB traces.
  * **knob digest** — canonical JSON over the result-relevant planning
    knobs (p, method, λ, seed, edge_order, weight_model, and any extras
    that change the output, e.g. dist-pipeline round quanta).

`FP_VERSION` is folded in so persisted caches invalidate themselves when
the fingerprint scheme (or bundle layout) changes.

A per-process **stat memo** maps (realpath, size, mtime_ns) -> content
digest so repeated requests against an unchanged file skip even the
hashing pass.  It is advisory only: a rewritten file with identical
size+mtime_ns (sub-resolution filesystems) could alias, so callers can
opt out with `use_stat_memo=False`.
"""
from __future__ import annotations

import hashlib
import json
import os

import numpy as np

__all__ = ["FP_VERSION", "content_digest", "graph_digest", "knob_digest",
           "plan_fingerprint", "clear_stat_memo"]

FP_VERSION = 1
_CHUNK = 1 << 20

_stat_memo: dict = {}


def clear_stat_memo() -> None:
    _stat_memo.clear()


def content_digest(source, use_stat_memo: bool = True) -> str:
    """Digest of the graph content behind `source` (path or IRGraph)."""
    if isinstance(source, (str, os.PathLike)):
        return _path_digest(os.fspath(source), use_stat_memo)
    return graph_digest(source)


def _path_digest(path: str, use_stat_memo: bool) -> str:
    real = os.path.realpath(path)
    key = None
    if use_stat_memo:
        st = os.stat(real)
        key = (real, st.st_size, st.st_mtime_ns)
        hit = _stat_memo.get(key)
        if hit is not None:
            return hit
    h = hashlib.blake2b(digest_size=20)
    with open(real, "rb") as f:
        while True:
            chunk = f.read(_CHUNK)
            if not chunk:
                break
            h.update(chunk)
    digest = h.hexdigest()
    if key is not None:
        _stat_memo[key] = digest
    return digest


def graph_digest(g) -> str:
    """Digest of an in-memory `IRGraph`'s canonical edge arrays."""
    h = hashlib.blake2b(digest_size=20)
    h.update(f"n={int(g.n)};m={int(g.num_edges)};".encode())
    # '<' pins byte order so the digest is host-independent
    h.update(np.ascontiguousarray(g.src, dtype="<i4").tobytes())
    h.update(np.ascontiguousarray(g.dst, dtype="<i4").tobytes())
    h.update(np.ascontiguousarray(g.w, dtype="<f8").tobytes())
    return h.hexdigest()


def knob_digest(p: int, method: str, lam: float, seed: int,
                edge_order: str, weight_model: str,
                extras: dict | None = None) -> str:
    doc = {"v": FP_VERSION, "p": int(p), "method": str(method),
           "lam": float(lam), "seed": int(seed),
           "edge_order": str(edge_order),
           "weight_model": str(weight_model),
           "extras": dict(sorted((extras or {}).items()))}
    blob = json.dumps(doc, sort_keys=True, separators=(",", ":"))
    return hashlib.blake2b(blob.encode(), digest_size=12).hexdigest()


def plan_fingerprint(source, p: int, method: str, lam: float,
                     seed: int = 0, edge_order: str = "auto",
                     weight_model: str = "bytes",
                     extras: dict | None = None,
                     use_stat_memo: bool = True) -> str:
    """`<content>-<knobs>` — the plan cache key."""
    return (content_digest(source, use_stat_memo=use_stat_memo)
            + "-" + knob_digest(p, method, lam, seed, edge_order,
                                weight_model, extras))
