"""``python -m repro.serve`` — the plan-service CLI.

Subcommands:

  plan   serve one request (cold on first call, cached after)::

             python -m repro.serve plan trace.ndjson -p 64 \
                 --method wb_libra --lam 1.1 --cache-dir .cache/plans

  batch  serve a JSON file of requests through `plan_many`; each entry
         is ``{"source": path, "p": int, "method": ..., "lam": ...}``

  cache  list the fingerprints committed in a cache directory

  metrics  replay an optional JSON request list, then print the live
           `PlanService.metrics()` snapshot (hit rate, plans/s,
           plan-latency p50/p99, evictions)::

             python -m repro.serve metrics requests.json \
                 --max-hot-entries 64
"""
from __future__ import annotations

import argparse
import json
import sys

from .cache import PlanCache
from .service import DEFAULT_CACHE_DIR, PlanRequest, PlanService


def _parse_requests(entries) -> list:
    """JSON request entries -> PlanRequest list (shared by batch/metrics)."""
    return [PlanRequest(source=e["source"], p=int(e["p"]),
                        method=e.get("method", "wb_libra"),
                        lam=float(e.get("lam", 1.0)),
                        seed=int(e.get("seed", 0)),
                        edge_order=e.get("edge_order", "auto"),
                        weight_model=e.get("weight_model", "bytes"))
            for e in entries]


def _add_knobs(ap) -> None:
    ap.add_argument("-p", type=int, required=True, help="cluster count")
    ap.add_argument("--method", default="wb_libra")
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--edge-order", default="auto",
                    choices=("auto", "trace", "shuffled"))
    ap.add_argument("--weight-model", default="bytes")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.serve")
    ap.add_argument("--cache-dir", default=DEFAULT_CACHE_DIR)
    ap.add_argument("--backend", default="fast")
    ap.add_argument("--max-hot-entries", type=int, default=None,
                    help="LRU bound on the in-memory hot map (entries)")
    ap.add_argument("--max-hot-bytes", type=int, default=None,
                    help="LRU bound on the in-memory hot map (bytes)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    s = sub.add_parser("plan", help="serve one plan request")
    s.add_argument("source", help="trace / .rtb / .npz path")
    _add_knobs(s)

    b = sub.add_parser("batch", help="serve a JSON request list")
    b.add_argument("requests", help="path to a JSON list of requests")

    sub.add_parser("cache", help="list committed plan fingerprints")

    m = sub.add_parser("metrics",
                       help="replay requests, print live service metrics")
    m.add_argument("requests", nargs="?", default=None,
                   help="optional JSON request list to replay first")

    args = ap.parse_args(argv)

    if args.cmd == "cache":
        for fp in PlanCache(args.cache_dir).fingerprints():
            print(fp)
        return 0

    svc = PlanService(cache_dir=args.cache_dir, backend=args.backend,
                      max_hot_entries=args.max_hot_entries,
                      max_hot_bytes=args.max_hot_bytes)
    if args.cmd == "metrics":
        if args.requests:
            with open(args.requests) as f:
                entries = json.load(f)
            svc.plan_many(_parse_requests(entries))
        print(json.dumps(svc.metrics(), indent=2, default=float))
        return 0
    if args.cmd == "plan":
        req = PlanRequest(source=args.source, p=args.p,
                          method=args.method, lam=args.lam,
                          seed=args.seed, edge_order=args.edge_order,
                          weight_model=args.weight_model)
        resp = svc.plan(req)
        print(json.dumps(resp.summary(), indent=2, default=float))
        return 0

    with open(args.requests) as f:
        entries = json.load(f)
    if not isinstance(entries, list):
        print("batch: the requests file must hold a JSON list",
              file=sys.stderr)
        return 1
    out = [r.summary() for r in svc.plan_many(_parse_requests(entries))]
    print(json.dumps({"responses": out, "stats": svc.stats()},
                     indent=2, default=float))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
