"""Persistent content-addressed plan cache on `checkpoint.store`.

Layout: one `CheckpointManager` directory per fingerprint —

    <root>/<fingerprint>/step_00000000/shard_0.npz + meta.json + COMMIT

The bundle's arrays (assignment, loads, replica CSR, core placement,
core times) ride in the npz shard; its scalars (exec_time, comm bytes,
graph shape, knobs) ride in the JSON metadata.  Reusing the checkpoint
store buys the crash-recovery contract for free: a plan is visible only
after the atomic COMMIT+rename, a crash mid-write leaves a stale `.tmp`
that the next manager GCs, and restarts are warm — a new service over
the same root serves every previously-planned fingerprint from disk.

An in-memory hot map (fingerprint -> bundle) sits in front of the disk
layer so repeat hits are dictionary lookups.  The hot map is LRU-bounded
(``max_entries`` / ``max_bytes``): a long-lived service over an
unbounded request universe must not grow without limit, and an evicted
bundle is never lost — it reloads from the checkpoint store on the next
request.  Evictions are counted into the owning service's metrics
registry when one is injected.
"""
from __future__ import annotations

import collections
import dataclasses
import os

import numpy as np

from .. import obs
from ..checkpoint.store import CheckpointManager

__all__ = ["PlanBundle", "PlanCache"]

_ARRAY_FIELDS = ("assignment", "loads", "edge_counts", "replica_indptr",
                 "replica_flat", "core_of", "core_times")


@dataclasses.dataclass
class PlanBundle:
    """The persisted outcome of one planning run: (partition, mapping,
    simulated cost) — everything a deployment needs, nothing that would
    require re-running the pipeline."""

    # partition (VertexCutResult essentials)
    assignment: np.ndarray          # int32[|E|] -> cluster id
    loads: np.ndarray               # float64[p]
    edge_counts: np.ndarray         # int64[p]
    replica_indptr: np.ndarray      # int64[|V|+1]
    replica_flat: np.ndarray        # int32[Σ|A(v)|]
    # mapping
    core_of: np.ndarray             # int[p] -> core id
    # simulation
    core_times: np.ndarray          # float64[n_cores]
    exec_time: float
    comm_bytes: float
    # identity
    graph_name: str
    n_vertices: int
    total_weight: float
    p: int
    method: str
    lam: float

    @property
    def replication_factor(self) -> float:
        return len(self.replica_flat) / max(1, self.n_vertices)

    def summary(self) -> dict:
        return {
            "graph": self.graph_name, "p": self.p, "method": self.method,
            "lam": self.lam,
            "replication_factor": round(self.replication_factor, 4),
            "exec_time": self.exec_time, "comm_bytes": self.comm_bytes,
        }


class PlanCache:
    """Two-tier plan cache: LRU hot map over the checkpoint store.

    ``max_entries`` / ``max_bytes`` bound the hot map (None = unbounded);
    the least-recently-used bundle is dropped first, counted as
    ``serve.cache.evictions`` in the injected ``metrics`` registry.
    """

    def __init__(self, root: str, max_entries: "int | None" = None,
                 max_bytes: "int | None" = None, metrics=None):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._hot: "collections.OrderedDict[str, PlanBundle]" = \
            collections.OrderedDict()
        self._hot_bytes = 0
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self.metrics = metrics          # MetricsRegistry or None
        self.evictions = 0

    def _manager(self, fp: str) -> CheckpointManager:
        return CheckpointManager(os.path.join(self.root, fp), keep=1)

    @staticmethod
    def _bundle_nbytes(bundle: PlanBundle) -> int:
        return sum(np.asarray(getattr(bundle, k)).nbytes
                   for k in _ARRAY_FIELDS)

    def _remember(self, fp: str, bundle: PlanBundle) -> None:
        if fp in self._hot:
            self._hot.move_to_end(fp)
            return
        self._hot[fp] = bundle
        self._hot_bytes += self._bundle_nbytes(bundle)
        while self._hot and (
                (self.max_entries is not None
                 and len(self._hot) > self.max_entries)
                or (self.max_bytes is not None
                    and self._hot_bytes > self.max_bytes)):
            _old_fp, old = self._hot.popitem(last=False)
            self._hot_bytes -= self._bundle_nbytes(old)
            self.evictions += 1
            obs.counter("serve.cache_evict", 1)
            if self.metrics is not None:
                self.metrics.counter("serve.cache.evictions")

    @property
    def hot_bytes(self) -> int:
        return self._hot_bytes

    def fingerprints(self) -> list:
        """Fingerprints with a committed bundle on disk."""
        out = []
        for name in sorted(os.listdir(self.root)):
            d = os.path.join(self.root, name)
            if os.path.isdir(d) and CheckpointManager(d).all_steps():
                out.append(name)
        return out

    def get(self, fp: str) -> "PlanBundle | None":
        """Hot map, then disk; returns None on a miss."""
        bundle = self._hot.get(fp)
        if bundle is not None:
            self._hot.move_to_end(fp)       # LRU recency
            obs.counter("serve.cache_hit_memory", 1)
            return bundle
        mgr = self._manager(fp)
        if mgr.latest_step() is None:
            return None
        with obs.span("serve.cache_load", cat="op", fp=fp[:16]):
            flat, meta = mgr.restore_flat()
        bundle = PlanBundle(
            **{k: flat[k] for k in _ARRAY_FIELDS},
            exec_time=float(meta["exec_time"]),
            comm_bytes=float(meta["comm_bytes"]),
            graph_name=str(meta["graph_name"]),
            n_vertices=int(meta["n_vertices"]),
            total_weight=float(meta["total_weight"]),
            p=int(meta["p"]), method=str(meta["method"]),
            lam=float(meta["lam"]))
        self._remember(fp, bundle)
        obs.counter("serve.cache_hit_disk", 1)
        return bundle

    def put(self, fp: str, bundle: PlanBundle) -> None:
        self._remember(fp, bundle)
        flat = {k: np.asarray(getattr(bundle, k)) for k in _ARRAY_FIELDS}
        meta = {"exec_time": bundle.exec_time,
                "comm_bytes": bundle.comm_bytes,
                "graph_name": bundle.graph_name,
                "n_vertices": bundle.n_vertices,
                "total_weight": bundle.total_weight,
                "p": bundle.p, "method": bundle.method, "lam": bundle.lam}
        with obs.span("serve.cache_store", cat="op", fp=fp[:16]):
            self._manager(fp).save(0, flat, meta)
        obs.counter("serve.cache_store", 1)
