"""Loop-aware cost analysis of compiled (SPMD-partitioned) HLO text.

XLA's `compiled.cost_analysis()` counts every while-loop body ONCE — a
61-layer scanned model reports ~1/61 of its real FLOPs.  This analyzer
re-derives per-step totals from `compiled.as_text()` with trip-count
multipliers:

  flops       — dot ops: 2·|out|·K (K from lhs_contracting_dims and the
                operand symbol table); elementwise/reduce: |elements|.
                Fusion computations are recursed into.
  hbm_bytes   — boundary-traffic model: for every non-fused top-level
                instruction, operand bytes + output bytes; `fusion` ops
                count at the fusion boundary only (internals live in
                registers/VMEM, matching XLA's execution model).
  collectives — all-gather / all-reduce / reduce-scatter / all-to-all /
                collective-permute output bytes, also ×trip when inside
                a loop body.

Trip counts come from the loop condition computation: the largest integer
`constant(N)` compared against the induction variable (exact for
lax.scan/fori loops, which is all this codebase produces).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["analyze_hlo", "HLOCost"]

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
               "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1,
               "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
               "c64": 8, "c128": 16}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "tanh", "exponential", "log", "rsqrt", "sqrt", "negate", "abs",
    "compare", "select", "and", "or", "xor", "not", "sign", "floor",
    "ceil", "round-nearest-afz", "clamp", "atan2", "expm1", "log1p",
    "cosine", "sine", "logistic", "remainder", "erf",
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
_OP_RE = re.compile(r"^(\([^)]*\)|[^\s(]+)\s+([\w\-]+)\(")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _first_shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class _Instr:
    name: str
    op: str
    out_type: str
    operands: list
    attrs: str
    raw: str = ""


@dataclass
class HLOCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: dict = field(default_factory=dict)
    collective_counts: dict = field(default_factory=dict)
    # f32 collective bytes counted at bf16 width: XLA's *CPU* backend
    # legalises bf16 dots to f32, so collectives adjacent to dot inputs/
    # outputs appear as f32 in the host-compiled HLO even though the
    # traced program (and a TPU compilation) moves bf16.  This field is
    # the TPU-equivalent wire volume (DESIGN.md §2).
    collective_bytes_bf16eq: dict = field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    @property
    def total_collective_bytes_bf16eq(self) -> float:
        return sum(self.collective_bytes_bf16eq.values())


def _parse_computations(text: str) -> dict:
    comps: dict[str, list[_Instr]] = {}
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        m = _COMP_RE.match(line.strip()) if "{" in line else None
        if m and "->" in line:
            cur = m.group(1).lstrip("%")
            comps[cur] = []
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, rhs = dm.groups()
        om = _OP_RE.match(rhs)
        if not om:
            continue
        out_type, op = om.groups()
        # operand names: first (...) group after op
        try:
            args = rhs.split(op + "(", 1)[1]
        except IndexError:
            continue
        depth, end = 1, 0
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = args[:end]
        attrs = args[end + 1:]
        operands = re.findall(r"%[\w.\-]+", operand_str)
        comps[cur].append(_Instr(name.lstrip("%"), op, out_type,
                                 [o.lstrip("%") for o in operands], attrs,
                                 raw=rhs))
    return comps


def _trip_count(cond_instrs: list) -> int:
    best = 1
    for ins in cond_instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((\d+)\)", ins.attrs or "")
        else:
            m = None
        # constants are parsed oddly (value in out_type position attrs);
        # fall back to scanning the whole definition
        if not m:
            continue
    # robust pass: regex over the raw attr text of all instructions
    for ins in cond_instrs:
        for m in re.finditer(r"constant\((\d+)\)", ins.attrs or ""):
            best = max(best, int(m.group(1)))
    return best


def analyze_hlo(text: str) -> HLOCost:
    comps = _parse_computations(text)
    # symbol tables: name -> out_type per computation
    symtab = {c: {i.name: i.out_type for i in instrs}
              for c, instrs in comps.items()}
    # (parameters are typed by their `%p = TYPE parameter(n)` lines,
    # which _parse_computations already records in the symbol table)

    entry = None
    em = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    if em:
        entry = em.group(1)
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None
    if entry is None:
        return HLOCost()

    memo: dict[str, HLOCost] = {}

    def cost_of(cname: str, count_bytes: bool = True) -> HLOCost:
        key = f"{cname}:{count_bytes}"
        if key in memo:
            return memo[key]
        out = HLOCost()
        table = symtab.get(cname, {})
        for ins in comps.get(cname, []):
            called = re.findall(r"(?:calls|body|condition|to_apply|"
                                r"branch_computations)=\{?%?([\w.\-]+)",
                                ins.attrs or "")
            if ins.op == "while":
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", ins.attrs or "")
                cm = re.search(r"condition=%?([\w.\-]+)", ins.attrs or "")
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                # XLA records exact trip counts for counted loops
                tm = re.search(r'known_trip_count[":{]+n["\s:]+(\d+)',
                               ins.attrs or "")
                if tm:
                    trip = int(tm.group(1))
                else:
                    trip = _trip_count(comps.get(cond, [])) if cond else 1
                if body:
                    sub = cost_of(body, count_bytes=True)
                    out.flops += sub.flops * trip
                    out.hbm_bytes += sub.hbm_bytes * trip
                    for k, v in sub.collective_bytes.items():
                        out.collective_bytes[k] = \
                            out.collective_bytes.get(k, 0.0) + v * trip
                    for k, v in sub.collective_bytes_bf16eq.items():
                        out.collective_bytes_bf16eq[k] = \
                            out.collective_bytes_bf16eq.get(k, 0.0) + v * trip
                    for k, v in sub.collective_counts.items():
                        out.collective_counts[k] = \
                            out.collective_counts.get(k, 0) + v * trip
                continue
            if ins.op == "fusion":
                # flops from the fused computation; bytes at the boundary.
                # Operands that are only *sliced* inside the fusion (scan
                # bodies reading one timestep of a stacked array) count at
                # the slice size, not the full-array size.
                for c in called:
                    sub = cost_of(c, count_bytes=False)
                    out.flops += sub.flops
                if count_bytes:
                    b = 0.0
                    fcomp = comps.get(called[0], []) if called else []
                    param_of = {}
                    for fi in fcomp:
                        if fi.op == "parameter":
                            pm = re.search(r"parameter\((\d+)\)",
                                           fi.raw or "")
                            if pm:
                                param_of[int(pm.group(1))] = fi.name
                    for idx, o in enumerate(ins.operands):
                        full = _shape_bytes(table.get(o, ""))
                        pname = param_of.get(idx)
                        if pname is not None:
                            users = [fi for fi in fcomp
                                     if pname in fi.operands]
                            if users and all(
                                    fi.op in ("dynamic-slice", "gather",
                                              "dynamic-update-slice")
                                    for fi in users):
                                sliced = sum(
                                    _shape_bytes(fi.out_type)
                                    if fi.op != "dynamic-update-slice"
                                    else _shape_bytes(table.get(
                                        fi.operands[1], "")
                                        if len(fi.operands) > 1 else "")
                                    for fi in users)
                                full = min(full, sliced)
                        b += full
                    out.hbm_bytes += b + _shape_bytes(ins.out_type)
                continue
            if ins.op in ("call", "conditional", "custom-call",
                          "async-start"):
                for c in called:
                    sub = cost_of(c, count_bytes=count_bytes)
                    out.flops += sub.flops
                    out.hbm_bytes += sub.hbm_bytes
                    for k, v in sub.collective_bytes.items():
                        out.collective_bytes[k] = \
                            out.collective_bytes.get(k, 0.0) + v
                    for k, v in sub.collective_bytes_bf16eq.items():
                        out.collective_bytes_bf16eq[k] = \
                            out.collective_bytes_bf16eq.get(k, 0.0) + v
                continue

            base_op = re.sub(r"-(start|done)$", "", ins.op)
            if base_op in _COLLECTIVES:
                if ins.op.endswith("-done"):
                    continue
                b = _shape_bytes(ins.out_type)
                beq = b / 2.0 if "f32[" in ins.out_type else b
                out.collective_bytes[base_op] = \
                    out.collective_bytes.get(base_op, 0.0) + b
                out.collective_bytes_bf16eq[base_op] = \
                    out.collective_bytes_bf16eq.get(base_op, 0.0) + beq
                out.collective_counts[base_op] = \
                    out.collective_counts.get(base_op, 0) + 1
                if count_bytes:
                    out.hbm_bytes += 2 * b
                continue

            # FLOPs
            if ins.op == "dot":
                out_elems = _shape_elems(ins.out_type)
                k = 1.0
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                               ins.attrs or "")
                if cm and ins.operands:
                    lhs_type = table.get(ins.operands[0], "")
                    dims = _first_shape_dims(lhs_type)
                    for ci in cm.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            k *= dims[int(ci)]
                out.flops += 2.0 * out_elems * k
            elif ins.op in _ELEMENTWISE:
                out.flops += _shape_elems(ins.out_type)
            elif ins.op == "reduce":
                if ins.operands:
                    out.flops += _shape_elems(
                        table.get(ins.operands[0], ins.out_type))
            elif ins.op == "convolution":
                out.flops += 2.0 * _shape_elems(ins.out_type) * 9

            # HBM bytes at top level
            if count_bytes and ins.op in ("dynamic-slice", "gather"):
                out.hbm_bytes += 2 * _shape_bytes(ins.out_type)
            elif count_bytes and ins.op in ("dynamic-update-slice",
                                            "scatter"):
                upd = (table.get(ins.operands[1], "")
                       if len(ins.operands) > 1 else "")
                out.hbm_bytes += 2 * _shape_bytes(upd)
            elif count_bytes and ins.op == "broadcast":
                out.hbm_bytes += _shape_bytes(ins.out_type)
            elif count_bytes and ins.op not in ("parameter", "constant",
                                                "get-tuple-element",
                                                "tuple", "bitcast"):
                b = sum(_shape_bytes(table.get(o, ""))
                        for o in ins.operands)
                out.hbm_bytes += b + _shape_bytes(ins.out_type)
        memo[key] = out
        return out

    return cost_of(entry)
