from .hlo_cost import HLOCost, analyze_hlo
__all__ = ["analyze_hlo", "HLOCost"]
