"""Power-law graph synthesis + the paper's random-cut theory (Eq. 4–10).

Two roles:
  * generate synthetic Zipf-degree graphs used by property tests and the
    replication-factor benchmark (paper Fig. 8 plots the Eq. 10 curve as the
    theoretical upper bound for the greedy algorithms);
  * closed-form expectations for the random weighted vertex cut.
"""
from __future__ import annotations

import numpy as np

from .graph import IRGraph

__all__ = [
    "zipf_degrees",
    "synthesize_powerlaw_graph",
    "expected_replication_random",
    "expected_replication_random_empirical",
]


def zipf_degrees(n: int, alpha: float, d_max: int | None = None,
                 seed: int = 0) -> np.ndarray:
    """Sample n vertex degrees from the truncated Zipf P(d) ∝ d^-alpha."""
    d_max = d_max or max(2, n - 1)
    rng = np.random.default_rng(seed)
    d = np.arange(1, d_max + 1, dtype=np.float64)
    pmf = d ** (-alpha)
    pmf /= pmf.sum()
    return rng.choice(np.arange(1, d_max + 1), size=n, p=pmf)


def synthesize_powerlaw_graph(n: int, alpha: float, seed: int = 0,
                              weight_cv: float = 1.0,
                              name: str | None = None) -> IRGraph:
    """Chung-Lu style generator: endpoints drawn ∝ target degree.

    Edge weights model memory-op times: log-normal (heavy-tailed, like
    cache-hit vs. DRAM-miss latencies), scaled so the mean is 1.0.
    """
    rng = np.random.default_rng(seed)
    deg = zipf_degrees(n, alpha, seed=seed).astype(np.float64)
    m = max(1, int(deg.sum() // 2))
    p = deg / deg.sum()
    src = rng.choice(n, size=m, p=p).astype(np.int32)
    dst = rng.choice(n, size=m, p=p).astype(np.int32)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    sigma = np.sqrt(np.log1p(weight_cv ** 2))
    w = rng.lognormal(mean=-sigma ** 2 / 2, sigma=sigma, size=len(src))
    return IRGraph(n=n, src=src, dst=dst, w=w,
                   name=name or f"powerlaw(n={n},a={alpha})")


def _zipf_norm(n: int, alpha: float) -> float:
    d = np.arange(1, n, dtype=np.float64)
    return float((d ** (-alpha)).sum())


def expected_replication_random(n_vertices: int, alpha: float,
                                p: int) -> float:
    """Paper Eq. (10): E[ 1/|V| Σ_v |A(v)| ] for the random weighted cut.

        p - p / h_|V|(alpha) * Σ_{d=1}^{|V|-1} ((p-1)/p)^d d^-alpha
    """
    if n_vertices < 2:
        return 1.0
    d = np.arange(1, n_vertices, dtype=np.float64)
    h = (d ** (-alpha)).sum()
    # ((p-1)/p)^d underflows gracefully for large d.
    s = (((p - 1.0) / p) ** d * d ** (-alpha)).sum()
    return float(p - p / h * s)


def expected_replication_random_empirical(degrees: np.ndarray,
                                          p: int) -> float:
    """Eq. (6) averaged over the *empirical* degree sequence:

        1/|V| Σ_v p (1 - (1 - 1/p)^D[v])

    A tighter bound than Eq. (10) when the graph's degrees are known.
    """
    d = np.asarray(degrees, dtype=np.float64)
    d = np.maximum(d, 0.0)
    return float(np.mean(p * (1.0 - (1.0 - 1.0 / p) ** d)))
