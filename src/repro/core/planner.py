"""Production integration of the vertex-cut framework (DESIGN.md §4).

Three consumers inside the training/serving framework:

  1. `plan_step` / `optimal_parallelism` — trace a jitted step function to
     an IR graph, partition it with WB-Libra, map the clusters with the
     memory-centric mapper and return the simulated cost.  This is the
     paper's "discover the optimal parallelization degree" applied to JAX
     programs.
  2. `expert_placement` — Weight Balanced Vertex Cut over the expert
     co-activation graph: experts are vertices, co-routed token pairs are
     weighted edges, and the cut's replica sets A(expert) give an
     expert→device placement in which *hot experts are replicated* across
     EP shards (the paper's "cut the high-degree vertex" move) and the
     per-device routed-token load is λ-balanced.
  3. `mesh_device_order` — Algorithm-2 mapping of model shards onto the
     ICI mesh so that heavily-communicating shards are neighbours
     (factor 2) and independent shards land in different mesh regions
     (factor 3); consumed by `launch/mesh.py`.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from .. import obs
from .graph import IRGraph
from .jaxpr_graph import trace_to_graph
from .mapping import (Machine, cluster_interaction_graphs,
                      memory_centric_mapping, resolve_mapping_backend)
from .simulator import coerce_graph, simulate, vertex_bytes_model
from .vertex_cut import VertexCutResult, vertex_cut

__all__ = ["PlanReport", "plan_graph", "plan_step", "optimal_parallelism",
           "ExpertPlacement", "expert_placement", "mesh_device_order"]


@dataclasses.dataclass
class PlanReport:
    graph: IRGraph
    cut: VertexCutResult
    exec_time: float
    comm_bytes: float
    p: int

    def summary(self) -> dict:
        return {
            "graph": self.graph.name, "p": self.p,
            "replication_factor": round(self.cut.replication_factor, 3),
            "edge_weight_imbalance":
                round(self.cut.edge_weight_imbalance, 4),
            "est_exec_time": self.exec_time,
            "est_comm_bytes": self.comm_bytes,
        }


def plan_graph(g, p: int, method: str = "wb_libra",
               lam: float = 1.0, machine: Machine | None = None,
               backend: str = "fast", workers: int = 1,
               merge_period: "int | None" = None,
               divergence: "float | None" = None) -> PlanReport:
    """Plan `g` — an `IRGraph`, or a path to an `.npz` snapshot / NDJSON
    dynamic trace (the `repro.trace` front end).  `backend` threads
    through every stage ("fast"/"native"/"python"/"pallas"/"reference");
    "pallas" keeps the finalize/metrics reductions on-accelerator, and
    "dist" runs the sharded streaming partitioner (`repro.dist`) on
    `workers` workers, ingesting trace paths through the parallel parse
    front end (`workers=1` is bit-identical to "fast")."""
    with obs.span("plan.cut", cat="section", backend=backend, p=p):
        if backend == "dist":
            if isinstance(g, (str, os.PathLike)) \
                    and not os.fspath(g).endswith(".npz"):
                from ..dist import dist_ingest
                g = dist_ingest(g, workers=workers)
            g = coerce_graph(g)
            from ..dist import dist_vertex_cut
            cut = dist_vertex_cut(g, p, method=method, lam=lam,
                                  workers=workers, merge_period=merge_period,
                                  divergence=divergence)
        else:
            g = coerce_graph(g)
            cut = vertex_cut(g, p, method=method, lam=lam, backend=backend)
    map_backend = resolve_mapping_backend(backend)
    with obs.span("plan.map", cat="section", backend=map_backend):
        comm, shared = cluster_interaction_graphs(
            cut, p, vertex_bytes_model(g), backend=map_backend)
        mapping = memory_centric_mapping(comm, shared,
                                         machine or Machine.for_clusters(p),
                                         backend=map_backend)
    with obs.span("plan.simulate", cat="section", backend=map_backend):
        rep = simulate(g, cut, mapping, backend=map_backend)
    return PlanReport(graph=g, cut=cut, exec_time=rep.exec_time,
                      comm_bytes=rep.data_comm_bytes, p=p)


def plan_step(fn, *args, p: int = 8, method: str = "wb_libra",
              lam: float = 1.0, backend: str = "fast", **kw) -> PlanReport:
    """Trace `fn(*args)` and plan its p-way partitioned execution."""
    g = trace_to_graph(fn, *args, **kw)
    return plan_graph(g, p, method=method, lam=lam, backend=backend)


def optimal_parallelism(fn, *args, candidates=(2, 4, 8, 16, 32),
                        method: str = "wb_libra",
                        backend: str = "fast") -> tuple[int, list]:
    """Pick the cluster count with the lowest simulated execution time —
    the paper's stated goal of 'discovering optimal parallelization
    degree' for a program."""
    g = trace_to_graph(fn, *args)
    reports = [plan_graph(g, p, method=method, backend=backend)
               for p in candidates]
    best = int(np.argmin([r.exec_time for r in reports]))
    return candidates[best], reports


# ---------------------------------------------------------------------- #
# MoE expert placement (EP integration)
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class ExpertPlacement:
    """Expert→device placement with replication of hot experts."""

    n_experts: int
    n_devices: int
    device_experts: list            # per device: sorted list of expert ids
    expert_devices: list            # per expert: sorted list of device ids
    device_load: np.ndarray         # expected routed tokens per device
    replication_factor: float       # mean replicas per expert
    all_to_all_fraction: float      # fraction of tokens leaving their shard

    def summary(self) -> dict:
        return {
            "n_experts": self.n_experts, "n_devices": self.n_devices,
            "replication_factor": round(self.replication_factor, 3),
            "load_imbalance": round(
                float(self.device_load.max()
                      / max(self.device_load.mean(), 1e-9)), 4),
            "all_to_all_fraction": round(self.all_to_all_fraction, 4),
        }


def expert_placement(expert_load: np.ndarray,
                     co_activation: np.ndarray | None = None,
                     n_devices: int = 8, lam: float = 1.0,
                     seed: int = 0,
                     max_replicas: int = 4,
                     backend: str = "fast") -> ExpertPlacement:
    """WB-Libra placement of MoE experts across EP shards.

    Builds the expert co-activation graph (vertices = experts; edge (i,j)
    weighted by tokens routed to both i and j in the same top-k set — the
    natural weighted power-law graph of MoE routing) and partitions its
    *edges* into `n_devices` clusters.  A(expert) — the replica set — is
    the set of devices serving that expert: hot experts end up replicated
    exactly like the paper's cut hub vertices, balancing per-device load
    while keeping co-routed experts on the same shard (fewer all-to-all
    hops for multi-expert tokens).

    Args:
      expert_load: [E] routed token counts (from routing statistics).
      co_activation: optional [E,E] co-routing counts; a rank-1 surrogate
        `load_i * load_j / total` is used when absent.
      n_devices: EP shards.
      lam: balance bound (paper Eq. 3).
      max_replicas: memory cap — an expert's weights are materialised on
        every replica shard, so A(expert) is trimmed to the
        `max_replicas` least-loaded members (hottest experts keep the
        most replicas, coldest collapse to 1 — DeepSeek's own redundant-
        experts deployment uses the same bound).
    """
    expert_load = np.asarray(expert_load, dtype=np.float64)
    e_cnt = len(expert_load)
    if co_activation is None:
        tot = max(expert_load.sum(), 1e-9)
        co_activation = np.outer(expert_load, expert_load) / tot
    co = np.array(co_activation, dtype=np.float64)
    np.fill_diagonal(co, 0.0)

    iu, ju = np.nonzero(np.triu(co > 0, k=1))
    wts = co[iu, ju]
    # keep the heaviest edges (the co-activation graph can be dense)
    if len(wts) > 64 * e_cnt:
        order = np.argsort(-wts)[: 64 * e_cnt]
        iu, ju, wts = iu[order], ju[order], wts[order]
    g = IRGraph(n=e_cnt, src=iu, dst=ju, w=wts, name="expert_coactivation")
    cut = vertex_cut(g, n_devices, method="wb_libra", lam=lam, seed=seed,
                     edge_order="shuffled", backend=backend)

    expert_devices: list = []
    for ex in range(e_cnt):
        a = cut.replicas[ex]
        if not a:  # cold expert: place on the least loaded device later
            expert_devices.append([])
        else:
            expert_devices.append(sorted(a))

    # distribute each expert's load over its replicas (hottest first so
    # the max_replicas trim keeps balance); cold experts fill gaps
    device_load = np.zeros(n_devices)
    for ex in np.argsort(-expert_load):
        ex = int(ex)
        devs = expert_devices[ex]
        if not devs:
            d = int(np.argmin(device_load))
            expert_devices[ex] = [d]
            devs = [d]
        if len(devs) > max_replicas:
            devs = sorted(devs, key=lambda d: device_load[d])[:max_replicas]
            expert_devices[ex] = sorted(devs)
        share = expert_load[ex] / len(devs)
        for d in devs:
            device_load[d] += share

    device_experts = [[] for _ in range(n_devices)]
    for ex, devs in enumerate(expert_devices):
        for d in devs:
            device_experts[d].append(ex)
    device_experts = [sorted(d) for d in device_experts]

    # all-to-all volume: a token on data-shard d routed to expert ex must
    # leave d unless ex is served locally.  With uniform token origin the
    # leave probability is 1 - |A(ex)|/n_devices.
    tot = max(expert_load.sum(), 1e-9)
    stay = sum(expert_load[ex] * len(expert_devices[ex]) / n_devices
               for ex in range(e_cnt))
    rf = float(np.mean([len(d) for d in expert_devices]))
    return ExpertPlacement(
        n_experts=e_cnt, n_devices=n_devices,
        device_experts=device_experts, expert_devices=expert_devices,
        device_load=device_load, replication_factor=rf,
        all_to_all_fraction=float(1.0 - stay / tot))


def naive_expert_placement(expert_load: np.ndarray,
                           n_devices: int) -> ExpertPlacement:
    """Contiguous block placement (the standard EP layout) for comparison."""
    expert_load = np.asarray(expert_load, dtype=np.float64)
    e_cnt = len(expert_load)
    per = int(np.ceil(e_cnt / n_devices))
    expert_devices = [[min(ex // per, n_devices - 1)] for ex in range(e_cnt)]
    device_load = np.zeros(n_devices)
    for ex in range(e_cnt):
        device_load[expert_devices[ex][0]] += expert_load[ex]
    device_experts = [[] for _ in range(n_devices)]
    for ex, devs in enumerate(expert_devices):
        device_experts[devs[0]].append(ex)
    tot = max(expert_load.sum(), 1e-9)
    stay = sum(expert_load[ex] / n_devices for ex in range(e_cnt))
    return ExpertPlacement(
        n_experts=e_cnt, n_devices=n_devices,
        device_experts=device_experts, expert_devices=expert_devices,
        device_load=device_load, replication_factor=1.0,
        all_to_all_fraction=float(1.0 - stay / tot))


# ---------------------------------------------------------------------- #
# mesh device ordering (Algorithm 2 on the ICI mesh)
# ---------------------------------------------------------------------- #
def mesh_device_order(shard_comm: np.ndarray, rows: int, cols: int,
                      backend: str = "fast") -> np.ndarray:
    """Assign model shards to ICI mesh coordinates.

    `shard_comm[i, j]` is the traffic between logical shards i and j (e.g.
    from the dry-run collective schedule).  Returns `core_of[shard] ->
    mesh slot` from the memory-centric mapping, so `launch/mesh.py` can
    permute `jax.devices()` before `make_mesh` — communicating shards
    become ICI neighbours (factor 2), independent shards spread across
    regions (factor 3).
    """
    p = shard_comm.shape[0]
    mach = Machine(rows=rows, cols=cols,
                   cluster_threshold=max(1, int(np.ceil(p / (rows * cols)))))
    mapping = memory_centric_mapping(shard_comm, np.zeros_like(shard_comm),
                                     mach,
                                     backend=resolve_mapping_backend(backend))
    return mapping.core_of
