"""Shared vectorized edge-array helpers for the partitioners.

Used by `graph.IRGraph.csr`, the METIS-like coarsener in `edge_cut`, the
vectorized `_finalize` of `vertex_cut`, and the array-native
mapping/simulator fast paths — one implementation of the sort-based
grouping and segment primitives instead of several hand-rolled loops.
"""
from __future__ import annotations

import numpy as np

__all__ = ["csr_adjacency", "dedup_edges", "replica_csr",
           "masks_to_replica_csr", "segment_entries",
           "interaction_from_csr", "star_triples",
           "merge_limb_masks", "merge_deltas"]


def csr_adjacency(n: int, src: np.ndarray, dst: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Undirected CSR adjacency: (indptr, neighbor ids, edge ids)."""
    m = len(src)
    ends = np.concatenate([src, dst])
    other = np.concatenate([dst, src])
    eid = np.concatenate([np.arange(m), np.arange(m)])
    order = np.argsort(ends, kind="stable")
    ends, other, eid = ends[order], other[order], eid[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, ends + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, other.astype(np.int32), eid.astype(np.int64)


def dedup_edges(n: int, src: np.ndarray, dst: np.ndarray, w: np.ndarray
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Merge parallel edges, summing their weights."""
    key = src.astype(np.int64) * n + dst
    order = np.argsort(key, kind="stable")
    key, src, dst, w = key[order], src[order], dst[order], w[order]
    first = np.ones(len(key), dtype=bool)
    first[1:] = key[1:] != key[:-1]
    idx = np.cumsum(first) - 1
    ws = np.zeros(int(first.sum()))
    np.add.at(ws, idx, w)
    return src[first], dst[first], ws


def replica_csr(n: int, p: int, src: np.ndarray, dst: np.ndarray,
                assignment: np.ndarray,
                backend: str = "numpy") -> tuple[np.ndarray, np.ndarray]:
    """Per-vertex replica sets A(v) as a CSR over sorted cluster ids.

    A vertex's replica set is the set of clusters hosting an incident
    edge; vectorized as a unique-sort over (vertex, cluster) pairs.
    Returns (indptr int64[n+1], flat int32[sum |A(v)|]).  With
    `backend="pallas"` the sort/unique runs on-device through
    `repro.core.pallas.metrics` (bit-identical; numpy views returned).
    """
    if backend == "pallas":
        from .pallas.metrics import replica_csr as _device_csr
        indptr, flat = _device_csr(n, p, src, dst, assignment)
        return np.asarray(indptr), np.asarray(flat)
    v = np.concatenate([src, dst]).astype(np.int64)
    c = np.concatenate([assignment, assignment]).astype(np.int64)
    key = np.unique(v * p + c)
    indptr = np.searchsorted(key, np.arange(n + 1, dtype=np.int64) * p)
    return indptr.astype(np.int64), (key % p).astype(np.int32)


def _masks_block_nonzero(rows: np.ndarray, p: int
                         ) -> tuple[np.ndarray, np.ndarray]:
    """(local vertex ids, cluster ids) of the set bits in one block of
    bitmask limb rows, in (vertex, cluster)-sorted order."""
    k, limbs = rows.shape
    # '<u8' pins the limb byte layout so bit j of limb l is cluster
    # 64*l + j on any host endianness
    bits = np.unpackbits(rows.astype("<u8").view(np.uint8).reshape(k, -1),
                         axis=1, bitorder="little")
    vs, cs = np.nonzero(bits[:, :p])
    return vs, cs.astype(np.int32)


def masks_to_replica_csr(masks: np.ndarray, n: int, limbs: int, p: int,
                         executor=None, shards: int = 1
                         ) -> tuple[np.ndarray, np.ndarray]:
    """Replica CSR decoded straight from bitmask limb rows.

    The streaming engines maintain `uint64[n*limbs]` A(v) rows as they
    place edges — after the final shard merge those rows ARE the replica
    sets, so the finalize can skip the sort-based `replica_csr` over all
    2|E| endpoints and decode n*limbs words instead.  Bit-identical to
    `replica_csr(n, p, src, dst, assignment)` whenever `masks` equals
    the assignment-derived sets (row-major `np.nonzero` yields each
    vertex's clusters in ascending order, exactly the sorted-CSR
    contract).  `masks` shorter than `n*limbs` is padded with empty
    rows (vertices the stream never grew to have empty replica sets).

    With `executor`/`shards` the decode fans out over contiguous vertex
    ranges (numpy releases the GIL in the unpack/nonzero passes), and
    the per-shard results concatenate in range order — the output is
    independent of `executor`, `shards`, and scheduling.
    """
    if len(masks) < n * limbs:
        padded = np.zeros(n * limbs, dtype=np.uint64)
        padded[:len(masks)] = masks
        masks = padded
    rows = masks[:n * limbs].reshape(n, limbs)
    shards = max(1, min(int(shards), max(1, n)))
    bounds = [n * s // shards for s in range(shards + 1)]
    blocks = [rows[a:b] for a, b in zip(bounds[:-1], bounds[1:]) if a < b]
    if executor is not None and len(blocks) > 1:
        parts = list(executor.map(lambda blk: _masks_block_nonzero(blk, p),
                                  blocks))
    else:
        parts = [_masks_block_nonzero(blk, p) for blk in blocks]
    counts = np.zeros(n, dtype=np.int64)
    flats = []
    for (vs, cs), a in zip(parts, bounds[:-1]):
        if len(vs):
            counts[a:a + int(vs[-1]) + 1] = np.bincount(vs)
        flats.append(cs)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    flat = (np.concatenate(flats) if flats
            else np.zeros(0, dtype=np.int32))
    return indptr, flat


# ---------------------------------------------------------------------- #
# shard-merge primitives (repro.dist periodic state merges)
# ---------------------------------------------------------------------- #
def merge_limb_masks(masks: "list[np.ndarray]") -> np.ndarray:
    """OR-combine per-shard replica bitmask limb arrays into one.

    Every shard keeps its own `uint64[n*limbs]` A(v) bitmask rows (the
    chunked-limb layout is shard-local by construction); the merged
    array is their element-wise union — order-free, so any combine
    order yields the identical result.
    """
    if not masks:
        raise ValueError("need at least one mask array to merge")
    out = masks[0].copy()
    for m in masks[1:]:
        np.bitwise_or(out, m, out=out)
    return out


def merge_deltas(snapshot: np.ndarray,
                 locals_: "list[np.ndarray]") -> np.ndarray:
    """Reduce per-shard accumulator views against their common snapshot.

    Each shard's `local` equals `snapshot + (its own contributions)`;
    the merged value is `snapshot + sum_s (local_s - snapshot)`,
    accumulated in shard order so the result is deterministic for a
    fixed shard list (exact for integer arrays, fixed-rounding for
    float loads).  Used for the periodic `load` / remaining-degree
    merges of the distributed partitioner.
    """
    out = snapshot.copy()
    for loc in locals_:
        out += loc - snapshot
    return out


# ---------------------------------------------------------------------- #
# segment primitives over a replica CSR (indptr, members)
# ---------------------------------------------------------------------- #
def segment_entries(indptr: np.ndarray
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-entry segment bookkeeping for a CSR.

    Returns (seg_id, first_pos, sizes): for every flat entry its segment
    (vertex) id and the flat position of that segment's first entry, plus
    the per-segment sizes.  `first_pos[i] == i` marks segment heads.
    """
    sizes = np.diff(indptr)
    seg_id = np.repeat(np.arange(len(sizes), dtype=np.int64), sizes)
    return seg_id, indptr[seg_id], sizes


def star_triples(indptr: np.ndarray, members: np.ndarray,
                 vertex_bytes: np.ndarray | None = None
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(owner, replica, bytes) triples of the replica-sync star pattern.

    The owner of a vertex is the lowest cluster id in A(v) (members are
    sorted per vertex); every other member receives one synchronisation
    message of `vertex_bytes[v]` bytes.  Triples come out grouped by
    vertex in member order — the exact order the reference loops emit.
    """
    seg_id, first_pos, _ = segment_entries(indptr)
    non_owner = np.arange(len(members), dtype=np.int64) != first_pos
    owners = members[first_pos[non_owner]]
    replicas = members[non_owner]
    if vertex_bytes is None:
        b = np.ones(len(replicas))
    else:
        b = np.asarray(vertex_bytes, dtype=np.float64)[seg_id[non_owner]]
    return owners, replicas, b


def interaction_from_csr(indptr: np.ndarray, members: np.ndarray, p: int,
                         vertex_bytes: np.ndarray | None = None,
                         pairwise_cap: int = 64
                         ) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized (comm[P,P], shared[P,P]) from a replica CSR.

    Same semantics as the reference loop in
    `mapping.cluster_interaction_graphs`: star-shaped owner->replica comm,
    diagonal reference counts, and capped pairwise shared counts (vertices
    replicated to more than `pairwise_cap` clusters skip the O(|A|^2)
    pairs but keep their star traffic).
    """
    comm = np.zeros((p, p))
    shared = np.zeros((p, p))
    if len(members) == 0:
        return comm, shared
    mem = members.astype(np.int64)
    # diagonal: vertices referencing each cluster (members unique per seg)
    diag = np.bincount(mem, minlength=p).astype(np.float64)
    shared.flat[:: p + 1] = diag

    # star comm as a sparse flat scatter of unique (owner, replica) keys —
    # the interaction pattern is sparse, so never materialise O(p^2)
    # temporaries (a dense bincount/transpose costs more than the whole
    # mapping at p >= 1024)
    owners, replicas, b = star_triples(indptr, members, vertex_bytes)
    if len(owners):
        key = owners.astype(np.int64) * p + replicas
        uq, inv = np.unique(key, return_inverse=True)
        sums = np.bincount(inv, weights=b)
        comm.flat[uq] += sums            # owner != replica: off-diagonal
        comm.flat[(uq % p) * p + uq // p] += sums

    sizes = np.diff(indptr)
    keys = []
    for s in np.unique(sizes):
        s = int(s)
        if s < 2 or s > pairwise_cap:
            continue
        base = indptr[:-1][sizes == s]
        iu, ju = np.triu_indices(s, k=1)
        x = mem[(base[:, None] + iu[None, :]).ravel()]
        y = mem[(base[:, None] + ju[None, :]).ravel()]
        keys.append(x * p + y)           # members sorted, so x < y always
    if keys:
        uq, cnt = np.unique(np.concatenate(keys), return_counts=True)
        shared.flat[uq] += cnt
        shared.flat[(uq % p) * p + uq // p] += cnt
    return comm, shared
