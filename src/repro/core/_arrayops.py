"""Shared vectorized edge-array helpers for the partitioners.

Used by `graph.IRGraph.csr`, the METIS-like coarsener in `edge_cut`, and
the vectorized `_finalize` of `vertex_cut` — one implementation of the
sort-based grouping primitives instead of three hand-rolled loops.
"""
from __future__ import annotations

import numpy as np

__all__ = ["csr_adjacency", "dedup_edges", "replica_csr"]


def csr_adjacency(n: int, src: np.ndarray, dst: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Undirected CSR adjacency: (indptr, neighbor ids, edge ids)."""
    m = len(src)
    ends = np.concatenate([src, dst])
    other = np.concatenate([dst, src])
    eid = np.concatenate([np.arange(m), np.arange(m)])
    order = np.argsort(ends, kind="stable")
    ends, other, eid = ends[order], other[order], eid[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, ends + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, other.astype(np.int32), eid.astype(np.int64)


def dedup_edges(n: int, src: np.ndarray, dst: np.ndarray, w: np.ndarray
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Merge parallel edges, summing their weights."""
    key = src.astype(np.int64) * n + dst
    order = np.argsort(key, kind="stable")
    key, src, dst, w = key[order], src[order], dst[order], w[order]
    first = np.ones(len(key), dtype=bool)
    first[1:] = key[1:] != key[:-1]
    idx = np.cumsum(first) - 1
    ws = np.zeros(int(first.sum()))
    np.add.at(ws, idx, w)
    return src[first], dst[first], ws


def replica_csr(n: int, p: int, src: np.ndarray, dst: np.ndarray,
                assignment: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-vertex replica sets A(v) as a CSR over sorted cluster ids.

    A vertex's replica set is the set of clusters hosting an incident
    edge; vectorized as a unique-sort over (vertex, cluster) pairs.
    Returns (indptr int64[n+1], flat int32[sum |A(v)|]).
    """
    v = np.concatenate([src, dst]).astype(np.int64)
    c = np.concatenate([assignment, assignment]).astype(np.int64)
    key = np.unique(v * p + c)
    indptr = np.searchsorted(key, np.arange(n + 1, dtype=np.int64) * p)
    return indptr.astype(np.int64), (key % p).astype(np.int32)
