"""Weighted directed dataflow-graph representation (the LLVM-IR-graph analogue).

The paper's object of study is G = (V, E, W): vertices are IR instructions,
edges are dynamic data dependencies, and edge weights are measured memory-op
times.  Here the same structure is built either from traced benchmark programs
(`core.benchgraphs`), from jaxprs (`core.jaxpr_graph`), or synthetically
(`core.powerlaw`).  Storage is flat numpy arrays (an edge list + lazily built
CSR adjacency) so graphs with millions of edges stay cheap.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from ._arrayops import csr_adjacency

__all__ = ["IRGraph"]


@dataclasses.dataclass
class IRGraph:
    """Edge-list weighted digraph.

    Attributes:
      n: number of vertices (ids are 0..n-1).
      src, dst: int32[|E|] edge endpoints, in *trace order* (the paper streams
        edges in program order; greedy placement quality depends on it).
      w: float64[|E|] edge weights (estimated memory-op time / bytes moved).
      name: label used in reports.
      node_labels: optional per-vertex labels (e.g. jaxpr primitive names).
    """

    n: int
    src: np.ndarray
    dst: np.ndarray
    w: np.ndarray
    name: str = "graph"
    node_labels: Sequence[str] | None = None

    def __post_init__(self):
        self.src = np.asarray(self.src, dtype=np.int32)
        self.dst = np.asarray(self.dst, dtype=np.int32)
        self.w = np.asarray(self.w, dtype=np.float64)
        if not (len(self.src) == len(self.dst) == len(self.w)):
            raise ValueError("src/dst/w must have equal length")
        if len(self.src) and (self.src.min() < 0 or
                              max(self.src.max(), self.dst.max()) >= self.n):
            raise ValueError("edge endpoint out of range")
        self._degree_cache: np.ndarray | None = None

    # ------------------------------------------------------------------ #
    # basic properties
    # ------------------------------------------------------------------ #
    @property
    def num_edges(self) -> int:
        return int(len(self.src))

    @property
    def num_vertices(self) -> int:
        return int(self.n)

    @property
    def total_weight(self) -> float:
        return float(self.w.sum())

    @property
    def avg_weight(self) -> float:
        return float(self.w.mean()) if len(self.w) else 0.0

    def degrees(self) -> np.ndarray:
        """Total (in+out) degree per vertex — the d_i of Algorithm 1 line 3."""
        if self._degree_cache is None:
            deg = np.bincount(self.src, minlength=self.n)
            deg += np.bincount(self.dst, minlength=self.n)
            self._degree_cache = deg.astype(np.int64)
        return self._degree_cache

    # ------------------------------------------------------------------ #
    # power-law statistics (paper §2, Table 4)
    # ------------------------------------------------------------------ #
    def power_law_alpha(self, d_min: int = 1) -> float:
        """MLE estimate of the power-law exponent alpha of the degree dist.

        Discrete MLE (Clauset et al.): alpha ≈ 1 + n / sum(ln(d / (d_min - .5))).
        """
        d = self.degrees()
        d = d[d >= d_min]
        if len(d) == 0:
            return float("nan")
        return float(1.0 + len(d) / np.log(d / (d_min - 0.5)).sum())

    def degree_histogram(self) -> tuple[np.ndarray, np.ndarray]:
        d = self.degrees()
        vals, counts = np.unique(d[d > 0], return_counts=True)
        return vals, counts

    def stats(self) -> dict:
        return {
            "name": self.name,
            "nodes": self.num_vertices,
            "edges": self.num_edges,
            "alpha": round(self.power_law_alpha(), 3),
            "total_weight": self.total_weight,
            "max_degree": int(self.degrees().max()) if self.n else 0,
        }

    # ------------------------------------------------------------------ #
    # adjacency / construction helpers
    # ------------------------------------------------------------------ #
    def csr(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Undirected CSR adjacency: (indptr, neighbor ids, edge ids)."""
        return csr_adjacency(self.n, self.src, self.dst)

    @classmethod
    def from_edges(cls, edges: Iterable[tuple[int, int, float]],
                   name: str = "graph", n: int | None = None) -> "IRGraph":
        e = list(edges)
        if e:
            src, dst, w = map(np.asarray, zip(*e))
        else:
            src = dst = w = np.zeros(0)
        n = int(n if n is not None else (max(src.max(), dst.max()) + 1 if len(e) else 0))
        return cls(n=n, src=src, dst=dst, w=w, name=name)

    def permuted_edges(self, seed: int = 0) -> "IRGraph":
        """Randomly permute edge stream order (for robustness experiments)."""
        rng = np.random.default_rng(seed)
        perm = rng.permutation(self.num_edges)
        return IRGraph(self.n, self.src[perm], self.dst[perm], self.w[perm],
                       name=f"{self.name}/shuffled")

    def save_npz(self, path: str) -> None:
        np.savez_compressed(path, n=self.n, src=self.src, dst=self.dst,
                            w=self.w, name=self.name)

    @classmethod
    def load_npz(cls, path: str) -> "IRGraph":
        z = np.load(path, allow_pickle=False)
        return cls(n=int(z["n"]), src=z["src"], dst=z["dst"], w=z["w"],
                   name=str(z["name"]))
