"""Pallas kernel layer: on-accelerator segment reductions for the
partition→metrics→mapping pipeline.

`segsum` holds the tiled segment-sum primitive (sorted-segment-ids
contract, per-block carry, interpret-mode fallback on CPU); `metrics`
ports the hot consumers — `_finalize`'s replica reduction, the replica
CSR, `cluster_interaction_graphs`, and the simulator accumulations —
onto it.  Selected through the existing engine switch as
`backend="pallas"`; the numpy paths remain the oracle.

The subpackage imports lazily from the core modules so `repro.core`
stays usable without jax; `pallas_available()` probes an actual tiny
reduction (not just the import) before the backend is offered.
"""
from .segsum import (DEFAULT_BLOCK, keyed_sum, pallas_available,
                     require_pallas, segment_sum)

__all__ = ["DEFAULT_BLOCK", "keyed_sum", "pallas_available",
           "require_pallas", "segment_sum"]
