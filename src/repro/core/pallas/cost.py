"""HLO-derived FLOP / HBM-byte costs for the Pallas kernel layer.

`benchmarks/roofline.py` judges the pallas bench rows against an
analytic roofline; the numbers come from here.  Each helper lowers the
*actual* jitted computation — `keyed_sum`'s stable-sort + segment-sum,
`replica_csr`'s `_csr_core` — at the pow2-bucketed shapes the pipeline
uses, compiles it, and feeds the compiled HLO text through
`repro.analysis.hlo_cost.analyze_hlo` (loop-aware, so the interpret-mode
grid/`fori_loop` while-loops are multiplied by their trip counts).
Results are `lru_cache`d per shape bucket: a bench suite pays a few
hundred milliseconds of lowering once per distinct bucket, which the
pow2 rounding keeps to a handful.
"""
from __future__ import annotations

import functools

from ...analysis.hlo_cost import analyze_hlo
from .segsum import _next_pow2, keyed_sum, require_pallas

try:                                    # optional accelerator layer
    import jax
    import jax.numpy as jnp
except Exception:                       # pragma: no cover - no jax in env
    jax = jnp = None

__all__ = ["keyed_sum_cost", "replica_csr_cost",
           "partitioner_finalize_cost", "interaction_cost"]

_MIN_PAD = 8


def _bucket(x: int, floor: int = _MIN_PAD) -> int:
    return max(_next_pow2(max(int(x), 1)), floor)


def _merge(*costs: dict) -> dict:
    return {"flops": sum(c["flops"] for c in costs),
            "hbm_bytes": sum(c["hbm_bytes"] for c in costs)}


@functools.lru_cache(maxsize=None)
def _keyed_sum_cost(m: int, num_keys: int) -> "tuple[float, float]":
    require_pallas()
    with jax.experimental.enable_x64():
        fn = jax.jit(lambda k, v: keyed_sum(k, v, num_keys, interpret=True))
        text = fn.lower(
            jax.ShapeDtypeStruct((m,), jnp.int64),
            jax.ShapeDtypeStruct((m,), jnp.float64),
        ).compile().as_text()
    cost = analyze_hlo(text)
    return cost.flops, cost.hbm_bytes


def keyed_sum_cost(m: int, num_keys: int) -> dict:
    """Cost of one ``keyed_sum`` over an ``m``-element stream into
    ``num_keys`` buckets, at the pow2 bucket of both (the kernel pads
    the same way, so nearby sizes share one lowering)."""
    if m <= 0 or num_keys <= 0:
        return {"flops": 0.0, "hbm_bytes": 0.0}
    flops, hbm = _keyed_sum_cost(_bucket(m), _bucket(num_keys, 1))
    return {"flops": flops, "hbm_bytes": hbm}


@functools.lru_cache(maxsize=None)
def _csr_cost(klen: int, pn: int, p: int) -> "tuple[float, float]":
    require_pallas()
    from .metrics import _csr_core
    with jax.experimental.enable_x64():
        text = _csr_core.lower(
            jax.ShapeDtypeStruct((klen,), jnp.int64), pn=pn, p=p,
        ).compile().as_text()
    cost = analyze_hlo(text)
    return cost.flops, cost.hbm_bytes


def replica_csr_cost(n: int, p: int, n_edges: int) -> dict:
    """Cost of `replica_csr`'s device core for an ``n``-vertex graph
    with ``n_edges`` edges cut into ``p`` parts (key stream is 2 keys
    per edge, padded like the real call)."""
    if n_edges <= 0:
        return {"flops": 0.0, "hbm_bytes": 0.0}
    flops, hbm = _csr_cost(_bucket(2 * n_edges), _bucket(n), int(p))
    return {"flops": flops, "hbm_bytes": hbm}


def partitioner_finalize_cost(n: int, m: int, p: int) -> dict:
    """Device work in `vertex_cut`'s pallas finalize: the replica CSR
    plus the two per-part reductions (loads, edge counts) over the
    ``m``-edge assignment stream."""
    return _merge(replica_csr_cost(n, p, m),
                  keyed_sum_cost(m, p), keyed_sum_cost(m, p))


def interaction_cost(n_members: int, p: int) -> dict:
    """Device work in `interaction_from_csr` for a replica set of
    ``n_members`` entries: the diagonal reference counts (p+1 keys) and
    the symmetrised star-comm reduction (p^2+1 keys), both streaming the
    padded member list.  The capped pairwise pass is size-class dependent
    and small next to these two; it is deliberately not modelled."""
    return _merge(keyed_sum_cost(n_members, p + 1),
                  keyed_sum_cost(n_members, p * p + 1))
