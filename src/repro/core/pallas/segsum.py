"""Tiled Pallas segment-sum kernel (sorted-segment-ids contract).

The metrics side of the pipeline — `_finalize`'s replica-set reduction,
`replica_csr`, `cluster_interaction_graphs`, and the simulator's
per-cluster/per-core accumulations — is one primitive applied over and
over: reduce a value stream by a *sorted* key stream.  This module
implements that primitive as a Pallas kernel so the whole reduction runs
on-accelerator next to the traced graphs (interpret mode keeps it
runnable on CPU CI).

Kernel shape
------------
One `pallas_call` with a 1-D grid over fixed-size blocks of the flat
(value, segment-id) stream.  Grid steps execute sequentially (TPU
"arbitrary" dimension semantics), so a segment spanning a block
boundary is handled with a **carry** held in SMEM scratch: the running
(segment id, partial sum) of the stream's current segment.  Inside a
block a `fori_loop` walks the elements in stream order, flushing the
carry into `out[segment]` whenever the id changes.  Because every
segment is flushed exactly once — when the next distinct id first
appears, or by the final block's epilogue — the kernel *assigns* rather
than scatter-adds, and the strict left-to-right accumulation makes the
result bit-identical to the sequential numpy oracles (`np.bincount`,
`np.add.at`) on the same sorted stream — not merely close: the same
float rounding.  (`np.add.reduceat` reduces pairwise, so floats match
it to rtol 1e-12 rather than exactly.)

The output block (`num_segments` slots plus one slack slot that absorbs
the padded tail) is revisited by every grid step and therefore lives in
VMEM for the whole call — `num_segments` must fit on-chip (fine for
cluster/core/p^2-keyed reductions; vertex-keyed reductions at millions
of segments would need an output-tiled variant, see ROADMAP).

Contract: `segment_ids` must be sorted ascending (the callers all
produce sorted keys via stable argsort — see `keyed_sum`); violations
silently misreduce unless `validate=True`.
"""
from __future__ import annotations

import functools
import os

import numpy as np

try:                                    # optional accelerator layer
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu
    _IMPORT_ERROR = None
except Exception as e:                  # pragma: no cover - no jax in env
    jax = jnp = lax = pl = pltpu = None
    _IMPORT_ERROR = e

__all__ = ["pallas_available", "require_pallas", "segment_sum", "keyed_sum",
           "with_x64", "DEFAULT_BLOCK"]

DEFAULT_BLOCK = 4096
_MIN_SEG_SLOTS = 128
_probe_result: "bool | None" = None
_probe_error: "BaseException | str | None" = None


def _interpret_default() -> bool:
    """Interpret mode everywhere except a real TPU backend.

    `REPRO_PALLAS_INTERPRET=0/1` overrides (e.g. to force-interpret on
    TPU while debugging, or to try the compiled path on GPU).
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "")
    try:
        return jax.default_backend() != "tpu"
    except Exception:                   # pragma: no cover - backend probing
        return True


def pallas_available() -> bool:
    """True when the Pallas segment-sum layer actually works here.

    Goes beyond an import check: runs one tiny multi-block reduction
    (cached) so a jax version with an incompatible pallas API reports
    unavailable instead of failing deep inside the pipeline — callers
    and CI then fall back to / test only the numpy backends.
    """
    global _probe_result, _probe_error
    if _probe_result is None:
        if jax is None:
            _probe_result, _probe_error = False, _IMPORT_ERROR
        else:
            try:
                got = segment_sum(
                    jnp.asarray(np.ones(6)), jnp.asarray([0, 0, 1, 3, 3, 3]),
                    4, block_size=2)
                _probe_result = np.array_equal(
                    np.asarray(got), [2.0, 1.0, 0.0, 3.0])
                if not _probe_result:   # pragma: no cover - foreign jax API
                    _probe_error = f"probe miscomputed: {np.asarray(got)!r}"
            except Exception as e:      # pragma: no cover - foreign jax API
                _probe_result, _probe_error = False, e
    return _probe_result


def require_pallas() -> None:
    if not pallas_available():
        raise RuntimeError(
            "backend='pallas' needs a working jax.experimental.pallas "
            f"(probe failed with: {_probe_error!r}); use backend='fast'")


def _next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1).bit_length())


def with_x64(fn):
    """Run `fn` under thread-scoped x64 (`jax.experimental.enable_x64`).

    The oracle paths carry float64 weights / int64 counters, and a
    silent downcast would break the rtol-1e-12 / bit-identical
    guarantees — but flipping the *global* x64 flag from a library
    import would leak into unrelated jax code in the same process (the
    model/serving stack traces with int32 indices).  The context
    manager scopes the precision to this layer's calls only; jit caches
    key on the config state, so traced kernels stay consistent.
    """
    @functools.wraps(fn)
    def wrapper(*args, **kw):
        if jax is None:
            raise RuntimeError(f"pallas layer needs jax: {_IMPORT_ERROR!r}")
        with jax.experimental.enable_x64():
            return fn(*args, **kw)
    return wrapper


if jax is not None:
    def _segsum_kernel(sid_ref, data_ref, out_ref, carry_sid, carry_acc,
                       *, block: int, nblocks: int):
        pid = pl.program_id(0)

        @pl.when(pid == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)
            carry_sid[0] = sid_ref[0]
            carry_acc[0] = jnp.zeros((), out_ref.dtype)

        def body(j, _):
            s_j = sid_ref[j]

            @pl.when(s_j != carry_sid[0])
            def _flush():
                out_ref[carry_sid[0]] = carry_acc[0]
                carry_acc[0] = jnp.zeros((), out_ref.dtype)
                carry_sid[0] = s_j

            carry_acc[0] = carry_acc[0] + data_ref[j]
            return 0

        lax.fori_loop(0, block, body, 0)

        @pl.when(pid == nblocks - 1)
        def _epilogue():
            # the stream's last segment never sees a successor id; with a
            # padded tail this writes the slack slot (sentinel id) instead
            out_ref[carry_sid[0]] = carry_acc[0]

    @functools.partial(jax.jit,
                       static_argnames=("out_slots", "block", "interpret"))
    def _segsum_call(sids, data, out_slots: int, block: int, interpret: bool):
        nblocks = sids.shape[0] // block
        return pl.pallas_call(
            functools.partial(_segsum_kernel, block=block, nblocks=nblocks),
            grid=(nblocks,),
            in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                      pl.BlockSpec((block,), lambda i: (i,))],
            out_specs=pl.BlockSpec((out_slots,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((out_slots,), data.dtype),
            scratch_shapes=[pltpu.SMEM((1,), jnp.int32),
                            pltpu.SMEM((1,), data.dtype)],
            interpret=interpret,
        )(sids, data)


@with_x64
def segment_sum(data, segment_ids, num_segments: int, *,
                block_size: int = DEFAULT_BLOCK,
                interpret: "bool | None" = None,
                validate: bool = False):
    """Sum `data` into `num_segments` buckets keyed by sorted ids.

    Equivalent to the per-segment reduction over the runs (empty
    segments yield 0), accumulated strictly left-to-right — hence
    bit-identical to `np.add.at`/`np.bincount` for ints and floats
    alike, and within rtol 1e-12 of the pairwise `np.add.reduceat`.
    Lengths are padded to a power-of-two number of `block_size` blocks
    (sentinel ids land in a slack slot) so repeated calls at nearby
    sizes share jit cache entries.

    Args:
      data: 1-D values (any numeric dtype; float64/int64 preserved).
      segment_ids: 1-D ascending ints parallel to `data`.
      num_segments: bucket count (ids must be < num_segments).
      block_size: flat-stream tile; segments may span any number of
        blocks (the carry handles the boundaries).
      interpret: force Pallas interpret mode (default: auto — compiled
        on TPU, interpret elsewhere; see REPRO_PALLAS_INTERPRET).
      validate: host-check the sorted/range contract (debug aid).

    Returns:
      jax array of shape (num_segments,), dtype of `data`.
    """
    if jax is None:
        raise RuntimeError(f"pallas layer needs jax: {_IMPORT_ERROR!r}")
    data = jnp.asarray(data)
    sids = jnp.asarray(segment_ids)
    if data.ndim != 1 or sids.shape != data.shape:
        raise ValueError("data and segment_ids must be parallel 1-D arrays")
    if num_segments < 0:
        raise ValueError("num_segments must be >= 0")
    if validate and data.shape[0]:
        s = np.asarray(sids)
        if (np.diff(s) < 0).any():
            raise ValueError("segment_ids must be sorted ascending")
        if s[0] < 0 or s[-1] >= num_segments:
            raise ValueError("segment_ids must lie in [0, num_segments)")
    m = data.shape[0]
    if m == 0 or num_segments == 0:
        return jnp.zeros((num_segments,), data.dtype)
    if interpret is None:
        interpret = _interpret_default()
    block = block_size
    padded = block * _next_pow2(-(-m // block))
    # one slack slot absorbs the padded tail's sentinel id; the segment
    # axis is padded to a floored power of two as well — together with
    # the power-of-two block count this collapses nearby problem sizes
    # onto a handful of jit-cache entries (compiles, not runs, dominate
    # interpret-mode cost on small inputs)
    out_slots = max(_next_pow2(num_segments), _MIN_SEG_SLOTS) + 1
    sids = jnp.concatenate(
        [sids.astype(jnp.int32),
         jnp.full((padded - m,), out_slots - 1, jnp.int32)])
    data = jnp.concatenate([data, jnp.zeros((padded - m,), data.dtype)])
    out = _segsum_call(sids, data, out_slots, block, bool(interpret))
    return out[:num_segments]


@with_x64
def keyed_sum(keys, values, num_keys: int, **kw):
    """`segment_sum` over *unsorted* keys: stable-sort first.

    The stable sort preserves the relative order of entries sharing a
    key, so the per-bucket accumulation order equals the stream order —
    exactly `np.bincount(keys, weights=values)` / `np.add.at`, bit for
    bit.  This is the workhorse the metric ports call.
    """
    if jax is None:
        raise RuntimeError(f"pallas layer needs jax: {_IMPORT_ERROR!r}")
    keys = jnp.asarray(keys)
    values = jnp.asarray(values)
    order = jnp.argsort(keys, stable=True)
    return segment_sum(values[order], keys[order], num_keys, **kw)
