"""Device-side ports of the replica-CSR / interaction metrics.

These mirror the numpy implementations in `.._arrayops` but keep every
intermediate a jax array, with the reductions routed through the Pallas
`segment_sum` kernel — partition → metrics → mapping runs end-to-end on
the accelerator next to the traced graphs.  (`vertex_cut._finalize` and
the simulator consume `keyed_sum` directly for their load/time
accumulations.)  Each function documents which numpy oracle it must
match and how tightly:

  * integer outputs (replica CSR, shared counts, edge counts) are
    bit-identical — integer sums are order-free;
  * float accumulations route through `keyed_sum`, whose stable sort +
    sequential kernel reproduces the oracle's `np.bincount`/`np.add.at`
    accumulation order, so loads / comm matrices are bit-identical too
    (the equivalence tests assert exact equality where the oracle order
    is reproduced and rtol 1e-12 where a true reduction reorders, e.g.
    `jnp.sum` for total comm bytes).

Compilation discipline
----------------------
The glue is **jitted end-to-end**, not dispatched op by op: each public
function runs one or two `jax.jit` cores whose shapes are padded to
powers of two (stream length, vertex count, pairwise base count), so
novel graph shapes collapse onto a handful of cache entries instead of
paying ~250 per-op dispatches (~5 s of compiles on jax CPU) before the
cache warms.  Data-dependent output sizes (the deduped CSR length, the
non-owner triple count) are computed host-side from cheap numpy
bookkeeping and applied as static slices *outside* the traced cores,
with in-core sentinels keeping padded elements out of every reduction
(sentinel keys land in a slack bucket that is sliced off; padded values
contribute `+0.0` after all real entries, which leaves float
accumulation orders — and hence bit-identity — intact).

Every traced core bumps a counter in `_TRACE_COUNTS` as a tracing side
effect (Python runs only while jax traces, i.e. on a cache miss);
`trace_count()` exposes it so tests can assert cache hits across
same-bucket graphs — the probe that keeps this module honestly jitted.
"""
from __future__ import annotations

import collections
import functools

import numpy as np

from .segsum import (_next_pow2, keyed_sum, require_pallas, segment_sum,
                     with_x64)

try:
    import jax
    import jax.numpy as jnp
except Exception:                       # pragma: no cover - no jax in env
    jax = jnp = None

__all__ = ["replica_csr", "star_triples", "interaction_from_csr",
           "trace_count"]

_MIN_PAD = 8                            # floor for pow2-padded axes
_TRACE_COUNTS: "collections.Counter[str]" = collections.Counter()


def trace_count(name: "str | None" = None) -> int:
    """Times the jitted cores have been *traced* (compiled), total or by
    core name — the cache-hit probe used by the compile-count tests."""
    if name is not None:
        return _TRACE_COUNTS[name]
    return sum(_TRACE_COUNTS.values())


def _mark(name: str) -> None:
    # executes only while jax traces the enclosing function: a cache
    # hit never reaches this line
    _TRACE_COUNTS[name] += 1


def _pad_pow2(a: np.ndarray, fill, min_len: int = _MIN_PAD) -> np.ndarray:
    n = max(_next_pow2(len(a)), min_len)
    if n == len(a):
        return a
    out = np.full(n, fill, dtype=a.dtype)
    out[:len(a)] = a
    return out


# ---------------------------------------------------------------------- #
# replica CSR
# ---------------------------------------------------------------------- #
if jax is not None:
    @functools.partial(jax.jit, static_argnames=("pn", "p"))
    def _csr_core(key, pn: int, p: int):
        """Sorted-unique (vertex, cluster) keys with sentinel-padded
        duplicates, plus searchsorted indptr over pn+1 boundaries."""
        _mark("replica_csr")
        sent = pn * p
        key = jnp.sort(key)
        dup = jnp.concatenate(
            [jnp.zeros((1,), bool), key[1:] == key[:-1]])
        key = jnp.sort(jnp.where(dup, sent, key))
        count = jnp.searchsorted(key, sent)
        bounds = jnp.arange(pn + 1, dtype=jnp.int64) * p
        indptr = jnp.searchsorted(key, bounds)
        return key % p, indptr, count


@with_x64
def replica_csr(n: int, p: int, src, dst, assignment):
    """Device port of `_arrayops.replica_csr` (sorted unique-key CSR).

    Returns (indptr int64[n+1], flat int32[sum |A(v)|]) as jax arrays;
    bit-identical to the numpy path (both reduce to the sorted unique
    (vertex, cluster) key set).
    """
    require_pallas()
    src = np.asarray(src)
    dst = np.asarray(dst)
    a = np.asarray(assignment, dtype=np.int64)
    pn = max(_next_pow2(n), _MIN_PAD)
    key = np.concatenate([src.astype(np.int64) * p + a,
                          dst.astype(np.int64) * p + a])
    key = _pad_pow2(key, pn * p)
    flat, indptr, count = _csr_core(jnp.asarray(key), pn, p)
    k = int(count)
    return indptr[:n + 1].astype(jnp.int64), flat[:k].astype(jnp.int32)


# ---------------------------------------------------------------------- #
# star triples
# ---------------------------------------------------------------------- #
if jax is not None:
    @functools.partial(jax.jit, static_argnames=("has_bytes",))
    def _star_core(indptr, sizes, members, vb, m, has_bytes: bool):
        """Compact (owner, replica, bytes) triples to the front.

        Valid non-owner entries keep their stream order (stable argsort
        on a 0/1 key), which is exactly the order the numpy boolean
        mask emits — float comm accumulation order is preserved.
        """
        _mark("star_triples")
        mp = members.shape[0]
        seg_id = jnp.repeat(jnp.arange(sizes.shape[0], dtype=jnp.int64),
                            sizes, total_repeat_length=mp)
        first_pos = indptr[seg_id]
        pos = jnp.arange(mp, dtype=jnp.int64)
        non_owner = (pos != first_pos) & (pos < m)
        order = jnp.argsort(jnp.where(non_owner, 0, 1), stable=True)
        owners = members[first_pos][order]
        replicas = members[order]
        if has_bytes:
            b = vb[seg_id][order]
        else:
            b = jnp.ones((mp,), jnp.float64)
        return owners, replicas, b


def _star_padded(indptr, members, vertex_bytes):
    """(owners, replicas, b) padded device arrays + valid count K."""
    ip = np.asarray(indptr, dtype=np.int64)
    mem = np.asarray(members)
    sizes = np.diff(ip)
    k = len(mem) - int(np.count_nonzero(sizes))
    pn = max(_next_pow2(len(sizes)), _MIN_PAD)
    ip_pad = np.full(pn + 1, ip[-1] if len(ip) else 0, dtype=np.int64)
    ip_pad[:len(ip)] = ip
    sizes_pad = _pad_pow2(sizes.astype(np.int64), 0, pn)[:pn]
    mem_pad = _pad_pow2(mem.astype(np.int64), 0)
    has_bytes = vertex_bytes is not None
    if has_bytes:
        vb = _pad_pow2(np.asarray(vertex_bytes, dtype=np.float64), 0.0, pn)
    else:
        vb = np.zeros(1, np.float64)    # placeholder, untraced branch
    owners, replicas, b = _star_core(
        jnp.asarray(ip_pad), jnp.asarray(sizes_pad), jnp.asarray(mem_pad),
        jnp.asarray(vb), len(mem), has_bytes)
    return owners, replicas, b, k


@with_x64
def star_triples(indptr, members, vertex_bytes=None):
    """Device port of `_arrayops.star_triples` (owner, replica, bytes)."""
    require_pallas()
    owners, replicas, b, k = _star_padded(indptr, members, vertex_bytes)
    return owners[:k], replicas[:k], b[:k]


# ---------------------------------------------------------------------- #
# interaction graphs
# ---------------------------------------------------------------------- #
if jax is not None:
    @functools.partial(jax.jit, static_argnames=("p",))
    def _diag_core(members, m, p: int):
        """Per-cluster reference counts (integer, order-free)."""
        _mark("interaction_diag")
        pos = jnp.arange(members.shape[0], dtype=jnp.int64)
        key = jnp.where(pos < m, members, p)
        return keyed_sum(key, jnp.ones(key.shape, jnp.int64), p + 1)[:p]

    @functools.partial(jax.jit, static_argnames=("p",))
    def _star_comm_core(owners, replicas, b, k, p: int):
        """Symmetrised owner->replica comm matrix over p^2 keys.

        Sentinel keys (p^2) absorb the padded tail; real entries keep
        their order through `keyed_sum`'s stable sort, so the sums are
        bit-identical to the numpy flat-scatter path.
        """
        _mark("interaction_star")
        pos = jnp.arange(owners.shape[0], dtype=jnp.int64)
        valid = pos < k
        key = jnp.where(valid, owners * p + replicas, p * p)
        bb = jnp.where(valid, b, 0.0)
        sums = keyed_sum(key, bb, p * p + 1)[:p * p].reshape(p, p)
        return sums + sums.T

    @functools.partial(jax.jit, static_argnames=("s", "p"))
    def _pair_keys_core(base, nb, members, s: int, p: int):
        """x*p+y keys for all member pairs of the size-`s` segments."""
        _mark("interaction_pairs")
        iu, ju = np.triu_indices(s, k=1)
        x = members[base[:, None] + jnp.asarray(iu)[None, :]]
        y = members[base[:, None] + jnp.asarray(ju)[None, :]]
        valid = (jnp.arange(base.shape[0]) < nb)[:, None]
        return jnp.where(valid, x * p + y, p * p).ravel()

    @functools.partial(jax.jit, static_argnames=("p",))
    def _pair_count_core(keys, p: int):
        """Pair-count matrix from sentinel-padded keys (integer sums)."""
        _mark("interaction_pair_count")
        cnt = segment_sum(jnp.ones(keys.shape, jnp.int64), jnp.sort(keys),
                          p * p + 1)[:p * p]
        return cnt.astype(jnp.float64).reshape(p, p)


@with_x64
def interaction_from_csr(indptr, members, p: int, vertex_bytes=None,
                         pairwise_cap: int = 64):
    """Device port of `_arrayops.interaction_from_csr`.

    (comm[P,P], shared[P,P]) built with p^2-keyed segment sums instead of
    flat scatters; the star/pairwise key sets are identical to the numpy
    path and every sum shares its accumulation order, so both outputs
    are bit-identical to the fast (and hence reference) backends.
    """
    require_pallas()
    ip = np.asarray(indptr, dtype=np.int64)
    mem = np.asarray(members)
    if len(mem) == 0:
        z = jnp.zeros((p, p), jnp.float64)
        return z, z

    # diagonal: vertices referencing each cluster (members unique per seg)
    mem_pad = jnp.asarray(_pad_pow2(mem.astype(np.int64), 0))
    diag = _diag_core(mem_pad, len(mem), p)
    shared = jnp.zeros((p, p), jnp.float64).at[
        jnp.arange(p), jnp.arange(p)].set(diag.astype(jnp.float64))

    # star comm: owner->replica sums over p^2 keys; owner != replica
    # always (the owner is the first sorted member), so M has an empty
    # diagonal and symmetrisation is exactly M + M.T
    owners, replicas, b, k = _star_padded(ip, mem, vertex_bytes)
    comm = jnp.zeros((p, p), jnp.float64)
    if k:
        comm = _star_comm_core(owners, replicas, b, k, p)

    # capped pairwise shared counts, one size class at a time (same
    # enumeration as the numpy path; x < y strictly, so S + S.T again);
    # each (size, padded-base-count) pair compiles once and is reused
    sizes = np.diff(ip)
    mem_dev = jnp.asarray(mem.astype(np.int64))
    keys = []
    for s in np.unique(sizes):
        s = int(s)
        if s < 2 or s > pairwise_cap:
            continue
        base = ip[:-1][sizes == s]
        keys.append(_pair_keys_core(
            jnp.asarray(_pad_pow2(base, 0)), len(base), mem_dev, s, p))
    if keys:
        cap = max(_next_pow2(sum(kk.shape[0] for kk in keys)), _MIN_PAD)
        pad = jnp.full((cap - sum(kk.shape[0] for kk in keys),), p * p,
                       jnp.int64)
        pairs = _pair_count_core(jnp.concatenate(keys + [pad]), p)
        shared = shared + pairs + pairs.T
    return comm, shared
