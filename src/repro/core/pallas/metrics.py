"""Device-side ports of the replica-CSR / interaction metrics.

These mirror the numpy implementations in `.._arrayops` but keep every
intermediate a jax array, with the reductions routed through the Pallas
`segment_sum` kernel — partition → metrics → mapping runs end-to-end on
the accelerator next to the traced graphs.  (`vertex_cut._finalize` and
the simulator consume `keyed_sum` directly for their load/time
accumulations.)  Each function documents which numpy oracle it must
match and how tightly:

  * integer outputs (replica CSR, shared counts, edge counts) are
    bit-identical — integer sums are order-free;
  * float accumulations route through `keyed_sum`, whose stable sort +
    sequential kernel reproduces the oracle's `np.bincount`/`np.add.at`
    accumulation order, so loads / comm matrices are bit-identical too
    (the equivalence tests assert exact equality where the oracle order
    is reproduced and rtol 1e-12 where a true reduction reorders, e.g.
    `jnp.sum` for total comm bytes).
"""
from __future__ import annotations

import numpy as np

from .segsum import keyed_sum, require_pallas, segment_sum, with_x64

try:
    import jax.numpy as jnp
except Exception:                       # pragma: no cover - no jax in env
    jnp = None

__all__ = ["replica_csr", "star_triples", "interaction_from_csr"]


@with_x64
def replica_csr(n: int, p: int, src, dst, assignment):
    """Device port of `_arrayops.replica_csr` (sorted unique-key CSR).

    Returns (indptr int64[n+1], flat int32[sum |A(v)|]) as jax arrays;
    bit-identical to the numpy path (both reduce to the sorted unique
    (vertex, cluster) key set).
    """
    require_pallas()
    v = jnp.concatenate([jnp.asarray(src), jnp.asarray(dst)]).astype(jnp.int64)
    c = jnp.concatenate([jnp.asarray(assignment)] * 2).astype(jnp.int64)
    key = jnp.sort(v * p + c)
    if key.shape[0]:
        keep = jnp.ones(key.shape, bool).at[1:].set(key[1:] != key[:-1])
        key = key[keep]
    indptr = jnp.searchsorted(key, jnp.arange(n + 1, dtype=jnp.int64) * p)
    return indptr.astype(jnp.int64), (key % p).astype(jnp.int32)


def _segment_heads(indptr):
    """(seg_id, first_pos) per flat CSR entry — device `segment_entries`."""
    sizes = jnp.diff(indptr)
    seg_id = jnp.repeat(jnp.arange(sizes.shape[0], dtype=jnp.int64), sizes)
    return seg_id, indptr[seg_id]


@with_x64
def star_triples(indptr, members, vertex_bytes=None):
    """Device port of `_arrayops.star_triples` (owner, replica, bytes)."""
    require_pallas()
    indptr = jnp.asarray(indptr)
    members = jnp.asarray(members)
    seg_id, first_pos = _segment_heads(indptr)
    non_owner = jnp.arange(members.shape[0], dtype=jnp.int64) != first_pos
    owners = members[first_pos[non_owner]]
    replicas = members[non_owner]
    if vertex_bytes is None:
        b = jnp.ones(replicas.shape, jnp.float64)
    else:
        b = jnp.asarray(vertex_bytes, jnp.float64)[seg_id[non_owner]]
    return owners, replicas, b


@with_x64
def interaction_from_csr(indptr, members, p: int, vertex_bytes=None,
                         pairwise_cap: int = 64):
    """Device port of `_arrayops.interaction_from_csr`.

    (comm[P,P], shared[P,P]) built with p^2-keyed segment sums instead of
    flat scatters; the star/pairwise key sets are identical to the numpy
    path and every sum shares its accumulation order, so both outputs
    are bit-identical to the fast (and hence reference) backends.
    """
    require_pallas()
    indptr = jnp.asarray(indptr)
    mem = jnp.asarray(members).astype(jnp.int64)
    if mem.shape[0] == 0:
        z = jnp.zeros((p, p), jnp.float64)
        return z, z
    # diagonal: vertices referencing each cluster (members unique per seg)
    diag = keyed_sum(mem, jnp.ones(mem.shape, jnp.int64), p)
    shared = jnp.zeros((p, p), jnp.float64).at[
        jnp.arange(p), jnp.arange(p)].set(diag.astype(jnp.float64))

    # star comm: owner->replica sums over p^2 keys; owner != replica
    # always (the owner is the first sorted member), so M has an empty
    # diagonal and symmetrisation is exactly M + M.T
    owners, replicas, b = star_triples(indptr, mem, vertex_bytes)
    comm = jnp.zeros((p, p), jnp.float64)
    if owners.shape[0]:
        sums = keyed_sum(owners * p + replicas, b, p * p).reshape(p, p)
        comm = sums + sums.T

    # capped pairwise shared counts, one size class at a time (same
    # enumeration as the numpy path; x < y strictly, so S + S.T again)
    sizes = jnp.diff(indptr)
    keys = []
    for s in np.unique(np.asarray(sizes)):
        s = int(s)
        if s < 2 or s > pairwise_cap:
            continue
        base = indptr[:-1][sizes == s]
        iu, ju = np.triu_indices(s, k=1)
        x = mem[(base[:, None] + jnp.asarray(iu)[None, :]).ravel()]
        y = mem[(base[:, None] + jnp.asarray(ju)[None, :]).ravel()]
        keys.append(x * p + y)
    if keys:
        k = jnp.concatenate(keys)
        cnt = segment_sum(jnp.ones(k.shape, jnp.int64), jnp.sort(k), p * p)
        pairs = cnt.astype(jnp.float64).reshape(p, p)
        shared = shared + pairs + pairs.T
    return comm, shared
