"""jaxpr → IR graph: the TPU-native analogue of LLVM graph construction.

The paper compiles C programs to LLVM IR and builds a weighted dataflow
graph from the dynamic trace (§3).  JAX programs already pass through an
SSA IR — the jaxpr — whose equations play the role of IR instructions and
whose variables carry shaped array types.  This module converts any
traceable JAX function into an `IRGraph`:

  * vertex  = one executed primitive (jaxpr eqn); scans/whiles can be
    unrolled so each iteration contributes its own vertices — the direct
    analogue of the paper's *dynamic* trace vs. static IR;
  * edge    = SSA def→use dependency;
  * weight  = bytes of the value moved (the memory-op cost stand-in for
    the paper's rdtsc timing; DESIGN.md §2).

The graphs are used by `core.planner` to drive partitioning/mapping
decisions for the training framework, and they exhibit the same power-law
degree skew as the paper's LLVM graphs (broadcast weights, residual
streams and rngs are the hubs).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.extend import core as jcore

from .graph import IRGraph

__all__ = ["jaxpr_to_graph", "trace_to_graph", "eqn_flops"]

# primitives whose inner jaxpr is inlined (call-like)
_CALL_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr")


def _aval_bytes(aval) -> float:
    try:
        size = int(np.prod(aval.shape)) if aval.shape else 1
        itemsize = np.dtype(aval.dtype).itemsize
        return float(size * itemsize)
    except Exception:
        return 8.0


def eqn_flops(eqn) -> float:
    """Rough FLOP estimate per primitive (planner cost model)."""
    prim = eqn.primitive.name
    out_sizes = [int(np.prod(v.aval.shape)) if v.aval.shape else 1
                 for v in eqn.outvars if hasattr(v.aval, "shape")]
    out_elems = max(out_sizes) if out_sizes else 1
    if prim == "dot_general":
        # 2 * M * N * K
        lhs = eqn.invars[0].aval.shape
        dims = eqn.params["dimension_numbers"]
        contract = dims[0][0]
        k = int(np.prod([lhs[i] for i in contract])) if contract else 1
        return 2.0 * out_elems * k
    if prim in ("conv_general_dilated",):
        return 2.0 * out_elems * 9  # rough
    return float(out_elems)


def jaxpr_to_graph(closed_jaxpr, name: str = "jaxpr",
                   unroll_scans: bool = True,
                   max_scan_unroll: int = 8) -> IRGraph:
    """Flatten a (closed) jaxpr into an IRGraph.

    Args:
      closed_jaxpr: output of `jax.make_jaxpr(fn)(*args)`.
      unroll_scans: replicate scan bodies (up to `max_scan_unroll` copies)
        so the graph reflects the dynamic trace, like the paper's
        instrumented execution-order traces.
      max_scan_unroll: cap on per-scan unroll (61-layer models would
        otherwise explode the planner graph without adding structure).
    """
    src: list[int] = []
    dst: list[int] = []
    w: list[float] = []
    labels: list[str] = []

    def new_node(label: str) -> int:
        labels.append(label)
        return len(labels) - 1

    def add_edge(s: int, d: int, bytes_: float) -> None:
        src.append(s)
        dst.append(d)
        w.append(max(bytes_, 1.0))

    def walk(jaxpr, env: dict) -> None:
        """env maps jaxpr Var -> producing node id."""
        for eqn in jaxpr.eqns:
            inner = None
            if unroll_scans:
                for pname in _CALL_PARAMS:
                    if pname in eqn.params:
                        inner = eqn.params[pname]
                        break
            if inner is not None and eqn.primitive.name in (
                    "pjit", "custom_jvp_call", "custom_vjp_call",
                    "remat", "checkpoint", "closed_call"):
                ij = inner.jaxpr if hasattr(inner, "jaxpr") else inner
                sub_env = {}
                for var, outer in zip(ij.invars, eqn.invars):
                    nid = _resolve(outer, env, new_node)
                    sub_env[var] = nid
                for var, const in zip(ij.constvars,
                                      getattr(inner, "consts", [])):
                    sub_env[var] = new_node("const")
                walk(ij, sub_env)
                for outer_out, inner_out in zip(eqn.outvars, ij.outvars):
                    env[outer_out] = _resolve(inner_out, sub_env, new_node)
                continue
            if inner is not None and eqn.primitive.name == "scan":
                ij = inner.jaxpr if hasattr(inner, "jaxpr") else inner
                length = int(eqn.params.get("length", 1))
                reps = min(length, max_scan_unroll)
                n_carry = eqn.params.get("num_carry", 0)
                n_consts = eqn.params.get("num_consts", 0)
                carry_nodes = [
                    _resolve(v, env, new_node)
                    for v in eqn.invars[n_consts:n_consts + n_carry]]
                const_nodes = [_resolve(v, env, new_node)
                               for v in eqn.invars[:n_consts]]
                x_nodes = [_resolve(v, env, new_node)
                           for v in eqn.invars[n_consts + n_carry:]]
                for it in range(reps):
                    sub_env = {}
                    body_in = ij.invars
                    ins = const_nodes + carry_nodes + x_nodes
                    for var, nid in zip(body_in, ins):
                        sub_env[var] = nid
                    for var in ij.constvars:
                        sub_env[var] = new_node("const")
                    walk(ij, sub_env)
                    outs = [_resolve(v, sub_env, new_node)
                            for v in ij.outvars]
                    carry_nodes = outs[:n_carry]
                for outer_out, nid in zip(
                        eqn.outvars[:n_carry], carry_nodes):
                    env[outer_out] = nid
                for outer_out in eqn.outvars[n_carry:]:
                    env[outer_out] = new_node("scan_stack")
                continue

            nid = new_node(eqn.primitive.name)
            for iv in eqn.invars:
                pid = _resolve(iv, env, new_node)
                add_edge(pid, nid, _aval_bytes(iv.aval))
            for ov in eqn.outvars:
                env[ov] = nid

    top = closed_jaxpr.jaxpr
    env: dict = {}
    for var in list(top.invars) + list(top.constvars):
        env[var] = new_node("input")
    walk(top, env)

    n = len(labels)
    g = IRGraph(n=n, src=np.asarray(src, np.int32),
                dst=np.asarray(dst, np.int32),
                w=np.asarray(w, np.float64), name=name,
                node_labels=labels)
    return g


def _resolve(var, env: dict, new_node) -> int:
    if isinstance(var, jcore.Literal):
        return new_node("lit")
    if var not in env:
        env[var] = new_node("free")
    return env[var]


def trace_to_graph(fn, *args, name: str | None = None,
                   unroll_scans: bool = True, **kw) -> IRGraph:
    """`jax.make_jaxpr` + `jaxpr_to_graph` in one call."""
    cj = jax.make_jaxpr(fn)(*args, **kw)
    return jaxpr_to_graph(cj, name=name or getattr(fn, "__name__", "fn"),
                          unroll_scans=unroll_scans)
