"""The paper's 10 benchmarks (Table 3/4) as traced dataflow programs.

The paper builds LLVM graphs by (1) compiling to IR, (2) instrumenting with
``rdtsc``/``printf`` to get the *dynamic* trace with per-memory-op timing,
(3) dependency analysis.  We reproduce the same construction with a tiny
trace VM: every executed operation becomes a vertex, SSA/register uses and
memory RAW dependencies become edges, and memory operations are timed by a
reuse-distance cache model standing in for ``rdtsc`` (DESIGN.md §2 records
this substitution).  The resulting graphs are weighted DAGs in execution
order with power-law degree distributions, matching Table 4 qualitatively
(`scale="paper"` lands within ~2x of the published node/edge counts;
`scale="reduced"` keeps CI fast).

Benchmarks: Dijkstra, FFT, K-means, Mandel, MD, NN, Neuron, CNN,
Strassen8 (8x8 matrices), Strassen16 (16x16 matrices).
"""
from __future__ import annotations

import math
import os

import numpy as np

from .graph import IRGraph

__all__ = ["Tracer", "build_graph", "BENCHMARKS", "all_benchmark_names"]

# reuse-distance cache model: (threshold, cycles) — L1 hit, L2 hit, DRAM
_L1_WINDOW, _L1_T = 256, 4.0
_L2_WINDOW, _L2_T = 4096, 12.0
_DRAM_T = 100.0
_REG_T = 1.0  # register-register dependency weight


class _Mem:
    """An alloca'd region: base-pointer node + per-cell metadata."""

    __slots__ = ("base", "cells", "last_gep", "n_geps")

    def __init__(self, base: int, cells: list):
        self.base = base
        self.cells = cells
        self.last_gep = base
        self.n_geps = 0


class Tracer:
    """Dynamic-trace recorder: executes the program while building G.

    `gep_chain_period` controls address-computation structure: every K-th
    access re-anchors at the base pointer (direct indexing), intermediate
    ones chain off the previous gep (pointer-bump idiom).  K=1 gives the
    pure hub-and-spoke shape of the paper's Fig. 5 examples.
    """

    __slots__ = ("src", "dst", "w", "n_nodes", "clock", "name",
                 "gep_chain_period")

    def __init__(self, name: str, gep_chain_period: int = 1):
        self.name = name
        self.gep_chain_period = max(1, gep_chain_period)
        self.src: list[int] = []
        self.dst: list[int] = []
        self.w: list[float] = []
        self.n_nodes = 0
        self.clock = 0

    # -- node/edge primitives ------------------------------------------- #
    def _node(self) -> int:
        nid = self.n_nodes
        self.n_nodes = nid + 1
        self.clock += 1
        return nid

    def _edge(self, s: int, d: int, w: float) -> None:
        self.src.append(s)
        self.dst.append(d)
        self.w.append(w)

    # -- IR ops ----------------------------------------------------------#
    def const(self, val) -> tuple[int, float]:
        return (self._node(), val)

    def bin(self, op: str, a, b):
        """Arithmetic/compare: new node depending on both operands."""
        nid = self._node()
        self._edge(a[0], nid, _REG_T)
        self._edge(b[0], nid, _REG_T)
        x, y = a[1], b[1]
        if op == "+":
            v = x + y
        elif op == "-":
            v = x - y
        elif op == "*":
            v = x * y
        elif op == "/":
            v = x / y if y != 0 else 0.0
        elif op == "<":
            v = float(x < y)
        elif op == "max":
            v = x if x > y else y
        else:
            raise ValueError(op)
        return (nid, v)

    def un(self, op: str, a):
        nid = self._node()
        self._edge(a[0], nid, _REG_T)
        x = a[1]
        if op == "neg":
            v = -x
        elif op == "relu":
            v = x if x > 0 else 0.0
        elif op == "sqrt":
            v = math.sqrt(x) if x > 0 else 0.0
        else:
            raise ValueError(op)
        return (nid, v)

    def alloca(self, n: int, init=0.0):
        """A memory region.  Returns (base_ptr_node, cells) where each cell
        is [last_writer_node, value, last_access_clock].  The base pointer
        register is the LLVM-trace hub: every access computes an address
        from it via a `getelementptr` node (light register edges), which is
        what gives these graphs their power-law degree skew."""
        base = self._node()  # the alloca instruction itself
        return _Mem(base, [[base, init, self.clock] for _ in range(n)])

    def _mem_time(self, cell) -> float:
        age = self.clock - cell[2]
        if age < _L1_WINDOW:
            return _L1_T
        if age < _L2_WINDOW:
            return _L2_T
        return _DRAM_T

    def _gep(self, mem) -> int:
        """Address computation (`getelementptr`).  Compiled loops mix the
        pointer-bump idiom (gep chained off the previous gep) with direct
        indexing off the base pointer; we re-anchor to the base every 8th
        access, which reproduces both the gep chains and the moderate
        base-pointer hubs of real dynamic IR traces."""
        gep = self._node()
        anchor = (mem.base if mem.n_geps % self.gep_chain_period == 0
                  else mem.last_gep)
        self._edge(anchor, gep, _REG_T)
        mem.last_gep = gep
        mem.n_geps += 1
        return gep

    def load(self, mem, i: int):
        cell = mem.cells[i]
        t = self._mem_time(cell)
        gep = self._gep(mem)
        nid = self._node()
        self._edge(gep, nid, _REG_T)     # address -> load
        self._edge(cell[0], nid, t)      # RAW memory dependency, timed
        cell[2] = self.clock
        return (nid, cell[1])

    def store(self, mem, i: int, val) -> None:
        cell = mem.cells[i]
        t = self._mem_time(cell)
        gep = self._gep(mem)
        nid = self._node()
        self._edge(gep, nid, _REG_T)     # address -> store
        self._edge(val[0], nid, t)       # value into memory, timed
        cell[0] = nid
        cell[1] = val[1]
        cell[2] = self.clock

    def graph(self) -> IRGraph:
        return IRGraph(n=self.n_nodes, src=np.array(self.src, np.int32),
                       dst=np.array(self.dst, np.int32),
                       w=np.array(self.w, np.float64), name=self.name)


# ---------------------------------------------------------------------- #
# benchmark programs (paper Table 3 inputs in scale="paper")
# ---------------------------------------------------------------------- #
def _dijkstra(t: Tracer, n: int) -> None:
    rng = np.random.default_rng(0)
    adj_np = rng.integers(1, 100, size=(n, n)).astype(float)
    adj = t.alloca(n * n)
    for i in range(n):
        for j in range(n):
            t.store(adj, i * n + j, t.const(adj_np[i, j]))
    dist = t.alloca(n, init=math.inf)
    done = t.alloca(n)
    t.store(dist, 0, t.const(0.0))
    for _ in range(n):
        best, best_v = -1, math.inf
        for v in range(n):
            dv = t.load(dist, v)
            fv = t.load(done, v)
            c = t.bin("<", dv, t.const(best_v))
            if fv[1] == 0.0 and c[1] == 1.0:
                best, best_v = v, dv[1]
        if best < 0:
            break
        t.store(done, best, t.const(1.0))
        du = t.load(dist, best)
        for v in range(n):
            wuv = t.load(adj, best * n + v)
            cand = t.bin("+", du, wuv)
            dv = t.load(dist, v)
            if cand[1] < dv[1]:
                t.store(dist, v, cand)


def _fft(t: Tracer, n: int) -> None:
    rng = np.random.default_rng(0)
    re = t.alloca(n)
    im = t.alloca(n)
    for i in range(n):
        t.store(re, i, t.const(float(rng.standard_normal())))
        t.store(im, i, t.const(0.0))
    # bit-reversal permutation
    j = 0
    for i in range(1, n):
        bit = n >> 1
        while j & bit:
            j ^= bit
            bit >>= 1
        j |= bit
        if i < j:
            a = t.load(re, i)
            b = t.load(re, j)
            t.store(re, i, b)
            t.store(re, j, a)
            a = t.load(im, i)
            b = t.load(im, j)
            t.store(im, i, b)
            t.store(im, j, a)
    # butterflies
    size = 2
    while size <= n:
        half = size // 2
        step = n // size
        for i in range(0, n, size):
            for k in range(half):
                ang = -2 * math.pi * k * step / n
                wr, wi = t.const(math.cos(ang)), t.const(math.sin(ang))
                ar, ai = t.load(re, i + k), t.load(im, i + k)
                br, bi = t.load(re, i + k + half), t.load(im, i + k + half)
                tr = t.bin("-", t.bin("*", br, wr), t.bin("*", bi, wi))
                ti = t.bin("+", t.bin("*", br, wi), t.bin("*", bi, wr))
                t.store(re, i + k, t.bin("+", ar, tr))
                t.store(im, i + k, t.bin("+", ai, ti))
                t.store(re, i + k + half, t.bin("-", ar, tr))
                t.store(im, i + k + half, t.bin("-", ai, ti))
        size *= 2


def _kmeans(t: Tracer, n: int, k: int = 4, iters: int = 12) -> None:
    rng = np.random.default_rng(0)
    px = t.alloca(n)
    py = t.alloca(n)
    for i in range(n):
        t.store(px, i, t.const(float(rng.standard_normal())))
        t.store(py, i, t.const(float(rng.standard_normal())))
    cx = t.alloca(k)
    cy = t.alloca(k)
    for c in range(k):
        t.store(cx, c, t.load(px, c))
        t.store(cy, c, t.load(py, c))
    assign = t.alloca(n)
    for _ in range(iters):
        for i in range(n):
            xi, yi = t.load(px, i), t.load(py, i)
            best, best_d = 0, math.inf
            for c in range(k):
                dx = t.bin("-", xi, t.load(cx, c))
                dy = t.bin("-", yi, t.load(cy, c))
                d = t.bin("+", t.bin("*", dx, dx), t.bin("*", dy, dy))
                if d[1] < best_d:
                    best, best_d = c, d[1]
            t.store(assign, i, t.const(float(best)))
        sx = t.alloca(k)
        sy = t.alloca(k)
        cnt = t.alloca(k)
        for i in range(n):
            c = int(t.load(assign, i)[1])
            t.store(sx, c, t.bin("+", t.load(sx, c), t.load(px, i)))
            t.store(sy, c, t.bin("+", t.load(sy, c), t.load(py, i)))
            t.store(cnt, c, t.bin("+", t.load(cnt, c), t.const(1.0)))
        for c in range(k):
            nc = t.load(cnt, c)
            if nc[1] > 0:
                t.store(cx, c, t.bin("/", t.load(sx, c), nc))
                t.store(cy, c, t.bin("/", t.load(sy, c), nc))


def _mandel(t: Tracer, npoints: int, max_iter: int = 24) -> None:
    side = int(math.sqrt(npoints))
    out = t.alloca(side * side)
    for i in range(side):
        for j in range(side):
            cre = t.const(-2.0 + 3.0 * i / side)
            cim = t.const(-1.5 + 3.0 * j / side)
            zr, zi = t.const(0.0), t.const(0.0)
            it = 0
            while it < max_iter:
                zr2 = t.bin("*", zr, zr)
                zi2 = t.bin("*", zi, zi)
                mag = t.bin("+", zr2, zi2)
                if mag[1] > 4.0:
                    break
                nzr = t.bin("+", t.bin("-", zr2, zi2), cre)
                zi = t.bin("+", t.bin("*", t.bin("*", t.const(2.0), zr), zi),
                           cim)
                zr = nzr
                it += 1
            t.store(out, i * side + j, t.const(float(it)))


def _md(t: Tracer, n: int) -> None:
    rng = np.random.default_rng(0)
    pos = [t.alloca(n) for _ in range(2)]
    force = [t.alloca(n) for _ in range(2)]
    for d in range(2):
        for i in range(n):
            t.store(pos[d], i, t.const(float(rng.standard_normal())))
    for i in range(n):
        fx, fy = t.const(0.0), t.const(0.0)
        xi, yi = t.load(pos[0], i), t.load(pos[1], i)
        for j in range(n):
            if j == i:
                continue
            dx = t.bin("-", xi, t.load(pos[0], j))
            dy = t.bin("-", yi, t.load(pos[1], j))
            r2 = t.bin("+", t.bin("*", dx, dx), t.bin("*", dy, dy))
            inv = t.bin("/", t.const(1.0), t.bin("+", r2, t.const(1e-3)))
            fx = t.bin("+", fx, t.bin("*", dx, inv))
            fy = t.bin("+", fy, t.bin("*", dy, inv))
        t.store(force[0], i, fx)
        t.store(force[1], i, fy)


def _matmul_fc(t: Tracer, x: list, w_np: np.ndarray, relu: bool) -> list:
    n_in, n_out = w_np.shape
    wmem = t.alloca(n_in * n_out)
    for i in range(n_in):
        for j in range(n_out):
            t.store(wmem, i * n_out + j, t.const(float(w_np[i, j])))
    out = t.alloca(n_out)
    for j in range(n_out):
        acc = t.const(0.0)
        for i in range(n_in):
            acc = t.bin("+", acc,
                        t.bin("*", t.load(x, i), t.load(wmem, i * n_out + j)))
        if relu:
            acc = t.un("relu", acc)
        t.store(out, j, acc)
    return out


def _nn(t: Tracer, n_in: int, hidden: tuple = (64, 64, 64),
        n_out: int = 10) -> None:
    rng = np.random.default_rng(0)
    x = t.alloca(n_in)
    for i in range(n_in):
        t.store(x, i, t.const(float(rng.standard_normal())))
    dims = [n_in, *hidden, n_out]
    for li in range(len(dims) - 1):
        w = rng.standard_normal((dims[li], dims[li + 1])) * 0.1
        x = _matmul_fc(t, x, w, relu=(li < len(dims) - 2))


def _neuron(t: Tracer, n_neurons: int, n_inputs: int = 100) -> None:
    rng = np.random.default_rng(0)
    x = t.alloca(n_inputs)
    for i in range(n_inputs):
        t.store(x, i, t.const(float(rng.standard_normal())))
    out = t.alloca(n_neurons)
    for nr in range(n_neurons):
        w = t.alloca(n_inputs)
        for i in range(n_inputs):
            t.store(w, i, t.const(float(rng.standard_normal() * 0.1)))
        acc = t.const(0.0)
        for i in range(n_inputs):
            acc = t.bin("+", acc, t.bin("*", t.load(x, i), t.load(w, i)))
        t.store(out, nr, t.un("relu", acc))


def _conv2d(t: Tracer, img: list, h: int, w: int, cin: int, cout: int,
            kern_np: np.ndarray) -> tuple[list, int, int]:
    kh = kw = kern_np.shape[2]
    oh, ow = h - kh + 1, w - kw + 1
    kern = t.alloca(cout * cin * kh * kw)
    for idx, val in enumerate(kern_np.ravel()):
        t.store(kern, idx, t.const(float(val)))
    out = t.alloca(cout * oh * ow)
    for co in range(cout):
        for i in range(oh):
            for j in range(ow):
                acc = t.const(0.0)
                for ci in range(cin):
                    for ki in range(kh):
                        for kj in range(kw):
                            px = t.load(img, ci * h * w + (i + ki) * w + (j + kj))
                            kv = t.load(kern, ((co * cin + ci) * kh + ki) * kw + kj)
                            acc = t.bin("+", acc, t.bin("*", px, kv))
                t.store(out, co * oh * ow + i * ow + j, t.un("relu", acc))
    return out, oh, ow


def _pool2(t: Tracer, img: list, c: int, h: int, w: int
           ) -> tuple[list, int, int]:
    oh, ow = h // 2, w // 2
    out = t.alloca(c * oh * ow)
    for ci in range(c):
        for i in range(oh):
            for j in range(ow):
                a = t.load(img, ci * h * w + 2 * i * w + 2 * j)
                b = t.load(img, ci * h * w + 2 * i * w + 2 * j + 1)
                cc = t.load(img, ci * h * w + (2 * i + 1) * w + 2 * j)
                d = t.load(img, ci * h * w + (2 * i + 1) * w + 2 * j + 1)
                t.store(out, ci * oh * ow + i * ow + j,
                        t.bin("max", t.bin("max", a, b), t.bin("max", cc, d)))
    return out, oh, ow


def _cnn(t: Tracer, img_side: int, c1: int = 6, c2: int = 12) -> None:
    rng = np.random.default_rng(0)
    img = t.alloca(img_side * img_side)
    for i in range(img_side * img_side):
        t.store(img, i, t.const(float(rng.standard_normal())))
    x, h, w = _conv2d(t, img, img_side, img_side, 1, c1,
                      rng.standard_normal((c1, 1, 3, 3)) * 0.1)
    x, h, w = _pool2(t, x, c1, h, w)
    x, h, w = _conv2d(t, x, h, w, c1, c2,
                      rng.standard_normal((c2, c1, 3, 3)) * 0.1)
    x, h, w = _pool2(t, x, c2, h, w)
    _matmul_fc(t, x, rng.standard_normal((c2 * h * w, 10)) * 0.1, relu=False)


def _strassen(t: Tracer, size: int, base: int = 2) -> None:
    rng = np.random.default_rng(0)

    def alloc_mat(n, init_np=None):
        m = t.alloca(n * n)
        if init_np is not None:
            for idx, val in enumerate(init_np.ravel()):
                t.store(m, idx, t.const(float(val)))
        return m

    def addsub(a, b, n, op):
        c = alloc_mat(n)
        for i in range(n * n):
            t.store(c, i, t.bin(op, t.load(a, i), t.load(b, i)))
        return c

    def quad(a, n, qi, qj):
        h = n // 2
        q = alloc_mat(h)
        for i in range(h):
            for j in range(h):
                t.store(q, i * h + j, t.load(a, (qi * h + i) * n + (qj * h + j)))
        return q

    def mul(a, b, n):
        if n <= base:
            c = alloc_mat(n)
            for i in range(n):
                for j in range(n):
                    acc = t.const(0.0)
                    for k in range(n):
                        acc = t.bin("+", acc, t.bin("*", t.load(a, i * n + k),
                                                    t.load(b, k * n + j)))
                    t.store(c, i * n + j, acc)
            return c
        h = n // 2
        a11, a12 = quad(a, n, 0, 0), quad(a, n, 0, 1)
        a21, a22 = quad(a, n, 1, 0), quad(a, n, 1, 1)
        b11, b12 = quad(b, n, 0, 0), quad(b, n, 0, 1)
        b21, b22 = quad(b, n, 1, 0), quad(b, n, 1, 1)
        m1 = mul(addsub(a11, a22, h, "+"), addsub(b11, b22, h, "+"), h)
        m2 = mul(addsub(a21, a22, h, "+"), b11, h)
        m3 = mul(a11, addsub(b12, b22, h, "-"), h)
        m4 = mul(a22, addsub(b21, b11, h, "-"), h)
        m5 = mul(addsub(a11, a12, h, "+"), b22, h)
        m6 = mul(addsub(a21, a11, h, "-"), addsub(b11, b12, h, "+"), h)
        m7 = mul(addsub(a12, a22, h, "-"), addsub(b21, b22, h, "+"), h)
        c = alloc_mat(n)
        for i in range(h):
            for j in range(h):
                k = i * h + j
                c11 = t.bin("+", t.bin("-", t.bin("+", t.load(m1, k),
                                                  t.load(m4, k)),
                                       t.load(m5, k)), t.load(m7, k))
                c12 = t.bin("+", t.load(m3, k), t.load(m5, k))
                c21 = t.bin("+", t.load(m2, k), t.load(m4, k))
                c22 = t.bin("+", t.bin("-", t.bin("+", t.load(m1, k),
                                                  t.load(m3, k)),
                                       t.load(m2, k)), t.load(m6, k))
                t.store(c, i * n + j, c11)
                t.store(c, i * n + (j + h), c12)
                t.store(c, (i + h) * n + j, c21)
                t.store(c, (i + h) * n + (j + h), c22)
        return c

    a = alloc_mat(size, rng.standard_normal((size, size)))
    b = alloc_mat(size, rng.standard_normal((size, size)))
    mul(a, b, size)


# ---------------------------------------------------------------------- #
# registry + caching
# ---------------------------------------------------------------------- #
# (builder, paper-scale kwargs, reduced-scale kwargs) — paper Table 3 inputs.
BENCHMARKS: dict = {
    "dijkstra":   (_dijkstra, {"n": 50}, {"n": 12}),
    "fft":        (_fft, {"n": 1024}, {"n": 64}),
    "kmeans":     (_kmeans, {"n": 128, "iters": 12}, {"n": 24, "iters": 4}),
    "mandel":     (_mandel, {"npoints": 4092}, {"npoints": 256}),
    "md":         (_md, {"n": 512}, {"n": 48}),
    "nn":         (_nn, {"n_in": 32, "hidden": (64, 64, 64)},
                   {"n_in": 12, "hidden": (16, 16, 16)}),
    "neuron":     (_neuron, {"n_neurons": 64, "n_inputs": 100},
                   {"n_neurons": 16, "n_inputs": 24}),
    "cnn":        (_cnn, {"img_side": 28}, {"img_side": 10}),
    "strassen8":  (_strassen, {"size": 8}, {"size": 4}),
    "strassen16": (_strassen, {"size": 16}, {"size": 8}),
}


def all_benchmark_names() -> list[str]:
    return list(BENCHMARKS)


def build_graph(name: str, scale: str = "reduced",
                cache_dir: str | None = ".cache/benchgraphs") -> IRGraph:
    """Build (or load cached) the dynamic-trace graph for a benchmark."""
    if name not in BENCHMARKS:
        raise ValueError(f"unknown benchmark {name!r}")
    if scale not in ("paper", "reduced"):
        raise ValueError("scale must be 'paper' or 'reduced'")
    path = None
    if cache_dir:
        os.makedirs(cache_dir, exist_ok=True)
        path = os.path.join(cache_dir, f"{name}_{scale}.npz")
        if os.path.exists(path):
            return IRGraph.load_npz(path)
    builder, paper_kw, reduced_kw = BENCHMARKS[name]
    t = Tracer(f"{name}/{scale}")
    builder(t, **(paper_kw if scale == "paper" else reduced_kw))
    g = t.graph()
    if path:
        g.save_npz(path)
    return g
