"""Optional native acceleration for the streaming vertex-cut engine.

`_fastcut.c` (shipped next to this module) implements the inner streaming
loop over the same flat numpy buffers the Python engines use: int32 edge
endpoints, a float64 load vector, and replica sets packed as rows of
uint64 bitmask limbs (one limb for p <= 64, a chunked `ceil(p/64)`-limb
row beyond that).  The kernel is compiled on first use with the system C
compiler into a per-user cache directory and loaded through ctypes — no
extra Python dependencies.  When no compiler is available the caller
falls back to the pure-Python fast engine transparently.

Set REPRO_NO_NATIVE=1 to disable the native engine (used in CI to test
the fallback path).
"""
from __future__ import annotations

import ctypes
import hashlib
import os
import shutil
import subprocess
import sys
import tempfile

import numpy as np

__all__ = ["native_engine", "native_available"]

_CACHE: list | None = None  # [fn_or_None], resolved once


def _source_path() -> str:
    return os.path.join(os.path.dirname(__file__), "_fastcut.c")


def _cache_dir() -> str | None:
    base = os.environ.get("XDG_CACHE_HOME") or os.path.join(
        os.path.expanduser("~"), ".cache")
    candidates = [
        os.path.join(base, "repro-fastcut"),
        # shared tmp fallback must be per-user and 0700: the .so name is
        # predictable, and ctypes.CDLL executes whatever sits there
        os.path.join(tempfile.gettempdir(),
                     f"repro-fastcut-{os.getuid()}"),
    ]
    for path in candidates:
        try:
            os.makedirs(path, mode=0o700, exist_ok=True)
            st = os.stat(path)
            if st.st_uid == os.getuid() and not (st.st_mode & 0o022):
                return path
        except OSError:
            continue
    return None


def _compiler() -> str | None:
    for cc in (os.environ.get("CC"), "cc", "gcc", "clang"):
        if cc and shutil.which(cc):
            return cc
    return None


def _build() -> ctypes.CDLL | None:
    if os.environ.get("REPRO_NO_NATIVE"):
        return None
    if sys.platform.startswith("win"):
        return None
    src = _source_path()
    if not os.path.exists(src):
        return None
    cc = _compiler()
    if cc is None:
        return None
    cache = _cache_dir()
    if cache is None:
        return None
    with open(src, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()[:16]
    so_path = os.path.join(cache, f"fastcut_{digest}.so")
    if not os.path.exists(so_path):
        fd, tmp = tempfile.mkstemp(suffix=".so", dir=os.path.dirname(so_path))
        os.close(fd)
        try:
            # plain -O3 keeps IEEE semantics (no -ffast-math), so the
            # native engine stays bit-identical to the Python engines
            subprocess.run([cc, "-O3", "-shared", "-fPIC", "-o", tmp, src],
                           check=True, capture_output=True, timeout=120)
            os.replace(tmp, so_path)
        except (OSError, subprocess.SubprocessError):
            if os.path.exists(tmp):
                os.unlink(tmp)
            return None
    try:
        return ctypes.CDLL(so_path)
    except OSError:
        return None


def _resolve():
    lib = _build()
    if lib is None:
        return None
    f64 = np.ctypeslib.ndpointer(np.float64, flags="C_CONTIGUOUS")
    i32 = np.ctypeslib.ndpointer(np.int32, flags="C_CONTIGUOUS")
    i64 = np.ctypeslib.ndpointer(np.int64, flags="C_CONTIGUOUS")
    u64 = np.ctypeslib.ndpointer(np.uint64, flags="C_CONTIGUOUS")
    fn = lib.stream_cut
    fn.restype = None
    fn.argtypes = [ctypes.c_int64, ctypes.c_int64, i32, i32, f64,
                   ctypes.c_int32, ctypes.c_int32, ctypes.c_double,
                   f64, u64, ctypes.c_int64, i64, i32]
    return fn


def native_engine():
    """The compiled `stream_cut` entry point, or None if unavailable."""
    global _CACHE
    if _CACHE is None:
        _CACHE = [_resolve()]
    return _CACHE[0]


def native_available() -> bool:
    return native_engine() is not None
