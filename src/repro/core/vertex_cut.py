"""Weight Balanced p-way Vertex Cut — paper §4 (Algorithm 1 and variants).

Implements all six vertex-cut strategies evaluated in the paper plus the
random baseline used for the theoretical analysis:

  random    — random edge placement (paper §4.2.1, analysed by Eq. 10)
  pg        — PowerGraph greedy, unweighted loads   [Gonzalez et al. 2012]
  libra     — degree-based greedy, unweighted       [Xie et al. 2014]
  w_pg      — Weighted PowerGraph                   (paper §4.3 case rules)
  wb_pg     — Weight Balanced PowerGraph            (paper §4.3, λ bound)
  w_libra   — Weighted Libra                        (paper §4.3 case rules)
  wb_libra  — Weight Balanced Libra                 (paper Algorithm 1)

All six greedy cuts share one streaming engine implementing the paper's
case rules; the unweighted baselines track loads in edge *counts*, the
weighted variants in edge *weights*.  Edges are streamed in SHUFFLED order
by default (`edge_order="shuffled"`), matching distributed graph-loading
practice [Gonzalez et al. 2012]: a shuffled stream hits Case 4 frequently
early on, seeding all p clusters — streaming a connected trace in strict
program order instead funnels every edge into the first cluster (a
pathology the λ bound of the WB variants repairs; see the edge-order
ablation in the benchmarks).

Two engines implement the same streaming semantics, selected with
`vertex_cut(..., backend=...)`:

  reference — the original per-edge Python loop over `set` replica sets
              with a lazy min-heap of cluster loads.  O(|E|·log p + Σ|A|),
              kept as the readable oracle the fast engines are verified
              against (see tests/test_backend_equivalence.py).
  fast      — array-native engine (the default).  Replica sets A(v) are
              packed bitmasks (a single machine word for p <= 64, chunked
              uint64 limbs up to p = 1024+), loads/degrees/remaining
              degrees live in flat arrays, the leading run of Case-4
              edges is seeded in one vectorized batch, and `_finalize`
              builds the replica CSR with a vectorized unique-sort
              instead of a per-edge loop.  The inner stream runs through
              an optional C kernel (`_fastcut.c`, compiled on first use —
              see `_native.py`) at ~15-20x reference throughput, or
              through a pure-Python bitmask loop when no compiler is
              available.  Both are bit-identical to the reference: same
              case rules, same double accumulation order, and the same
              deterministic (load, cluster-id) argmin tie-breaking.
  native    — force the C kernel (raises if unavailable).
  python    — force the pure-Python bitmask engine.
  pallas    — stream on the fast engine, then run `_finalize`'s replica
              and load reductions on-accelerator through the Pallas
              segment-sum kernel layer (`repro.core.pallas`); interpret
              mode keeps it runnable on CPU.  Loads and the replica CSR
              are bit-identical to the numpy finalize (the kernel
              reproduces `np.bincount`'s accumulation order).
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from .. import obs
from ._arrayops import replica_csr
from ._native import native_available, native_engine
from .graph import IRGraph

__all__ = ["VertexCutResult", "vertex_cut", "ALGORITHMS", "BACKENDS",
           "resolve_backend", "ShardCutState"]

ALGORITHMS = ("random", "pg", "libra", "w_pg", "wb_pg", "w_libra", "wb_libra")
BACKENDS = ("fast", "native", "python", "pallas", "reference")


def resolve_backend(backend: str = "fast") -> str:
    """Concrete engine a backend choice runs on ("native"/"python"/...).

    "pallas" resolves to itself: its *stream* runs on the fast engine,
    but the finalize/metrics reductions run on the Pallas kernel layer.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; choose from {BACKENDS}")
    if backend == "fast":
        return "native" if native_available() else "python"
    return backend


@dataclasses.dataclass
class VertexCutResult:
    """Outcome of a p-way vertex cut on graph `g`.

    Replica sets are stored as a CSR over sorted cluster ids
    (`replica_indptr`, `replica_flat`); the `replicas` property
    materialises the legacy list-of-sets view (None == empty) on demand.
    """

    graph_name: str
    method: str
    p: int
    lam: float
    assignment: np.ndarray          # int32[|E|] -> cluster id M(e)
    loads: np.ndarray               # float64[p], weighted loads Σ w_e
    edge_counts: np.ndarray         # int64[p]
    n_vertices: int
    total_weight: float
    replica_indptr: np.ndarray      # int64[|V|+1]
    replica_flat: np.ndarray        # int32[Σ|A(v)|], sorted per vertex
    _replicas_cache: list | None = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def replicas(self) -> list:
        """Per-vertex replica set A(v) as list of sets (None == empty)."""
        if self._replicas_cache is None:
            ip, flat = self.replica_indptr, self.replica_flat
            self._replicas_cache = [
                set(flat[ip[v]:ip[v + 1]].tolist()) if ip[v + 1] > ip[v]
                else None
                for v in range(self.n_vertices)]
        return self._replicas_cache

    def replica_csr(self) -> tuple[np.ndarray, np.ndarray]:
        """Replica sets as (indptr, members) — the array-native view the
        mapping/simulator fast paths consume directly (members are sorted
        cluster ids per vertex; the owner is the first entry)."""
        return self.replica_indptr, self.replica_flat

    def replica_sizes(self) -> np.ndarray:
        """|A(v)| per vertex (0 for isolated vertices)."""
        return np.diff(self.replica_indptr)

    # -- paper metrics ------------------------------------------------- #
    @property
    def replication_factor(self) -> float:
        """Eq. (2): 1/|V| Σ_v |A(v)|  (isolated vertices contribute 0)."""
        return len(self.replica_flat) / max(1, self.n_vertices)

    @property
    def replication_factor_active(self) -> float:
        sizes = self.replica_sizes()
        sizes = sizes[sizes > 0]
        return float(sizes.mean()) if len(sizes) else 0.0

    @property
    def edge_weight_imbalance(self) -> float:
        """Paper §6.2.2: (max_m Σ_{M(e)=m} w_e) / (w_avg |E| / p)."""
        ideal = self.total_weight / self.p
        return float(self.loads.max() / ideal) if ideal > 0 else 1.0

    @property
    def edge_count_imbalance(self) -> float:
        m = len(self.assignment)
        ideal = m / self.p
        return float(self.edge_counts.max() / ideal) if ideal > 0 else 1.0

    def replica_sync_volume(self, vertex_bytes: np.ndarray | float = 1.0
                            ) -> float:
        """Inter-cluster traffic of a vertex cut = replica synchronisation:
        Σ_v (|A(v)| - 1) · bytes(v).  (Paper §6.2.4 — the only communication
        in a vertex-cut partition is between a cut vertex and its replicas.)
        """
        extra = np.maximum(self.replica_sizes() - 1, 0)
        if np.isscalar(vertex_bytes):
            return float(extra.sum() * vertex_bytes)
        return float((extra * np.asarray(vertex_bytes)).sum())

    def summary(self) -> dict:
        return {
            "graph": self.graph_name, "method": self.method, "p": self.p,
            "replication_factor": round(self.replication_factor, 4),
            "edge_weight_imbalance": round(self.edge_weight_imbalance, 6),
            "edge_count_imbalance": round(self.edge_count_imbalance, 6),
        }


# ---------------------------------------------------------------------- #
# resumable shard state (the repro.dist worker building block)
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class ShardCutState:
    """Resumable greedy-stream state for one shard of the edge stream.

    Wraps exactly the flat buffers the fast engines mutate — loads,
    bitmask limb rows, remaining degrees — so a stream can be run in
    chunks: streaming a shard through repeated `stream_chunk` calls is
    bit-identical to one uninterrupted `_stream_fast` pass (the engines
    are pure functions of this state; the lazy heap is only an argmin
    accelerator rebuilt per call).  `repro.dist` runs one state per
    worker and periodically installs a merged near-global snapshot with
    `adopt` (PowerGraph-style oblivious mode; see
    `_arrayops.merge_limb_masks` / `merge_deltas`).
    """

    p: int
    limbs: int
    bound: float
    rule_pg: int                    # 0 = Libra rule (pre-swapped), 1 = PG
    engine: str                     # "native" or "python"
    loads: np.ndarray               # float64[p] — local near-global view
    masks: np.ndarray               # uint64[n*limbs] — A(v) limb rows
    rem: np.ndarray                 # int64[n] — remaining-degree view
    fresh: bool = True              # all-zero state (Case-4 batch eligible)

    @classmethod
    def create(cls, n: int, p: int, deg: np.ndarray, bound: float,
               libra_rule: bool, backend: str = "fast") -> "ShardCutState":
        """Fresh all-zero shard state for an n-vertex graph."""
        engine = resolve_backend(backend)
        if engine not in ("native", "python"):
            raise ValueError(
                f"shard streaming runs on the fast engines only, not "
                f"{backend!r} (the greedy stream is inherently sequential)")
        if engine == "native" and native_engine() is None:
            raise RuntimeError(
                "native backend requested but no C compiler is available "
                "(or REPRO_NO_NATIVE is set); use backend='fast'")
        limbs = (p + 63) // 64
        return cls(p=p, limbs=limbs, bound=bound,
                   rule_pg=0 if libra_rule else 1, engine=engine,
                   loads=np.zeros(p, dtype=np.float64),
                   masks=np.zeros(n * limbs, dtype=np.uint64),
                   rem=deg.astype(np.int64, copy=True))

    def stream_chunk(self, su: np.ndarray, sv: np.ndarray, w: np.ndarray,
                     out: np.ndarray) -> None:
        """Stream one contiguous chunk of (pre-swapped) edges.

        Mutates this state in place and writes cluster ids into `out`
        (a view over the chunk's slice of the stream-order output).
        The batched Case-4 seeding applies only while the state is
        fresh — exactly when `_stream_fast` would apply it.
        """
        m = len(su)
        if m == 0:
            return
        start = 0
        if self.fresh:
            start = _seed_case4(su, sv, w, self.p, self.loads, self.masks,
                                self.rem, out, self.limbs, bool(self.rule_pg))
            self.fresh = False
        if self.engine == "native":
            native_engine()(start, m, su, sv, w, self.p, self.rule_pg,
                            self.bound, self.loads, self.masks, self.limbs,
                            self.rem, out)
        else:
            _stream_python(start, m, su, sv, w, self.p, self.rule_pg,
                           self.bound, self.loads, self.masks, self.limbs,
                           self.rem, out, writeback=True)

    def adopt(self, loads: np.ndarray, rem: "np.ndarray | None",
              masks: np.ndarray) -> None:
        """Install a merged near-global snapshot (the full merge hook).

        `repro.dist.engine` calls this at merge barriers after reducing
        all shards' views (`merge_limb_masks` for replica masks,
        `merge_deltas` for loads / remaining degrees); the shard
        resumes streaming against the merged arrays.  `rem=None` skips
        the remaining-degree install — the Libra placement rule never
        consults `rem`, so Libra-method merges ship loads+masks only.
        Also clears `fresh`, so Case-4 batch seeding never re-fires
        mid-stream.
        """
        np.copyto(self.loads, loads)
        if rem is not None:
            np.copyto(self.rem, rem)
        np.copyto(self.masks, masks)
        self.fresh = False

    def adopt_loads(self, loads: np.ndarray) -> None:
        """Install merged loads only (the cheap adaptive-merge hook).

        The adaptive merge schedule reconciles the O(p) load vector
        every round but defers the O(n·limbs) replica/remaining-degree
        merge until the load-divergence bound trips — loads drive the
        λ-bound and every least-loaded argmin, so keeping them
        near-global is what protects balance between full merges.
        Clears `fresh` for the same reason `adopt` does: seeding
        assumes an all-zero load vector.
        """
        np.copyto(self.loads, loads)
        self.fresh = False

    def clone(self) -> "ShardCutState":
        """Deep copy: stream the copy without disturbing the original.

        The incremental repartitioner (`repro.serve`) flushes a pending
        edge tail into a clone at plan time, so the durable state only
        ever advances by full round quanta."""
        return ShardCutState(
            p=self.p, limbs=self.limbs, bound=self.bound,
            rule_pg=self.rule_pg, engine=self.engine,
            loads=self.loads.copy(), masks=self.masks.copy(),
            rem=self.rem.copy(), fresh=self.fresh)

    def grow(self, n: int) -> None:
        """Extend the state to an `n`-vertex graph (new rows empty).

        The pipelined dataflow creates shard states before the parse
        has discovered the full vertex set and grows them as merged
        parse shards arrive; unseen vertices have empty replica sets
        and zero remaining degree, which is exactly the all-zero
        extension.  A no-op when the state already covers `n`.
        """
        old = len(self.rem)
        if n <= old:
            return
        grown = np.zeros(n * self.limbs, dtype=np.uint64)
        grown[:old * self.limbs] = self.masks
        self.masks = grown
        rem = np.zeros(n, dtype=np.int64)
        rem[:old] = self.rem
        self.rem = rem


# ---------------------------------------------------------------------- #
# the streaming greedy engine
# ---------------------------------------------------------------------- #
def vertex_cut(g: IRGraph, p: int, method: str = "wb_libra",
               lam: float = 1.0, seed: int = 0,
               edge_order: str = "auto",
               backend: str = "fast") -> VertexCutResult:
    """Partition the edges of `g` into `p` clusters.

    Args:
      g: weighted dataflow graph.
      p: number of clusters (cores) — paper's |C|.
      method: one of ALGORITHMS.
      lam: λ ≥ 1 imbalance factor for the WB-* variants (paper Eq. 3).
      seed: RNG seed (random placement / stream shuffling).
      edge_order: "trace" (strict program order), "shuffled" (loader
        order), or "auto" (default): trace order for the λ-bounded WB
        variants — they exploit stream locality and the bound guards
        against its pathology — and shuffled order for the unbounded
        greedy variants, whose native regime is distributed graph loading
        [Gonzalez et al. 2012] and which funnel a connected program-order
        stream into a single cluster (the benchmark suite carries an
        edge-order ablation quantifying this).
      backend: "fast" (array-native; C kernel when available, else the
        pure-Python bitmask engine), "native"/"python" to force one fast
        engine, "pallas" (fast stream + on-accelerator finalize), or
        "reference" for the original loop (the oracle).  All backends
        produce identical assignments.
    """
    if method not in ALGORITHMS:
        raise ValueError(f"unknown method {method!r}; choose from {ALGORITHMS}")
    if p < 1:
        raise ValueError("p must be >= 1")
    if lam < 1.0:
        raise ValueError("lambda must be >= 1 (paper Eq. 3)")
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; choose from {BACKENDS}")

    m = g.num_edges
    weighted = method in ("w_pg", "wb_pg", "w_libra", "wb_libra")
    balanced = method in ("wb_pg", "wb_libra")
    libra_rule = method in ("libra", "w_libra", "wb_libra")
    if weighted and m and float(g.w.min()) < 0:
        # every engine's lazy min-heap relies on loads growing monotonically
        raise ValueError("edge weights must be >= 0 for the greedy cuts")

    rng = np.random.default_rng(seed)

    if backend == "pallas":
        from .pallas import require_pallas
        require_pallas()

    if method == "random":
        assignment = np.empty(m, dtype=np.int32)
        assignment[:] = rng.integers(0, p, size=m)
        return _finalize(g, method, p, lam, assignment, backend)

    if edge_order == "auto":
        edge_order = "trace" if balanced else "shuffled"
    if edge_order == "shuffled":
        perm = rng.permutation(m)
    elif edge_order == "trace":
        perm = np.arange(m)
    else:
        raise ValueError("edge_order must be 'shuffled', 'trace' or 'auto'")

    src = g.src[perm]
    dst = g.dst[perm]
    # Loads for greedy decisions: weights for the weighted variants, edge
    # counts for the unweighted PG/Libra baselines.
    w = g.w[perm] if weighted else np.ones(m)
    w = np.ascontiguousarray(w, dtype=np.float64)
    deg = g.degrees()
    # Algorithm 1 line 4: cluster weight-sum bound b = λ Σ w_e / p.
    # (Computed once here so every backend sees the identical bound.)
    total_load = float(w.sum())
    bound = lam * total_load / p if balanced else float("inf")

    if backend == "reference":
        with obs.span("cut.stream", engine="reference", edges=len(src)):
            assignment = _stream_reference(g.n, p, src, dst, w, deg,
                                           bound, libra_rule, perm)
    else:
        # the pallas backend streams on the fast engine: the greedy
        # stream is inherently sequential, only the reductions move
        assignment = _stream_fast(g.n, p, src, dst, w, deg, bound,
                                  libra_rule, perm,
                                  "fast" if backend == "pallas" else backend)
    return _finalize(g, method, p, lam, assignment, backend)


# ---------------------------------------------------------------------- #
# reference engine: the original per-edge loop over Python sets (oracle)
# ---------------------------------------------------------------------- #
def _stream_reference(n: int, p: int, src_a: np.ndarray, dst_a: np.ndarray,
                      w_a: np.ndarray, deg_a: np.ndarray, bound: float,
                      libra_rule: bool, perm: np.ndarray) -> np.ndarray:
    m = len(src_a)
    src = src_a.tolist()
    dst = dst_a.tolist()
    wl = w_a.tolist()
    # Algorithm 1 line 3: count degrees.
    deg = deg_a.tolist()
    # PowerGraph case-2 rule needs *unassigned* (remaining) degree.
    rem = list(deg)

    assignment = np.empty(m, dtype=np.int32)
    loads = [0.0] * p
    heap = [(0.0, c) for c in range(p)]  # lazy min-heap of (load, cluster)
    A: list = [None] * n                 # replica sets A(v)

    def least_global() -> int:
        while True:
            ld, c = heap[0]
            if loads[c] == ld:
                return c
            heapq.heappop(heap)

    def least_in(s) -> int:
        # deterministic argmin: lowest cluster id among minimum loads
        best, best_l = -1, float("inf")
        for c in s:
            lc = loads[c]
            if lc < best_l or (lc == best_l and c < best):
                best, best_l = c, lc
        return best

    for e in range(m):
        u, v = src[e], dst[e]
        Au, Av = A[u], A[v]
        we = wl[e]

        if not Au and not Av:
            # Case 4: both empty -> least loaded of all p clusters.
            c = least_global()
        elif not Av:
            # Case 3 (A(u) nonempty only).
            c = least_in(Au)
            if loads[c] >= bound:
                c = least_global()
        elif not Au:
            c = least_in(Av)
            if loads[c] >= bound:
                c = least_global()
        else:
            inter = Au & Av
            if inter:
                # Case 1: intersection nonempty.
                c = least_in(inter)
                if loads[c] >= bound:
                    c = least_in(Au | Av)
                    if loads[c] >= bound:
                        c = least_global()
            else:
                # Case 2: both nonempty, disjoint.
                if libra_rule:
                    # Libra: favour the LOWER-degree endpoint's clusters
                    # (the higher-degree vertex is cut — Alg. 1 line 27).
                    s_set, t_set = (Au, Av) if deg[u] <= deg[v] else (Av, Au)
                else:
                    # PowerGraph: endpoint with MORE unassigned edges.
                    s_set, t_set = (Au, Av) if rem[u] >= rem[v] else (Av, Au)
                c = least_in(s_set)
                if loads[c] >= bound:
                    c = least_in(t_set)
                    if loads[c] >= bound:
                        c = least_global()

        # Algorithm 1 line 37: M(e) <- m; A(v_i) <- m; A(v_j) <- m.
        assignment[perm[e]] = c
        nl = loads[c] + we
        loads[c] = nl
        heapq.heappush(heap, (nl, c))
        if Au is None:
            A[u] = {c}
        else:
            Au.add(c)
        if Av is None:
            A[v] = {c}
        else:
            Av.add(c)
        rem[u] -= 1
        rem[v] -= 1

    return assignment


# ---------------------------------------------------------------------- #
# fast engine: flat arrays + packed bitmask replica sets
# ---------------------------------------------------------------------- #
def _stream_fast(n: int, p: int, src: np.ndarray, dst: np.ndarray,
                 w: np.ndarray, deg: np.ndarray, bound: float,
                 libra_rule: bool, perm: np.ndarray,
                 backend: str) -> np.ndarray:
    m = len(src)
    if libra_rule:
        # Libra's case-2 rule compares static degrees, so the endpoint
        # order can be pre-swapped once, vectorized: A(su) is tried first.
        swap = deg[src] > deg[dst]
        su = np.ascontiguousarray(np.where(swap, dst, src), dtype=np.int32)
        sv = np.ascontiguousarray(np.where(swap, src, dst), dtype=np.int32)
    else:
        su = np.ascontiguousarray(src, dtype=np.int32)
        sv = np.ascontiguousarray(dst, dtype=np.int32)
    rule_pg = 0 if libra_rule else 1

    limbs = (p + 63) // 64
    loads = np.zeros(p, dtype=np.float64)
    masks = np.zeros(n * limbs, dtype=np.uint64)  # A(v) bitmask limb rows
    rem = deg.astype(np.int64, copy=True)
    out = np.empty(m, dtype=np.int32)

    run = _seed_case4(su, sv, w, p, loads, masks, rem, out, limbs,
                      bool(rule_pg))

    engine = None
    if backend in ("fast", "native"):
        engine = native_engine()
        if engine is None and backend == "native":
            raise RuntimeError(
                "native backend requested but no C compiler is available "
                "(or REPRO_NO_NATIVE is set); use backend='fast'")
    if engine is not None:
        with obs.span("cut.stream", engine="native", edges=m):
            engine(run, m, su, sv, w, p, rule_pg, bound, loads, masks,
                   limbs, rem, out)
    else:
        with obs.span("cut.stream", engine="python", edges=m):
            _stream_python(run, m, su, sv, w, p, rule_pg, bound, loads,
                           masks, limbs, rem, out)

    assignment = np.empty(m, dtype=np.int32)
    assignment[perm] = out
    return assignment


def _seed_case4(su: np.ndarray, sv: np.ndarray, w: np.ndarray, p: int,
                loads: np.ndarray, masks: np.ndarray, rem: np.ndarray,
                out: np.ndarray, limbs: int, rule_pg: bool) -> int:
    """Batched Case-4 seeding: the leading run of edges touching only
    fresh vertices goes to clusters 0..run-1 in one vectorized step.

    Exact because before cluster `i` is seeded, clusters i..p-1 all carry
    load 0 and the lazy heap breaks ties by lowest id — the sequential
    engine would pick exactly cluster i (weights must be positive so a
    seeded cluster can never drop back below an untouched one).
    """
    m = len(su)
    cap = min(p, m)
    if cap == 0:
        return 0
    ends = np.empty(2 * cap, dtype=np.int64)
    ends[0::2] = su[:cap]
    ends[1::2] = sv[:cap]
    order = np.argsort(ends, kind="stable")
    se = ends[order]
    dup = se[1:] == se[:-1]
    if dup.any():
        # a repeated vertex is no longer fresh: its second occurrence
        # (and everything after) is left to the streaming engine
        second = np.maximum(order[1:][dup], order[:-1][dup])
        run = int(second.min()) // 2
    else:
        run = cap
    if run:
        pos = w[:run] > 0
        if not pos.all():
            run = int(np.argmin(pos))
    if run == 0:
        return 0
    cs = np.arange(run, dtype=np.int64)
    loads[:run] = w[:run]
    bit = np.uint64(1) << (cs % 64).astype(np.uint64)
    masks[su[:run].astype(np.int64) * limbs + cs // 64] |= bit
    masks[sv[:run].astype(np.int64) * limbs + cs // 64] |= bit
    out[:run] = cs
    if rule_pg:
        np.subtract.at(rem, su[:run], 1)
        np.subtract.at(rem, sv[:run], 1)
    return run


def _stream_python(start: int, m: int, su_a: np.ndarray, sv_a: np.ndarray,
                   w_a: np.ndarray, p: int, rule_pg: int, bound: float,
                   loads_a: np.ndarray, masks: np.ndarray, limbs: int,
                   rem_a: np.ndarray, out: np.ndarray,
                   writeback: bool = False) -> None:
    """Pure-Python fast engine (fallback when the C kernel is absent).

    Same decisions as the reference loop, with the structural costs
    stripped: the stream starts after the batched Case-4 seeding, the
    Libra endpoint order is pre-swapped so the degree rule is branch-free,
    and the global argmin uses a fixed-size lazy lower-bound heap (an
    entry is a stale lower bound refreshed when it surfaces — valid
    because loads only grow) instead of one heap push per edge into an
    ever-growing heap.

    With `writeback=True` the final loads / remaining degrees / replica
    bitmasks are re-encoded into the caller's arrays so the stream is
    resumable (`ShardCutState.stream_chunk`); the one-shot `_stream_fast`
    path skips that O(n) epilogue because only `out` is consumed.
    """
    n = len(rem_a)
    loads = loads_a.tolist()
    A: list = [None] * n
    if start or masks.any():
        # decode existing replica bitmasks: present after the batched
        # Case-4 seeding, and on every resumed ShardCutState chunk
        rows = masks.reshape(n, limbs)
        for v in np.flatnonzero(rows.any(axis=1)).tolist():
            # '<u8' pins the limb layout so the decode also holds on
            # big-endian hosts
            x = int.from_bytes(rows[v].astype("<u8").tobytes(), "little")
            s = set()
            while x:
                b = x & -x
                s.add(b.bit_length() - 1)
                x ^= b
            A[v] = s
    rem = rem_a.tolist()
    su = su_a[start:].tolist()
    sv = sv_a[start:].tolist()
    wl = w_a[start:].tolist()

    heap = [(loads[c], c) for c in range(p)]
    heapq.heapify(heap)
    heapreplace = heapq.heapreplace
    res = [0] * (m - start)
    inf = float("inf")

    def least_in(s) -> int:
        # deterministic argmin: lowest cluster id among minimum loads
        best, best_l = -1, inf
        for c in s:
            lc = loads[c]
            if lc < best_l or (lc == best_l and c < best):
                best, best_l = c, lc
        return best

    def least_global() -> int:
        while True:
            ld, c = heap[0]
            if loads[c] == ld:
                return c
            heapreplace(heap, (loads[c], c))

    i = 0
    for u, v, we in zip(su, sv, wl):
        Au = A[u]
        Av = A[v]
        if Au:
            if Av:
                inter = Au & Av
                if inter:                            # case 1
                    c = least_in(inter)
                    if loads[c] >= bound:
                        c = least_in(Au | Av)
                        if loads[c] >= bound:
                            c = least_global()
                else:                                # case 2
                    if rule_pg and rem[u] < rem[v]:
                        s_set, t_set = Av, Au
                    else:                            # libra order pre-swapped
                        s_set, t_set = Au, Av
                    c = least_in(s_set)
                    if loads[c] >= bound:
                        c = least_in(t_set)
                        if loads[c] >= bound:
                            c = least_global()
            else:                                    # case 3
                c = least_in(Au)
                if loads[c] >= bound:
                    c = least_global()
        elif Av:                                     # case 3'
            c = least_in(Av)
            if loads[c] >= bound:
                c = least_global()
        else:                                        # case 4
            c = least_global()
            nl = loads[c] + we
            loads[c] = nl
            heapreplace(heap, (nl, c))
            A[u] = {c}
            A[v] = {c} if u != v else A[u]
            if rule_pg:
                rem[u] -= 1
                rem[v] -= 1
            res[i] = c
            i += 1
            continue

        loads[c] += we
        if Au is None:
            A[u] = {c}
        else:
            Au.add(c)
        Av = A[v]
        if Av is None:
            A[v] = {c}
        else:
            Av.add(c)
        if rule_pg:
            rem[u] -= 1
            rem[v] -= 1
        res[i] = c
        i += 1

    out[start:] = res
    if writeback:
        loads_a[:] = loads
        rem_a[:] = rem
        rows = masks.reshape(n, limbs)
        nbytes = limbs * 8
        for v, a in enumerate(A):
            if a:
                x = 0
                for c in a:
                    x |= 1 << c
                rows[v] = np.frombuffer(x.to_bytes(nbytes, "little"),
                                        dtype="<u8")


def _finalize(g: IRGraph, method: str, p: int, lam: float,
              assignment: np.ndarray,
              backend: str = "fast") -> VertexCutResult:
    with obs.span("cut.finalize", backend=backend):
        return _finalize_impl(g, method, p, lam, assignment, backend)


def _finalize_impl(g: IRGraph, method: str, p: int, lam: float,
                   assignment: np.ndarray,
                   backend: str = "fast") -> VertexCutResult:
    if backend == "pallas":
        # replica CSR through the shared _arrayops dispatch; loads and
        # edge counts through the segment-sum kernel (keyed_sum's
        # stable sort reproduces np.bincount's accumulation order, so
        # both are bit-identical to the numpy branch below)
        from .pallas import keyed_sum
        indptr, flat = replica_csr(g.n, p, g.src, g.dst, assignment,
                                   backend="pallas")
        loads = np.asarray(keyed_sum(assignment,
                                     np.asarray(g.w, np.float64), p))
        counts = np.asarray(keyed_sum(assignment,
                                      np.ones(len(assignment), np.int64), p))
    else:
        indptr, flat = replica_csr(g.n, p, g.src, g.dst, assignment)
        loads = np.bincount(assignment, weights=g.w,
                            minlength=p).astype(np.float64)
        counts = np.bincount(assignment, minlength=p).astype(np.int64)
    return VertexCutResult(
        graph_name=g.name, method=method, p=p, lam=lam,
        assignment=assignment, loads=loads,
        edge_counts=counts, n_vertices=g.n, total_weight=g.total_weight,
        replica_indptr=indptr, replica_flat=flat)
