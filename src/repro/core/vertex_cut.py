"""Weight Balanced p-way Vertex Cut — paper §4 (Algorithm 1 and variants).

Implements all six vertex-cut strategies evaluated in the paper plus the
random baseline used for the theoretical analysis:

  random    — random edge placement (paper §4.2.1, analysed by Eq. 10)
  pg        — PowerGraph greedy, unweighted loads   [Gonzalez et al. 2012]
  libra     — degree-based greedy, unweighted       [Xie et al. 2014]
  w_pg      — Weighted PowerGraph                   (paper §4.3 case rules)
  wb_pg     — Weight Balanced PowerGraph            (paper §4.3, λ bound)
  w_libra   — Weighted Libra                        (paper §4.3 case rules)
  wb_libra  — Weight Balanced Libra                 (paper Algorithm 1)

All six greedy cuts share one streaming engine implementing the paper's
case rules; the unweighted baselines track loads in edge *counts*, the
weighted variants in edge *weights*.  Edges are streamed in SHUFFLED order
by default (`edge_order="shuffled"`), matching distributed graph-loading
practice [Gonzalez et al. 2012]: a shuffled stream hits Case 4 frequently
early on, seeding all p clusters — streaming a connected trace in strict
program order instead funnels every edge into the first cluster (a
pathology the λ bound of the WB variants repairs; see the edge-order
ablation in the benchmarks).  Per-cluster loads are tracked with a lazy
min-heap (O(log p) amortised global argmin), subset argmin by direct scan
of the (small) replica sets: overall O(|E|·log p + Σ|A|), matching the
paper's O(|E|·|C|) bound with a better constant.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from .graph import IRGraph

__all__ = ["VertexCutResult", "vertex_cut", "ALGORITHMS"]

ALGORITHMS = ("random", "pg", "libra", "w_pg", "wb_pg", "w_libra", "wb_libra")


@dataclasses.dataclass
class VertexCutResult:
    """Outcome of a p-way vertex cut on graph `g`."""

    graph_name: str
    method: str
    p: int
    lam: float
    assignment: np.ndarray          # int32[|E|] -> cluster id M(e)
    replicas: list                  # per-vertex set A(v) (None == empty)
    loads: np.ndarray               # float64[p], weighted loads Σ w_e
    edge_counts: np.ndarray         # int64[p]
    n_vertices: int
    total_weight: float

    # -- paper metrics ------------------------------------------------- #
    @property
    def replication_factor(self) -> float:
        """Eq. (2): 1/|V| Σ_v |A(v)|  (isolated vertices contribute 0)."""
        tot = sum(len(a) for a in self.replicas if a)
        return tot / max(1, self.n_vertices)

    @property
    def replication_factor_active(self) -> float:
        sizes = [len(a) for a in self.replicas if a]
        return float(np.mean(sizes)) if sizes else 0.0

    @property
    def edge_weight_imbalance(self) -> float:
        """Paper §6.2.2: (max_m Σ_{M(e)=m} w_e) / (w_avg |E| / p)."""
        ideal = self.total_weight / self.p
        return float(self.loads.max() / ideal) if ideal > 0 else 1.0

    @property
    def edge_count_imbalance(self) -> float:
        m = len(self.assignment)
        ideal = m / self.p
        return float(self.edge_counts.max() / ideal) if ideal > 0 else 1.0

    def replica_sync_volume(self, vertex_bytes: np.ndarray | float = 1.0) -> float:
        """Inter-cluster traffic of a vertex cut = replica synchronisation:
        Σ_v (|A(v)| - 1) · bytes(v).  (Paper §6.2.4 — the only communication
        in a vertex-cut partition is between a cut vertex and its replicas.)
        """
        if np.isscalar(vertex_bytes):
            return float(sum((len(a) - 1) for a in self.replicas if a)
                         * vertex_bytes)
        tot = 0.0
        for v, a in enumerate(self.replicas):
            if a:
                tot += (len(a) - 1) * float(vertex_bytes[v])
        return tot

    def summary(self) -> dict:
        return {
            "graph": self.graph_name, "method": self.method, "p": self.p,
            "replication_factor": round(self.replication_factor, 4),
            "edge_weight_imbalance": round(self.edge_weight_imbalance, 6),
            "edge_count_imbalance": round(self.edge_count_imbalance, 6),
        }


# ---------------------------------------------------------------------- #
# the streaming greedy engine
# ---------------------------------------------------------------------- #
def vertex_cut(g: IRGraph, p: int, method: str = "wb_libra",
               lam: float = 1.0, seed: int = 0,
               edge_order: str = "auto") -> VertexCutResult:
    """Partition the edges of `g` into `p` clusters.

    Args:
      g: weighted dataflow graph.
      p: number of clusters (cores) — paper's |C|.
      method: one of ALGORITHMS.
      lam: λ ≥ 1 imbalance factor for the WB-* variants (paper Eq. 3).
      seed: RNG seed (random placement / stream shuffling).
      edge_order: "trace" (strict program order), "shuffled" (loader
        order), or "auto" (default): trace order for the λ-bounded WB
        variants — they exploit stream locality and the bound guards
        against its pathology — and shuffled order for the unbounded
        greedy variants, whose native regime is distributed graph loading
        [Gonzalez et al. 2012] and which funnel a connected program-order
        stream into a single cluster (the benchmark suite carries an
        edge-order ablation quantifying this).
    """
    if method not in ALGORITHMS:
        raise ValueError(f"unknown method {method!r}; choose from {ALGORITHMS}")
    if p < 1:
        raise ValueError("p must be >= 1")
    if lam < 1.0:
        raise ValueError("lambda must be >= 1 (paper Eq. 3)")

    m = g.num_edges
    weighted = method in ("w_pg", "wb_pg", "w_libra", "wb_libra")
    balanced = method in ("wb_pg", "wb_libra")
    libra_rule = method in ("libra", "w_libra", "wb_libra")

    assignment = np.empty(m, dtype=np.int32)
    rng = np.random.default_rng(seed)

    if method == "random":
        assignment[:] = rng.integers(0, p, size=m)
        return _finalize(g, method, p, lam, assignment)

    if edge_order == "auto":
        edge_order = "trace" if balanced else "shuffled"
    if edge_order == "shuffled":
        perm = rng.permutation(m)
    elif edge_order == "trace":
        perm = np.arange(m)
    else:
        raise ValueError("edge_order must be 'shuffled', 'trace' or 'auto'")
    src = g.src[perm].tolist()
    dst = g.dst[perm].tolist()
    # Loads for greedy decisions: weights for the weighted variants, edge
    # counts for the unweighted PG/Libra baselines.
    wl = g.w[perm].tolist() if weighted else [1.0] * m

    # Algorithm 1 line 3: count degrees.
    deg = g.degrees().tolist()
    # PowerGraph case-2 rule needs *unassigned* (remaining) degree.
    rem = list(deg)

    # Algorithm 1 line 4: cluster weight-sum bound b = λ Σ w_e / p.
    total_load = float(sum(wl))
    bound = lam * total_load / p if balanced else float("inf")

    loads = [0.0] * p
    heap = [(0.0, c) for c in range(p)]  # lazy min-heap of (load, cluster)
    A: list = [None] * g.n               # replica sets A(v)

    def least_global() -> int:
        while True:
            l, c = heap[0]
            if loads[c] == l:
                return c
            heapq.heappop(heap)

    def least_in(s) -> int:
        best, best_l = -1, float("inf")
        for c in s:
            lc = loads[c]
            if lc < best_l:
                best, best_l = c, lc
        return best

    for e in range(m):
        u, v = src[e], dst[e]
        Au, Av = A[u], A[v]
        we = wl[e]

        if not Au and not Av:
            # Case 4: both empty -> least loaded of all p clusters.
            c = least_global()
        elif not Av:
            # Case 3 (A(u) nonempty only).
            c = least_in(Au)
            if balanced and loads[c] >= bound:
                c = least_global()
        elif not Au:
            c = least_in(Av)
            if balanced and loads[c] >= bound:
                c = least_global()
        else:
            inter = Au & Av
            if inter:
                # Case 1: intersection nonempty.
                c = least_in(inter)
                if balanced and loads[c] >= bound:
                    c = least_in(Au | Av)
                    if loads[c] >= bound:
                        c = least_global()
            else:
                # Case 2: both nonempty, disjoint.
                if libra_rule:
                    # Libra: favour the LOWER-degree endpoint's clusters
                    # (the higher-degree vertex is cut — Alg. 1 line 27).
                    s_set, t_set = (Au, Av) if deg[u] <= deg[v] else (Av, Au)
                else:
                    # PowerGraph: endpoint with MORE unassigned edges.
                    s_set, t_set = (Au, Av) if rem[u] >= rem[v] else (Av, Au)
                c = least_in(s_set)
                if balanced and loads[c] >= bound:
                    c = least_in(t_set)
                    if loads[c] >= bound:
                        c = least_global()

        # Algorithm 1 line 37: M(e) <- m; A(v_i) <- m; A(v_j) <- m.
        assignment[perm[e]] = c
        nl = loads[c] + we
        loads[c] = nl
        heapq.heappush(heap, (nl, c))
        if Au is None:
            A[u] = {c}
        else:
            Au.add(c)
        if Av is None:
            A[v] = {c}
        else:
            Av.add(c)
        rem[u] -= 1
        rem[v] -= 1

    return _finalize(g, method, p, lam, assignment, replicas=A)


def _finalize(g: IRGraph, method: str, p: int, lam: float,
              assignment: np.ndarray, replicas: list | None = None
              ) -> VertexCutResult:
    if replicas is None:
        replicas = [None] * g.n
        for e in range(g.num_edges):
            a = int(assignment[e])
            for x in (int(g.src[e]), int(g.dst[e])):
                if replicas[x] is None:
                    replicas[x] = {a}
                else:
                    replicas[x].add(a)
    loads = np.zeros(p, dtype=np.float64)
    np.add.at(loads, assignment, g.w)
    counts = np.bincount(assignment, minlength=p).astype(np.int64)
    return VertexCutResult(
        graph_name=g.name, method=method, p=p, lam=lam,
        assignment=assignment, replicas=replicas, loads=loads,
        edge_counts=counts, n_vertices=g.n, total_weight=g.total_weight)
