"""Edge-cut baselines the paper compares against (§6.1).

  compnet — community-detection-inspired partitioner of [Xiao et al. 2017]:
            weighted label propagation finds communities, which are then
            packed into p balanced clusters (LPT).  Low cut, weaker balance.
  metis   — METIS-style multilevel edge cut [LaSalle et al. 2015]:
            heavy-edge-matching coarsening, LPT initial partition of the
            coarsest graph, then boundary-refinement (FM-lite) during
            uncoarsening.  Strong balance, more cut edges on power-law
            graphs — exactly the failure mode the paper exploits.

Both return an `EdgeCutResult` (vertex → cluster).  In an edge-cut
partition the inter-cluster traffic is the weight of *all* cut edges
(paper §6.2.4), unlike the vertex cut whose only traffic is replica sync.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ._arrayops import csr_adjacency, dedup_edges
from .graph import IRGraph

__all__ = ["EdgeCutResult", "edge_cut", "EDGE_CUT_METHODS"]

EDGE_CUT_METHODS = ("compnet", "metis")


@dataclasses.dataclass
class EdgeCutResult:
    graph_name: str
    method: str
    p: int
    parts: np.ndarray            # int32[|V|] vertex -> cluster
    loads: np.ndarray            # float64[p]: Σ w_e of edges owned by cluster
    cut_weight: float            # Σ w_e over inter-cluster edges
    cut_edges: int
    total_weight: float

    @property
    def edge_weight_imbalance(self) -> float:
        ideal = self.total_weight / self.p
        return float(self.loads.max() / ideal) if ideal > 0 else 1.0

    def cross_traffic(self) -> float:
        """Bytes moved between clusters = weight of cut edges."""
        return self.cut_weight

    def summary(self) -> dict:
        return {
            "graph": self.graph_name, "method": self.method, "p": self.p,
            "cut_weight": round(self.cut_weight, 2),
            "cut_edges": self.cut_edges,
            "edge_weight_imbalance": round(self.edge_weight_imbalance, 6),
        }


# ---------------------------------------------------------------------- #
def edge_cut(g: IRGraph, p: int, method: str = "metis",
             seed: int = 0) -> EdgeCutResult:
    if method == "compnet":
        parts = _compnet(g, p, seed)
    elif method == "metis":
        parts = _metis_like(g, p, seed)
    else:
        raise ValueError(f"unknown edge-cut method {method!r}")
    return _finalize(g, method, p, parts)


def _finalize(g: IRGraph, method: str, p: int,
              parts: np.ndarray) -> EdgeCutResult:
    parts = parts.astype(np.int32)
    cross = parts[g.src] != parts[g.dst]
    cut_w = float(g.w[cross].sum())
    # Work ownership: an edge is executed where its consumer (dst) lives.
    loads = np.zeros(p, dtype=np.float64)
    np.add.at(loads, parts[g.dst], g.w)
    return EdgeCutResult(graph_name=g.name, method=method, p=p, parts=parts,
                         loads=loads, cut_weight=cut_w,
                         cut_edges=int(cross.sum()),
                         total_weight=g.total_weight)


# ---------------------------------------------------------------------- #
# CompNet: weighted label propagation -> LPT packing
# ---------------------------------------------------------------------- #
def _compnet(g: IRGraph, p: int, seed: int, sweeps: int = 8) -> np.ndarray:
    rng = np.random.default_rng(seed)
    indptr, nbr, eid = g.csr()
    ew = g.w
    labels = np.arange(g.n, dtype=np.int64)
    order = np.arange(g.n)
    for _ in range(sweeps):
        rng.shuffle(order)
        changed = 0
        for v in order:
            lo, hi = indptr[v], indptr[v + 1]
            if lo == hi:
                continue
            ls = labels[nbr[lo:hi]]
            ws = ew[eid[lo:hi]]
            # adopt the label with the largest incident weight
            uniq, inv = np.unique(ls, return_inverse=True)
            scores = np.zeros(len(uniq))
            np.add.at(scores, inv, ws)
            best = uniq[int(np.argmax(scores))]
            if best != labels[v]:
                labels[v] = best
                changed += 1
        if changed == 0:
            break
    # pack communities into p clusters by vertex work (LPT)
    comm_ids, comm_inv = np.unique(labels, return_inverse=True)
    vwork = np.zeros(g.n)
    np.add.at(vwork, g.dst, g.w)     # consumer-side work
    cwork = np.zeros(len(comm_ids))
    np.add.at(cwork, comm_inv, vwork)
    order = np.argsort(-cwork)
    cluster_of_comm = np.zeros(len(comm_ids), dtype=np.int32)
    loads = np.zeros(p)
    for c in order:
        tgt = int(np.argmin(loads))
        cluster_of_comm[c] = tgt
        loads[tgt] += cwork[c]
    return cluster_of_comm[comm_inv]


# ---------------------------------------------------------------------- #
# METIS-like multilevel edge cut
# ---------------------------------------------------------------------- #
def _metis_like(g: IRGraph, p: int, seed: int,
                coarsest: int | None = None) -> np.ndarray:
    coarsest = coarsest or max(4 * p, 256)
    rng = np.random.default_rng(seed)

    # Work per vertex (balance target), collapsed during coarsening.
    vwork = np.zeros(g.n)
    np.add.at(vwork, g.dst, g.w)
    vwork += 1e-9  # keep isolated vertices movable

    n, src, dst, w, work = g.n, g.src.copy(), g.dst.copy(), g.w.copy(), vwork
    graphs = [(n, src, dst, w, work)]   # level 0 = finest
    matches: list[np.ndarray] = []      # match[i]: level i ids -> level i+1
    while n > coarsest:
        match = _heavy_edge_matching(n, src, dst, w, rng)
        n2 = int(match.max()) + 1
        if n2 >= n * 0.98:  # insufficient progress
            break
        s2, d2 = match[src], match[dst]
        keep = s2 != d2
        s2, d2, w2 = dedup_edges(n2, s2[keep], d2[keep], w[keep])
        work2 = np.zeros(n2)
        np.add.at(work2, match, work)
        matches.append(match)
        n, src, dst, w, work = n2, s2, d2, w2, work2
        graphs.append((n, src, dst, w, work))

    parts = _lpt_initial(n, src, dst, w, work, p, rng)
    parts = _refine(n, src, dst, w, work, parts, p)

    # project back through the levels, refining at each
    for lvl in range(len(matches) - 1, -1, -1):
        parts = parts[matches[lvl]]
        n, src, dst, w, work = graphs[lvl]
        parts = _refine(n, src, dst, w, work, parts, p, passes=1)
    return parts


def _heavy_edge_matching(n, src, dst, w, rng) -> np.ndarray:
    order = np.argsort(-w, kind="stable")
    matched = np.full(n, -1, dtype=np.int64)
    nxt = 0
    for e in order:
        u, v = int(src[e]), int(dst[e])
        if matched[u] < 0 and matched[v] < 0 and u != v:
            matched[u] = matched[v] = nxt
            nxt += 1
    for v in range(n):
        if matched[v] < 0:
            matched[v] = nxt
            nxt += 1
    return matched


def _lpt_initial(n, src, dst, w, work, p, rng) -> np.ndarray:
    order = np.argsort(-work)
    parts = np.zeros(n, dtype=np.int32)
    loads = np.zeros(p)
    for v in order:
        tgt = int(np.argmin(loads))
        parts[v] = tgt
        loads[tgt] += work[v]
    return parts


def _refine(n, src, dst, w, work, parts, p, passes: int = 3,
            balance_tol: float = 1.08) -> np.ndarray:
    if len(src) == 0:
        return parts
    indptr, nbr, eid = csr_adjacency(n, src, dst)
    ew = w
    loads = np.zeros(p)
    np.add.at(loads, parts, work)
    ideal = loads.sum() / p
    for _ in range(passes):
        moved = 0
        boundary = np.unique(np.concatenate(
            [src[parts[src] != parts[dst]], dst[parts[src] != parts[dst]]]))
        for v in boundary:
            lo, hi = indptr[v], indptr[v + 1]
            if lo == hi:
                continue
            cur = parts[v]
            ls = parts[nbr[lo:hi]]
            ws = ew[eid[lo:hi]]
            uniq, inv = np.unique(ls, return_inverse=True)
            gain = np.zeros(len(uniq))
            np.add.at(gain, inv, ws)
            internal = gain[uniq == cur].sum() if (uniq == cur).any() else 0.0
            best_gain, best_t = 0.0, cur
            for t, gsum in zip(uniq, gain):
                if t == cur:
                    continue
                if loads[t] + work[v] > balance_tol * ideal:
                    continue
                dg = gsum - internal
                if dg > best_gain:
                    best_gain, best_t = dg, int(t)
            if best_t != cur:
                loads[cur] -= work[v]
                loads[best_t] += work[v]
                parts[v] = best_t
                moved += 1
        if moved == 0:
            break
    return parts
