"""Deterministic NUMA multi-core cost simulator (paper §6 evaluation rig).

The paper evaluates partitions by executing them in gem5 on an out-of-order
NUMA mesh (Table 2).  gem5 is out of scope here; instead we charge each
cluster an analytic cost on the same machine model used by the mapper:

  compute   — Σ of edge weights (weights *are* memory-op time, §3) plus a
              fixed per-instruction issue cost; clusters sharing a core
              serialize (the paper's threshold=4 colocations).
  replica sync (vertex cut) — for every cut vertex, its owner pushes the
              value to each replica: hops·hop_latency + bytes/link_bw,
              charged to the receiving core; zero if owner and replica
              share a core (factor-1 benefit).
  cut edges (edge cut) — every inter-cluster edge moves its payload
              between the producing and consuming cores.
  synchronisation — critical-section/coherence traffic grows superlinearly
              with the cluster count (the paper observes comm turning back
              up beyond 128 clusters); modelled as σ·P·log2(P) messages.

Outputs: overall execution time (max over cores + sync) and total
inter-core data communication, the two quantities in Tables 6–9.

Like the partitioner and the mapper, the simulator runs on one of three
engines selected with `backend=`: "fast" (default) builds the vertex-cut
(owner, dst, bytes) replica-sync triples straight from the replica CSR
with no Python loop (`_arrayops.star_triples`); "pallas" runs the same
accumulations on-accelerator through the segment-sum kernel layer
(`repro.core.pallas`); "reference" is the original per-vertex loop over
`set` replica sets, kept as the oracle (tests assert all SimReports
agree to rtol 1e-12; the pallas/fast core_times are bit-identical).
"""
from __future__ import annotations

import dataclasses
import math
import os

import numpy as np

from .. import obs
from ._arrayops import star_triples
from .graph import IRGraph
from .mapping import (Machine, MappingResult, cluster_interaction_graphs,
                      resolve_mapping_backend)
from .vertex_cut import VertexCutResult
from .edge_cut import EdgeCutResult

__all__ = ["SimReport", "simulate", "run_pipeline", "vertex_bytes_model",
           "coerce_graph"]

# -- cost constants (machine-model scale; Table 2: 2.4 GHz OoO cores) ----
CYCLE = 1.0 / 2.4e9                   # edge weights are cycles (rdtsc units)
INSTR_COST = 0.5 * CYCLE              # avg non-memory issue cost (s/instr)
CACHE_LINE = 64.0                     # bytes moved per dependency/sync msg
SYNC_MSG_BYTES = 64.0                 # one cache line per sync message
SYNC_BASE = 100 * CYCLE               # critical-section entry cost (s)
WEIGHT_TO_SECONDS = CYCLE             # edge-weight unit -> seconds


@dataclasses.dataclass
class SimReport:
    graph_name: str
    method: str
    p: int
    exec_time: float                  # seconds (modelled)
    data_comm_bytes: float            # inter-core traffic
    core_times: np.ndarray
    sync_time: float
    sync_bytes: float

    def summary(self) -> dict:
        return {"graph": self.graph_name, "method": self.method, "p": self.p,
                "exec_time": self.exec_time,
                "data_comm_bytes": self.data_comm_bytes}


def vertex_bytes_model(g: IRGraph) -> np.ndarray:
    """Bytes synced per vertex replica: one cache line per value (§6.2.4 —
    the only vertex-cut traffic is replica synchronisation of cut vertices).
    """
    return np.full(g.n, CACHE_LINE)


# ---------------------------------------------------------------------- #
def simulate(g: IRGraph, partition, mapping: MappingResult,
             backend: str = "fast") -> SimReport:
    """Execute a partition (vertex- or edge-cut) on the mapped machine.

    `backend="pallas"` applies to vertex cuts (the paper's subject);
    edge-cut baselines always score on the numpy path.
    """
    backend = resolve_mapping_backend(backend)
    if isinstance(partition, VertexCutResult):
        with obs.span("sim.run", backend=backend, kind="vertex"):
            return _simulate_vertex_cut(g, partition, mapping, backend)
    if isinstance(partition, EdgeCutResult):
        with obs.span("sim.run", backend=backend, kind="edge"):
            return _simulate_edge_cut(g, partition, mapping)
    raise TypeError(f"unsupported partition type {type(partition)}")


def _per_cluster_compute(g: IRGraph, edge_cluster: np.ndarray,
                         p: int) -> np.ndarray:
    t = np.zeros(p)
    np.add.at(t, edge_cluster, g.w * WEIGHT_TO_SECONDS + INSTR_COST)
    return t


def _core_compute(cluster_time: np.ndarray, mapping: MappingResult
                  ) -> np.ndarray:
    core_t = np.zeros(mapping.machine.n_cores)
    np.add.at(core_t, mapping.core_of, cluster_time)
    return core_t


def _sync_model(p: int, n_cores: int) -> tuple[float, float]:
    """Critical-section synchronisation cost/traffic, same for all methods."""
    if p <= 1:
        return 0.0, 0.0
    rounds = p * math.log2(p)
    sync_bytes = rounds * SYNC_MSG_BYTES * max(1.0, p / 256.0)
    sync_time = rounds * SYNC_BASE / max(1, n_cores)
    return sync_time, sync_bytes


def _vc_triples_reference(r: VertexCutResult, vb: np.ndarray
                          ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Oracle: per-vertex loop flattening (owner, dst, bytes) triples."""
    owners, dsts, sizes = [], [], []
    for v, a in enumerate(r.replicas):
        if not a or len(a) < 2:
            continue
        members = sorted(a)
        owners.extend([members[0]] * (len(members) - 1))
        dsts.extend(members[1:])
        sizes.extend([vb[v]] * (len(members) - 1))
    return (np.asarray(owners, dtype=np.int64),
            np.asarray(dsts, dtype=np.int64), np.asarray(sizes))


def _simulate_pallas_vertex_cut(g: IRGraph, r: VertexCutResult,
                                mapping: MappingResult) -> SimReport:
    """Pallas engine: the same cost model with every accumulation routed
    through the on-device segment-sum kernel (`keyed_sum` reproduces the
    `np.add.at` accumulation order, so core_times are bit-identical to
    the fast engine; only the final `sum` reduction may reassociate,
    hence the rtol-1e-12 contract on `data_comm_bytes`)."""
    import jax
    import jax.numpy as jnp
    from .pallas import keyed_sum, require_pallas
    from .pallas import metrics as pm

    require_pallas()      # clean error on a broken pallas install
    mach = mapping.machine
    cluster_t = np.asarray(keyed_sum(
        r.assignment, g.w * WEIGHT_TO_SECONDS + INSTR_COST, r.p))
    core_t = np.asarray(keyed_sum(mapping.core_of, cluster_t,
                                  mach.n_cores))

    owners, dsts, b = pm.star_triples(*r.replica_csr(), vertex_bytes_model(g))
    core_wait = np.zeros(mach.n_cores)
    comm_bytes = 0.0
    if owners.shape[0]:
        # the eager glue needs the same thread-scoped x64 as the kernel
        # layer — float32 hop latencies would void the rtol-1e-12 bound
        with jax.experimental.enable_x64():
            core_of = jnp.asarray(mapping.core_of)
            oc = core_of[owners].astype(jnp.int64)
            dc = core_of[dsts].astype(jnp.int64)
            diff = oc != dc       # factor-1 colocation: coherence-free
            oc, dc, b = oc[diff], dc[diff], b[diff]
            hops = (jnp.abs(oc // mach.cols - dc // mach.cols)
                    + jnp.abs(oc % mach.cols - dc % mach.cols))
            lat = hops * mach.hop_latency + mach.coherence_penalty
            core_wait = np.asarray(keyed_sum(
                dc, lat / mach.mshr_overlap + b / mach.link_bw,
                mach.n_cores))
            comm_bytes = float(jnp.sum(b))
    sync_t, sync_b = _sync_model(r.p, mach.n_cores)
    exec_time = float((core_t + core_wait).max() + sync_t)
    return SimReport(g.name, r.method, r.p, exec_time,
                     comm_bytes + sync_b, core_t + core_wait, sync_t, sync_b)


def _simulate_vertex_cut(g: IRGraph, r: VertexCutResult,
                         mapping: MappingResult,
                         backend: str = "fast") -> SimReport:
    if backend == "pallas":
        return _simulate_pallas_vertex_cut(g, r, mapping)
    mach = mapping.machine
    cluster_t = _per_cluster_compute(g, r.assignment, r.p)
    core_t = _core_compute(cluster_t, mapping)

    vb = vertex_bytes_model(g)
    core_wait = np.zeros(mach.n_cores)
    # flatten (owner_core, dst_core, bytes) across all replica sets;
    # the fast path reads them straight off the replica CSR
    if backend == "fast":
        owners, dsts, b = star_triples(*r.replica_csr(), vb)
    else:
        owners, dsts, b = _vc_triples_reference(r, vb)
    if len(owners):
        oc = mapping.core_of[owners].astype(np.int64)
        dc = mapping.core_of[dsts].astype(np.int64)
        diff = oc != dc           # factor-1 colocation: coherence-free
        oc, dc, b = oc[diff], dc[diff], b[diff]
        hops = (np.abs(oc // mach.cols - dc // mach.cols)
                + np.abs(oc % mach.cols - dc % mach.cols))
        lat = hops * mach.hop_latency + mach.coherence_penalty
        np.add.at(core_wait, dc,
                  lat / mach.mshr_overlap + b / mach.link_bw)
        comm_bytes = float(b.sum())
    else:
        comm_bytes = 0.0
    sync_t, sync_b = _sync_model(r.p, mach.n_cores)
    exec_time = float((core_t + core_wait).max() + sync_t)
    return SimReport(g.name, r.method, r.p, exec_time,
                     comm_bytes + sync_b, core_t + core_wait, sync_t, sync_b)


def _simulate_edge_cut(g: IRGraph, r: EdgeCutResult,
                       mapping: MappingResult) -> SimReport:
    mach = mapping.machine
    # edge executed at consumer's cluster
    edge_cluster = r.parts[g.dst]
    cluster_t = _per_cluster_compute(g, edge_cluster, r.p)
    core_t = _core_compute(cluster_t, mapping)

    cu = r.parts[g.src]
    cv = r.parts[g.dst]
    cross = cu != cv
    core_wait = np.zeros(mach.n_cores)
    src_cores = mapping.core_of[cu[cross]].astype(np.int64)
    dst_cores = mapping.core_of[cv[cross]].astype(np.int64)
    diff = src_cores != dst_cores
    sc, dc = src_cores[diff], dst_cores[diff]
    hops = (np.abs(sc // mach.cols - dc // mach.cols)
            + np.abs(sc % mach.cols - dc % mach.cols))
    lat = hops * mach.hop_latency + mach.coherence_penalty
    np.add.at(core_wait, dc,
              lat / mach.mshr_overlap + CACHE_LINE / mach.link_bw)
    comm_bytes = float(len(sc) * CACHE_LINE)
    sync_t, sync_b = _sync_model(r.p, mach.n_cores)
    exec_time = float((core_t + core_wait).max() + sync_t)
    return SimReport(g.name, r.method, r.p, exec_time,
                     comm_bytes + sync_b, core_t + core_wait, sync_t, sync_b)


# ---------------------------------------------------------------------- #
def coerce_graph(g) -> IRGraph:
    """Accept an `IRGraph` or a path to one, in any serialization the
    repo knows: an `.npz` snapshot, a `.rtb[.gz|.zst]` binary trace
    container, or a TRACE_SCHEMA v0 NDJSON dynamic trace (plain or
    compressed — see `repro.trace.load_graph` for the suffix dispatch).
    The whole pipeline takes either an object or a path."""
    if isinstance(g, IRGraph):
        return g
    if isinstance(g, (str, os.PathLike)):
        from ..trace import load_graph
        return load_graph(g)
    raise TypeError(f"expected IRGraph or path, got {type(g).__name__}")


def run_pipeline(g, p: int, method: str, lam: float = 1.0,
                 machine: Machine | None = None, seed: int = 0,
                 backend: str = "fast", workers: int = 1,
                 merge_period: "int | None" = None,
                 divergence: "float | None" = None,
                 profile: "str | None" = None):
    """partition -> map -> simulate, returning (partition, mapping, report).

    The end-to-end path of Fig. 1: structure analysis is already in `g`
    (an `IRGraph`, or a path to an `.npz` snapshot / NDJSON dynamic
    trace), vertex/edge cut produces clusters, the memory-centric mapping
    schedules them, and the simulator scores the result.  `backend`
    selects the engine for every stage: the partitioner accepts any of
    its backends ("fast"/"native"/"python"/"pallas"/"reference") plus
    "dist" — the sharded streaming partitioner of `repro.dist`, which
    ingests trace paths through the parallel parse front end and runs
    the cut on `workers` shard workers merging every `merge_period`
    edges — full state merges every round, or adaptively when the
    per-cluster load drift exceeds `divergence` × the mean cluster load
    (`workers=1` is bit-identical to "fast").  The mapping and
    simulator run their reference oracle iff `backend == "reference"`
    and the Pallas segment-sum layer iff `backend == "pallas"`
    (interpret mode on CPU — see README Backends).

    `profile="out.json"` records the run's telemetry (ingest /
    partition / map / simulate stage spans plus every engine-level span
    beneath them) and writes a Perfetto-loadable profile to that path —
    the call-site twin of the `REPRO_PROFILE` env hook; render it with
    `python -m repro.obs summarize out.json`.  See docs/observability.md.
    """
    if profile is not None:
        with obs.profiled(profile):
            return _run_pipeline_impl(g, p, method, lam, machine, seed,
                                      backend, workers, merge_period,
                                      divergence)
    return _run_pipeline_impl(g, p, method, lam, machine, seed, backend,
                              workers, merge_period, divergence)


def _run_pipeline_impl(g, p: int, method: str, lam: float,
                       machine: "Machine | None", seed: int, backend: str,
                       workers: int, merge_period: "int | None",
                       divergence: "float | None"):
    from .edge_cut import EDGE_CUT_METHODS, edge_cut as _edge_cut
    from .vertex_cut import ALGORITHMS, vertex_cut as _vertex_cut
    from .mapping import memory_centric_mapping

    with obs.span("pipeline.ingest", cat="section", backend=backend):
        if backend == "dist" and isinstance(g, (str, os.PathLike)) \
                and not os.fspath(g).endswith(".npz"):
            from ..dist import dist_ingest
            g = dist_ingest(g, workers=workers)
        g = coerce_graph(g)

    machine = machine or Machine.for_clusters(p)
    map_backend = resolve_mapping_backend(backend)
    if method in ALGORITHMS:
        with obs.span("pipeline.partition", cat="section", backend=backend,
                      method=method, p=p):
            if backend == "dist":
                from ..dist import dist_vertex_cut
                part = dist_vertex_cut(g, p, method=method, lam=lam,
                                       seed=seed, workers=workers,
                                       merge_period=merge_period,
                                       divergence=divergence)
            else:
                part = _vertex_cut(g, p, method=method, lam=lam, seed=seed,
                                   backend=backend)
        with obs.span("pipeline.map", cat="section", backend=map_backend):
            comm, shared = cluster_interaction_graphs(
                part, p, vertex_bytes_model(g), backend=map_backend)
            mapping = memory_centric_mapping(comm, shared, machine,
                                             backend=map_backend)
    elif method in EDGE_CUT_METHODS:
        with obs.span("pipeline.partition", cat="section", backend=backend,
                      method=method, p=p):
            part = _edge_cut(g, p, method=method, seed=seed)
        with obs.span("pipeline.map", cat="section", backend=map_backend):
            # inter-cluster comm graph from cut edges (one line per
            # dependency)
            comm = np.zeros((p, p))
            cu, cv = part.parts[g.src], part.parts[g.dst]
            cross = cu != cv
            np.add.at(comm, (cu[cross], cv[cross]), CACHE_LINE)
            comm = comm + comm.T
            mapping = memory_centric_mapping(comm, np.zeros_like(comm),
                                             machine, backend=map_backend)
    else:
        raise ValueError(f"unknown method {method!r}")
    with obs.span("pipeline.simulate", cat="section", backend=map_backend):
        report = simulate(g, part, mapping, backend=map_backend)
    return part, mapping, report
