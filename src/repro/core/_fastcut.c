/* Streaming greedy vertex-cut engine over flat numpy buffers.
 *
 * Exact mirror of the reference Python loop in repro/core/vertex_cut.py:
 * same case rules, same (load, cluster-id) tie-breaking, same double
 * accumulation order, so assignments are bit-identical.
 */
#include <stdint.h>

static inline int least_in_mask(const uint64_t *mask, int64_t L,
                                const double *loads) {
    int best = -1;
    double best_l = 0.0;
    for (int64_t i = 0; i < L; i++) {
        uint64_t word = mask[i];
        while (word) {
            int c = (int)(i * 64 + __builtin_ctzll(word));
            double lc = loads[c];
            if (best < 0 || lc < best_l) { best = c; best_l = lc; }
            word &= word - 1;
        }
    }
    return best;
}

static inline int least_in_and(const uint64_t *a, const uint64_t *b,
                               int64_t L, const double *loads) {
    int best = -1;
    double best_l = 0.0;
    for (int64_t i = 0; i < L; i++) {
        uint64_t word = a[i] & b[i];
        while (word) {
            int c = (int)(i * 64 + __builtin_ctzll(word));
            double lc = loads[c];
            if (best < 0 || lc < best_l) { best = c; best_l = lc; }
            word &= word - 1;
        }
    }
    return best;
}

static inline int least_in_or(const uint64_t *a, const uint64_t *b,
                              int64_t L, const double *loads) {
    int best = -1;
    double best_l = 0.0;
    for (int64_t i = 0; i < L; i++) {
        uint64_t word = a[i] | b[i];
        while (word) {
            int c = (int)(i * 64 + __builtin_ctzll(word));
            double lc = loads[c];
            if (best < 0 || lc < best_l) { best = c; best_l = lc; }
            word &= word - 1;
        }
    }
    return best;
}

static inline int least_global(const double *loads, int p) {
    int best = 0;
    double best_l = loads[0];
    for (int c = 1; c < p; c++)
        if (loads[c] < best_l) { best = c; best_l = loads[c]; }
    return best;
}

static inline int mask_any(const uint64_t *m, int64_t L) {
    for (int64_t i = 0; i < L; i++)
        if (m[i]) return 1;
    return 0;
}

static inline int mask_and_any(const uint64_t *a, const uint64_t *b,
                               int64_t L) {
    for (int64_t i = 0; i < L; i++)
        if (a[i] & b[i]) return 1;
    return 0;
}

/* rule_pg: 0 = Libra (su/sv pre-swapped so A(su) is tried first),
 *          1 = PowerGraph (endpoint with more unassigned edges first). */
void stream_cut(int64_t start, int64_t m,
                const int32_t *su, const int32_t *sv, const double *w,
                int32_t p, int32_t rule_pg, double bound,
                double *loads, uint64_t *masks, int64_t L,
                int64_t *rem, int32_t *out) {
    for (int64_t e = start; e < m; e++) {
        int32_t u = su[e], v = sv[e];
        uint64_t *au = masks + (int64_t)u * L;
        uint64_t *av = masks + (int64_t)v * L;
        double we = w[e];
        int c;
        int has_u = mask_any(au, L), has_v = mask_any(av, L);
        if (has_u && has_v) {
            if (mask_and_any(au, av, L)) {           /* case 1 */
                c = least_in_and(au, av, L, loads);
                if (loads[c] >= bound) {
                    c = least_in_or(au, av, L, loads);
                    if (loads[c] >= bound)
                        c = least_global(loads, p);
                }
            } else {                                  /* case 2 */
                uint64_t *s = au, *t = av;
                if (rule_pg && rem[u] < rem[v]) { s = av; t = au; }
                c = least_in_mask(s, L, loads);
                if (loads[c] >= bound) {
                    c = least_in_mask(t, L, loads);
                    if (loads[c] >= bound)
                        c = least_global(loads, p);
                }
            }
        } else if (has_u || has_v) {                  /* case 3 */
            c = least_in_mask(has_u ? au : av, L, loads);
            if (loads[c] >= bound)
                c = least_global(loads, p);
        } else {                                      /* case 4 */
            c = least_global(loads, p);
        }
        loads[c] += we;
        au[c >> 6] |= 1ull << (c & 63);
        av[c >> 6] |= 1ull << (c & 63);
        if (rule_pg) { rem[u]--; rem[v]--; }
        out[e] = c;
    }
}
