"""Memory-centric run-time mapping — paper §5 (Algorithm 2).

Maps partitioner clusters onto a multi-core NUMA platform modelled as a
2-D mesh NoC (paper Table 2: mesh topology, XY routing).  The three
factors of Fig. 7 drive the greedy decisions:

  factor 1 — clusters referencing the same data structures -> same core
             (avoids cache-coherence fetches and block memory ops),
             capped by a per-core cluster threshold (=4 in the paper);
  factor 2 — communicating clusters -> adjacent cores (short XY routes);
  factor 3 — independent clusters  -> different mesh regions
             (architecture decomposition spreads traffic).

The same `Machine` abstraction doubles as the TPU-pod ICI mesh in
`launch/mesh.py`, where "cores" are chips and "NUMA regions" are pods.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Machine", "MappingResult", "memory_centric_mapping",
           "cluster_interaction_graphs"]


@dataclasses.dataclass(frozen=True)
class Machine:
    """A rows×cols mesh of cores with NUMA regions (quadrant decomposition).

    Latency/bandwidth defaults follow paper Table 2 scaled to seconds:
    2.4 GHz cores, 8 GB/s memory bandwidth, per-hop NoC latency.
    """
    rows: int
    cols: int
    n_regions: int = 4
    hop_latency: float = 5e-9          # per-hop wire+router latency (s)
    link_bw: float = 8e9               # NoC link bandwidth (B/s)
    local_mem_bw: float = 8e9          # DRAM bandwidth (B/s), Table 2
    coherence_penalty: float = 60e-9   # cache-line fetch from remote L1/L2
    mshr_overlap: int = 16             # outstanding misses (Table 2: 16 MSHRs)
    cluster_threshold: int = 4         # max clusters per core (paper §5.2)

    @property
    def n_cores(self) -> int:
        return self.rows * self.cols

    def coords(self, core: int) -> tuple[int, int]:
        return divmod(core, self.cols)

    def hops(self, a: int, b: int) -> int:
        """XY-routing hop count between cores a and b."""
        ra, ca = self.coords(a)
        rb, cb = self.coords(b)
        return abs(ra - rb) + abs(ca - cb)

    def region_of(self, core: int) -> int:
        """Grid-style architecture decomposition (factor 3)."""
        r, c = self.coords(core)
        rr = max(1, int(np.sqrt(self.n_regions)))
        cc = max(1, self.n_regions // rr)
        return (r * rr // self.rows) * cc + (c * cc // self.cols)

    @classmethod
    def for_clusters(cls, p: int, max_cores: int = 64, **kw) -> "Machine":
        """Near-square mesh with min(p, max_cores) cores.

        The paper scales clusters 8→1024 on a *fixed* multi-core platform;
        when p exceeds the core budget, clusters share cores (the per-core
        threshold grows accordingly).
        """
        n = min(p, max_cores)
        rows = int(np.ceil(np.sqrt(n)))
        cols = int(np.ceil(n / rows))
        kw.setdefault("cluster_threshold",
                      max(4, int(np.ceil(p / (rows * cols)))))
        return cls(rows=rows, cols=cols, **kw)


@dataclasses.dataclass
class MappingResult:
    machine: Machine
    core_of: np.ndarray           # int32[P] cluster -> core
    p: int

    def clusters_on(self, core: int) -> np.ndarray:
        return np.nonzero(self.core_of == core)[0]

    @property
    def cores_used(self) -> int:
        return len(np.unique(self.core_of))


# ---------------------------------------------------------------------- #
# interaction graphs from a vertex cut result
# ---------------------------------------------------------------------- #
def cluster_interaction_graphs(replicas: list, p: int,
                               vertex_bytes: np.ndarray | None = None,
                               pairwise_cap: int = 64
                               ) -> tuple[np.ndarray, np.ndarray]:
    """Derive (comm[P,P], shared_mem[P,P]) from the replica sets A(v).

    Replica synchronisation is star-shaped from the owner (lowest cluster id
    in A(v)) to each replica — the only inter-cluster traffic of a vertex
    cut.  `shared_mem` counts vertices whose data both clusters reference
    (drives factor 1).  Vertices replicated to more than `pairwise_cap`
    clusters are effectively global data structures; their O(|A|^2) shared
    pairs are skipped (every core shares them anyway) while their star
    traffic is still counted.
    """
    comm = np.zeros((p, p))
    shared = np.zeros((p, p))
    for v, a in enumerate(replicas):
        if not a:
            continue
        members = sorted(a)
        # diagonal: total vertices each cluster references (overlap denom.)
        for x in members:
            shared[x, x] += 1
        if len(members) < 2:
            continue
        b = 1.0 if vertex_bytes is None else float(vertex_bytes[v])
        owner = members[0]
        for r in members[1:]:
            comm[owner, r] += b
            comm[r, owner] += b
        if len(members) <= pairwise_cap:
            for i, x in enumerate(members):
                for y in members[i + 1:]:
                    shared[x, y] += 1
                    shared[y, x] += 1
    return comm, shared


# ---------------------------------------------------------------------- #
# Algorithm 2
# ---------------------------------------------------------------------- #
def memory_centric_mapping(comm: np.ndarray, shared: np.ndarray,
                           machine: Machine | None = None,
                           cluster_order: np.ndarray | None = None,
                           colocate_min_overlap: float = 0.5
                           ) -> MappingResult:
    """Greedy cluster→core mapping per Algorithm 2 (O(P·k), k = peers).

    Args:
      comm:   [P,P] inter-cluster communication volume (factor 2 signal).
      shared: [P,P] shared-data-structure counts (factor 1 signal); the
        diagonal holds each cluster's own referenced-vertex count.
      machine: target platform; default smallest mesh with >= P cores.
      cluster_order: schedulable order (run queue); default by descending
        total interaction so hub clusters anchor placement.
      colocate_min_overlap: factor-1 colocation (same core) only fires when
        the shared-data overlap exceeds this fraction of the smaller
        cluster's references — `ClusterFromMem` in Algorithm 2 targets
        clusters working on the *same data structure*, not any two clusters
        that happen to share a replica of a hub vertex.
    """
    p = comm.shape[0]
    machine = machine or Machine.for_clusters(p)
    n_cores = machine.n_cores

    off_diag = shared - np.diag(np.diag(shared))
    if cluster_order is None:
        cluster_order = np.argsort(-(comm.sum(1) + off_diag.sum(1)),
                                   kind="stable")

    core_of = np.full(p, -1, dtype=np.int32)
    core_count = np.zeros(n_cores, dtype=np.int64)
    regions = [machine.region_of(c) for c in range(n_cores)]
    n_regions = max(regions) + 1
    region_rr = 0  # round-robin cursor for architecture decomposition

    def nearby_core(anchor: int) -> int:
        """Least-occupied *other* core, ties broken by distance to `anchor`
        (factor 2: communicating clusters on adjacent processors).  Occupancy
        is the primary key — a core executing another cluster serializes it,
        which costs orders of magnitude more than a NoC hop, so "nearby"
        means the closest *available* processor."""
        best, best_key = anchor, None
        for c in range(n_cores):
            if c == anchor or core_count[c] >= machine.cluster_threshold:
                continue
            key = (core_count[c], machine.hops(anchor, c))
            if best_key is None or key < best_key:
                best, best_key = c, key
        return best if best_key is not None else int(np.argmin(core_count))

    def diff_region_core(avoid_region: int | None) -> int:
        """Least-utilised core in a different region (factor 3)."""
        nonlocal region_rr
        for off in range(n_regions):
            reg = (region_rr + off) % n_regions
            if avoid_region is not None and reg == avoid_region:
                continue
            cands = [c for c in range(n_cores) if regions[c] == reg]
            cands = [c for c in cands
                     if core_count[c] < machine.cluster_threshold]
            if cands:
                region_rr = (reg + 1) % n_regions
                return min(cands, key=lambda c: core_count[c])
        return int(np.argmin(core_count))

    own = np.maximum(np.diag(shared), 1.0)
    for cl in cluster_order:
        cl = int(cl)
        placed = core_of >= 0
        # factor 1: already-placed peer sharing a dominant data structure
        mem_peer = -1
        if placed.any():
            srow = np.where(placed, off_diag[cl], -1.0)
            j = int(np.argmax(srow))
            if srow[j] > colocate_min_overlap * min(own[cl], own[j]):
                mem_peer = j
        # factor 2: strongest already-placed communication peer
        ipc_peer = -1
        if placed.any():
            crow = np.where(placed, comm[cl], -1.0)
            j = int(np.argmax(crow))
            if crow[j] > 0:
                ipc_peer = j

        if mem_peer >= 0:
            tgt = int(core_of[mem_peer])
            if core_count[tgt] < machine.cluster_threshold:
                core_of[cl] = tgt           # factor 1: colocate
            else:
                core_of[cl] = nearby_core(tgt)
        elif ipc_peer >= 0:
            core_of[cl] = nearby_core(int(core_of[ipc_peer]))  # factor 2
        else:
            avoid = (machine.region_of(int(core_of[ipc_peer]))
                     if ipc_peer >= 0 else None)
            core_of[cl] = diff_region_core(avoid)               # factor 3
        core_count[core_of[cl]] += 1

    return MappingResult(machine=machine, core_of=core_of, p=p)


def round_robin_mapping(p: int, machine: Machine | None = None
                        ) -> MappingResult:
    """Locality-oblivious baseline mapping (for ablations)."""
    machine = machine or Machine.for_clusters(p)
    core_of = (np.arange(p) % machine.n_cores).astype(np.int32)
    return MappingResult(machine=machine, core_of=core_of, p=p)
