"""Memory-centric run-time mapping — paper §5 (Algorithm 2).

Maps partitioner clusters onto a multi-core NUMA platform modelled as a
2-D mesh NoC (paper Table 2: mesh topology, XY routing).  The three
factors of Fig. 7 drive the greedy decisions:

  factor 1 — clusters referencing the same data structures -> same core
             (avoids cache-coherence fetches and block memory ops),
             capped by a per-core cluster threshold (=4 in the paper);
  factor 2 — communicating clusters -> adjacent cores (short XY routes);
  factor 3 — independent clusters  -> different mesh regions
             (architecture decomposition spreads traffic), avoiding the
             region of the cluster's strongest (weak) interaction peer.

Like `vertex_cut`, the layer runs on one of three engines selected with
`backend=`:

  reference — the original per-cluster Python scans over every core and
              the per-vertex replica-set loop of
              `cluster_interaction_graphs`; kept as the readable oracle.
  fast      — array-native (the default): interaction graphs are
              vectorized segment ops over the replica CSR
              (`_arrayops.interaction_from_csr`), and the greedy
              placement replaces its `for c in range(n_cores)` candidate
              scans with precomputed hop-distance/region arrays and
              masked argmin selection.  Bit-identical `core_of` to the
              reference: same greedy order, same (occupancy, hops)
              lexicographic keys, same lowest-index tie-breaking.
  pallas    — interaction graphs run on-accelerator through the Pallas
              segment-sum kernel layer (`repro.core.pallas.metrics`),
              bit-identical to the fast path; the greedy placement
              itself is an inherently sequential scalar loop and reuses
              the fast engine, so `core_of` stays bit-identical too.

The same `Machine` abstraction doubles as the TPU-pod ICI mesh in
`launch/mesh.py`, where "cores" are chips and "NUMA regions" are pods.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .. import obs
from ._arrayops import interaction_from_csr
from .vertex_cut import BACKENDS as _PARTITIONER_BACKENDS

__all__ = ["Machine", "MappingResult", "memory_centric_mapping",
           "cluster_interaction_graphs", "round_robin_mapping",
           "MAPPING_BACKENDS", "resolve_mapping_backend"]

MAPPING_BACKENDS = ("fast", "reference", "pallas")


def resolve_mapping_backend(backend: str) -> str:
    """Map a pipeline-level backend choice onto a mapping/sim engine.

    The partitioner distinguishes "native"/"python" fast engines (plus
    the sharded "dist" mode of `repro.dist`); the mapping and simulator
    layers keep "reference" and "pallas" and run everything else on the
    numpy fast path.
    """
    if backend != "dist" and backend not in _PARTITIONER_BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; choose from "
                         f"{_PARTITIONER_BACKENDS + ('dist',)}")
    return backend if backend in ("reference", "pallas") else "fast"


@dataclasses.dataclass(frozen=True)
class Machine:
    """A rows×cols mesh of cores with NUMA regions (quadrant decomposition).

    Latency/bandwidth defaults follow paper Table 2 scaled to seconds:
    2.4 GHz cores, 8 GB/s memory bandwidth, per-hop NoC latency.
    """
    rows: int
    cols: int
    n_regions: int = 4
    hop_latency: float = 5e-9          # per-hop wire+router latency (s)
    link_bw: float = 8e9               # NoC link bandwidth (B/s)
    local_mem_bw: float = 8e9          # DRAM bandwidth (B/s), Table 2
    coherence_penalty: float = 60e-9   # cache-line fetch from remote L1/L2
    mshr_overlap: int = 16             # outstanding misses (Table 2: 16 MSHRs)
    cluster_threshold: int = 4         # max clusters per core (paper §5.2)

    @property
    def n_cores(self) -> int:
        return self.rows * self.cols

    def coords(self, core: int) -> tuple[int, int]:
        return divmod(core, self.cols)

    def hops(self, a: int, b: int) -> int:
        """XY-routing hop count between cores a and b."""
        ra, ca = self.coords(a)
        rb, cb = self.coords(b)
        return abs(ra - rb) + abs(ca - cb)

    def region_grid(self) -> tuple[int, int]:
        """(row_bands, col_bands) with row_bands·col_bands == n_regions.

        The factor pair closest to square (largest divisor <= sqrt), with
        the longer band axis along the longer mesh axis so every region
        id is realisable whenever the mesh has enough rows/cols — a
        non-perfect-square n_regions (6, 5, ...) must not silently drop
        regions.
        """
        n = max(1, self.n_regions)
        small = max(d for d in range(1, int(np.sqrt(n)) + 1) if n % d == 0)
        big = n // small
        return (big, small) if self.rows >= self.cols else (small, big)

    def region_of(self, core: int) -> int:
        """Grid-style architecture decomposition (factor 3)."""
        r, c = self.coords(core)
        rb, cb = self.region_grid()
        return (r * rb // self.rows) * cb + (c * cb // self.cols)

    # -- vectorized views (the fast mapping backend's precomputation) --- #
    def hop_matrix(self) -> np.ndarray:
        """int64[n_cores, n_cores] all-pairs XY hop counts."""
        ids = np.arange(self.n_cores, dtype=np.int64)
        r, c = np.divmod(ids, self.cols)
        return (np.abs(r[:, None] - r[None, :])
                + np.abs(c[:, None] - c[None, :]))

    def region_array(self) -> np.ndarray:
        """int64[n_cores] region id per core (vectorized `region_of`)."""
        ids = np.arange(self.n_cores, dtype=np.int64)
        r, c = np.divmod(ids, self.cols)
        rb, cb = self.region_grid()
        return (r * rb // self.rows) * cb + (c * cb // self.cols)

    @classmethod
    def for_clusters(cls, p: int, max_cores: int = 64, **kw) -> "Machine":
        """Near-square mesh with min(p, max_cores) cores.

        The paper scales clusters 8→1024 on a *fixed* multi-core platform;
        when p exceeds the core budget, clusters share cores (the per-core
        threshold grows accordingly).
        """
        n = min(p, max_cores)
        rows = int(np.ceil(np.sqrt(n)))
        cols = int(np.ceil(n / rows))
        kw.setdefault("cluster_threshold",
                      max(4, int(np.ceil(p / (rows * cols)))))
        return cls(rows=rows, cols=cols, **kw)


@dataclasses.dataclass
class MappingResult:
    machine: Machine
    core_of: np.ndarray           # int32[P] cluster -> core
    p: int

    def clusters_on(self, core: int) -> np.ndarray:
        return np.nonzero(self.core_of == core)[0]

    @property
    def cores_used(self) -> int:
        return len(np.unique(self.core_of))


# ---------------------------------------------------------------------- #
# interaction graphs from a vertex cut result
# ---------------------------------------------------------------------- #
def _as_replica_csr(replicas) -> tuple[np.ndarray, np.ndarray]:
    """Replica CSR (indptr, members) from a VertexCutResult or list[set]."""
    csr = getattr(replicas, "replica_csr", None)
    if csr is not None:
        return csr()
    sizes = np.fromiter((len(a) if a else 0 for a in replicas),
                        dtype=np.int64, count=len(replicas))
    indptr = np.zeros(len(replicas) + 1, dtype=np.int64)
    np.cumsum(sizes, out=indptr[1:])
    flat = np.fromiter((c for a in replicas if a for c in sorted(a)),
                       dtype=np.int32, count=int(indptr[-1]))
    return indptr, flat


def _as_replica_list(replicas) -> list:
    rep = getattr(replicas, "replicas", None)
    return rep if rep is not None else replicas


def cluster_interaction_graphs(replicas, p: int,
                               vertex_bytes: np.ndarray | None = None,
                               pairwise_cap: int = 64,
                               backend: str = "fast"
                               ) -> tuple[np.ndarray, np.ndarray]:
    """Derive (comm[P,P], shared_mem[P,P]) from the replica sets A(v).

    Replica synchronisation is star-shaped from the owner (lowest cluster id
    in A(v)) to each replica — the only inter-cluster traffic of a vertex
    cut.  `shared_mem` counts vertices whose data both clusters reference
    (drives factor 1).  Vertices replicated to more than `pairwise_cap`
    clusters are effectively global data structures; their O(|A|^2) shared
    pairs are skipped (every core shares them anyway) while their star
    traffic is still counted.

    `replicas` is a `VertexCutResult` (preferred — its replica CSR feeds
    the vectorized fast path directly) or the legacy list-of-sets view.
    """
    backend = resolve_mapping_backend(backend)
    with obs.span("map.cluster_graphs", engine=backend, p=p):
        if backend == "pallas":
            from .pallas import metrics as _pallas_metrics
            indptr, members = _as_replica_csr(replicas)
            comm, shared = _pallas_metrics.interaction_from_csr(
                indptr, members, p, vertex_bytes, pairwise_cap)
            return np.asarray(comm), np.asarray(shared)
        if backend == "fast":
            indptr, members = _as_replica_csr(replicas)
            return interaction_from_csr(indptr, members, p, vertex_bytes,
                                        pairwise_cap)
        return _interaction_reference(_as_replica_list(replicas), p,
                                      vertex_bytes, pairwise_cap)


def _interaction_reference(replicas: list, p: int,
                           vertex_bytes: np.ndarray | None,
                           pairwise_cap: int
                           ) -> tuple[np.ndarray, np.ndarray]:
    """Oracle: the original per-vertex loop over `set` replica sets."""
    comm = np.zeros((p, p))
    shared = np.zeros((p, p))
    for v, a in enumerate(replicas):
        if not a:
            continue
        members = sorted(a)
        # diagonal: total vertices each cluster references (overlap denom.)
        for x in members:
            shared[x, x] += 1
        if len(members) < 2:
            continue
        b = 1.0 if vertex_bytes is None else float(vertex_bytes[v])
        owner = members[0]
        for r in members[1:]:
            comm[owner, r] += b
            comm[r, owner] += b
        if len(members) <= pairwise_cap:
            for i, x in enumerate(members):
                for y in members[i + 1:]:
                    shared[x, y] += 1
                    shared[y, x] += 1
    return comm, shared


# ---------------------------------------------------------------------- #
# Algorithm 2
# ---------------------------------------------------------------------- #
def memory_centric_mapping(comm: np.ndarray, shared: np.ndarray,
                           machine: Machine | None = None,
                           cluster_order: np.ndarray | None = None,
                           colocate_min_overlap: float = 0.5,
                           backend: str = "fast"
                           ) -> MappingResult:
    """Greedy cluster→core mapping per Algorithm 2 (O(P·k), k = peers).

    Args:
      comm:   [P,P] inter-cluster communication volume (factor 2 signal).
      shared: [P,P] shared-data-structure counts (factor 1 signal); the
        diagonal holds each cluster's own referenced-vertex count.
      machine: target platform; default smallest mesh with >= P cores.
      cluster_order: schedulable order (run queue); default by descending
        total interaction so hub clusters anchor placement.
      colocate_min_overlap: factor-1 colocation (same core) only fires when
        the shared-data overlap exceeds this fraction of the smaller
        cluster's references — `ClusterFromMem` in Algorithm 2 targets
        clusters working on the *same data structure*, not any two clusters
        that happen to share a replica of a hub vertex.
      backend: "fast" (masked-argmin placement over precomputed hop and
        region arrays) or "reference" (per-core Python scans, the oracle).
        Both produce bit-identical `core_of`; the partitioner-level
        engine names "native"/"python" resolve to "fast", and "pallas"
        also places on the fast engine (the greedy loop is an inherently
        sequential scalar scan — only the interaction reductions have an
        accelerator port).
    """
    backend = resolve_mapping_backend(backend)
    p = comm.shape[0]
    machine = machine or Machine.for_clusters(p)

    off_diag = shared.copy()
    np.fill_diagonal(off_diag, 0.0)
    if cluster_order is None:
        cluster_order = np.argsort(-(comm.sum(1) + off_diag.sum(1)),
                                   kind="stable")
    own = np.maximum(np.diagonal(shared), 1.0)

    place = _place_reference if backend == "reference" else _place_fast
    with obs.span("map.place", backend=backend, p=p):
        core_of = place(comm, off_diag, own, machine, cluster_order,
                        colocate_min_overlap)
    return MappingResult(machine=machine, core_of=core_of, p=p)


def _select_peers(cl: int, placed: np.ndarray, comm: np.ndarray,
                  off_diag: np.ndarray, own: np.ndarray,
                  colocate_min_overlap: float) -> tuple[int, int]:
    """(mem_peer, ipc_peer) for cluster `cl`; -1 when a factor is silent."""
    mem_peer = ipc_peer = -1
    if placed.any():
        # factor 1: already-placed peer sharing a dominant data structure
        srow = np.where(placed, off_diag[cl], -1.0)
        j = int(np.argmax(srow))
        if srow[j] > colocate_min_overlap * min(own[cl], own[j]):
            mem_peer = j
        # factor 2: strongest already-placed communication peer
        crow = np.where(placed, comm[cl], -1.0)
        j = int(np.argmax(crow))
        if crow[j] > 0:
            ipc_peer = j
    return mem_peer, ipc_peer


def _weak_peer(cl: int, placed: np.ndarray, comm: np.ndarray,
               off_diag: np.ndarray) -> int:
    """Strongest already-placed interaction peer by the combined signal
    (factor 3 avoids its region); -1 if nothing placed interacts at all."""
    if not placed.any():
        return -1
    irow = np.where(placed, comm[cl] + off_diag[cl], -1.0)
    j = int(np.argmax(irow))
    return j if irow[j] > 0 else -1


def _place_reference(comm: np.ndarray, off_diag: np.ndarray, own: np.ndarray,
                     machine: Machine, cluster_order: np.ndarray,
                     colocate_min_overlap: float) -> np.ndarray:
    """Oracle placement: per-core Python scans (the original engine)."""
    p = comm.shape[0]
    n_cores = machine.n_cores
    core_of = np.full(p, -1, dtype=np.int32)
    core_count = np.zeros(n_cores, dtype=np.int64)
    regions = [machine.region_of(c) for c in range(n_cores)]
    n_regions = max(regions) + 1
    region_rr = 0  # round-robin cursor for architecture decomposition

    def nearby_core(anchor: int) -> int:
        """Least-occupied *other* core, ties broken by distance to `anchor`
        (factor 2: communicating clusters on adjacent processors).  Occupancy
        is the primary key — a core executing another cluster serializes it,
        which costs orders of magnitude more than a NoC hop, so "nearby"
        means the closest *available* processor."""
        best, best_key = anchor, None
        for c in range(n_cores):
            if c == anchor or core_count[c] >= machine.cluster_threshold:
                continue
            key = (core_count[c], machine.hops(anchor, c))
            if best_key is None or key < best_key:
                best, best_key = c, key
        return best if best_key is not None else int(np.argmin(core_count))

    def diff_region_core(avoid_region: int | None) -> int:
        """Least-utilised core in a different region (factor 3)."""
        nonlocal region_rr
        for off in range(n_regions):
            reg = (region_rr + off) % n_regions
            if avoid_region is not None and reg == avoid_region:
                continue
            cands = [c for c in range(n_cores) if regions[c] == reg]
            cands = [c for c in cands
                     if core_count[c] < machine.cluster_threshold]
            if cands:
                region_rr = (reg + 1) % n_regions
                return min(cands, key=lambda c: core_count[c])
        return int(np.argmin(core_count))

    for cl in cluster_order:
        cl = int(cl)
        placed = core_of >= 0
        mem_peer, ipc_peer = _select_peers(cl, placed, comm, off_diag, own,
                                           colocate_min_overlap)
        if mem_peer >= 0:
            tgt = int(core_of[mem_peer])
            if core_count[tgt] < machine.cluster_threshold:
                core_of[cl] = tgt           # factor 1: colocate
            else:
                core_of[cl] = nearby_core(tgt)
        elif ipc_peer >= 0:
            core_of[cl] = nearby_core(int(core_of[ipc_peer]))  # factor 2
        else:
            # factor 3: spread away from the strongest (weak) peer's region
            peer = _weak_peer(cl, placed, comm, off_diag)
            avoid = regions[int(core_of[peer])] if peer >= 0 else None
            core_of[cl] = diff_region_core(avoid)
        core_count[core_of[cl]] += 1

    return core_of


def _place_fast(comm: np.ndarray, off_diag: np.ndarray, own: np.ndarray,
                machine: Machine, cluster_order: np.ndarray,
                colocate_min_overlap: float) -> np.ndarray:
    """Array-native placement: masked argmin over precomputed hop/region
    arrays.  The greedy loop over clusters is inherently sequential; every
    per-core scan inside it is a vectorized argmin whose lowest-index
    tie-breaking matches the reference scans exactly, and the per-cluster
    peer selection reuses one preallocated masked buffer instead of fresh
    np.where temporaries."""
    p = comm.shape[0]
    n_cores = machine.n_cores
    thr = machine.cluster_threshold
    hops = machine.hop_matrix()
    regions = machine.region_array()
    n_regions = int(regions.max()) + 1
    # lexicographic (occupancy, hops) packed into one integer key
    key_scale = np.int64(hops.max() + 1)
    big = np.iinfo(np.int64).max

    core_of = np.full(p, -1, dtype=np.int32)
    core_count = np.zeros(n_cores, dtype=np.int64)
    free = core_count < thr               # maintained incrementally
    # occupancy part of the (occupancy, hops) key, maintained incrementally
    count_key = core_count * key_scale
    n_placed = 0
    region_rr = 0
    # multiply-masking: masked(row) = row * placed01 + (placed01 - 1)
    # keeps placed entries (row >= 0) and maps unplaced ones to exactly
    # -1.0, the reference oracle's np.where sentinel — three contiguous
    # vector ops per lookup, no boolean fancy indexing
    placed01 = np.zeros(p)
    neg = placed01 - 1.0
    srow = np.empty(p)
    crow = np.empty(p)

    def nearby_core(anchor: int) -> int:
        key = np.where(free, count_key + hops[anchor], big)
        key[anchor] = big
        c = int(np.argmin(key))
        return c if key[c] < big else int(np.argmin(core_count))

    def diff_region_core(avoid_region: int | None) -> int:
        nonlocal region_rr
        for off in range(n_regions):
            reg = (region_rr + off) % n_regions
            if avoid_region is not None and reg == avoid_region:
                continue
            mask = free & (regions == reg)
            if mask.any():
                region_rr = (reg + 1) % n_regions
                return int(np.argmin(np.where(mask, core_count, big)))
        return int(np.argmin(core_count))

    for cl in cluster_order:
        cl = int(cl)
        mem_peer = ipc_peer = -1
        if n_placed:
            np.multiply(off_diag[cl], placed01, out=srow)
            srow += neg
            np.multiply(comm[cl], placed01, out=crow)
            crow += neg
            j0 = int(np.argmax(srow))
            j1 = int(np.argmax(crow))
            # factor 1: already-placed peer sharing a dominant data structure
            if srow[j0] > colocate_min_overlap * min(own[cl], own[j0]):
                mem_peer = j0
            # factor 2: strongest already-placed communication peer
            if crow[j1] > 0:
                ipc_peer = j1
        if mem_peer >= 0:
            tgt = int(core_of[mem_peer])
            if core_count[tgt] < thr:
                core_of[cl] = tgt           # factor 1: colocate
            else:
                core_of[cl] = nearby_core(tgt)
        elif ipc_peer >= 0:
            core_of[cl] = nearby_core(int(core_of[ipc_peer]))  # factor 2
        else:
            # factor 3: spread away from the strongest (weak) peer's region
            avoid = None
            if n_placed:
                # masked entries sum to -2 < 0, so they never win the argmax
                irow = srow + crow
                j = int(np.argmax(irow))
                if irow[j] > 0:
                    avoid = int(regions[core_of[j]])
            core_of[cl] = diff_region_core(avoid)
        tgt = int(core_of[cl])
        core_count[tgt] += 1
        count_key[tgt] += key_scale
        free[tgt] = core_count[tgt] < thr
        placed01[cl] = 1.0
        neg[cl] = 0.0
        n_placed += 1

    return core_of


def round_robin_mapping(p: int, machine: Machine | None = None
                        ) -> MappingResult:
    """Locality-oblivious baseline mapping (for ablations)."""
    machine = machine or Machine.for_clusters(p)
    core_of = (np.arange(p) % machine.n_cores).astype(np.int32)
    return MappingResult(machine=machine, core_of=core_of, p=p)
