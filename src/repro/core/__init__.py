"""Core library: the paper's contribution (Weight Balanced p-way Vertex Cut).

Public API:
  IRGraph                      — weighted dataflow graph (LLVM-graph analogue)
  vertex_cut / ALGORITHMS      — the 6 greedy cuts + random (paper §4)
  edge_cut / EDGE_CUT_METHODS  — CompNet + METIS-like baselines (paper §6.1)
  memory_centric_mapping       — Algorithm 2 cluster→core scheduling
  simulate / run_pipeline      — NUMA multicore cost simulation (paper §6)
  build_graph / BENCHMARKS     — the paper's 10 traced benchmarks (Table 3)
  expected_replication_random  — Eq. (10) theory
"""
from .graph import IRGraph
from .powerlaw import (expected_replication_random,
                       expected_replication_random_empirical,
                       synthesize_powerlaw_graph, zipf_degrees)
from .vertex_cut import (ALGORITHMS, BACKENDS, ShardCutState,
                         VertexCutResult, resolve_backend, vertex_cut)
from .edge_cut import EDGE_CUT_METHODS, EdgeCutResult, edge_cut
from .mapping import (MAPPING_BACKENDS, Machine, MappingResult,
                      cluster_interaction_graphs, memory_centric_mapping,
                      resolve_mapping_backend, round_robin_mapping)
from .simulator import (SimReport, coerce_graph, run_pipeline, simulate,
                        vertex_bytes_model)
from .benchgraphs import BENCHMARKS, Tracer, all_benchmark_names, build_graph

__all__ = [
    "IRGraph", "vertex_cut", "VertexCutResult", "ALGORITHMS",
    "BACKENDS", "resolve_backend", "ShardCutState",
    "edge_cut", "EdgeCutResult", "EDGE_CUT_METHODS",
    "Machine", "MappingResult", "memory_centric_mapping",
    "round_robin_mapping", "cluster_interaction_graphs",
    "MAPPING_BACKENDS", "resolve_mapping_backend",
    "SimReport", "simulate", "run_pipeline", "vertex_bytes_model",
    "coerce_graph",
    "BENCHMARKS", "Tracer", "all_benchmark_names", "build_graph",
    "expected_replication_random", "expected_replication_random_empirical",
    "synthesize_powerlaw_graph", "zipf_degrees",
]
