from .sharding import (DATA_AXES, batch_specs, cache_specs, maybe_shard,
                       param_specs)
__all__ = ["param_specs", "batch_specs", "cache_specs", "maybe_shard",
           "DATA_AXES"]
