"""Logical-axis → PartitionSpec rules for every parameter tree.

Sharding scheme (DESIGN.md §5):
  * 'pod'   — pure data parallelism across pods (DCN boundary);
  * 'data'  — data parallelism inside a pod; with FSDP enabled it also
              shards the *contraction* dim of every large weight (ZeRO-3
              style scatter, gathered by GSPMD where needed);
  * 'model' — tensor parallelism: attention heads / MLP ff dim / MoE
              expert dim (EP) / vocab dim of the embedding.

Rules are name-based over the param-tree paths produced by
`models.init_params`, applied with tree_map_with_path so stacked stage
dims (leading axes added by scan-stacking) are handled by rank offset.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig

__all__ = ["param_specs", "batch_specs", "cache_specs", "DATA_AXES",
           "maybe_shard", "sanitize_specs"]

DATA_AXES = ("pod", "data")   # batch is sharded over both


def maybe_shard(x, *axes):
    """with_sharding_constraint that degrades to a no-op outside a mesh.

    `axes` name mesh axes per dim (None / "data" / "model" / a tuple);
    axes not present in the ambient abstract mesh are dropped, and "data"
    expands to every data axis present (("pod", "data") on the multi-pod
    mesh).  Models call this on activations so GSPMD keeps batch/ff/expert
    dims sharded instead of replicating large intermediates.
    """
    get_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_mesh is not None:
        mesh = get_mesh()
    else:  # pre-get_abstract_mesh JAX: ambient mesh is thread-local
        from jax._src import mesh as mesh_lib
        mesh = mesh_lib.thread_resources.env.physical_mesh
        if mesh is not None and mesh.empty:
            mesh = None
    names = getattr(mesh, "axis_names", ()) if mesh is not None else ()
    if not names:
        return x

    def fix(a):
        if a is None:
            return None
        if a == "data":
            a = DATA_AXES
        if isinstance(a, (tuple, list)):
            t = tuple(ax for ax in a if ax in names)
            return t if t else None
        return a if a in names else None

    spec = P(*(fix(a) for a in axes))
    return jax.lax.with_sharding_constraint(x, spec)


def _leaf_spec(path: tuple, shape: tuple, cfg: ModelConfig,
               par: ParallelConfig) -> P:
    names = [getattr(k, "key", str(k)) for k in path]
    name = names[-1] if names[-1] != "w" else names[-2]
    data = "data" if par.fsdp else None
    tp = "model" if par.tp else None
    rank = len(shape)

    def with_stage_prefix(*dims):
        """Pad leading None for stacked stage dims."""
        pad = rank - len(dims)
        return P(*([None] * pad + list(dims)))

    # ---- embeddings -------------------------------------------------- #
    if name == "table":
        return P(tp, None)
    if name == "unembed":
        return P(None, tp)

    # ---- MoE stacked expert weights [E, d, ff] ----------------------- #
    # "2d" (default): E over 'model' + d over 'data' (ZeRO-3 style;
    # weights re-gathered per microbatch — the dominant collective on
    # deepseek-v3).  "ep_pod": E over ('pod','model') = 32-way EP on the
    # multi-pod mesh — weights fully resident, zero gathers, MoE
    # all-to-all rides DCN instead (EXPERIMENTS §Perf deepseek iter 3).
    if name in ("w_in", "w_gate", "w_out") and rank >= 3 and cfg.is_moe \
            and shape[-3] == cfg.n_experts:
        e_axis = ("pod", "model") if par.expert_layout == "ep_pod" \
            else "model"
        if name == "w_out":
            return with_stage_prefix(
                e_axis, None, data if par.expert_layout == "2d" else None)
        return with_stage_prefix(
            e_axis, data if par.expert_layout == "2d" else None, None)
    if name == "router":
        return with_stage_prefix(data, None)

    # ---- projections: contraction over d -> head/ff dim sharded ------ #
    if name in ("wq", "wk", "wv", "w_in", "w_gate", "wq_b", "wk_b",
                "wv_b", "wx", "wy", "wr", "wi", "wg", "ck", "cr",
                "w_lora_a", "w_lora_b", "wq_a", "wkv_a"):
        return with_stage_prefix(data, tp)
    # ---- output projections: sharded dim contracts ------------------- #
    if name in ("wo", "w_out", "cv"):
        return with_stage_prefix(tp, data)
    if name == "conv_w":
        return with_stage_prefix(None, tp)

    # ---- vectors ------------------------------------------------------ #
    if rank >= 1 and shape[-1] in (cfg.rglru_width or 0, cfg.d_model) \
            and name in ("lam", "u", "conv_b"):
        return with_stage_prefix(tp)
    return P(*([None] * rank))   # norms, mixes, biases: replicated


def param_specs(params, cfg: ModelConfig, par: ParallelConfig):
    """PartitionSpec tree matching `params` (works on ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, x: _leaf_spec(path, x.shape, cfg, par), params)


def batch_specs(cfg: ModelConfig, batch: dict,
                data_axes=("data",), micro_split: bool = False) -> dict:
    """Input shardings: batch dim over the data axes, seq/features
    replicated.  `micro_split` marks a leading [n_micro] accumulation dim
    (replicated)."""
    da = tuple(data_axes)
    lead = [None] if micro_split else []
    specs = {}
    for k, v in batch.items():
        if k == "mrope_pos":                       # [(micro,)? 3, B, S]
            specs[k] = P(*(lead + [None, da, None]))
        elif hasattr(v, "ndim") and v.ndim >= 1:
            rest = v.ndim - len(lead) - 1
            specs[k] = P(*(lead + [da] + [None] * rest))
        else:
            specs[k] = P()
    return specs


def _cache_leaf_spec(path: tuple, shape: tuple, data_axes=("data",),
                     seq_shard: bool = True) -> P:
    """Caches: batch dim over (pod, data); long attention caches are also
    SEQUENCE-sharded over 'model' (context parallelism — the 32k KV cache
    is the decode memory hog; softmax over the sharded seq dim makes GSPMD
    insert the expected cross-shard max/sum collectives).  Layout per
    block type: attention k/v [stages?, B, W, Hkv, hd]; MLA ckv/krope
    [stages?, B, S, r]; rec h [stages?, B, rw], conv [stages?, B, W-1,
    rw]; rwkv state [stages?, B, H, dk, dv]; enc [B, S, d]."""
    names = [getattr(k, "key", str(k)) for k in path]
    rank = len(shape)
    has_stage = "stages" in names
    b_axis = 1 if has_stage else 0
    dims = [None] * rank
    if rank > b_axis:
        dims[b_axis] = tuple(data_axes)
    leaf = names[-1]
    if seq_shard and leaf in ("k", "v", "ckv", "krope") \
            and rank > b_axis + 1 and shape[b_axis + 1] >= 4096:
        dims[b_axis + 1] = "model"
    return P(*dims)


def cache_specs(cache, data_axes=("data",), seq_shard: bool = True) -> dict:
    return jax.tree_util.tree_map_with_path(
        lambda path, x: _cache_leaf_spec(path, x.shape, data_axes,
                                         seq_shard), cache)


def sanitize_specs(spec_tree, shape_tree, mesh):
    """Drop sharding on dims not divisible by the mesh-axis product.

    jit *argument* shardings require exact divisibility (e.g. granite's
    vocab 49155 is not divisible by 16); such dims fall back to
    replicated, which GSPMD handles fine internally."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix_leaf(spec, x):
        dims = list(spec) + [None] * (len(x.shape) - len(spec))
        out = []
        for d, axis in zip(x.shape, dims):
            if axis is None:
                out.append(None)
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            prod = 1
            for a in axes:
                prod *= sizes.get(a, 1)
            out.append(axis if d % prod == 0 else None)
        return P(*out)

    return jax.tree.map(fix_leaf, spec_tree, shape_tree,
                        is_leaf=lambda t: isinstance(t, P))
