"""Distributed sharded streaming partitioner (parallel parse -> workers
-> periodic merge).

The scale-out front end for the vertex-cut framework: NDJSON dynamic
traces are parsed over W byte-range shards in parallel (`parse.py`,
with cross-shard def-table resolution at a cheap sequential merge), and
the greedy streaming cut runs on W per-shard workers whose replica/load
views are periodically merged PowerGraph-oblivious style (`engine.py`,
built on `core.vertex_cut.ShardCutState`).

Contract: `workers=1` is bit-identical to the single-stream fast
engine; `workers>1` is deterministic for a fixed (W, seed,
merge_period) and its cut quality is gated in the `dist_scaling`
benchmark.  Consumed through `run_pipeline(..., backend="dist",
workers=W)`, `plan_graph`, the `repro.trace` CLI (`--workers`), or
directly:

    from repro.dist import dist_ingest, dist_vertex_cut
    g = dist_ingest("trace.ndjson", workers=4)
    cut = dist_vertex_cut(g, p=64, workers=4)
"""
from .engine import DEFAULT_MERGE_PERIOD, dist_vertex_cut, shard_bounds
from .parse import (ShardParse, dist_ingest, dist_ingest_with_stats,
                    shard_byte_ranges)

__all__ = [
    "DEFAULT_MERGE_PERIOD", "dist_vertex_cut", "shard_bounds",
    "ShardParse", "dist_ingest", "dist_ingest_with_stats",
    "shard_byte_ranges",
]
