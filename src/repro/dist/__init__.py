"""Distributed sharded streaming partitioner (pipelined parse→cut
dataflow + periodic merges).

The scale-out front end for the vertex-cut framework: NDJSON dynamic
traces are parsed over W byte-range shards in parallel (`parse.py`,
with incremental cross-shard def-table resolution — `ShardMerger` /
`open_shard_parses`), and the greedy streaming cut runs on W resident
shard workers whose replica/load views are merged PowerGraph-oblivious
style at round barriers (`engine.py`, built on
`core.vertex_cut.ShardCutState`).

For NDJSON trace paths with `workers>1` the two stages *pipeline*:
merged parse shards stream straight into the cut workers, so cutting
starts while later shards are still parsing instead of behind a
whole-file parse barrier.  Merges are fixed-period or adaptive
(`divergence=` defers the expensive replica-mask merge until the
per-cluster load drift trips a bound), and workers run on a thread
pool (native kernel, GIL-released) or resident processes (pure-Python
engine on no-compiler hosts).

Contract: `workers=1` is bit-identical to the single-stream fast
engine; `workers>1` is deterministic for a fixed (W, seed,
merge_period, divergence) regardless of pool/parse scheduling, and its
cut quality and scaling are gated in the `dist_scaling` benchmark.
Consumed through `run_pipeline(..., backend="dist", workers=W)`,
`plan_graph`, the `repro.trace` CLI (`--workers`, `--divergence`), or
directly:

    from repro.dist import dist_ingest, dist_vertex_cut
    cut = dist_vertex_cut("trace.ndjson", p=64, workers=4)  # pipelined
    g = dist_ingest("trace.ndjson", workers=4)
    cut = dist_vertex_cut(g, p=64, workers=4, divergence=0.05)
"""
from .engine import (DEFAULT_MERGE_PERIOD, WORKER_POOLS, dist_vertex_cut,
                     shard_bounds)
from .parse import (ShardMerger, ShardParse, dist_ingest,
                    dist_ingest_with_stats, open_shard_parses,
                    shard_byte_ranges)

__all__ = [
    "DEFAULT_MERGE_PERIOD", "WORKER_POOLS", "dist_vertex_cut",
    "shard_bounds", "ShardMerger", "ShardParse", "dist_ingest",
    "dist_ingest_with_stats", "open_shard_parses", "shard_byte_ranges",
]
