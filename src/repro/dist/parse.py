"""Parallel (sharded) NDJSON trace parse front end.

The sequential ingester (`repro.trace.ingest`) is a single pass with two
kinds of cross-record state: vertex ids assigned in stream order, and
rolling per-function def-tables binding SSA uses to their producers.
This module parallelizes that pass over W byte-range shards with a
cheap sequential merge — the "per-shard def-table seeding" of the
distributed front end:

  1. **Shard** the file into W byte ranges aligned to line boundaries
     (`shard_byte_ranges`); compressed sources (.gz / .zst) are not
     seekable-splittable, so they are decompressed once and cut into W
     in-memory line blocks instead.
  2. **Parse** each shard independently (`_ShardBuilder`, one per
     worker process).  Vertex ids are shard-local; a use of a value id
     with no local def creates a *provisional live-in* vertex and is
     recorded as **pending** — it may actually be produced by an
     earlier shard.
  3. **Merge** sequentially (cheap — dict updates and vectorized id
     remaps, no JSON): walk shards in stream order, resolve each
     shard's pending symbols against the accumulated def-tables of the
     shards before it, drop the resolved placeholder vertices
     (compacting ids), rewrite their edges to the true producers,
     recompute those edges' weights with the producer's def bytes, and
     fold the shard's def exports into the global tables (later defs
     overwrite earlier ones, exactly like the rolling tables).

Because pending uses bind to the def-table state at shard start — the
same state the sequential pass would have had — the merged graph is
**bit-identical to the sequential ingester for any W** on well-formed
traces (asserted in tests; `workers=1` is the degenerate single-shard
case).  The only divergence is bookkeeping at shard boundaries on
*malformed* traces: program-point/CFG ordering validation resets at a
boundary, so a record the sequential pass would reject as out-of-order
can be accepted by the shard that starts on it, and error line numbers
are shard-relative.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from time import perf_counter

import numpy as np

from .. import obs
from ..core.graph import IRGraph
from ..trace.ingest import (DEFAULT_CHUNK_EDGES, TraceStats, _open_lines,
                            _source_name, _StreamBuilder, CFG, load_cfg)
from ..trace.weights import resolve_weight_model

__all__ = ["shard_byte_ranges", "dist_ingest", "dist_ingest_with_stats",
           "ShardParse", "ShardMerger", "open_shard_parses"]

POOLS = ("auto", "process", "serial")


# ---------------------------------------------------------------------- #
# sharding
# ---------------------------------------------------------------------- #
def shard_byte_ranges(path, workers: int) -> "list[tuple[int, int]]":
    """Split a plain NDJSON file into <= `workers` byte ranges.

    Cut points target `size * s / workers` and advance to the next line
    boundary, so every line belongs to exactly one range; ranges are a
    pure function of (file bytes, workers) — the determinism anchor of
    the whole front end.
    """
    size = os.path.getsize(path)
    if workers <= 1 or size == 0:
        return [(0, size)]
    cuts = [0]
    with open(path, "rb") as f:
        for s in range(1, workers):
            tgt = size * s // workers
            if tgt <= cuts[-1]:
                continue
            f.seek(tgt)
            f.readline()                 # finish the line containing tgt
            pos = f.tell()
            if cuts[-1] < pos < size:
                cuts.append(pos)
    cuts.append(size)
    return list(zip(cuts[:-1], cuts[1:]))


def _text_line_blocks(text: str, workers: int) -> "list[str]":
    """Cut decompressed text into <= `workers` blocks at line boundaries."""
    if workers <= 1 or not text:
        return [text] if text else []
    cuts = [0]
    for s in range(1, workers):
        tgt = len(text) * s // workers
        if tgt <= cuts[-1]:
            continue
        nl = text.find("\n", tgt)
        pos = len(text) if nl < 0 else nl + 1
        if cuts[-1] < pos < len(text):
            cuts.append(pos)
    cuts.append(len(text))
    return [text[a:b] for a, b in zip(cuts[:-1], cuts[1:]) if a < b]


# ---------------------------------------------------------------------- #
# per-shard builder
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class ShardParse:
    """One shard's parse output, in shard-local vertex ids."""

    n: int                        # local vertex count
    src: np.ndarray               # int64[|E_s|] local producer ids
    dst: np.ndarray               # int64[|E_s|] local consumer ids
    w: np.ndarray                 # float64[|E_s|]
    labels: "list | None"
    defs_by_fn: dict              # fn -> {sym: (local vid, def bytes)}
    pend_syms: list               # [(fn, sym, placeholder vid)] first-use order
    pend_edges: list              # [(edge idx, placeholder vid, op, use_ty)]
    counters: dict                # TraceStats fields to sum/max
    fns: set                      # function names seen
    bbs: set                      # (fn, bb) pairs seen
    # telemetry spans timed inside the (possibly remote) parse worker;
    # the merging coordinator absorbs them into the active collector,
    # rewriting the lane to the shard's stream position
    events: list = dataclasses.field(default_factory=list)


class _ShardBuilder(_StreamBuilder):
    """`_StreamBuilder` variant that records cross-shard pending uses.

    Only the operand scan (`_add_use_edges`) is overridden, with three
    changes: an unresolved non-const use registers its placeholder in
    the pending tables; a later use that binds to a pending placeholder
    is appended to the pending-edge list (so the merge can rewrite it
    too); and the edge counter tracks flat edge indices for those
    rewrites.  The validation/ordering prologue, the def-table
    rollover, and the def registration are the parent's — the parent
    remains the oracle the W=1 equality tests hold this class to, and
    future changes there apply to both parsers by construction.
    """

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._pend_vids: set = set()     # unresolved placeholder local vids
        self._pend_syms: list = []       # (fn, sym, vid) in first-use order
        self._pend_edges: list = []      # (edge idx, vid, op, use_ty)
        self._edges = 0                  # flat edge index within the shard

    def _add_use_edges(self, nid: int, n: int, op: str, uses,
                       use_tys) -> int:
        defs_get = self.defs.get
        weight_fn = self.weight_fn
        src_append = self._src.append
        dst_append = self._dst.append
        w_append = self._w.append
        labels = self.labels
        pend_vids = self._pend_vids
        pend_edges = self._pend_edges
        edge_idx = self._edges
        fn = self._cur_fn               # the prologue switched tables
        for i, u in enumerate(uses):
            ty = use_tys[i] if use_tys is not None else None
            entry = defs_get(u)
            if entry is not None:
                pid, pbytes = entry
                if pbytes is None and pid in pend_vids:
                    # re-use of a provisional live-in: the merge may
                    # rebind this edge to an earlier shard's def
                    pend_edges.append((edge_idx, pid, op, ty))
            elif u.startswith("const:"):
                pid, pbytes = n, None
                n += 1
                self._const_uses += 1
                if labels is not None:
                    labels.append("const")
            else:
                # provisional live-in: pending until the merge knows
                # whether an earlier shard defined `u`
                pid, pbytes = n, None
                n += 1
                self.defs[u] = (pid, None)
                pend_vids.add(pid)
                self._pend_syms.append((fn, u, pid))
                pend_edges.append((edge_idx, pid, op, ty))
                self._livein_uses += 1
                if labels is not None:
                    labels.append(u)
            src_append(pid)
            dst_append(nid)
            w_append(weight_fn(op, ty, pbytes))
            edge_idx += 1
        self._edges = edge_idx
        return n

    def finalize_shard(self) -> ShardParse:
        self._flush()
        if self._batches:
            src = np.concatenate([b[0] for b in self._batches]).astype(
                np.int64)
            dst = np.concatenate([b[1] for b in self._batches]).astype(
                np.int64)
            w = np.concatenate([b[2] for b in self._batches])
        else:
            src = np.zeros(0, np.int64)
            dst = np.zeros(0, np.int64)
            w = np.zeros(0, np.float64)
        counters = {
            "lines": self._lines, "records": self._records,
            "cfg_records": self._cfg_records, "skipped": self._skipped,
            "const_uses": self._const_uses, "livein_uses": self._livein_uses,
            "void_defs": self._void_defs,
            "cfg_violations": self._cfg_violations,
            "peak_chunk_edges": self._peak,
        }
        return ShardParse(
            n=self.n, src=src, dst=dst, w=w, labels=self.labels,
            defs_by_fn=self._defs_by_fn, pend_syms=self._pend_syms,
            pend_edges=self._pend_edges, counters=counters,
            fns=set(self._defs_by_fn), bbs=self._bbs)


_RANGE_READ_BLOCK = 1 << 20


def _iter_range_lines(path, start: int, end: int):
    """Stream the lines of a byte range, splitting ONLY on b"\\n".

    Two properties matter here: memory stays O(read block), preserving
    the sequential ingester's bounded-buffer discipline for plain
    files; and lines are cut exactly where the byte-range sharder cuts
    them — at 0x0A bytes.  `str.splitlines()` would also break on
    U+2028/NEL/form-feed, which are legal *raw inside JSON strings*,
    tearing well-formed records apart.  Splitting the raw bytes is
    UTF-8-safe (0x0A never occurs in a continuation byte) and each
    line decodes whole.
    """
    with open(path, "rb") as f:
        f.seek(start)
        carry = b""
        left = end - start
        while left > 0:
            data = f.read(min(_RANGE_READ_BLOCK, left))
            if not data:
                break
            left -= len(data)
            pieces = (carry + data).split(b"\n")
            carry = pieces.pop()
            for piece in pieces:
                yield piece.decode("utf-8")
        if carry:
            yield carry.decode("utf-8")


def _iter_block_lines(text: str):
    """Lines of an in-memory decompressed block, splitting only on \\n
    (same contract as `_iter_range_lines`; the trailing newline does
    not produce a phantom empty line)."""
    lines = text.split("\n")
    if lines and lines[-1] == "":
        lines.pop()
    return lines


def _parse_shard(task) -> ShardParse:
    """Worker entry: parse one shard (path byte-range or text block)."""
    (path, start, end, text, weight_model, chunk_edges, keep_labels, cfg,
     on_error) = task
    b = _ShardBuilder(resolve_weight_model(weight_model), chunk_edges,
                      keep_labels, cfg, on_error)
    lines = (_iter_range_lines(path, start, end) if text is None
             else _iter_block_lines(text))
    parse_line, add_record = b.parse_line, b.add_record
    t0 = perf_counter()
    for lineno, line in enumerate(lines, start=1):
        rec = parse_line(lineno, line)
        if rec is not None:
            add_record(lineno, rec)
    sp = b.finalize_shard()
    # one span per shard, recorded unconditionally (a dict per shard is
    # noise-free): perf_counter is system-wide, so the coordinator can
    # splice worker-process spans into its own profile
    sp.events.append({
        "name": "parse.shard", "ph": "X", "ts": t0 * 1e6,
        "dur": (perf_counter() - t0) * 1e6, "lane": "parse", "cat": "op",
        "args": {"lines": sp.counters["lines"], "edges": int(len(sp.src))}})
    return sp


# ---------------------------------------------------------------------- #
# incremental merge
# ---------------------------------------------------------------------- #
class ShardMerger:
    """Incremental cross-shard def-table resolution, in stream order.

    One `add(shard)` per parse shard, strictly in shard order: it
    resolves the shard's pending live-ins against the def tables
    accumulated from earlier shards, remaps the shard's edges to global
    vertex ids, and returns them — so a consumer (the pipelined cut
    engine) can start streaming a shard's edges the moment it is merged,
    without waiting for the rest of the parse.  `finish()` assembles the
    full `(IRGraph, TraceStats)`; feeding every shard through `add` and
    calling `finish` is exactly the old one-shot merge (the sequential
    ingester equivalence contract is unchanged).
    """

    def __init__(self, weight_fn, keep_labels: bool):
        self._weight_fn = weight_fn
        self._global_defs: dict = {}   # fn -> {sym: (global vid, bytes)}
        self.n = 0                     # global vertex count so far
        self.edges = 0                 # global edge count so far
        self._srcs: list = []
        self._dsts: list = []
        self._ws: list = []
        self._labels: "list | None" = [] if keep_labels else None
        self._sums = dict.fromkeys(
            ("lines", "records", "cfg_records", "skipped", "const_uses",
             "livein_uses", "void_defs", "cfg_violations"), 0)
        self._peak = 0
        self._fns: set = set()
        self._bbs: set = set()

    def add(self, sh: ShardParse
            ) -> "tuple[np.ndarray, np.ndarray, np.ndarray]":
        """Merge the next shard; return its (src, dst, w) in global ids."""
        weight_fn = self._weight_fn
        resolved: dict = {}            # placeholder local vid -> (gvid, b)
        for fn, sym, vid in sh.pend_syms:
            entry = self._global_defs.get(fn, {}).get(sym)
            if entry is not None:
                resolved[vid] = entry
        keep = np.ones(sh.n, dtype=bool)
        if resolved:
            keep[np.fromiter(resolved, dtype=np.int64,
                             count=len(resolved))] = False
        l2g = np.cumsum(keep) - 1 + self.n
        for vid, (gvid, _b) in resolved.items():
            l2g[vid] = gvid

        w = sh.w
        for edge_idx, vid, op, ty in sh.pend_edges:
            entry = resolved.get(vid)
            if entry is not None:
                # the true producer's def bytes were unknown at parse
                # time; recompute exactly what the sequential pass paid
                w[edge_idx] = weight_fn(op, ty, entry[1])
        src = l2g[sh.src] if sh.n else sh.src
        dst = l2g[sh.dst] if sh.n else sh.dst
        self._srcs.append(src)
        self._dsts.append(dst)
        self._ws.append(w)
        self.edges += len(src)

        for fn, table in sh.defs_by_fn.items():
            gt = self._global_defs.setdefault(fn, {})
            for sym, (vid, b) in table.items():
                if vid in resolved and b is None:
                    # entry is a resolved placeholder: the earlier
                    # shard's def already owns this symbol
                    continue
                gt[sym] = (int(l2g[vid]), b)

        if self._labels is not None and sh.labels is not None:
            if resolved:
                self._labels.extend(lab for i, lab in enumerate(sh.labels)
                                    if keep[i])
            else:
                self._labels.extend(sh.labels)
        self.n += int(keep.sum())

        c = sh.counters
        for k in self._sums:
            self._sums[k] += c[k]
        self._sums["livein_uses"] -= len(resolved)  # provisional, not real
        self._peak = max(self._peak, c["peak_chunk_edges"])
        self._fns |= sh.fns
        self._bbs |= sh.bbs
        return src, dst, w

    def finish(self, name: str) -> "tuple[IRGraph, TraceStats]":
        if self._srcs:
            src = np.concatenate(self._srcs).astype(np.int32)
            dst = np.concatenate(self._dsts).astype(np.int32)
            w = np.concatenate(self._ws)
        else:
            src = np.zeros(0, np.int32)
            dst = np.zeros(0, np.int32)
            w = np.zeros(0, np.float64)
        stats = TraceStats(peak_chunk_edges=self._peak,
                           functions=len(self._fns),
                           blocks=len(self._bbs), **self._sums)
        g = IRGraph(n=self.n, src=src, dst=dst, w=w, name=name,
                    node_labels=self._labels)
        return g, stats


def _merge_shards(shards: "list[ShardParse]", weight_fn, name: str,
                  keep_labels: bool) -> "tuple[IRGraph, TraceStats]":
    mg = ShardMerger(weight_fn, keep_labels)
    for sh in shards:
        mg.add(sh)
    return mg.finish(name)


# ---------------------------------------------------------------------- #
# public entry points
# ---------------------------------------------------------------------- #
def dist_ingest_with_stats(source, *, workers: int = 1,
                           weight_model="bytes",
                           chunk_edges: int = DEFAULT_CHUNK_EDGES,
                           on_error: str = "raise", cfg=None,
                           name: "str | None" = None,
                           keep_labels: bool = False,
                           pool: str = "auto"):
    """Parallel `ingest_trace_with_stats` over byte-sharded NDJSON.

    Args:
      source: path to an NDJSON trace (`.gz` / `.zst` decompress
        transparently but shard over in-memory line blocks — compressed
        streams have no seekable line boundaries, so the O(chunk)
        memory bound is traded for parallelism there).
      workers: shard count W.  The merged graph is bit-identical to the
        sequential ingester for any W on well-formed traces; `workers=1`
        is the degenerate single-shard case.
      pool: "process" (fork/spawn worker pool), "serial" (parse shards
        in-process — determinism oracle and small-input path), or
        "auto": processes when `workers > 1` and the weight model is a
        registered name (a bare callable may not pickle).
      Everything else matches `ingest_trace_with_stats`; `on_error`
        line numbers are shard-relative in dist mode.

    Returns:
      (IRGraph, TraceStats)
    """
    if not isinstance(source, (str, os.PathLike)):
        raise TypeError("dist ingestion shards a file path; got "
                        f"{type(source).__name__} (use ingest_trace for "
                        "file-like or iterable sources)")
    from ..trace.binfmt import is_binary_trace_path, read_trace_bin
    if is_binary_trace_path(source):
        # .rtb containers are pre-chunked columnar arrays: there is no
        # line splitting to parallelise, and the loaded graph is the
        # conversion-time graph for any worker count by construction
        if cfg is not None:
            raise ValueError(
                "cfg validation applies to NDJSON traces; a .rtb binary "
                "trace is already a validated graph")
        g, stats = read_trace_bin(source, keep_labels=keep_labels)
        if name is not None:
            g = dataclasses.replace(g, name=name)
        return g, stats
    tasks = _shard_tasks(source, workers, weight_model, chunk_edges,
                         keep_labels, cfg, on_error, pool)
    mg = ShardMerger(resolve_weight_model(weight_model), keep_labels)
    col = obs.current()
    with open_shard_parses(tasks, pool, weight_model) as shards:
        for i, sh in enumerate(shards):
            if col is not None and sh.events:
                for ev in sh.events:
                    ev["lane"] = f"parse/p{i}"
                col.absorb_events(sh.events)
            with obs.span("parse.merge", lane="coord"):
                mg.add(sh)
    return mg.finish(_source_name(source, name))


def _shard_tasks(source, workers: int, weight_model, chunk_edges: int,
                 keep_labels: bool, cfg, on_error: str,
                 pool: str) -> list:
    """Build the per-shard parse task tuples for an NDJSON source."""
    if pool not in POOLS:
        raise ValueError(f"unknown pool {pool!r}; choose from {POOLS}")
    workers = max(1, int(workers))
    if cfg is not None and not isinstance(cfg, CFG):
        cfg = load_cfg(cfg)
    path = os.fspath(source)
    compressed = path.endswith((".gz", ".zst", ".zstd"))
    if compressed:
        f, close = _open_lines(path)
        try:
            blocks = _text_line_blocks(f.read(), workers)
        finally:
            close()
        tasks = [(None, 0, 0, blk, weight_model, chunk_edges, keep_labels,
                  cfg, on_error) for blk in blocks]
    else:
        tasks = [(path, a, b, None, weight_model, chunk_edges, keep_labels,
                  cfg, on_error)
                 for a, b in shard_byte_ranges(path, workers)]
    if not tasks:
        tasks = [(None, 0, 0, "", weight_model, chunk_edges, keep_labels,
                  cfg, on_error)]
    return tasks


@contextlib.contextmanager
def open_shard_parses(tasks: list, pool: str, weight_model):
    """Yield an iterator of `ShardParse` results, strictly in task order.

    With a process pool the shards parse concurrently and stream back
    through an ordered `imap` — the consumer can merge (and cut) shard
    k while shards k+1..W are still parsing, which is the parse side of
    the pipelined dataflow.  `pool` semantics match
    `dist_ingest_with_stats`; the serial path is the determinism oracle
    and the degenerate 1-task path.
    """
    if pool not in POOLS:
        raise ValueError(f"unknown pool {pool!r}; choose from {POOLS}")
    use_processes = (pool == "process"
                     or (pool == "auto" and len(tasks) > 1
                         and isinstance(weight_model, str)))
    if use_processes and len(tasks) > 1:
        import multiprocessing as mp
        method = "fork" if "fork" in mp.get_all_start_methods() else None
        ctx = mp.get_context(method)
        with ctx.Pool(processes=len(tasks)) as p:
            yield p.imap(_parse_shard, tasks)
    else:
        yield (_parse_shard(t) for t in tasks)


def dist_ingest(source, **kw) -> IRGraph:
    """`dist_ingest_with_stats` without the stats (the common call)."""
    return dist_ingest_with_stats(source, **kw)[0]
