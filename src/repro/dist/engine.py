"""Sharded streaming vertex-cut engine (pipelined parse→cut dataflow).

The greedy streaming cut is inherently sequential *within* a stream,
but PowerGraph-style oblivious placement is shard-local by
construction: each worker places a slice of the edge stream against
its own replica/load view, and views are periodically reconciled so
placement happens against near-global state.  Two dataflow modes share
the worker/merge machinery:

**Two-phase** (in-memory graphs, `.npz`/`.rtb` inputs, `workers=1`,
shuffled streams, PG-rule methods): the (possibly permuted) edge
stream is split into W contiguous shards; each worker owns a
`ShardCutState` and streams `merge_period` edges per round; round
barriers reconcile the states.  `workers=1` runs the single shard
through the identical chunked engine path and is bit-identical to
`vertex_cut(..., backend="fast")`.

**Pipelined** (NDJSON trace paths, `workers>1`, Libra-rule methods in
trace order — the `wb_libra` default): byte-range parse shards stream
through an ordered process-pool `imap` into the incremental shard
merger, and merged edge chunks feed resident cut workers round-robin —
cutting starts as soon as the first shard is merged, while later
shards are still parsing, instead of behind a whole-file parse
barrier.  Round r covers global edge offsets [r·W·q, (r+1)·W·q)
(q = `merge_period`), worker s takes the r·W+s-th chunk, and the
Libra degree swap and the λ load bound use *prefix* snapshots taken at
the round's end offset (degrees and Σw over the edges streamed so
far).  Those snapshots are pure functions of the trace's edge stream
and the round quantum — independent of parse shard boundaries, pool
choice, and thread/process timing — so the pipelined output is
deterministic, but it legitimately differs from the two-phase output,
whose swap/bound see the *final* degrees and total weight (pass
`pipeline=False` to force two-phase parity on paths).

**Merges** are either fixed-period (every round, `divergence=None` —
the legacy schedule) or adaptive: every round the O(p) load vectors
are delta-reduced and re-adopted (cheap, keeps the λ bound and the
least-loaded argmins near-global), but the O(n·limbs) replica-mask /
remaining-degree merge runs only when the max per-cluster load drift
since the last full merge exceeds `divergence` × the mean cluster
load.  The drift test reads only merged loads, so the schedule — and
therefore the output — stays a pure function of the inputs.

**Worker pools**: rounds run on resident workers in one of three
interchangeable pools — `thread` (the C kernel streams GIL-released),
`process` (resident `multiprocessing` workers fed chunks over pipes,
so the pure-Python engine scales on no-compiler hosts instead of
serializing on the GIL), or `serial` (in-process loop, the scheduling
oracle).  Workers see the identical call sequence in every pool, so
the pool choice never affects the result.

**Finalize** decodes the replica CSR straight from the merged bitmask
limb rows (`_arrayops.masks_to_replica_csr`, sharded over vertex
ranges on the thread pool) instead of re-sorting all 2|E| endpoints —
bit-identical to `_finalize` because the merged worker masks *are* the
assignment-derived replica sets.

Determinism contract: the output is a pure function of (graph, p,
method, lam, seed, edge_order, workers, merge_period, divergence) —
rounds cover fixed edge offsets in fixed shard order and merges are
load-triggered off deterministic merged values, so pool choice, parse
sharding, and scheduling cannot influence the result.  `workers=1` is
bit-identical to `vertex_cut(..., backend="fast")` (asserted in tests
and gated in the `dist_scaling` bench).
"""
from __future__ import annotations

import os
import warnings
from concurrent.futures import ThreadPoolExecutor
from time import perf_counter

import numpy as np

from .. import obs
from ..core.vertex_cut import (ALGORITHMS, ShardCutState, VertexCutResult,
                               resolve_backend, vertex_cut)
from ..core._arrayops import (masks_to_replica_csr, merge_deltas,
                              merge_limb_masks)

__all__ = ["dist_vertex_cut", "DEFAULT_MERGE_PERIOD", "shard_bounds",
           "WORKER_POOLS"]

DEFAULT_MERGE_PERIOD = 1 << 16
WORKER_POOLS = ("auto", "thread", "process", "serial")
_FINALIZE_SHARDS = 8


def shard_bounds(m: int, workers: int) -> "list[int]":
    """Contiguous stream slice boundaries: W+1 offsets over m edges."""
    workers = max(1, min(int(workers), max(1, m)))
    return [m * s // workers for s in range(workers + 1)]


# ---------------------------------------------------------------------- #
# resident worker pools
# ---------------------------------------------------------------------- #
class _SerialPool:
    """All shard states in-process; rounds run as a plain loop.

    The scheduling oracle: thread and process pools must produce the
    identical result because workers see the identical call sequence.
    """

    kind = "serial"

    def __init__(self, nshards: int, n: int, p: int, deg: np.ndarray,
                 bound: float, libra_rule: bool, engine: str):
        self.states = [ShardCutState.create(n, p, deg, bound, libra_rule,
                                            engine)
                       for _ in range(nshards)]

    def run_round(self, jobs) -> "list[tuple[float, float]]":
        """Returns one (t0, us) pair per job: the absolute perf_counter
        start (seconds) and duration (µs) of the worker's stream_chunk —
        the coordinator turns them into per-lane telemetry spans."""
        us = []
        for s, su, sv, w, out in jobs:
            t0 = perf_counter()
            self.states[s].stream_chunk(su, sv, w, out)
            us.append((t0, (perf_counter() - t0) * 1e6))
        return us

    def local_loads(self) -> "list[np.ndarray]":
        return [st.loads for st in self.states]

    def collect_rm(self):
        return ([st.rem for st in self.states],
                [st.masks for st in self.states])

    def adopt(self, loads, rem, masks) -> None:
        for st in self.states:
            st.adopt(loads, rem, masks)

    def adopt_loads(self, loads) -> None:
        for st in self.states:
            st.adopt_loads(loads)

    def set_bound(self, bound: float) -> None:
        for st in self.states:
            st.bound = bound

    def grow(self, n: int) -> None:
        for st in self.states:
            st.grow(n)

    def close(self) -> None:
        pass


class _ThreadPool(_SerialPool):
    """Rounds fan out over a thread pool (the C kernel streams with the
    GIL released, so shard chunks execute in parallel)."""

    kind = "thread"

    def __init__(self, *args):
        super().__init__(*args)
        self._ex = ThreadPoolExecutor(max_workers=len(self.states))

    def run_round(self, jobs) -> "list[tuple[float, float]]":
        def go(job):
            s, su, sv, w, out = job
            t0 = perf_counter()
            self.states[s].stream_chunk(su, sv, w, out)
            return (t0, (perf_counter() - t0) * 1e6)

        return list(self._ex.map(go, jobs))

    def map_blocks(self, fn, blocks):
        """Fan arbitrary block work (the sharded finalize) over the pool."""
        return list(self._ex.map(fn, blocks))

    def close(self) -> None:
        self._ex.shutdown(wait=False)


def _cut_worker_main(conn, n: int, p: int, deg, bound: float,
                     libra_rule: bool, engine: str) -> None:
    """Resident process-pool worker: owns one ShardCutState, executes
    the coordinator's message stream until "stop"."""
    try:
        st = ShardCutState.create(n, p, deg, bound, libra_rule, engine)
        while True:
            msg = conn.recv()
            tag = msg[0]
            if tag == "chunk":
                su, sv, w = msg[1], msg[2], msg[3]
                # t0 rides home with the result: perf_counter is
                # CLOCK_MONOTONIC (system-wide), so the coordinator can
                # place this span on the worker's telemetry lane
                out = np.empty(len(su), dtype=np.int32)
                t0 = perf_counter()
                st.stream_chunk(su, sv, w, out)
                us = (perf_counter() - t0) * 1e6
                conn.send(("out", out, st.loads.copy(), t0, us))
            elif tag == "adopt":
                st.adopt(msg[1], msg[2], msg[3])
            elif tag == "adopt_loads":
                st.adopt_loads(msg[1])
            elif tag == "bound":
                st.bound = msg[1]
            elif tag == "grow":
                st.grow(msg[1])
            elif tag == "collect":
                conn.send(("rm", st.rem.copy(), st.masks.copy()))
            elif tag == "stop":
                return
    except (EOFError, KeyboardInterrupt):
        return
    except Exception as exc:  # surface worker failures to the coordinator
        try:
            conn.send(("error", f"{type(exc).__name__}: {exc}"))
        except (OSError, BrokenPipeError):
            pass
    finally:
        conn.close()


class _ProcessPool:
    """Resident multiprocessing workers, one ShardCutState each.

    The coordinator ships edge chunks and merge snapshots over pipes;
    workers stream with their own interpreter/GIL, which is what makes
    the pure-Python engine scale on hosts without a C compiler.  The
    message sequence per worker is identical to the other pools', so
    the output is too.
    """

    kind = "process"

    def __init__(self, nshards: int, n: int, p: int, deg: np.ndarray,
                 bound: float, libra_rule: bool, engine: str):
        import multiprocessing as mp
        method = "fork" if "fork" in mp.get_all_start_methods() else None
        ctx = mp.get_context(method)
        self._procs = []
        self._conns = []
        self._loads = [np.zeros(p, dtype=np.float64)
                       for _ in range(nshards)]
        for _ in range(nshards):
            here, there = ctx.Pipe()
            proc = ctx.Process(target=_cut_worker_main,
                               args=(there, n, p, deg, bound, libra_rule,
                                     engine), daemon=True)
            proc.start()
            there.close()
            self._procs.append(proc)
            self._conns.append(here)

    def _recv(self, s: int):
        msg = self._conns[s].recv()
        if msg[0] == "error":
            raise RuntimeError(f"dist cut worker {s} failed: {msg[1]}")
        return msg

    def run_round(self, jobs) -> "list[tuple[float, float]]":
        for s, su, sv, w, _out in jobs:
            self._conns[s].send(("chunk", su, sv, w))
        us = []
        for s, _su, _sv, _w, out in jobs:
            _tag, chunk_out, loads, chunk_t0, chunk_us = self._recv(s)
            out[:] = chunk_out
            self._loads[s] = loads
            us.append((chunk_t0, chunk_us))
        return us

    def local_loads(self) -> "list[np.ndarray]":
        # workers report loads with every chunk result; a worker with no
        # job this round hasn't streamed, so its cached copy is current
        return self._loads

    def collect_rm(self):
        for conn in self._conns:
            conn.send(("collect",))
        rems, masks = [], []
        for s in range(len(self._conns)):
            _tag, rem, mk = self._recv(s)
            rems.append(rem)
            masks.append(mk)
        return rems, masks

    def _broadcast(self, msg) -> None:
        for conn in self._conns:
            conn.send(msg)

    def adopt(self, loads, rem, masks) -> None:
        self._broadcast(("adopt", loads, rem, masks))
        for i in range(len(self._loads)):
            self._loads[i] = loads.copy()

    def adopt_loads(self, loads) -> None:
        self._broadcast(("adopt_loads", loads))
        for i in range(len(self._loads)):
            self._loads[i] = loads.copy()

    def set_bound(self, bound: float) -> None:
        self._broadcast(("bound", bound))

    def grow(self, n: int) -> None:
        self._broadcast(("grow", n))

    def close(self) -> None:
        for conn in self._conns:
            try:
                conn.send(("stop",))
            except (OSError, BrokenPipeError):
                pass
        for proc in self._procs:
            proc.join(timeout=5)
            if proc.is_alive():
                proc.terminate()
        for conn in self._conns:
            conn.close()


def _resolve_worker_pool(pool: str, engine: str, nshards: int) -> str:
    """Concrete pool kind for this (engine, shard count) combination."""
    if pool not in WORKER_POOLS:
        raise ValueError(f"unknown pool {pool!r}; choose from {WORKER_POOLS}")
    if nshards <= 1:
        return "serial"
    if pool == "auto":
        if engine == "native":
            return "thread"          # the kernel releases the GIL
        # pure-Python engine: threads would serialize on the GIL and run
        # W>1 strictly slower than W=1 — resident processes instead
        return "process"
    if pool == "thread" and engine == "python":
        warnings.warn(
            "dist pool='thread' with the pure-Python engine holds the GIL: "
            "W>1 will not run faster than W=1; use pool='process' (or "
            "'auto')", RuntimeWarning, stacklevel=3)
    return pool


def _make_pool(kind: str, nshards: int, n: int, p: int, deg: np.ndarray,
               bound: float, libra_rule: bool, engine: str,
               stacklevel: int = 3):
    cls = {"serial": _SerialPool, "thread": _ThreadPool,
           "process": _ProcessPool}[kind]
    try:
        return cls(nshards, n, p, deg, bound, libra_rule, engine)
    except (ImportError, OSError) as exc:
        if kind == "process":
            # stacklevel points past dist_vertex_cut (and _pipelined_cut
            # when routed through it) at the user's call site
            warnings.warn(f"dist process pool unavailable ({exc}); "
                          "falling back to serial rounds", RuntimeWarning,
                          stacklevel=stacklevel)
            return _SerialPool(nshards, n, p, deg, bound, libra_rule, engine)
        raise


# ---------------------------------------------------------------------- #
# merge scheduling
# ---------------------------------------------------------------------- #
class _MergeController:
    """Round-barrier merge schedule: fixed-period or load-divergence.

    Every round the per-shard load vectors are delta-reduced against
    the last snapshot and re-adopted (O(W·p)).  A *full* merge — the
    O(n·limbs) replica-mask OR plus the remaining-degree reduction —
    runs every round when `divergence` is None (the legacy fixed
    schedule) or when the max per-cluster drift since the last full
    merge exceeds `divergence` × the mean cluster load.  All decisions
    read merged (deterministic) values only.
    """

    def __init__(self, p: int, rem0: "np.ndarray | None",
                 divergence: "float | None"):
        self.p = p
        self.divergence = divergence
        self.snapshot_loads = np.zeros(p, dtype=np.float64)
        self.last_full_loads = np.zeros(p, dtype=np.float64)
        self.snapshot_rem = rem0       # None => rem is not merged (Libra)
        self.full_merges = 0
        self.round_merges = 0

    def round_merge(self, pool) -> bool:
        """Reconcile after a round barrier; returns True on full merge."""
        est = merge_deltas(self.snapshot_loads, pool.local_loads())
        self.round_merges += 1
        full = self.divergence is None
        if not full:
            mean = est.sum() / self.p
            if mean > 0:
                drift = float(np.abs(est - self.last_full_loads).max())
                full = drift > self.divergence * mean
            else:
                full = True
        if full:
            rems, masks_list = pool.collect_rm()
            rem = (merge_deltas(self.snapshot_rem, rems)
                   if self.snapshot_rem is not None else None)
            masks = merge_limb_masks(masks_list)
            pool.adopt(est, rem, masks)
            if rem is not None:
                self.snapshot_rem = rem
            self.last_full_loads = est.copy()
            self.full_merges += 1
        else:
            pool.adopt_loads(est)
        self.snapshot_loads = est
        return full


# ---------------------------------------------------------------------- #
# finalize (sharded, masks-based)
# ---------------------------------------------------------------------- #
def _finalize_from_masks(g, method: str, p: int, lam: float,
                         assignment: np.ndarray, masks: np.ndarray,
                         executor=None) -> VertexCutResult:
    """Build the VertexCutResult from the merged worker bitmasks.

    The union of the worker masks is exactly the assignment-derived
    replica sets (every placement sets both endpoints' bits in the
    placing worker's rows), so the CSR decode is bit-identical to the
    sort-based `_finalize` — without touching the 2|E| endpoint arrays.
    The decode is sharded over vertex ranges; loads/counts stay serial
    `np.bincount` for float bit-identity.
    """
    limbs = (p + 63) // 64
    indptr, flat = masks_to_replica_csr(masks, g.n, limbs, p,
                                        executor=executor,
                                        shards=_FINALIZE_SHARDS)
    loads = np.bincount(assignment, weights=g.w,
                        minlength=p).astype(np.float64)
    counts = np.bincount(assignment, minlength=p).astype(np.int64)
    return VertexCutResult(
        graph_name=g.name, method=method, p=p, lam=lam,
        assignment=assignment, loads=loads, edge_counts=counts,
        n_vertices=g.n, total_weight=g.total_weight,
        replica_indptr=indptr, replica_flat=flat)


# ---------------------------------------------------------------------- #
# pipelined dataflow (parse shards stream into resident cut workers)
# ---------------------------------------------------------------------- #
class _EdgeBacklog:
    """FIFO of merged edge arrays; pops exact round-sized slices."""

    def __init__(self):
        self._parts: list = []
        self._head = 0
        self.size = 0

    def push(self, src, dst, w) -> None:
        if len(src):
            self._parts.append((src, dst, w))
            self.size += len(src)

    def pop(self, k: int):
        k = min(k, self.size)
        take_s, take_d, take_w = [], [], []
        got = 0
        while got < k:
            src, dst, w = self._parts[0]
            avail = len(src) - self._head
            t = min(avail, k - got)
            sl = slice(self._head, self._head + t)
            take_s.append(src[sl])
            take_d.append(dst[sl])
            take_w.append(w[sl])
            got += t
            if t == avail:
                self._parts.pop(0)
                self._head = 0
            else:
                self._head += t
        self.size -= got
        if len(take_s) == 1:
            return take_s[0], take_d[0], take_w[0]
        return (np.concatenate(take_s), np.concatenate(take_d),
                np.concatenate(take_w))


def _pipelined_cut(path: str, p: int, method: str, lam: float,
                   workers: int, merge_period: int,
                   divergence: "float | None", engine: str,
                   pool_kind: str, parse_workers: int,
                   timeline: "dict | None") -> VertexCutResult:
    """Stream parse shards through the merger into resident cut workers.

    Round r covers edges [r·W·q, (r+1)·W·q) of the merged trace stream;
    the Libra swap and λ bound snapshot prefix degrees / prefix Σw at
    the round's end offset.  Deterministic for fixed (trace, p, method,
    lam, W, merge_period, divergence) — see the module docstring.
    """
    from ..trace.ingest import DEFAULT_CHUNK_EDGES, _source_name
    from ..trace.weights import resolve_weight_model
    from .parse import ShardMerger, _shard_tasks, open_shard_parses

    weighted = method in ("w_pg", "wb_pg", "w_libra", "wb_libra")
    balanced = method in ("wb_pg", "wb_libra")
    q = merge_period
    round_edges = workers * q

    tasks = _shard_tasks(path, parse_workers, "bytes", DEFAULT_CHUNK_EDGES,
                         False, None, "raise", "auto")
    merger = ShardMerger(resolve_weight_model("bytes"), False)
    backlog = _EdgeBacklog()
    deg = np.zeros(0, dtype=np.int64)
    wsum = 0.0
    outs: list = []
    rounds_tl: "list | None" = [] if timeline is not None else None

    pool = _make_pool(pool_kind, workers, 0, p, np.zeros(0, np.int64),
                      float("inf"), True, engine, stacklevel=4)
    ctrl = _MergeController(p, None, divergence)
    col = obs.current()
    shard_i = 0
    try:
        t_parse0 = perf_counter()
        with open_shard_parses(tasks, "auto", "bytes") as shard_iter:
            it = iter(shard_iter)
            exhausted = False
            while True:
                t0 = perf_counter()
                while backlog.size < round_edges and not exhausted:
                    sh = next(it, None)
                    if sh is None:
                        exhausted = True
                    else:
                        if col is not None and sh.events:
                            # parse spans were timed inside the (possibly
                            # remote) parse worker; land them on a lane
                            # keyed by shard order, which the worker
                            # itself does not know
                            for ev in sh.events:
                                ev["lane"] = f"parse/p{shard_i}"
                            col.absorb_events(sh.events)
                        shard_i += 1
                        with obs.span("parse.merge", lane="coord"):
                            backlog.push(*merger.add(sh))
                parse_wait_us = (perf_counter() - t0) * 1e6
                obs.complete("dist.parse_wait", t0,
                             t0 + parse_wait_us / 1e6, lane="coord",
                             cat="wait", round=len(outs))
                obs.observe("dist.parse_wait_us", parse_wait_us)
                if backlog.size == 0:
                    break
                src_r, dst_r, w_r = backlog.pop(round_edges)
                k = len(src_r)
                n_now = merger.n
                if len(deg) < n_now:
                    grown = np.zeros(n_now, dtype=np.int64)
                    grown[:len(deg)] = deg
                    deg = grown
                deg += np.bincount(src_r, minlength=len(deg))
                deg += np.bincount(dst_r, minlength=len(deg))
                if weighted:
                    if k and float(w_r.min()) < 0:
                        raise ValueError(
                            "edge weights must be >= 0 for the greedy cuts")
                    wl = np.ascontiguousarray(w_r, dtype=np.float64)
                else:
                    wl = np.ones(k)
                wsum += float(wl.sum())
                bound = lam * wsum / p if balanced else float("inf")
                # Libra endpoint swap against the prefix-degree snapshot
                swap = deg[src_r] > deg[dst_r]
                su = np.ascontiguousarray(np.where(swap, dst_r, src_r),
                                          dtype=np.int32)
                sv = np.ascontiguousarray(np.where(swap, src_r, dst_r),
                                          dtype=np.int32)
                pool.grow(n_now)
                pool.set_bound(bound)
                out_r = np.empty(k, dtype=np.int32)
                jobs = []
                for s in range(workers):
                    a, b = s * q, min((s + 1) * q, k)
                    if a < b:
                        jobs.append((s, su[a:b], sv[a:b], wl[a:b],
                                     out_r[a:b]))
                cut_us = pool.run_round(jobs)
                r = len(outs)
                # worker durations arrive over the pool's result channel
                # (a pipe for process pools), so the coordinator merges
                # every worker's samples into one histogram here — no
                # shared memory, identical distribution to a serial run
                for (s, _su, _sv, _w, _out), (ct0, cus) in zip(jobs, cut_us):
                    obs.complete("dist.cut", ct0, ct0 + cus / 1e6,
                                 lane=f"cut/w{s}", round=r)
                    obs.observe("dist.cut_us", cus)
                obs.counter("dist.edges", k)
                obs.observe("dist.round_edges", k)
                outs.append(out_r)
                t1 = perf_counter()
                more = backlog.size > 0 or not exhausted
                full = ctrl.round_merge(pool) if more else False
                merge_us = (perf_counter() - t1) * 1e6
                if more:
                    obs.complete("dist.merge", t1, t1 + merge_us / 1e6,
                                 lane="coord", round=r, full=bool(full))
                    obs.observe("dist.merge_us", merge_us)
                if rounds_tl is not None:
                    rounds_tl.append({
                        "round": r, "edges": k,
                        "parse_wait_us": round(parse_wait_us, 1),
                        "cut_us": [round(u, 1) for _t, u in cut_us],
                        "merge_us": round(merge_us, 1),
                        "full_merge": bool(full)})
        parse_us = (perf_counter() - t_parse0) * 1e6
        g, _stats = merger.finish(_source_name(path, None))
        t2 = perf_counter()
        _rems, masks_list = pool.collect_rm()
        masks = merge_limb_masks(masks_list)
    finally:
        pool.close()

    assignment = (np.concatenate(outs) if outs
                  else np.empty(0, dtype=np.int32))
    with ThreadPoolExecutor(max_workers=_FINALIZE_SHARDS) as ex:
        result = _finalize_from_masks(g, method, p, lam, assignment, masks,
                                      executor=ex)
    finalize_us = (perf_counter() - t2) * 1e6
    obs.complete("dist.finalize", t2, t2 + finalize_us / 1e6, lane="coord")
    obs.observe("dist.finalize_us", finalize_us)
    obs.counter("dist.full_merges", ctrl.full_merges)
    obs.counter("dist.round_merges", ctrl.round_merges)
    if timeline is not None:
        timeline.update({
            "mode": "pipelined", "pool": pool.kind, "engine": engine,
            "workers": workers, "merge_period": merge_period,
            "divergence": divergence, "rounds": rounds_tl,
            "full_merges": ctrl.full_merges,
            "round_merges": ctrl.round_merges,
            "parse_and_cut_us": round(parse_us, 1),
            "finalize_us": round(finalize_us, 1)})
    return result


# ---------------------------------------------------------------------- #
# public entry point
# ---------------------------------------------------------------------- #
def dist_vertex_cut(g, p: int, method: str = "wb_libra", lam: float = 1.0,
                    seed: int = 0, edge_order: str = "auto",
                    workers: int = 1,
                    merge_period: "int | None" = None,
                    divergence: "float | None" = None,
                    backend: str = "fast",
                    pool: str = "auto",
                    pipeline: "bool | str" = "auto",
                    parse_workers: "int | None" = None,
                    timeline: "dict | None" = None) -> VertexCutResult:
    """Partition `g`'s edges into `p` clusters on W sharded workers.

    Args:
      g: `IRGraph`, or a path (`.npz` snapshot / `.rtb` container /
        NDJSON trace).  NDJSON paths are eligible for the pipelined
        parse→cut dataflow; everything else two-phases (parse/load,
        then cut).
      workers: shard count W.  1 reproduces `backend="fast"` bit for
        bit; W > 1 is deterministic for fixed (W, seed, merge_period,
        divergence).
      merge_period: edges each worker streams between round barriers
        (default `DEFAULT_MERGE_PERIOD`); smaller tracks global state
        more closely (better quality, more merge overhead).
      divergence: None (default) runs a full state merge at every
        round barrier — the fixed legacy schedule.  A float d >= 0
        merges loads every round but defers the expensive replica-mask
        merge until the max per-cluster load drift since the last full
        merge exceeds d × the mean cluster load (d ~ 0.05 keeps
        quality close to the fixed schedule at a fraction of the merge
        traffic; d = 0 is the fixed schedule again).
      backend: fast-engine selector for the workers ("fast", "native",
        "python").  The greedy stream never runs on "reference"/"pallas"
        — use `vertex_cut` for those.
      pool: "thread" / "process" / "serial" worker pool, or "auto":
        threads when the C kernel is available (it streams
        GIL-released), resident processes for the pure-Python engine
        (threads would serialize on the GIL).  The pool never affects
        the result.
      pipeline: "auto" (default) streams parse shards directly into
        the cut workers for NDJSON paths with W > 1 Libra-rule
        trace-order cuts; True forces it (raises when ineligible);
        False always two-phases.  Pipelined output uses prefix
        degree/bound snapshots and differs (deterministically) from
        the two-phase output — see the module docstring.
      parse_workers: byte-range parse shard count for the pipelined
        dataflow (default: `workers`).  Parse sharding never affects
        the output — rounds cover global edge offsets.
      timeline: legacy back-compat shim — an optional dict the engine
        fills with per-round, per-worker phase timings
        (parse/cut/merge/finalize), built from the same measurements
        the engine now emits as `repro.obs` telemetry spans.  New code
        should activate a collector (`REPRO_PROFILE=out.json` or
        `obs.scoped()`) and read the profile instead; see
        docs/observability.md.

    Everything else matches `vertex_cut`.
    """
    if method not in ALGORITHMS:
        raise ValueError(f"unknown method {method!r}; choose from {ALGORITHMS}")
    if p < 1:
        raise ValueError("p must be >= 1")
    if lam < 1.0:
        raise ValueError("lambda must be >= 1 (paper Eq. 3)")
    if merge_period is None:
        merge_period = DEFAULT_MERGE_PERIOD
    if merge_period < 1:
        raise ValueError("merge_period must be >= 1")
    if divergence is not None and divergence < 0:
        raise ValueError("divergence must be >= 0 (or None for the fixed "
                         "merge schedule)")
    if pipeline not in (True, False, "auto"):
        raise ValueError("pipeline must be True, False or 'auto'")
    workers = max(1, int(workers))
    engine = resolve_backend(backend)
    if engine not in ("native", "python"):
        raise ValueError(
            f"shard streaming runs on the fast engines only, not "
            f"{backend!r} (the greedy stream is inherently sequential)")

    balanced = method in ("wb_pg", "wb_libra")
    libra_rule = method in ("libra", "w_libra", "wb_libra")
    eff_order = edge_order
    if eff_order == "auto":
        eff_order = "trace" if balanced else "shuffled"

    path = os.fspath(g) if isinstance(g, (str, os.PathLike)) else None
    ndjson_path = (path is not None and not path.endswith(".npz")
                   and not _is_binary(path))
    pipe_ok = (ndjson_path and workers > 1 and libra_rule
               and eff_order == "trace" and method != "random")
    if pipeline is True and not pipe_ok:
        raise ValueError(
            "pipeline=True needs an NDJSON trace path, workers >= 2, a "
            "Libra-rule method and edge_order='trace' (the prefix-snapshot "
            "semantics only exist for streamed trace-order Libra cuts); "
            f"got path={path!r}, workers={workers}, method={method!r}, "
            f"edge_order={eff_order!r}")
    if pipeline in (True, "auto") and pipe_ok:
        pool_kind = _resolve_worker_pool(pool, engine, workers)
        return _pipelined_cut(path, p, method, lam, workers, merge_period,
                              divergence, engine, pool_kind,
                              parse_workers or workers, timeline)

    t_ingest0 = perf_counter()
    if path is not None:
        if path.endswith(".npz"):
            from ..core.graph import IRGraph
            g = IRGraph.load_npz(path)
        else:
            from .parse import dist_ingest
            g = dist_ingest(path, workers=workers)
        obs.complete("dist.ingest", t_ingest0, perf_counter(), lane="coord",
                     cat="section", source=os.path.basename(path))
    ingest_us = (perf_counter() - t_ingest0) * 1e6

    if method == "random":
        # no streaming state to shard; identical to the fast engine
        return vertex_cut(g, p, method=method, lam=lam, seed=seed,
                          edge_order=edge_order, backend="fast")

    m = g.num_edges
    weighted = method in ("w_pg", "wb_pg", "w_libra", "wb_libra")
    if weighted and m and float(g.w.min()) < 0:
        raise ValueError("edge weights must be >= 0 for the greedy cuts")

    # stream-order selection: must mirror vertex_cut exactly (same rng
    # construction) so workers=1 sees the identical stream
    rng = np.random.default_rng(seed)
    if eff_order == "shuffled":
        perm = rng.permutation(m)
    elif eff_order == "trace":
        perm = np.arange(m)
    else:
        raise ValueError("edge_order must be 'shuffled', 'trace' or 'auto'")

    src = g.src[perm]
    dst = g.dst[perm]
    w = g.w[perm] if weighted else np.ones(m)
    w = np.ascontiguousarray(w, dtype=np.float64)
    deg = g.degrees()
    total_load = float(w.sum())
    bound = lam * total_load / p if balanced else float("inf")

    if libra_rule:
        swap = deg[src] > deg[dst]
        su = np.ascontiguousarray(np.where(swap, dst, src), dtype=np.int32)
        sv = np.ascontiguousarray(np.where(swap, src, dst), dtype=np.int32)
    else:
        su = np.ascontiguousarray(src, dtype=np.int32)
        sv = np.ascontiguousarray(dst, dtype=np.int32)

    bounds = shard_bounds(m, workers)
    nshards = len(bounds) - 1
    out = np.empty(m, dtype=np.int32)
    pool_kind = _resolve_worker_pool(pool, engine, nshards)
    wpool = _make_pool(pool_kind, nshards, g.n, p, deg, bound, libra_rule,
                       engine)
    rounds_tl: "list | None" = [] if timeline is not None else None
    ctrl = _MergeController(
        p, deg.astype(np.int64, copy=True) if not libra_rule else None,
        divergence)
    try:
        if nshards == 1:
            # single shard: the chunked resumable path is bit-identical
            # to one uninterrupted _stream_fast pass (no merges to run)
            st = wpool.states[0]
            with obs.span("dist.cut", lane="cut/w0", rounds=1):
                for a in range(0, m, merge_period):
                    b = min(a + merge_period, m)
                    st.stream_chunk(su[a:b], sv[a:b], w[a:b], out[a:b])
        else:
            shard_len = max(bounds[s + 1] - bounds[s]
                            for s in range(nshards))
            rounds = -(-shard_len // merge_period)
            for r in range(rounds):
                jobs = []
                for s in range(nshards):
                    a = bounds[s] + r * merge_period
                    b = min(a + merge_period, bounds[s + 1])
                    if a < b:
                        jobs.append((s, su[a:b], sv[a:b], w[a:b],
                                     out[a:b]))
                cut_us = wpool.run_round(jobs)
                for (s, _su, _sv, _w, _o), (ct0, cus) in zip(jobs, cut_us):
                    obs.complete("dist.cut", ct0, ct0 + cus / 1e6,
                                 lane=f"cut/w{s}", round=r)
                    obs.observe("dist.cut_us", cus)
                t1 = perf_counter()
                full = ctrl.round_merge(wpool) if r + 1 < rounds else False
                merge_us = (perf_counter() - t1) * 1e6
                if r + 1 < rounds:
                    obs.complete("dist.merge", t1, t1 + merge_us / 1e6,
                                 lane="coord", round=r, full=bool(full))
                    obs.observe("dist.merge_us", merge_us)
                if rounds_tl is not None:
                    rounds_tl.append({
                        "round": r,
                        "cut_us": [round(u, 1) for _t, u in cut_us],
                        "merge_us": round(merge_us, 1),
                        "full_merge": bool(full)})
        t2 = perf_counter()
        _rems, masks_list = wpool.collect_rm()
        masks = merge_limb_masks(masks_list)
    finally:
        wpool.close()

    assignment = np.empty(m, dtype=np.int32)
    assignment[perm] = out
    with ThreadPoolExecutor(max_workers=_FINALIZE_SHARDS) as ex:
        result = _finalize_from_masks(g, method, p, lam, assignment, masks,
                                      executor=ex)
    t3 = perf_counter()
    obs.complete("dist.finalize", t2, t3, lane="coord")
    obs.observe("dist.finalize_us", (t3 - t2) * 1e6)
    if timeline is not None:
        timeline.update({
            "mode": "two-phase", "pool": wpool.kind, "engine": engine,
            "workers": nshards, "merge_period": merge_period,
            "divergence": divergence, "rounds": rounds_tl,
            "full_merges": ctrl.full_merges,
            "round_merges": ctrl.round_merges,
            "ingest_us": round(ingest_us, 1),
            "finalize_us": round((perf_counter() - t2) * 1e6, 1)})
    return result


def _is_binary(path: str) -> bool:
    from ..trace.binfmt import is_binary_trace_path
    return is_binary_trace_path(path)
