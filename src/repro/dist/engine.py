"""Sharded streaming vertex-cut engine (per-shard workers + merges).

The greedy streaming cut is inherently sequential *within* a stream,
but PowerGraph-style oblivious placement is shard-local by
construction: each worker places its slice of the edge stream against
its own replica/load view, and views are periodically reconciled so
placement happens against near-global state.  Concretely:

  * the (possibly permuted) edge stream is split into W contiguous
    shards; each worker owns a `ShardCutState` — the same flat buffers
    the fast engines mutate (loads, bitmask limb rows, remaining
    degrees), created per shard;
  * workers stream `merge_period` edges per round (the C kernel runs
    with the GIL released, so rounds execute in parallel threads);
  * at every round barrier the shard states are merged — replica limb
    rows by bitwise OR, loads / remaining degrees by delta reduction
    against the round's snapshot (`_arrayops.merge_limb_masks` /
    `merge_deltas`) — and the merged snapshot is installed back into
    every shard (the paper lineage's "oblivious greedy" mode);
  * the final assignment is finalized by the standard `_finalize`, so
    the result is an ordinary `VertexCutResult` the mapping/simulator/
    planner layers consume unchanged.

Determinism contract: the output is a pure function of
(graph, p, method, lam, seed, edge_order, workers, merge_period) —
merges happen at fixed edge counts in fixed shard order, so thread
scheduling cannot influence the result.  `workers=1` runs the single
shard through the identical chunked engine path and is bit-identical
to `vertex_cut(..., backend="fast")` (asserted in tests and gated in
the `dist_scaling` bench).
"""
from __future__ import annotations

import os
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from ..core.vertex_cut import (ALGORITHMS, ShardCutState, VertexCutResult,
                               _finalize, vertex_cut)
from ..core._arrayops import merge_deltas, merge_limb_masks

__all__ = ["dist_vertex_cut", "DEFAULT_MERGE_PERIOD", "shard_bounds"]

DEFAULT_MERGE_PERIOD = 1 << 16


def shard_bounds(m: int, workers: int) -> "list[int]":
    """Contiguous stream slice boundaries: W+1 offsets over m edges."""
    workers = max(1, min(int(workers), max(1, m)))
    return [m * s // workers for s in range(workers + 1)]


def dist_vertex_cut(g, p: int, method: str = "wb_libra", lam: float = 1.0,
                    seed: int = 0, edge_order: str = "auto",
                    workers: int = 1,
                    merge_period: "int | None" = None,
                    backend: str = "fast") -> VertexCutResult:
    """Partition `g`'s edges into `p` clusters on W sharded workers.

    Args:
      g: `IRGraph`, or a path (`.npz` snapshot / NDJSON trace — traces
        are ingested through the parallel sharded parse front end with
        the same worker count).
      workers: shard count W.  1 reproduces `backend="fast"` bit for
        bit; W > 1 is deterministic for fixed (W, seed, merge_period).
      merge_period: edges each worker streams between merge barriers
        (default `DEFAULT_MERGE_PERIOD`); smaller tracks global state
        more closely (better quality, more merge overhead).
      backend: fast-engine selector for the workers ("fast", "native",
        "python").  The greedy stream never runs on "reference"/"pallas"
        — use `vertex_cut` for those.

    Everything else matches `vertex_cut`.
    """
    if isinstance(g, (str, os.PathLike)):
        path = os.fspath(g)
        if path.endswith(".npz"):
            from ..core.graph import IRGraph
            g = IRGraph.load_npz(path)
        else:
            from .parse import dist_ingest
            g = dist_ingest(path, workers=workers)
    if method not in ALGORITHMS:
        raise ValueError(f"unknown method {method!r}; choose from {ALGORITHMS}")
    if p < 1:
        raise ValueError("p must be >= 1")
    if lam < 1.0:
        raise ValueError("lambda must be >= 1 (paper Eq. 3)")
    if merge_period is None:
        merge_period = DEFAULT_MERGE_PERIOD
    if merge_period < 1:
        raise ValueError("merge_period must be >= 1")
    workers = max(1, int(workers))

    if method == "random":
        # no streaming state to shard; identical to the fast engine
        return vertex_cut(g, p, method=method, lam=lam, seed=seed,
                          edge_order=edge_order, backend="fast")

    m = g.num_edges
    weighted = method in ("w_pg", "wb_pg", "w_libra", "wb_libra")
    balanced = method in ("wb_pg", "wb_libra")
    libra_rule = method in ("libra", "w_libra", "wb_libra")
    if weighted and m and float(g.w.min()) < 0:
        raise ValueError("edge weights must be >= 0 for the greedy cuts")

    # stream-order selection: must mirror vertex_cut exactly (same rng
    # construction) so workers=1 sees the identical stream
    rng = np.random.default_rng(seed)
    if edge_order == "auto":
        edge_order = "trace" if balanced else "shuffled"
    if edge_order == "shuffled":
        perm = rng.permutation(m)
    elif edge_order == "trace":
        perm = np.arange(m)
    else:
        raise ValueError("edge_order must be 'shuffled', 'trace' or 'auto'")

    src = g.src[perm]
    dst = g.dst[perm]
    w = g.w[perm] if weighted else np.ones(m)
    w = np.ascontiguousarray(w, dtype=np.float64)
    deg = g.degrees()
    total_load = float(w.sum())
    bound = lam * total_load / p if balanced else float("inf")

    if libra_rule:
        swap = deg[src] > deg[dst]
        su = np.ascontiguousarray(np.where(swap, dst, src), dtype=np.int32)
        sv = np.ascontiguousarray(np.where(swap, src, dst), dtype=np.int32)
    else:
        su = np.ascontiguousarray(src, dtype=np.int32)
        sv = np.ascontiguousarray(dst, dtype=np.int32)

    bounds = shard_bounds(m, workers)
    nshards = len(bounds) - 1
    out = np.empty(m, dtype=np.int32)
    states = [ShardCutState.create(g.n, p, deg, bound, libra_rule, backend)
              for _ in range(nshards)]

    if nshards == 1:
        # single shard: the chunked resumable path is bit-identical to
        # one uninterrupted _stream_fast pass (no merges to run)
        st = states[0]
        for a in range(0, m, merge_period):
            b = min(a + merge_period, m)
            st.stream_chunk(su[a:b], sv[a:b], w[a:b], out[a:b])
    else:
        shard_len = max(bounds[s + 1] - bounds[s] for s in range(nshards))
        rounds = -(-shard_len // merge_period)
        snapshot_loads = np.zeros(p, dtype=np.float64)
        snapshot_rem = deg.astype(np.int64, copy=True)

        def run_round(r: int, s: int) -> None:
            a = bounds[s] + r * merge_period
            b = min(a + merge_period, bounds[s + 1])
            if a < b:
                states[s].stream_chunk(su[a:b], sv[a:b], w[a:b], out[a:b])

        with ThreadPoolExecutor(max_workers=nshards) as ex:
            for r in range(rounds):
                list(ex.map(lambda s, _r=r: run_round(_r, s),
                            range(nshards)))
                if r + 1 < rounds:
                    loads = merge_deltas(snapshot_loads,
                                         [st.loads for st in states])
                    rem = merge_deltas(snapshot_rem,
                                       [st.rem for st in states])
                    masks = merge_limb_masks([st.masks for st in states])
                    for st in states:
                        st.adopt(loads, rem, masks)
                    snapshot_loads = loads
                    snapshot_rem = rem

    assignment = np.empty(m, dtype=np.int32)
    assignment[perm] = out
    return _finalize(g, method, p, lam, assignment, "fast")
