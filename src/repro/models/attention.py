"""Attention blocks: GQA/MQA (with local windows, softcap, RoPE/M-RoPE),
Multi-head Latent Attention (DeepSeek-V3), and cross-attention.

Each block provides:
  init(key, cfg, ...)                                -> params
  apply(params, cfg, x, positions, ...)              -> y          (full seq)
  init_cache(cfg, batch, max_len, ...)               -> cache      (decode)
  apply_decode(params, cfg, x, cache, pos, ...)      -> y, cache   (one token)

Caches for windowed layers are ring buffers of size min(window, max_len);
MLA caches store the *compressed* latent (kv_lora + rope dims per token),
which is what makes 32k-context decode of a 128-head model feasible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from .layers import init_dense, dense, init_rms_norm, rms_norm, rope, mrope

__all__ = ["GQA", "MLA", "CrossAttention"]


def _apply_rope(cfg: ModelConfig, x, positions):
    if cfg.mrope_sections is not None:
        return mrope(x, positions, tuple(cfg.mrope_sections),
                     cfg.rope_theta)
    return rope(x, positions, cfg.rope_theta)


class GQA:
    """Grouped-query attention (covers MHA and MQA)."""

    @staticmethod
    def init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
        d, hd = cfg.d_model, cfg.head_dim
        kq, kk, kv, ko = jax.random.split(key, 4)
        return {
            "wq": init_dense(kq, d, cfg.n_heads * hd, dtype),
            "wk": init_dense(kk, d, cfg.n_kv_heads * hd, dtype),
            "wv": init_dense(kv, d, cfg.n_kv_heads * hd, dtype),
            "wo": init_dense(ko, cfg.n_heads * hd, d, dtype),
        }

    @staticmethod
    def _qkv(p, cfg, x, positions):
        B, S, _ = x.shape
        hd = cfg.head_dim
        q = dense(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
        k = dense(p["wk"], x).reshape(B, S, cfg.n_kv_heads, hd)
        v = dense(p["wv"], x).reshape(B, S, cfg.n_kv_heads, hd)
        q = _apply_rope(cfg, q, positions)
        k = _apply_rope(cfg, k, positions)
        return q, k, v

    @staticmethod
    def apply(p: dict, cfg: ModelConfig, x: jax.Array,
              positions: jax.Array, window: int | None = None,
              impl: str = "auto") -> jax.Array:
        B, S, _ = x.shape
        q, k, v = GQA._qkv(p, cfg, x, positions)
        o = ops.attention(q, k, v, causal=True, window=window,
                          softcap=cfg.attn_softcap, impl=impl)
        return dense(p["wo"], o.reshape(B, S, -1))

    @staticmethod
    def apply_bidirectional(p: dict, cfg: ModelConfig, x: jax.Array,
                            positions: jax.Array,
                            impl: str = "auto") -> jax.Array:
        """Encoder self-attention: no causal mask."""
        B, S, _ = x.shape
        q, k, v = GQA._qkv(p, cfg, x, positions)
        o = ops.attention(q, k, v, causal=False,
                          softcap=cfg.attn_softcap, impl=impl)
        return dense(p["wo"], o.reshape(B, S, -1))

    # -- decode ---------------------------------------------------------- #
    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, max_len: int,
                   window: int | None = None, dtype=jnp.float32) -> dict:
        W = min(window, max_len) if window else max_len
        hd = cfg.head_dim
        return {
            "k": jnp.zeros((batch, W, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, W, cfg.n_kv_heads, hd), dtype),
        }

    @staticmethod
    def apply_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache: dict,
                     pos: jax.Array, window: int | None = None
                     ) -> tuple[jax.Array, dict]:
        """x [B, 1, d]; pos: scalar int32 absolute position."""
        B = x.shape[0]
        hd = cfg.head_dim
        if cfg.mrope_sections is not None:
            positions = jnp.full((3, B, 1), pos, jnp.int32)  # text mode
        else:
            positions = jnp.full((B, 1), pos, jnp.int32)
        q, k, v = GQA._qkv(p, cfg, x, positions)
        W = cache["k"].shape[1]
        slot = pos % W  # ring buffer for windowed layers; == pos otherwise
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        # positions of ring slots: slot i holds absolute pos p where
        # p % W == i and p <= pos and p > pos - W
        idx = jnp.arange(W)
        abs_pos = pos - ((pos - idx) % W)
        valid = (abs_pos >= 0) & (abs_pos <= pos)
        if window is not None:
            valid &= abs_pos > pos - window
        logits_mask = jnp.where(valid, 0.0, -1e30)
        # grouped-query einsum: no materialised head-repeat of the cache,
        # bf16 operands with f32 accumulation (decode is HBM-bound — the
        # cache read IS the cost; see EXPERIMENTS §Perf)
        Hkv = cfg.n_kv_heads
        G = cfg.n_heads // Hkv
        qg = (q * (hd ** -0.5)).reshape(B, 1, Hkv, G, hd)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, ck,
                       preferred_element_type=jnp.float32)
        if cfg.attn_softcap is not None:
            s = jnp.tanh(s / cfg.attn_softcap) * cfg.attn_softcap
        s = s + logits_mask[None, None, None, None, :]
        probs = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd",
                       probs.astype(ck.dtype), cv,
                       preferred_element_type=jnp.float32)
        y = dense(p["wo"], o.reshape(B, 1, -1).astype(x.dtype))
        return y, {"k": ck, "v": cv}


class MLA:
    """Multi-head Latent Attention (DeepSeek-V3).

    Prefill/train materialise per-head k/v from the compressed latent;
    decode uses the *absorbed* form: scores and values are computed in the
    kv_lora latent space so the cache holds only (kv_lora + rope_dim)
    floats per token."""

    @staticmethod
    def init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
        d = cfg.d_model
        H = cfg.n_heads
        dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        keys = jax.random.split(key, 8)
        p = {}
        if cfg.q_lora_rank:
            p["wq_a"] = init_dense(keys[0], d, cfg.q_lora_rank, dtype)
            p["q_norm"] = init_rms_norm(cfg.q_lora_rank, dtype)
            p["wq_b"] = init_dense(keys[1], cfg.q_lora_rank,
                                   H * (dn + dr), dtype)
        else:
            p["wq"] = init_dense(keys[1], d, H * (dn + dr), dtype)
        p["wkv_a"] = init_dense(keys[2], d, cfg.kv_lora_rank + dr, dtype)
        p["kv_norm"] = init_rms_norm(cfg.kv_lora_rank, dtype)
        p["wk_b"] = init_dense(keys[3], cfg.kv_lora_rank, H * dn, dtype)
        p["wv_b"] = init_dense(keys[4], cfg.kv_lora_rank, H * dv, dtype)
        p["wo"] = init_dense(keys[5], H * dv, d, dtype)
        return p

    @staticmethod
    def _q(p, cfg, x, positions):
        B, S, _ = x.shape
        H = cfg.n_heads
        dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
        if cfg.q_lora_rank:
            q = dense(p["wq_b"], rms_norm(p["q_norm"], dense(p["wq_a"], x)))
        else:
            q = dense(p["wq"], x)
        q = q.reshape(B, S, H, dn + dr)
        q_nope, q_rope = q[..., :dn], q[..., dn:]
        q_rope = rope(q_rope, positions, cfg.rope_theta)
        return q_nope, q_rope

    @staticmethod
    def _latent(p, cfg, x, positions):
        B, S, _ = x.shape
        dr = cfg.qk_rope_head_dim
        kv = dense(p["wkv_a"], x)
        c_kv = rms_norm(p["kv_norm"], kv[..., :cfg.kv_lora_rank])
        k_rope = rope(kv[..., cfg.kv_lora_rank:].reshape(B, S, 1, dr),
                      positions, cfg.rope_theta)
        return c_kv, k_rope

    @staticmethod
    def apply(p: dict, cfg: ModelConfig, x: jax.Array,
              positions: jax.Array, window: int | None = None,
              impl: str = "auto") -> jax.Array:
        B, S, _ = x.shape
        H = cfg.n_heads
        dn, dr, dv = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                      cfg.v_head_dim)
        q_nope, q_rope = MLA._q(p, cfg, x, positions)
        c_kv, k_rope = MLA._latent(p, cfg, x, positions)
        k_nope = dense(p["wk_b"], c_kv).reshape(B, S, H, dn)
        v = dense(p["wv_b"], c_kv).reshape(B, S, H, dv)
        q = jnp.concatenate([q_nope, q_rope], -1)
        k = jnp.concatenate([k_nope,
                             jnp.broadcast_to(k_rope, (B, S, H, dr))], -1)
        o = ops.attention(q, k, v, causal=True, window=window,
                          scale=(dn + dr) ** -0.5, impl=impl)
        return dense(p["wo"], o.reshape(B, S, -1))

    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, max_len: int,
                   window: int | None = None, dtype=jnp.float32) -> dict:
        return {
            "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim),
                               dtype),
        }

    @staticmethod
    def apply_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache: dict,
                     pos: jax.Array, window: int | None = None
                     ) -> tuple[jax.Array, dict]:
        B = x.shape[0]
        H = cfg.n_heads
        dn, dr, dv = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                      cfg.v_head_dim)
        L = cfg.kv_lora_rank
        positions = jnp.full((B, 1), pos, jnp.int32)
        q_nope, q_rope = MLA._q(p, cfg, x, positions)      # [B,1,H,*]
        c_kv, k_rope = MLA._latent(p, cfg, x, positions)   # [B,1,L],[B,1,1,dr]
        ckv = jax.lax.dynamic_update_slice(
            cache["ckv"], c_kv.astype(cache["ckv"].dtype), (0, pos, 0))
        krope = jax.lax.dynamic_update_slice(
            cache["krope"], k_rope[:, :, 0].astype(cache["krope"].dtype),
            (0, pos, 0))
        # absorbed scores: q_nope projected into latent space
        wk = p["wk_b"]["w"].reshape(L, H, dn)
        q_lat = jnp.einsum("bqhd,lhd->bqhl", q_nope.astype(jnp.float32),
                           wk.astype(jnp.float32))          # [B,1,H,L]
        s_nope = jnp.einsum("bqhl,bkl->bhqk", q_lat,
                            ckv.astype(jnp.float32))
        s_rope = jnp.einsum("bqhd,bkd->bhqk",
                            q_rope.astype(jnp.float32),
                            krope.astype(jnp.float32))
        s = (s_nope + s_rope) * ((dn + dr) ** -0.5)
        S_max = ckv.shape[1]
        valid = jnp.arange(S_max) <= pos
        s = jnp.where(valid[None, None, None, :], s, -1e30)
        probs = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhqk,bkl->bqhl", probs,
                           ckv.astype(jnp.float32))          # [B,1,H,L]
        wv = p["wv_b"]["w"].reshape(L, H, dv)
        o = jnp.einsum("bqhl,lhd->bqhd", o_lat, wv.astype(jnp.float32))
        y = dense(p["wo"], o.reshape(B, 1, -1).astype(x.dtype))
        return y, {"ckv": ckv, "krope": krope}


class CrossAttention:
    """Encoder-decoder cross attention (seamless)."""

    @staticmethod
    def init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
        return GQA.init(key, cfg, dtype)

    @staticmethod
    def apply(p: dict, cfg: ModelConfig, x: jax.Array,
              enc: jax.Array, impl: str = "auto") -> jax.Array:
        B, S, _ = x.shape
        Se = enc.shape[1]
        hd = cfg.head_dim
        q = dense(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
        k = dense(p["wk"], enc).reshape(B, Se, cfg.n_kv_heads, hd)
        v = dense(p["wv"], enc).reshape(B, Se, cfg.n_kv_heads, hd)
        o = ops.attention(q, k, v, causal=False, impl=impl)
        return dense(p["wo"], o.reshape(B, S, -1))
