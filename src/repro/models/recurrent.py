"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Structure (per Griffin):  x -> [branch1: dense+gelu] ⊙ [branch2: conv1d(4)
-> RG-LRU] -> dense out.  The RG-LRU gate:

    r_t = σ(x W_r + b_r)          (recurrence gate)
    i_t = σ(x W_i + b_i)          (input gate)
    a_t = a^(c·r_t),  a = σ(Λ)    (per-channel learned decay, c = 8)
    h_t = a_t h_{t-1} + sqrt(1-a_t²)·(i_t ⊙ x_t)

The scan itself runs in the Pallas kernel (kernels.ops.rglru).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from .layers import init_dense, dense

__all__ = ["RGLRUBlock"]

_C = 8.0


class RGLRUBlock:

    @staticmethod
    def init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
        d = cfg.d_model
        rw = cfg.rglru_width or d
        W = cfg.conv1d_width
        keys = jax.random.split(key, 6)
        return {
            "wx": init_dense(keys[0], d, rw, dtype),      # recurrent branch
            "wy": init_dense(keys[1], d, rw, dtype),      # gate branch
            "conv_w": jax.random.normal(keys[2], (W, rw), dtype) * 0.02,
            "conv_b": jnp.zeros((rw,), dtype),
            "wr": init_dense(keys[3], rw, rw, dtype),
            "wi": init_dense(keys[4], rw, rw, dtype),
            "lam": jnp.full((rw,), 3.0, dtype),           # σ(3)≈0.95 decay
            "wo": init_dense(keys[5], rw, d, dtype),
        }

    # -- helpers --------------------------------------------------------- #
    @staticmethod
    def _conv(p, x, state=None):
        """Causal depthwise conv1d, width W.  x [B,S,rw].
        `state` [B, W-1, rw] carries the left context for decode."""
        W = p["conv_w"].shape[0]
        if state is None:
            pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
        else:
            pad = state.astype(x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)            # [B,S+W-1,rw]
        out = sum(xp[:, i:i + x.shape[1], :]
                  * p["conv_w"][i].astype(x.dtype)
                  for i in range(W))
        return out + p["conv_b"].astype(x.dtype), xp[:, -(W - 1):, :]

    @staticmethod
    def _gates(p, u):
        r = jax.nn.sigmoid(dense(p["wr"], u).astype(jnp.float32))
        i = jax.nn.sigmoid(dense(p["wi"], u).astype(jnp.float32))
        log_a = -_C * r * jax.nn.softplus(p["lam"].astype(jnp.float32))
        a = jnp.exp(log_a)
        gated = (i * u.astype(jnp.float32)).astype(u.dtype)
        return a.astype(u.dtype), gated

    @staticmethod
    def apply(p: dict, cfg: ModelConfig, x: jax.Array,
              impl: str = "auto") -> jax.Array:
        gate = jax.nn.gelu(dense(p["wy"], x), approximate=True)
        u = dense(p["wx"], x)
        u, _ = RGLRUBlock._conv(p, u)
        a, gated = RGLRUBlock._gates(p, u)
        h, _ = ops.rglru(gated, a, impl=impl)
        return dense(p["wo"], h * gate)

    # -- decode ---------------------------------------------------------- #
    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
        rw = cfg.rglru_width or cfg.d_model
        return {
            "h": jnp.zeros((batch, rw), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv1d_width - 1, rw), dtype),
        }

    @staticmethod
    def apply_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache: dict,
                     pos: jax.Array) -> tuple[jax.Array, dict]:
        gate = jax.nn.gelu(dense(p["wy"], x), approximate=True)
        u = dense(p["wx"], x)                              # [B,1,rw]
        u, conv_state = RGLRUBlock._conv(p, u, cache["conv"])
        a, gated = RGLRUBlock._gates(p, u)
        af = a.astype(jnp.float32)[:, 0]
        bf = (jnp.sqrt(jnp.clip(1 - af * af, 0, 1))
              * gated.astype(jnp.float32)[:, 0])
        h = af * cache["h"] + bf                           # [B,rw]
        y = dense(p["wo"], (h[:, None].astype(x.dtype) * gate))
        return y, {"h": h, "conv": conv_state}
