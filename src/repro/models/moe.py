"""Mixture-of-Experts layer with capacity-based scatter dispatch (EP).

Routing: softmax top-k (DeepSeek-V3's sigmoid+bias variant is simplified
to softmax — recorded in DESIGN.md).  Dispatch is scatter/gather based
rather than GShard one-hot-einsum: per token group (the leading batch
dim, sharded over 'data'), tokens are scattered into [E, C, d] expert
buffers.  Under GSPMD the buffers are resharded from data-sharded groups
to expert-sharded compute — exactly the EP all-to-all — without ever
materialising a [tokens, E, C] one-hot.

Expert weights are stacked [E, ...] so the E axis shards over 'model'
(expert parallelism).  `vertex_cut` expert placement (core.planner)
permutes the expert axis so co-activated experts land on the same shard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import maybe_shard
from .layers import act_fn, init_dense, init_mlp, mlp

__all__ = ["MoE"]


class MoE:

    @staticmethod
    def init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
        d = cfg.d_model
        ff = cfg.moe_d_ff or cfg.d_ff
        E = cfg.n_experts
        kr, ki, kg, ko, ks = jax.random.split(key, 5)
        scale = (2.0 / (d + ff)) ** 0.5
        p = {
            "router": init_dense(kr, d, E, dtype),
            "w_in": jax.random.normal(ki, (E, d, ff), dtype) * scale,
            "w_gate": jax.random.normal(kg, (E, d, ff), dtype) * scale,
            "w_out": jax.random.normal(ko, (E, ff, d), dtype) * scale,
        }
        if cfg.n_shared_experts:
            p["shared"] = init_mlp(ks, d, ff * cfg.n_shared_experts, dtype)
        return p

    @staticmethod
    def apply(p: dict, cfg: ModelConfig, x: jax.Array,
              capacity_factor: float | None = None) -> jax.Array:
        """x [G, S, d] (G = token groups, sharded over data axis)."""
        G, S, d = x.shape
        E, k = cfg.n_experts, cfg.experts_per_token
        cf = capacity_factor or cfg.capacity_factor
        C = max(int(S * k * cf / E), 4)

        logits = jnp.einsum("gsd,de->gse", x, p["router"]["w"].astype(x.dtype))
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)           # [G, S, k]
        top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)

        # position of each (token, slot) within its expert, per group
        onehot = jax.nn.one_hot(top_e, E, dtype=jnp.int32)   # [G,S,k,E]
        pos_in_e = (jnp.cumsum(onehot.reshape(G, S * k, E), axis=1)
                    .reshape(G, S, k, E) - 1)
        pos = jnp.take_along_axis(
            pos_in_e, top_e[..., None], axis=-1)[..., 0]      # [G,S,k]
        keep = pos < C

        def dispatch_group(xg, eg, posg, keepg):
            # xg [S,d], eg/posg/keepg [S,k]
            buf = jnp.zeros((E, C, d), xg.dtype)
            tok = jnp.broadcast_to(jnp.arange(S)[:, None], (S, k))
            e_flat = jnp.where(keepg, eg, E - 1).reshape(-1)
            p_flat = jnp.where(keepg, posg, C - 1).reshape(-1)
            x_flat = (xg[tok.reshape(-1)]
                      * keepg.reshape(-1)[:, None].astype(xg.dtype))
            return buf.at[e_flat, p_flat].add(x_flat)

        buffers = jax.vmap(dispatch_group)(x, top_e, pos, keep)  # [G,E,C,d]
        # dispatch buffers are data-sharded on G; the expert einsums want
        # E sharded over 'model' — this constraint is the EP all-to-all
        buffers = maybe_shard(buffers, "data", "model", None, None)

        # expert compute (E sharded over 'model' => all-to-all here)
        h_in = jnp.einsum("gecd,edf->gecf", buffers,
                          p["w_in"].astype(x.dtype))
        h_gate = jnp.einsum("gecd,edf->gecf", buffers,
                            p["w_gate"].astype(x.dtype))
        h = act_fn(cfg.hidden_act, h_gate) * h_in
        out_buf = jnp.einsum("gecf,efd->gecd", h,
                             p["w_out"].astype(x.dtype))       # [G,E,C,d]

        def combine_group(bufg, eg, posg, keepg, wg):
            vals = bufg[eg.reshape(-1), posg.reshape(-1)].reshape(
                eg.shape + (d,))                                # [S,k,d]
            w = (wg * keepg).astype(vals.dtype)[..., None]
            return (vals * w).sum(axis=1)                       # [S,d]

        # reshard expert outputs back to token owners (return all-to-all)
        out_buf = maybe_shard(out_buf, "data", None, None, None)
        y = jax.vmap(combine_group)(out_buf, top_e, pos, keep, top_p)
        y = maybe_shard(y, "data", None, None)
        if "shared" in p:
            y = y + mlp(p["shared"], x, cfg.hidden_act)
        return y

    @staticmethod
    def aux_loss(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
        """Load-balancing auxiliary loss (Switch-style)."""
        logits = jnp.einsum("gsd,de->gse", x,
                            p["router"]["w"].astype(x.dtype))
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        _, top_e = jax.lax.top_k(probs, cfg.experts_per_token)
        frac = jax.nn.one_hot(top_e, cfg.n_experts).mean((0, 1, 2))
        imp = probs.mean((0, 1))
        return cfg.n_experts * jnp.sum(frac * imp)
