"""RWKV6 (Finch) block: time-mix (WKV recurrence with data-dependent
decay) + channel-mix, both with token-shift.

Time-mix per head (the scan runs in kernels.ops.rwkv6):

    out_t = r_t (S + u ⊙ k_t^T v_t),   S <- diag(w_t) S + k_t^T v_t

with w_t = exp(-exp(wd_t)) computed from a LoRA on the shifted input —
the data-dependent decay that distinguishes Finch from RWKV5.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from .layers import init_dense, dense, init_rms_norm, rms_norm

__all__ = ["RWKV6Block"]

_LORA = 64


class RWKV6Block:

    @staticmethod
    def init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
        d = cfg.d_model
        keys = jax.random.split(key, 12)
        p = {
            # time-mix
            "mix_r": jnp.full((d,), 0.5, dtype),
            "mix_k": jnp.full((d,), 0.5, dtype),
            "mix_v": jnp.full((d,), 0.5, dtype),
            "mix_w": jnp.full((d,), 0.5, dtype),
            "mix_g": jnp.full((d,), 0.5, dtype),
            "wr": init_dense(keys[0], d, d, dtype),
            "wk": init_dense(keys[1], d, d, dtype),
            "wv": init_dense(keys[2], d, d, dtype),
            "wg": init_dense(keys[3], d, d, dtype),
            "w_lora_a": init_dense(keys[4], d, _LORA, dtype),
            "w_lora_b": init_dense(keys[5], _LORA, d, dtype),
            "w_base": jnp.full((d,), -6.0, dtype),
            "u": jax.random.normal(keys[6], (d,), dtype) * 0.1,
            "wo": init_dense(keys[7], d, d, dtype),
            "ln_x": init_rms_norm(d, dtype),
            # channel-mix
            "cmix_k": jnp.full((d,), 0.5, dtype),
            "cmix_r": jnp.full((d,), 0.5, dtype),
            "ck": init_dense(keys[8], d, cfg.d_ff, dtype),
            "cv": init_dense(keys[9], cfg.d_ff, d, dtype),
            "cr": init_dense(keys[10], d, d, dtype),
        }
        return p

    # -- helpers --------------------------------------------------------- #
    @staticmethod
    def _shift(x, last=None):
        """Token shift: x_{t-1} (zeros / `last` for t=0).  x [B,S,d]."""
        if last is None:
            last = jnp.zeros_like(x[:, :1])
        else:
            last = last[:, None].astype(x.dtype)
        return jnp.concatenate([last, x[:, :-1]], axis=1)

    @staticmethod
    def _time_mix_inputs(p, cfg, x, shifted):
        def mix(mu):
            m = p[mu].astype(x.dtype)
            return x * m + shifted * (1 - m)
        H = cfg.n_heads
        hd = cfg.head_dim
        B, S, d = x.shape
        r = dense(p["wr"], mix("mix_r")).reshape(B, S, H, hd)
        k = dense(p["wk"], mix("mix_k")).reshape(B, S, H, hd)
        v = dense(p["wv"], mix("mix_v")).reshape(B, S, H, hd)
        g = jax.nn.silu(dense(p["wg"], mix("mix_g")))
        wd = dense(p["w_lora_b"],
                   jnp.tanh(dense(p["w_lora_a"], mix("mix_w"))))
        w = jnp.exp(-jnp.exp((p["w_base"].astype(jnp.float32)
                              + wd.astype(jnp.float32))))
        w = w.reshape(B, S, H, hd)
        return r, k, v, g, w

    @staticmethod
    def apply(p: dict, cfg: ModelConfig, x: jax.Array,
              impl: str = "auto") -> jax.Array:
        B, S, d = x.shape
        H, hd = cfg.n_heads, cfg.head_dim
        # --- time mix
        shifted = RWKV6Block._shift(x)
        r, k, v, g, w = RWKV6Block._time_mix_inputs(p, cfg, x, shifted)
        u = p["u"].astype(jnp.float32).reshape(H, hd)
        o, _ = ops.rwkv6(r, k, v, w.astype(x.dtype), u, impl=impl)
        o = rms_norm(p["ln_x"], o.reshape(B, S, d))
        y = x + dense(p["wo"], o * g)
        # --- channel mix
        shifted2 = RWKV6Block._shift(y)
        mk = p["cmix_k"].astype(y.dtype)
        mr = p["cmix_r"].astype(y.dtype)
        xk = y * mk + shifted2 * (1 - mk)
        xr = y * mr + shifted2 * (1 - mr)
        kk = jnp.square(jax.nn.relu(dense(p["ck"], xk)))
        return y + jax.nn.sigmoid(dense(p["cr"], xr)) * dense(p["cv"], kk)

    # -- decode ---------------------------------------------------------- #
    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
        H, hd = cfg.n_heads, cfg.head_dim
        d = cfg.d_model
        return {
            "state": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "last_tm": jnp.zeros((batch, d), dtype),
            "last_cm": jnp.zeros((batch, d), dtype),
        }

    @staticmethod
    def apply_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache: dict,
                     pos: jax.Array) -> tuple[jax.Array, dict]:
        B, _, d = x.shape
        H, hd = cfg.n_heads, cfg.head_dim
        shifted = RWKV6Block._shift(x, cache["last_tm"])
        r, k, v, g, w = RWKV6Block._time_mix_inputs(p, cfg, x, shifted)
        u = p["u"].astype(jnp.float32).reshape(H, hd)
        o, state = ops.rwkv6(r, k, v, w.astype(x.dtype), u,
                             s0=cache["state"], impl="ref")
        o = rms_norm(p["ln_x"], o.reshape(B, 1, d))
        y = x + dense(p["wo"], o * g)
        shifted2 = RWKV6Block._shift(y, cache["last_cm"])
        mk = p["cmix_k"].astype(y.dtype)
        mr = p["cmix_r"].astype(y.dtype)
        xk = y * mk + shifted2 * (1 - mk)
        xr = y * mr + shifted2 * (1 - mr)
        kk = jnp.square(jax.nn.relu(dense(p["ck"], xk)))
        out = y + jax.nn.sigmoid(dense(p["cr"], xr)) * dense(p["cv"], kk)
        return out, {"state": state, "last_tm": x[:, 0],
                     "last_cm": y[:, 0]}
