"""Shared model primitives: norms, rotary embeddings, MLPs, embeddings.

Functional style: params are nested dicts of jnp arrays; every init_*
function is pure (usable under `jax.eval_shape` for the dry-run) and
every apply function is jit/pjit-compatible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

__all__ = ["rms_norm", "init_rms_norm", "rope", "mrope", "init_dense",
           "dense", "init_mlp", "mlp", "init_embedding", "embed",
           "unembed", "act_fn"]


def init_rms_norm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}


def rms_norm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------- #
# rotary embeddings
# ---------------------------------------------------------------------- #
def _rope_angles(positions: jax.Array, dim: int, theta: float) -> tuple:
    """positions [...], returns (sin, cos) of shape [..., dim//2]."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def rope(x: jax.Array, positions: jax.Array,
         theta: float = 10_000.0) -> jax.Array:
    """x [B, S, H, D], positions [B, S] (absolute)."""
    D = x.shape[-1]
    sin, cos = _rope_angles(positions, D, theta)     # [B, S, D/2]
    sin = sin[:, :, None, :]
    cos = cos[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope(x: jax.Array, positions: jax.Array, sections: tuple,
          theta: float = 1_000_000.0) -> jax.Array:
    """Qwen2-VL multimodal rotary: positions [3, B, S] (t/h/w streams),
    `sections` gives the per-stream split of the half-dim frequency bands
    (e.g. (16, 24, 24) for head_dim 128)."""
    D = x.shape[-1]
    assert sum(sections) == D // 2, (sections, D)
    sins, coss = [], []
    for i, sec in enumerate(sections):
        lo = sum(sections[:i])
        freqs = 1.0 / (theta ** (jnp.arange(0, D, 2,
                                            dtype=jnp.float32) / D))
        f = freqs[lo:lo + sec]
        ang = positions[i].astype(jnp.float32)[..., None] * f  # [B,S,sec]
        sins.append(jnp.sin(ang))
        coss.append(jnp.cos(ang))
    sin = jnp.concatenate(sins, -1)[:, :, None, :]
    cos = jnp.concatenate(coss, -1)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- #
# dense / MLP
# ---------------------------------------------------------------------- #
def init_dense(key, d_in: int, d_out: int, dtype=jnp.float32) -> dict:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}


def dense(p: dict, x: jax.Array) -> jax.Array:
    return x @ p["w"].astype(x.dtype)


def act_fn(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(name)


def init_mlp(key, d: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_in": init_dense(k1, d, d_ff, dtype),
        "w_gate": init_dense(k2, d, d_ff, dtype),
        "w_out": init_dense(k3, d_ff, d, dtype),
    }


def mlp(p: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    """Gated MLP (SwiGLU / GeGLU by `act`)."""
    return dense(p["w_out"], act_fn(act, dense(p["w_gate"], x))
                 * dense(p["w_in"], x))


# ---------------------------------------------------------------------- #
# embeddings
# ---------------------------------------------------------------------- #
def init_embedding(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"table": jax.random.normal(
        k1, (cfg.vocab_size, cfg.d_model), dtype) * 0.02}
    if not cfg.tie_embeddings:
        p["unembed"] = jax.random.normal(
            k2, (cfg.d_model, cfg.vocab_size), dtype) * 0.02
    return p


def embed(p: dict, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    h = jnp.take(p["table"], tokens, axis=0)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    return h


def unembed(p: dict, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = h @ p["table"].astype(h.dtype).T
    else:
        logits = h @ p["unembed"].astype(h.dtype)
    if cfg.final_softcap is not None:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return logits
