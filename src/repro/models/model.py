"""Unified model: every assigned architecture is a stack of pattern-typed
blocks (attn / local / global / rec / rwkv) + embeddings (+ encoder for
enc-dec, + frontends for VLM/audio, + MTP head for DeepSeek-V3).

Layer stacking uses `jax.lax.scan` over *stages* (one stage = one repeat
of `cfg.layer_pattern`), so HLO size is O(pattern), not O(n_layers) —
essential for compiling the 61-layer DeepSeek config.  A partial tail
stage (e.g. recurrentgemma's 38 = 12×3 + 2) is unrolled.

API (all pure functions of (cfg, params, ...)):
  init_params(cfg, key, dtype)                  # eval_shape-able
  forward(cfg, params, batch)  -> (logits, aux)
  loss_fn(cfg, params, batch)  -> scalar
  init_cache(cfg, batch, max_len, dtype)
  prefill(cfg, params, batch, max_len) -> (logits_last, cache)
  decode_step(cfg, params, cache, tokens, pos) -> (logits, cache)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import maybe_shard
from .attention import GQA, MLA, CrossAttention
from .layers import (embed, init_embedding, init_mlp, init_rms_norm, mlp,
                     rms_norm, unembed)
from .moe import MoE
from .recurrent import RGLRUBlock
from .rwkv import RWKV6Block

__all__ = ["init_params", "forward", "loss_fn", "init_cache", "prefill",
           "decode_step"]


# ---------------------------------------------------------------------- #
# block-level init / apply
# ---------------------------------------------------------------------- #
def _window_for(cfg: ModelConfig, kind: str) -> int | None:
    if kind == "local":
        return cfg.local_window
    if kind == "attn" and cfg.family == "hybrid":
        return cfg.local_window
    return None


def _attn_cls(cfg: ModelConfig):
    return MLA if cfg.use_mla else GQA


def _block_init(key, cfg: ModelConfig, kind: str, dtype,
                cross: bool = False) -> dict:
    keys = jax.random.split(key, 4)
    if kind == "rwkv":
        return {"ln": init_rms_norm(cfg.d_model, dtype),
                "rwkv": RWKV6Block.init(keys[0], cfg, dtype)}
    p = {"ln1": init_rms_norm(cfg.d_model, dtype),
         "ln2": init_rms_norm(cfg.d_model, dtype)}
    if kind == "rec":
        p["rec"] = RGLRUBlock.init(keys[0], cfg, dtype)
    else:
        p["attn"] = _attn_cls(cfg).init(keys[0], cfg, dtype)
    if cfg.is_moe:
        p["moe"] = MoE.init(keys[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(keys[1], cfg.d_model, cfg.d_ff, dtype)
    if cross:
        p["ln_x"] = init_rms_norm(cfg.d_model, dtype)
        p["xattn"] = CrossAttention.init(keys[2], cfg, dtype)
    return p


def _block_apply(p: dict, cfg: ModelConfig, kind: str, h, positions,
                 enc=None, impl: str = "auto"):
    """One block, full-sequence.  Returns (h, moe_aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "rwkv":
        return RWKV6Block.apply(p["rwkv"], cfg,
                                rms_norm(p["ln"], h), impl=impl), aux
    if kind == "rec":
        h = h + RGLRUBlock.apply(p["rec"], cfg, rms_norm(p["ln1"], h),
                                 impl=impl)
    else:
        h = h + _attn_cls(cfg).apply(
            p["attn"], cfg, rms_norm(p["ln1"], h), positions,
            window=_window_for(cfg, kind), impl=impl)
    if "xattn" in p and enc is not None:
        h = h + CrossAttention.apply(p["xattn"], cfg,
                                     rms_norm(p["ln_x"], h), enc, impl=impl)
    x = rms_norm(p["ln2"], h)
    if cfg.is_moe:
        h = h + MoE.apply(p["moe"], cfg, x)
        aux = MoE.aux_loss(p["moe"], cfg, x)
    else:
        h = h + mlp(p["mlp"], x, cfg.hidden_act)
    return h, aux


def _block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                 dtype) -> dict:
    if kind == "rwkv":
        return RWKV6Block.init_cache(cfg, batch, dtype)
    if kind == "rec":
        return RGLRUBlock.init_cache(cfg, batch, dtype)
    return _attn_cls(cfg).init_cache(cfg, batch, max_len,
                                     window=_window_for(cfg, kind),
                                     dtype=dtype)


def _block_decode(p: dict, cfg: ModelConfig, kind: str, h, cache, pos,
                  enc=None):
    if kind == "rwkv":
        return RWKV6Block.apply_decode(p["rwkv"], cfg,
                                       rms_norm(p["ln"], h), cache, pos)
    if kind == "rec":
        y, cache = RGLRUBlock.apply_decode(p["rec"], cfg,
                                           rms_norm(p["ln1"], h),
                                           cache, pos)
        h = h + y
    else:
        y, cache = _attn_cls(cfg).apply_decode(
            p["attn"], cfg, rms_norm(p["ln1"], h), cache, pos,
            window=_window_for(cfg, kind))
        h = h + y
    if "xattn" in p and enc is not None:
        h = h + CrossAttention.apply(p["xattn"], cfg,
                                     rms_norm(p["ln_x"], h), enc)
    x = rms_norm(p["ln2"], h)
    if cfg.is_moe:
        h = h + MoE.apply(p["moe"], cfg, x)
    else:
        h = h + mlp(p["mlp"], x, cfg.hidden_act)
    return h, cache


# ---------------------------------------------------------------------- #
# stage (= one repeat of the pattern) helpers
# ---------------------------------------------------------------------- #
def _stages(cfg: ModelConfig) -> tuple[tuple[str, ...], int, tuple[str, ...]]:
    pattern = tuple(cfg.layer_pattern)
    n_stages = cfg.n_layers // len(pattern)
    tail = pattern[: cfg.n_layers % len(pattern)]
    return pattern, n_stages, tail


def _stage_init(key, cfg: ModelConfig, pattern, dtype, cross=False) -> dict:
    keys = jax.random.split(key, len(pattern))
    return {f"b{i}_{kind}": _block_init(k, cfg, kind, dtype, cross=cross)
            for i, (kind, k) in enumerate(zip(pattern, keys))}


def _stage_apply(sp: dict, cfg: ModelConfig, pattern, h, positions,
                 enc=None, impl="auto"):
    aux = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(pattern):
        h, a = _block_apply(sp[f"b{i}_{kind}"], cfg, kind, h, positions,
                            enc=enc, impl=impl)
        aux = aux + a
    return h, aux


# ---------------------------------------------------------------------- #
# params
# ---------------------------------------------------------------------- #
def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> dict:
    pattern, n_stages, tail = _stages(cfg)
    keys = jax.random.split(key, 8)
    cross = cfg.n_encoder_layers > 0
    params = {
        "embed": init_embedding(keys[0], cfg, dtype),
        "final_ln": init_rms_norm(cfg.d_model, dtype),
        "stages": jax.vmap(
            lambda k: _stage_init(k, cfg, pattern, dtype, cross=cross))(
            jax.random.split(keys[1], n_stages)),
    }
    if tail:
        params["tail"] = _stage_init(keys[2], cfg, tail, dtype, cross=cross)
    if cfg.n_encoder_layers:
        params["encoder"] = {
            "stages": jax.vmap(
                lambda k: _stage_init(k, cfg, ("attn",), dtype))(
                jax.random.split(keys[3], cfg.n_encoder_layers)),
            "final_ln": init_rms_norm(cfg.d_model, dtype),
        }
    if cfg.mtp_depth:
        params["mtp"] = _stage_init(keys[4], cfg,
                                    ("attn",) * cfg.mtp_depth, dtype)
        params["mtp_ln"] = init_rms_norm(cfg.d_model, dtype)
    return params


# ---------------------------------------------------------------------- #
# forward
# ---------------------------------------------------------------------- #
def _positions_for(cfg: ModelConfig, batch: dict, B: int, S: int):
    if cfg.mrope_sections is not None:
        if "mrope_pos" in batch:
            return batch["mrope_pos"]                 # [3, B, S]
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        return jnp.stack([pos, pos, pos])
    return jnp.broadcast_to(jnp.arange(S)[None], (B, S))


def _encode(cfg: ModelConfig, params: dict, frames: jax.Array,
            impl="auto") -> jax.Array:
    """Run the (non-causal) encoder over precomputed frame embeddings."""
    h = frames
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(hc, sp):
        # encoder blocks are bidirectional: plain attention, no mask
        blk = sp["b0_attn"]
        y = GQA.apply_bidirectional(blk["attn"], cfg,
                                    rms_norm(blk["ln1"], hc), positions,
                                    impl=impl)
        hc = hc + y
        hc = hc + mlp(blk["mlp"], rms_norm(blk["ln2"], hc), cfg.hidden_act)
        return hc, None

    h, _ = jax.lax.scan(body, h, params["encoder"]["stages"])
    return rms_norm(params["encoder"]["final_ln"], h)


def _inputs_to_hidden(cfg: ModelConfig, params: dict, batch: dict):
    """Token embedding + modality frontend stubs."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = embed(params["embed"], cfg, tokens)
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(h.dtype)     # [B, N, d]
        n = pe.shape[1]
        h = jnp.concatenate([pe, h[:, n:]], axis=1)
    enc = None
    if cfg.n_encoder_layers and "frame_embeds" in batch:
        enc = _encode(cfg, params, batch["frame_embeds"].astype(h.dtype))
    return h, enc


_BARRIER_AD: bool | None = None


def _opt_barrier(x):
    """`optimization_barrier` that degrades to identity on JAX versions
    whose barrier primitive has no differentiation rule (the barrier is
    a perf hint, never a semantics change)."""
    global _BARRIER_AD
    if _BARRIER_AD is None:
        try:
            jax.eval_shape(
                jax.grad(lambda v: jax.lax.optimization_barrier(v)),
                jax.ShapeDtypeStruct((), jnp.float32))
            _BARRIER_AD = True
        except NotImplementedError:
            _BARRIER_AD = False
    return jax.lax.optimization_barrier(x) if _BARRIER_AD else x


def forward(cfg: ModelConfig, params: dict, batch: dict,
            impl: str = "auto",
            remat: bool = False) -> tuple[jax.Array, jax.Array]:
    """batch: {"tokens": [B,S], optional frontend inputs}.
    Returns (logits [B,S,V], moe_aux scalar).  With `remat`, each stage of
    the layer scan is checkpointed: backward recomputes the stage instead
    of keeping its internals stacked across all n_stages iterations (the
    difference between ~30 MB and ~500 GB of per-device residuals)."""
    pattern, n_stages, tail = _stages(cfg)
    h, enc = _inputs_to_hidden(cfg, params, batch)
    B, S = batch["tokens"].shape
    positions = _positions_for(cfg, batch, B, S)

    def body(carry, sp):
        hc, aux = carry
        # barrier: stops XLA hoisting per-stage f32 converts of the carry
        # out of the loop as one full [n_stages, ...] f32 stack (14 GB on
        # deepseek-v3 — §Perf iteration)
        hc = _opt_barrier(hc)
        hc = maybe_shard(hc, "data", None, None)
        hc, a = _stage_apply(sp, cfg, pattern, hc, positions, enc=enc,
                             impl=impl)
        hc = maybe_shard(hc, "data", None, None)
        return (hc, aux + a), None

    if remat:
        body = jax.checkpoint(body)
    (h, aux), _ = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), params["stages"])
    if tail:
        h, a = _stage_apply(params["tail"], cfg, tail, h, positions,
                            enc=enc, impl=impl)
        aux = aux + a
    h = rms_norm(params["final_ln"], h)
    logits = maybe_shard(unembed(params["embed"], cfg, h),
                         "data", None, "model")
    return logits, aux


def loss_fn(cfg: ModelConfig, params: dict, batch: dict,
            impl: str = "auto", aux_weight: float = 0.01,
            mtp_weight: float = 0.3, remat: bool = False) -> jax.Array:
    """Next-token cross entropy (+ MoE aux + MTP head for DeepSeek)."""
    tokens = batch["tokens"]
    logits, aux = forward(cfg, params, batch, impl=impl, remat=remat)
    lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, tokens[:, 1:, None], axis=-1)[..., 0]
    loss = nll.mean()
    if cfg.is_moe:
        loss = loss + aux_weight * aux
    if cfg.mtp_depth and "mtp" in params:
        # MTP: one extra block on the pre-head hidden predicts t+2
        h, enc = _inputs_to_hidden(cfg, params, batch)
        B, S = tokens.shape
        positions = _positions_for(cfg, batch, B, S)
        h2, _ = _stage_apply(params["mtp"], cfg,
                             ("attn",) * cfg.mtp_depth, h, positions,
                             impl=impl)
        logits2 = unembed(params["embed"], cfg,
                          rms_norm(params["mtp_ln"], h2))
        lp2 = jax.nn.log_softmax(logits2[:, :-2].astype(jnp.float32), -1)
        nll2 = -jnp.take_along_axis(lp2, tokens[:, 2:, None], -1)[..., 0]
        loss = loss + mtp_weight * nll2.mean()
    return loss


# ---------------------------------------------------------------------- #
# decode
# ---------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.float32) -> dict:
    pattern, n_stages, tail = _stages(cfg)

    def stage_cache():
        return {f"b{i}_{kind}": _block_cache(cfg, kind, batch, max_len,
                                             dtype)
                for i, kind in enumerate(pattern)}

    one = stage_cache()
    stacked = jax.tree.map(
        lambda x: jnp.zeros((n_stages,) + x.shape, x.dtype), one)
    cache = {"stages": stacked}
    if tail:
        cache["tail"] = {f"b{i}_{kind}": _block_cache(
            cfg, kind, batch, max_len, dtype)
            for i, kind in enumerate(tail)}
    return cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array, pos: jax.Array,
                enc: jax.Array | None = None) -> tuple[jax.Array, dict]:
    """tokens [B] (current token), pos scalar.  Returns (logits [B,V],
    new cache).  For enc-dec pass `enc` (from prefill/cache["enc"])."""
    pattern, n_stages, tail = _stages(cfg)
    if enc is None:
        enc = cache.get("enc")
    h = embed(params["embed"], cfg, tokens[:, None])

    def body(hc, sp_cache):
        sp, cc = sp_cache
        new_cc = {}
        for i, kind in enumerate(pattern):
            key = f"b{i}_{kind}"
            hc, new_cc[key] = _block_decode(sp[key], cfg, kind, hc,
                                            cc[key], pos, enc=enc)
        return hc, new_cc

    h, new_stage_cache = jax.lax.scan(
        body, h, (params["stages"], cache["stages"]))
    new_cache = dict(cache)
    new_cache["stages"] = new_stage_cache
    if tail:
        new_tail = {}
        for i, kind in enumerate(tail):
            key = f"b{i}_{kind}"
            h, new_tail[key] = _block_decode(params["tail"][key], cfg,
                                             kind, h, cache["tail"][key],
                                             pos, enc=enc)
        new_cache["tail"] = new_tail
    h = rms_norm(params["final_ln"], h)
    logits = unembed(params["embed"], cfg, h)
    return logits[:, 0], new_cache


def prefill(cfg: ModelConfig, params: dict, batch: dict, max_len: int,
            impl: str = "auto") -> tuple[jax.Array, dict]:
    """Process the full prompt, returning (last-position logits, cache).

    The prompt forward pass (the dominant prefill cost, and what the
    `prefill_*` dry-run cells lower) runs here; the returned cache starts
    empty and the serving loop replays the prompt through `decode_step`
    to populate it (see launch/serve.py) — correctness of that path is
    covered by the decode-vs-forward equivalence tests."""
    logits, _ = forward(cfg, params, batch, impl=impl)
    B, S = batch["tokens"].shape
    cache = init_cache(cfg, B, max_len,
                       dtype=params["final_ln"]["scale"].dtype)
    if cfg.n_encoder_layers and "frame_embeds" in batch:
        cache["enc"] = _encode(
            cfg, params, batch["frame_embeds"].astype(logits.dtype))
    return logits[:, -1], cache
