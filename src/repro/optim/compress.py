"""Error-feedback int8 gradient compression for the DCN (pod) axis.

Cross-pod gradient all-reduces ride the data-center network, which is an
order of magnitude slower than ICI — compressing the pod-axis reduction
4x (f32 -> int8 + per-block scales) moves the DCN term of the roofline
down.  Error feedback keeps the quantisation bias out of the training
trajectory (residual carried to the next step).

Pure-jnp, pytree-generic; the compressed representation is what a
production DCN reducer would put on the wire, and the error-feedback
state shards exactly like the gradients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_error_feedback", "compress_grads", "decompress_grads",
           "ef_compress_cycle", "compressed_bytes"]

_BLOCK = 256


def init_error_feedback(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8: returns (q int8 [N], scales f32 [blocks])."""
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def _dequantize(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    blocks = q.astype(jnp.float32) * scale[:, None]
    n = 1
    for d in shape:
        n *= d
    return blocks.reshape(-1)[:n].reshape(shape)


def compress_grads(grads):
    return jax.tree.map(_quantize, grads)


def decompress_grads(compressed, template):
    return jax.tree.map(
        lambda qs, t: _dequantize(qs[0], qs[1], t.shape),
        compressed, template,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)


def ef_compress_cycle(grads, ef_state):
    """One error-feedback round: returns (decompressed grads to apply,
    new error state).  apply(g) == g only in aggregate over steps."""
    def leaf(g, e):
        target = g.astype(jnp.float32) + e
        q, s = _quantize(target)
        deq = _dequantize(q, s, g.shape)
        return deq.astype(g.dtype), target - deq

    pairs = jax.tree.map(leaf, grads, ef_state)
    out = jax.tree.map(lambda t: t[0], pairs,
                       is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], pairs,
                          is_leaf=lambda t: isinstance(t, tuple))
    return out, new_ef


def compressed_bytes(grads) -> tuple[int, int]:
    """(raw f32 bytes, compressed wire bytes) for reporting."""
    raw = comp = 0
    for g in jax.tree.leaves(grads):
        n = g.size
        raw += n * 4
        comp += n + 4 * ((n + _BLOCK - 1) // _BLOCK)
    return raw, comp
