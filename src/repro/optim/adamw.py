"""AdamW with cosine schedule, global-norm clipping and gradient
accumulation — pure-jnp, pytree-generic, shardable (moments inherit the
param PartitionSpecs, so FSDP shards optimizer state ZeRO-style).

`moment_dtype` lets memory-tight cells (DeepSeek-V3 on 512 chips) keep
m/v in bf16 — recorded per-cell in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: Any = jnp.float32


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def adamw_init(params, cfg: AdamWConfig) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)  # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state: dict, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        update = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (update + cfg.weight_decay * pf)
        return (pf.astype(p.dtype), mf.astype(m.dtype),
                vf.astype(v.dtype))

    flat = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
