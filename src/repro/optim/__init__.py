from .adamw import (AdamWConfig, adamw_init, adamw_update,
                    clip_by_global_norm, cosine_schedule)
from .compress import (compress_grads, compressed_bytes, decompress_grads,
                       ef_compress_cycle, init_error_feedback)
__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "clip_by_global_norm", "compress_grads", "decompress_grads",
           "ef_compress_cycle", "init_error_feedback", "compressed_bytes"]
