"""Docs gate: verify markdown links resolve and python blocks run.

    python tools/check_docs.py README.md docs/*.md benchmarks/README.md

Two checks per file:

* every relative markdown link / image target exists on disk (external
  http(s)/mailto links and pure #fragments are skipped — CI must not
  flake on network);
* every fenced ```python code block executes cleanly in a subprocess
  with the repo on PYTHONPATH, from the repo root.  Blocks whose info
  string contains ``no-run`` (e.g. ```python no-run) are skipped —
  use that tag for illustrative snippets that reference files which
  don't exist in a checkout.

Exits 1 listing every broken link / failed block.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# [text](target) and ![alt](target); target up to the first ')' or space
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_FENCE = re.compile(r"^```(.*)$")
_EXTERNAL = ("http://", "https://", "mailto:")


def _strip_code(text: str) -> str:
    """Blank out fenced code blocks so their contents aren't link-checked."""
    out, in_fence = [], False
    for line in text.splitlines():
        if _FENCE.match(line):
            in_fence = not in_fence
            out.append("")
        else:
            out.append("" if in_fence else line)
    return "\n".join(out)


def check_links(path: str, text: str) -> list[str]:
    errors = []
    base = os.path.dirname(os.path.abspath(path))
    for m in _LINK.finditer(_strip_code(text)):
        target = m.group(1)
        if target.startswith(_EXTERNAL) or target.startswith("#"):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not os.path.exists(os.path.join(base, rel)):
            errors.append(f"{path}: broken link -> {target}")
    return errors


def _python_blocks(text: str):
    """Yield (start_lineno, info_string, source) per ```python fence."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = _FENCE.match(lines[i])
        if m and m.group(1).strip().split() and \
                m.group(1).strip().split()[0] == "python":
            info, start, body = m.group(1).strip(), i + 1, []
            i += 1
            while i < len(lines) and not _FENCE.match(lines[i]):
                body.append(lines[i])
                i += 1
            yield start, info, "\n".join(body)
        elif m:                         # non-python fence: skip to close
            i += 1
            while i < len(lines) and not _FENCE.match(lines[i]):
                i += 1
        i += 1


def check_blocks(path: str, text: str) -> list[str]:
    errors = []
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    for lineno, info, source in _python_blocks(text):
        if "no-run" in info.split():
            continue
        proc = subprocess.run([sys.executable, "-c", source], cwd=REPO,
                              env=env, capture_output=True, text=True,
                              timeout=300)
        if proc.returncode != 0:
            tail = (proc.stderr or proc.stdout).strip().splitlines()[-3:]
            errors.append(f"{path}:{lineno}: python block failed: "
                          + " | ".join(tail))
    return errors


def main(argv=None) -> int:
    paths = (argv or sys.argv[1:])
    if not paths:
        print("usage: python tools/check_docs.py FILE.md [...]",
              file=sys.stderr)
        return 2
    errors = []
    for path in paths:
        with open(path, encoding="utf-8") as f:
            text = f.read()
        errors += check_links(path, text)
        errors += check_blocks(path, text)
        print(f"checked {path}")
    if errors:
        print("\nDOCS GATE FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    print(f"\ndocs gate passed: {len(paths)} files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
