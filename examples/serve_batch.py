"""Batched-request serving example: prefill + greedy decode with KV/state
caches for three different architecture families (full attention, hybrid
recurrent, attention-free) — demonstrating the same serve_step API the
decode_* dry-run cells lower.

    PYTHONPATH=src python examples/serve_batch.py
"""
import sys

from repro.launch import serve as serve_mod

for arch in ("gemma-2b", "recurrentgemma-9b", "rwkv6-7b"):
    print(f"\n=== {arch} ===")
    sys.argv = ["serve_batch", "--arch", arch, "--reduced",
                "--batch", "2", "--prompt-len", "16", "--gen", "16"]
    serve_mod.main()
