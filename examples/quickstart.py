"""Quickstart: the paper's full pipeline (Fig. 1) in ~40 lines.

  program -> dynamic-trace IR graph -> Weight Balanced p-way Vertex Cut
  -> memory-centric mapping (Algorithm 2) -> simulated NUMA execution,

plus the same planner applied to a JAX program via its jaxpr.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import build_graph, run_pipeline
from repro.core.planner import optimal_parallelism

# 1) Build the dynamic-trace graph for the paper's FFT benchmark.
g = build_graph("fft", scale="reduced")
print(f"graph: {g.stats()}\n")

# 2) Partition with every method; map; simulate (paper Tables 6-9).
print(f"{'method':10s} {'exec(us)':>9s} {'comm(KB)':>9s} "
      f"{'imbalance':>9s} {'repl':>6s}")
base = None
for method in ("compnet", "metis", "pg", "libra",
               "w_pg", "wb_pg", "w_libra", "wb_libra"):
    part, mapping, rep = run_pipeline(g, p=8, method=method)
    if base is None:
        base = rep.exec_time
    imb = part.edge_weight_imbalance
    rf = getattr(part, "replication_factor_active", float("nan"))
    print(f"{method:10s} {rep.exec_time*1e6:9.1f} "
          f"{rep.data_comm_bytes/1e3:9.1f} {imb:9.4f} {rf:6.2f}")

# 3) The same framework on a JAX computation: trace the jaxpr, find the
#    parallelization degree with the lowest simulated execution time.
def train_like_step(w1, w2, x):
    def layer(h, _):
        return jnp.tanh(h @ w1) @ w2, None
    h, _ = jax.lax.scan(layer, x, None, length=4)
    return (h ** 2).mean()

w1 = jnp.zeros((128, 512))
w2 = jnp.zeros((512, 128))
x = jnp.zeros((16, 128))
best_p, reports = optimal_parallelism(train_like_step, w1, w2, x,
                                      candidates=(2, 4, 8, 16))
print(f"\njaxpr planning: best parallelization degree = {best_p}")
for r in reports:
    print(f"  p={r.p:3d} est_exec={r.exec_time*1e6:8.1f}us "
          f"replication={r.cut.replication_factor_active:.2f}")
