"""MoE expert placement with the Weight Balanced Vertex Cut.

The paper's insight (replicate high-degree vertices to balance load) maps
directly onto MoE serving: hot experts are the high-degree vertices of
the expert co-activation graph.  This example:

  1. synthesises DeepSeek-V3-like routing statistics (Zipf expert
     popularity, correlated co-activation);
  2. places 256 experts on 16 EP shards with WB-Libra (replicating hot
     experts, bounded by max_replicas) vs the standard contiguous layout;
  3. applies the placement to an actual (reduced) MoE layer by permuting
     the stacked expert-weight axis and reports the per-shard token loads
     a forward pass produces.

    PYTHONPATH=src python examples/expert_placement_moe.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced_config
from repro.core.planner import expert_placement, naive_expert_placement
from repro.models.moe import MoE

# --- 1) routing statistics ------------------------------------------- #
rng = np.random.default_rng(0)
E, K, SHARDS = 64, 8, 8
pop = (np.arange(1, E + 1) ** -1.2)[rng.permutation(E)]
pop /= pop.sum()
load = pop * 1e6
co = np.zeros((E, E))
for row in rng.choice(E, size=(3000, K), p=pop):
    for i in range(K):
        for j in range(i + 1, K):
            co[row[i], row[j]] += 1
            co[row[j], row[i]] += 1

# --- 2) placements ---------------------------------------------------- #
vc = expert_placement(load, co, n_devices=SHARDS, max_replicas=3)
nv = naive_expert_placement(load, SHARDS)
print("placement            load_imb   all2all   replicas/expert")
for name, p in (("vertex-cut (WB-Libra)", vc), ("contiguous", nv)):
    print(f"{name:20s} {p.device_load.max()/p.device_load.mean():9.3f}"
          f" {p.all_to_all_fraction:9.3f} {p.replication_factor:10.2f}")

# --- 3) wire into a real MoE layer ------------------------------------ #
cfg = reduced_config(ARCHS["dbrx-132b"], n_experts=E, experts_per_token=4)
params = MoE.init(jax.random.PRNGKey(0), cfg)
# permute the expert axis so each shard's experts are contiguous
order = np.argsort([min(d) for d in vc.expert_devices])
for wname in ("w_in", "w_gate", "w_out"):
    params[wname] = params[wname][order]
params["router"]["w"] = params["router"]["w"][:, order]

x = jnp.asarray(rng.standard_normal((4, 32, cfg.d_model)), jnp.float32)
y = MoE.apply(params, cfg, x)
print(f"\nMoE forward with vertex-cut expert order: out {y.shape}, "
      f"finite={bool(jnp.isfinite(y).all())}")
print("expert order (first 16):", order[:16].tolist())
