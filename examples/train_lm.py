"""End-to-end training driver: a ~100M-parameter smollm-family model
trained for a few hundred steps on synthetic data (assignment deliverable
(b)): data pipeline -> model -> AdamW -> checkpointing, with loss
reported at start/end.

Default is a fast CPU-sized run; pass --full for the ~100M configuration
(several hours on this 1-core container; identical code path).

    PYTHONPATH=src python examples/train_lm.py            # fast demo
    PYTHONPATH=src python examples/train_lm.py --full     # ~100M params
"""
import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args, _ = ap.parse_known_args()

    if args.full:
        # ~100M params: d_model=768, 12 layers, vocab 4096
        argv = ["--arch", "smollm-360m", "--reduced", "--d-model", "768",
                "--n-layers", "12", "--steps", str(args.steps or 300),
                "--batch", "8", "--seq", "256", "--microbatches", "2",
                "--ckpt-dir", ".ckpt/train_lm_full", "--save-every", "100"]
    else:
        argv = ["--arch", "smollm-360m", "--reduced",
                "--steps", str(args.steps or 120), "--batch", "8",
                "--seq", "128", "--ckpt-dir", ".ckpt/train_lm",
                "--save-every", "60"]
    sys.argv = ["train_lm"] + argv
    train_mod.main()


if __name__ == "__main__":
    main()
