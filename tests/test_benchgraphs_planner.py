"""Trace-VM benchmark graphs (paper Table 3/4) + planner integration."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import all_benchmark_names, build_graph
from repro.core.jaxpr_graph import trace_to_graph
from repro.core.planner import (expert_placement, mesh_device_order,
                                naive_expert_placement, optimal_parallelism,
                                plan_step)


@pytest.mark.parametrize("name", all_benchmark_names())
def test_benchmark_graph_wellformed(name):
    g = build_graph(name, scale="reduced", cache_dir=None)
    assert g.num_vertices > 100, name
    assert g.num_edges > 100, name
    # DAG property: every edge points forward in trace order
    assert (g.src < g.dst).all(), f"{name} not in topological trace order"
    # weighted: memory ops cost more than register deps
    assert g.w.max() > g.w.min()
    # heavy-tailed degrees (power-law-ish): hub degree >> median
    deg = g.degrees()
    assert deg.max() >= 10 * np.median(deg[deg > 0]), name


def test_graph_cache_roundtrip(tmp_path):
    g1 = build_graph("strassen8", scale="reduced", cache_dir=str(tmp_path))
    g2 = build_graph("strassen8", scale="reduced", cache_dir=str(tmp_path))
    np.testing.assert_array_equal(g1.src, g2.src)
    np.testing.assert_array_equal(g1.w, g2.w)


def test_alpha_in_powerlaw_range():
    g = build_graph("fft", scale="reduced", cache_dir=None)
    assert 1.2 < g.power_law_alpha() < 3.5


# ------------------------------------------------------------------ #
def _toy_step(w, x):
    def layer(h, _):
        return jnp.tanh(h @ w), ()
    h, _ = jax.lax.scan(layer, x, None, length=4)
    return h.sum()


def test_trace_to_graph_unrolls_scan():
    w = jnp.zeros((16, 16))
    x = jnp.zeros((4, 16))
    g_unrolled = trace_to_graph(_toy_step, w, x, unroll_scans=True)
    g_static = trace_to_graph(_toy_step, w, x, unroll_scans=False)
    assert g_unrolled.num_vertices > g_static.num_vertices


def test_plan_step_and_optimal_parallelism():
    w = jnp.zeros((16, 16))
    x = jnp.zeros((4, 16))
    rep = plan_step(_toy_step, w, x, p=4)
    assert rep.cut.replication_factor_active >= 1.0
    assert rep.exec_time > 0
    best, reports = optimal_parallelism(_toy_step, w, x, candidates=(2, 4))
    assert best in (2, 4)
    assert len(reports) == 2


def test_expert_placement_balances_load():
    rng = np.random.default_rng(0)
    load = rng.zipf(1.5, size=64).astype(float).clip(max=1e5)
    ep = expert_placement(load, n_devices=8)
    nv = naive_expert_placement(load, 8)
    imb_ep = ep.device_load.max() / ep.device_load.mean()
    imb_nv = nv.device_load.max() / nv.device_load.mean()
    assert imb_ep < imb_nv  # hot-expert replication balances shards
    assert ep.all_to_all_fraction <= nv.all_to_all_fraction + 1e-9
    # every expert served somewhere
    assert all(len(d) >= 1 for d in ep.expert_devices)
    # device lists consistent
    for d, exps in enumerate(ep.device_experts):
        for ex in exps:
            assert d in ep.expert_devices[ex]


def test_mesh_device_order_permutation():
    rng = np.random.default_rng(0)
    comm = rng.random((16, 16))
    comm = comm + comm.T
    order = mesh_device_order(comm, 4, 4)
    assert len(order) == 16
    assert set(order.tolist()) <= set(range(16))
