"""Hypothesis suite for the shard determinism contract (`repro.dist`).

Property-based versions of the distributed partitioner's contract:
`workers=1` bit-identity against the single-stream engine, fixed
(W, seed, merge_period) reproducibility across runs, and sharded-parse
equality against the sequential ingester — including a round trip
through a gzip-compressed `.ndjson.gz` trace.
"""
import gzip
import json

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the [test] extra: pip install -e .[test]")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import IRGraph, vertex_cut  # noqa: E402
from repro.dist import dist_ingest_with_stats, dist_vertex_cut  # noqa: E402
from repro.trace import ingest_trace_with_stats  # noqa: E402


@st.composite
def small_graphs(draw):
    n = draw(st.integers(min_value=2, max_value=60))
    m = draw(st.integers(min_value=1, max_value=200))
    src = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    dst = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    w = draw(st.lists(st.floats(0.1, 100.0), min_size=m, max_size=m))
    return IRGraph(n=n, src=np.array(src), dst=np.array(dst),
                   w=np.array(w), name="hyp")


@st.composite
def small_traces(draw):
    """NDJSON instruction lines over a small value-id space, so shard
    boundaries routinely split def/use pairs (the pending machinery)."""
    n_fns = draw(st.integers(1, 3))
    n_lines = draw(st.integers(1, 120))
    lines = []
    for i in range(n_lines):
        fn = f"fn{draw(st.integers(0, n_fns - 1))}"
        uses = draw(st.lists(
            st.one_of(st.sampled_from([f"v{k}" for k in range(12)]),
                      st.sampled_from(["const:i32:1", "const:i32:7"])),
            min_size=0, max_size=3))
        rec = {"fn": fn, "bb": f"bb{draw(st.integers(0, 2))}",
               "op": draw(st.sampled_from(["add", "load", "store", "mul"])),
               "uses": uses,
               "def": (f"v{draw(st.integers(0, 11))}"
                       if draw(st.booleans()) else None)}
        if draw(st.booleans()):
            rec["def_ty"] = draw(st.sampled_from(
                ["i32", "i64", "double", "<4 x float>"]))
        lines.append(json.dumps(rec))
    return "\n".join(lines) + "\n"


@given(g=small_graphs(), p=st.integers(2, 16),
       method=st.sampled_from(["pg", "libra", "w_pg", "wb_pg",
                               "w_libra", "wb_libra"]),
       seed=st.integers(0, 5),
       merge_period=st.sampled_from([7, 64, 1 << 16]))
@settings(max_examples=50, deadline=None)
def test_workers1_bit_identity(g, p, method, seed, merge_period):
    ref = vertex_cut(g, p, method=method, seed=seed, backend="fast")
    got = dist_vertex_cut(g, p, method=method, seed=seed, workers=1,
                          merge_period=merge_period)
    np.testing.assert_array_equal(got.assignment, ref.assignment)
    assert got.replication_factor == ref.replication_factor
    np.testing.assert_array_equal(got.loads, ref.loads)


@given(g=small_graphs(), p=st.integers(2, 12),
       workers=st.integers(2, 5), seed=st.integers(0, 5),
       merge_period=st.sampled_from([5, 33, 1024]))
@settings(max_examples=40, deadline=None)
def test_fixed_w_seed_reproducible(g, p, workers, seed, merge_period):
    a = dist_vertex_cut(g, p, seed=seed, workers=workers,
                        merge_period=merge_period)
    b = dist_vertex_cut(g, p, seed=seed, workers=workers,
                        merge_period=merge_period)
    np.testing.assert_array_equal(a.assignment, b.assignment)
    # still a valid cut
    assert (a.assignment >= 0).all() and (a.assignment < p).all()
    assert np.isclose(a.loads.sum(), g.total_weight)


@given(text=small_traces(), workers=st.integers(1, 6))
@settings(max_examples=40, deadline=None)
def test_sharded_parse_equals_sequential(tmp_path_factory, text, workers):
    path = tmp_path_factory.mktemp("hyp") / "t.ndjson"
    path.write_text(text)
    g0, s0 = ingest_trace_with_stats(str(path))
    g, s = dist_ingest_with_stats(str(path), workers=workers,
                                  pool="serial")
    assert g.n == g0.n
    np.testing.assert_array_equal(g.src, g0.src)
    np.testing.assert_array_equal(g.dst, g0.dst)
    np.testing.assert_array_equal(g.w, g0.w)
    d0, d1 = s0.summary(), s.summary()
    d0.pop("peak_chunk_edges")
    d1.pop("peak_chunk_edges")
    assert d0 == d1


@given(text=small_traces(), workers=st.integers(2, 4),
       p=st.integers(2, 8), seed=st.integers(0, 3))
@settings(max_examples=25, deadline=None)
def test_gzip_trace_reproducible(tmp_path_factory, text, workers, p, seed):
    """Fixed-(W, seed) reproducibility from an ingested .ndjson.gz trace,
    and parse equality against the sequential gzip path."""
    d = tmp_path_factory.mktemp("hypgz")
    gz = d / "t.ndjson.gz"
    with gzip.open(gz, "wt", encoding="utf-8") as f:
        f.write(text)
    g0, _ = ingest_trace_with_stats(str(gz))
    g, _ = dist_ingest_with_stats(str(gz), workers=workers, pool="serial")
    np.testing.assert_array_equal(g.src, g0.src)
    np.testing.assert_array_equal(g.w, g0.w)
    if g.num_edges:
        a = dist_vertex_cut(g, p, seed=seed, workers=workers,
                            merge_period=16)
        b = dist_vertex_cut(g0, p, seed=seed, workers=workers,
                            merge_period=16)
        np.testing.assert_array_equal(a.assignment, b.assignment)


@given(g=small_graphs(), p=st.integers(2, 12),
       workers=st.integers(2, 4), seed=st.integers(0, 3),
       divergence=st.sampled_from([0.0, 0.05, 0.5, 2.0]))
@settings(max_examples=30, deadline=None)
def test_adaptive_merge_reproducible_and_quality(g, p, workers, seed,
                                                 divergence):
    """Adaptive merges stay a pure function of the inputs, and a tight
    divergence bound never degrades quality materially vs the fixed
    every-round schedule (d=0 trips every round, so it matches it)."""
    kw = dict(seed=seed, workers=workers, merge_period=16)
    fixed = dist_vertex_cut(g, p, **kw)
    a = dist_vertex_cut(g, p, divergence=divergence, **kw)
    b = dist_vertex_cut(g, p, divergence=divergence, **kw)
    np.testing.assert_array_equal(a.assignment, b.assignment)
    assert (a.assignment >= 0).all() and (a.assignment < p).all()
    assert np.isclose(a.loads.sum(), g.total_weight)
    if divergence <= 0.05:
        assert (a.replication_factor
                <= fixed.replication_factor * 1.05 + 1e-9)


@given(text=small_traces(), workers=st.integers(2, 4),
       p=st.integers(2, 8),
       merge_period=st.sampled_from([3, 17, 256]))
@settings(max_examples=25, deadline=None)
def test_pipelined_trace_path_reproducible(tmp_path_factory, text, workers,
                                           p, merge_period):
    """Pipelined cut from a trace path: bit-identical across runs and
    across worker pools, for any (tiny) round quantum."""
    path = tmp_path_factory.mktemp("hyp-pipe") / "t.ndjson"
    path.write_text(text)
    a = dist_vertex_cut(str(path), p, workers=workers,
                        merge_period=merge_period)
    b = dist_vertex_cut(str(path), p, workers=workers,
                        merge_period=merge_period, pool="serial")
    np.testing.assert_array_equal(a.assignment, b.assignment)
    assert (a.assignment >= 0).all() and (a.assignment < p).all()
