"""Distributed sharded partitioner (`repro.dist`): contracts and merges.

The subsystem's determinism contract, tested without hypothesis (the
property suite lives in test_dist_property.py):

  * `workers=1` is bit-identical to the single-stream fast engine, for
    the raw cut and through `run_pipeline(backend="dist")`;
  * `workers>1` is a pure function of (graph, p, method, lam, seed,
    merge_period, W) — identical across repeated runs — and still a
    valid vertex cut;
  * the sharded parallel parse produces the *same graph* as the
    sequential streaming ingester for any worker count on well-formed
    traces (plain and gzip sources, process and serial pools);
  * the `ShardCutState` resume path and the `_arrayops` merge helpers
    behave as the engines' chunked/merged building blocks.
"""
import gzip
import json
import os

import numpy as np
import pytest

from repro.core import (IRGraph, ShardCutState, run_pipeline,
                        synthesize_powerlaw_graph, vertex_cut)
from repro.core._arrayops import merge_deltas, merge_limb_masks
from repro.dist import (dist_ingest, dist_ingest_with_stats,
                        dist_vertex_cut, shard_bounds, shard_byte_ranges)
from repro.trace import ingest_trace_with_stats, synthesize_trace

METHODS = ("wb_libra", "w_pg", "pg", "libra")


@pytest.fixture(scope="module")
def graph():
    return synthesize_powerlaw_graph(n=4000, alpha=2.2, seed=1)


@pytest.fixture(scope="module")
def trace_path(tmp_path_factory):
    path = tmp_path_factory.mktemp("traces") / "synth.ndjson"
    synthesize_trace(str(path), 20_000, seed=0)
    return str(path)


# ---------------------------------------------------------------------- #
# engine contracts
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("method", METHODS)
def test_workers1_bit_identical_to_fast(graph, method):
    ref = vertex_cut(graph, 64, method=method, seed=3, backend="fast")
    for merge_period in (1 << 16, 997):    # chunking must not matter
        got = dist_vertex_cut(graph, 64, method=method, seed=3,
                              workers=1, merge_period=merge_period)
        np.testing.assert_array_equal(got.assignment, ref.assignment)
        assert got.replication_factor == ref.replication_factor
        np.testing.assert_array_equal(got.loads, ref.loads)
        np.testing.assert_array_equal(got.replica_flat, ref.replica_flat)


@pytest.mark.parametrize("workers", (2, 4, 7))
def test_multi_worker_deterministic(graph, workers):
    a = dist_vertex_cut(graph, 32, seed=5, workers=workers,
                        merge_period=1000)
    b = dist_vertex_cut(graph, 32, seed=5, workers=workers,
                        merge_period=1000)
    np.testing.assert_array_equal(a.assignment, b.assignment)
    assert a.replication_factor == b.replication_factor


def test_multi_worker_valid_cut(graph):
    p = 16
    r = dist_vertex_cut(graph, p, workers=4, merge_period=500)
    assert len(r.assignment) == graph.num_edges
    assert (r.assignment >= 0).all() and (r.assignment < p).all()
    assert np.isclose(r.loads.sum(), graph.total_weight)
    # replica sets contain every incident edge's cluster
    replicas = r.replicas
    for e in range(0, graph.num_edges, 97):
        c = int(r.assignment[e])
        assert c in replicas[graph.src[e]]
        assert c in replicas[graph.dst[e]]


def test_merge_period_changes_are_deterministic(graph):
    """Different merge periods may change quality, never validity or
    reproducibility."""
    rfs = []
    for mp_ in (250, 4000):
        a = dist_vertex_cut(graph, 32, workers=4, merge_period=mp_)
        b = dist_vertex_cut(graph, 32, workers=4, merge_period=mp_)
        assert np.array_equal(a.assignment, b.assignment)
        rfs.append(a.replication_factor)
    assert all(rf > 0 for rf in rfs)


def test_run_pipeline_dist_matches_fast(graph):
    """Acceptance contract: backend="dist", workers=1 reproduces
    backend="fast" bit for bit through partition -> map -> simulate."""
    pf, mf, rf = run_pipeline(graph, 16, "wb_libra", backend="fast")
    pd, md, rd = run_pipeline(graph, 16, "wb_libra", backend="dist",
                              workers=1)
    np.testing.assert_array_equal(pd.assignment, pf.assignment)
    assert pd.replication_factor == pf.replication_factor
    np.testing.assert_array_equal(md.core_of, mf.core_of)
    assert rd.exec_time == rf.exec_time
    assert rd.data_comm_bytes == rf.data_comm_bytes


def test_run_pipeline_dist_multiworker(graph):
    part, mapping, rep = run_pipeline(graph, 16, "wb_libra",
                                      backend="dist", workers=3,
                                      merge_period=2000)
    assert part.p == 16
    assert rep.exec_time > 0
    assert len(mapping.core_of) == 16


def test_random_method_delegates(graph):
    a = dist_vertex_cut(graph, 8, method="random", seed=2, workers=4)
    b = vertex_cut(graph, 8, method="random", seed=2, backend="fast")
    np.testing.assert_array_equal(a.assignment, b.assignment)


def test_dist_rejects_bad_args(graph):
    with pytest.raises(ValueError):
        dist_vertex_cut(graph, 8, method="nope")
    with pytest.raises(ValueError):
        dist_vertex_cut(graph, 0)
    with pytest.raises(ValueError):
        dist_vertex_cut(graph, 8, lam=0.5)
    with pytest.raises(ValueError):
        dist_vertex_cut(graph, 8, merge_period=0)
    with pytest.raises(ValueError):
        dist_vertex_cut(graph, 8, backend="reference")


# ---------------------------------------------------------------------- #
# shard state + merge hooks
# ---------------------------------------------------------------------- #
def test_shard_state_chunked_equals_one_shot(graph):
    p = 24
    ref = vertex_cut(graph, p, method="wb_libra", backend="fast")
    deg = graph.degrees()
    bound = 1.0 * graph.total_weight / p
    # wb_libra auto order is trace order with the Libra pre-swap
    swap = deg[graph.src] > deg[graph.dst]
    su = np.ascontiguousarray(
        np.where(swap, graph.dst, graph.src), np.int32)
    sv = np.ascontiguousarray(
        np.where(swap, graph.src, graph.dst), np.int32)
    w = np.ascontiguousarray(graph.w, np.float64)
    st = ShardCutState.create(graph.n, p, deg, bound, True)
    out = np.empty(graph.num_edges, np.int32)
    for a in range(0, graph.num_edges, 1234):
        b = min(a + 1234, graph.num_edges)
        st.stream_chunk(su[a:b], sv[a:b], w[a:b], out[a:b])
    np.testing.assert_array_equal(out, ref.assignment)
    np.testing.assert_array_equal(st.loads, ref.loads)


def test_shard_state_rejects_non_fast_backends(graph):
    with pytest.raises(ValueError):
        ShardCutState.create(10, 4, np.zeros(10, np.int64), np.inf, True,
                             backend="pallas")


def test_merge_limb_masks():
    a = np.array([0b0011, 0, 0b1000], dtype=np.uint64)
    b = np.array([0b0100, 0b0001, 0], dtype=np.uint64)
    got = merge_limb_masks([a, b])
    np.testing.assert_array_equal(
        got, np.array([0b0111, 0b0001, 0b1000], np.uint64))
    np.testing.assert_array_equal(merge_limb_masks([a]), a)
    # inputs untouched
    assert a[0] == 0b0011 and b[0] == 0b0100
    with pytest.raises(ValueError):
        merge_limb_masks([])


def test_merge_deltas():
    snap = np.array([10.0, 20.0, 0.0])
    l1 = snap + np.array([1.0, 0.0, 2.0])
    l2 = snap + np.array([0.0, 5.0, 1.0])
    got = merge_deltas(snap, [l1, l2])
    np.testing.assert_allclose(got, [11.0, 25.0, 3.0])
    # integer exactness
    snap_i = np.array([7, 9], dtype=np.int64)
    got_i = merge_deltas(snap_i, [snap_i - 3, snap_i - 4])
    np.testing.assert_array_equal(got_i, [0, 2])


def test_shard_bounds():
    assert shard_bounds(10, 1) == [0, 10]
    assert shard_bounds(10, 2) == [0, 5, 10]
    b = shard_bounds(7, 3)
    assert b[0] == 0 and b[-1] == 7 and len(b) == 4
    assert shard_bounds(2, 8) == [0, 1, 2]      # W capped at m
    assert shard_bounds(0, 4) == [0, 0]


# ---------------------------------------------------------------------- #
# sharded parallel parse
# ---------------------------------------------------------------------- #
def _stats_no_peak(stats):
    d = stats.summary()
    d.pop("peak_chunk_edges")       # per-shard buffer high-water mark
    d.pop("engine")                 # provenance tag, not a semantic stat
    return d


@pytest.mark.parametrize("workers", (1, 2, 5))
@pytest.mark.parametrize("pool", ("serial", "process"))
def test_sharded_parse_matches_sequential(trace_path, workers, pool):
    g0, s0 = ingest_trace_with_stats(trace_path)
    g, s = dist_ingest_with_stats(trace_path, workers=workers, pool=pool)
    assert g.n == g0.n
    np.testing.assert_array_equal(g.src, g0.src)
    np.testing.assert_array_equal(g.dst, g0.dst)
    np.testing.assert_array_equal(g.w, g0.w)
    assert _stats_no_peak(s) == _stats_no_peak(s0)
    if workers == 1:
        # single shard: exact stats up to provenance (engine + buffer
        # high-water mark differ when the scanner handles the seq path)
        assert _stats_no_peak(s) == _stats_no_peak(s0)


def test_sharded_parse_gzip(trace_path, tmp_path):
    gz = tmp_path / "t.ndjson.gz"
    with open(trace_path) as f, gzip.open(gz, "wt", encoding="utf-8") as z:
        z.write(f.read())
    g0, _ = ingest_trace_with_stats(trace_path)
    g, _ = dist_ingest_with_stats(str(gz), workers=4)
    np.testing.assert_array_equal(g.src, g0.src)
    np.testing.assert_array_equal(g.w, g0.w)


def test_cross_shard_def_resolution(tmp_path):
    """Defs in early shards must bind later shards' uses — including the
    producer-bytes weight recompute — exactly like the rolling tables."""
    lines = [json.dumps({"fn": "f", "bb": "b0", "op": "load",
                         "def": f"v{i}", "def_ty": "i32", "uses": []})
             for i in range(40)]
    lines += [json.dumps({"fn": "f", "bb": "b1", "op": "add",
                          "def": f"x{i}", "def_ty": "<4 x float>",
                          "uses": [f"v{i % 40}",
                                   f"x{i - 1}" if i else "v0"]})
              for i in range(400)]
    path = tmp_path / "defs.ndjson"
    path.write_text("\n".join(lines) + "\n")
    g0, s0 = ingest_trace_with_stats(str(path))
    assert set(g0.w.tolist()) == {4.0, 16.0}    # recompute has teeth
    for workers in (2, 3, 9):
        g, s = dist_ingest_with_stats(str(path), workers=workers)
        assert g.n == g0.n
        np.testing.assert_array_equal(g.src, g0.src)
        np.testing.assert_array_equal(g.w, g0.w)
        assert _stats_no_peak(s) == _stats_no_peak(s0)


def test_sharded_parse_keep_labels(tmp_path):
    lines = [json.dumps({"fn": "f", "bb": "b", "op": f"op{i}",
                         "def": f"v{i}", "uses": [f"v{i-1}"] if i else []})
             for i in range(200)]
    path = tmp_path / "lab.ndjson"
    path.write_text("\n".join(lines) + "\n")
    g0, _ = ingest_trace_with_stats(str(path), keep_labels=True)
    g, _ = dist_ingest_with_stats(str(path), workers=3, keep_labels=True)
    assert list(g.node_labels) == list(g0.node_labels)


def test_sharded_parse_on_error_skip(tmp_path):
    lines = [json.dumps({"fn": "f", "bb": "b", "op": "add",
                         "def": f"v{i}", "uses": []}) for i in range(60)]
    lines[10] = "not json"
    lines[40] = json.dumps({"op": 3})            # non-string op
    path = tmp_path / "bad.ndjson"
    path.write_text("\n".join(lines) + "\n")
    g, s = dist_ingest_with_stats(str(path), workers=3, on_error="skip")
    assert s.skipped == 2
    assert g.n == 58


def test_shard_byte_ranges_cover_file(trace_path):
    size = os.path.getsize(trace_path)
    with open(trace_path, "rb") as f:
        data = f.read()
    for workers in (1, 2, 3, 8):
        ranges = shard_byte_ranges(trace_path, workers)
        assert ranges[0][0] == 0 and ranges[-1][1] == size
        for (a0, b0), (a1, b1) in zip(ranges, ranges[1:]):
            assert b0 == a1                      # contiguous
        for a, b in ranges[:-1]:
            assert data[b - 1:b] == b"\n"        # newline-aligned cuts


def test_unicode_line_separators_inside_strings(tmp_path):
    """U+2028/NEL/form-feed are legal raw inside JSON strings and must
    not be treated as line breaks by the sharded parse (only \\n is) —
    plain byte-range and in-memory block paths alike."""
    lines = [json.dumps({"fn": "f", "bb": "b", "op": f"op {i}x",
                         "def": f"v{i}", "uses": [f"v{i-1}"] if i else []},
                        ensure_ascii=False)
             for i in range(30)]
    path = tmp_path / "u.ndjson"
    path.write_text("\n".join(lines) + "\n", encoding="utf-8")
    g0, s0 = ingest_trace_with_stats(str(path))
    assert s0.records == 30
    for workers in (1, 3):
        g, s = dist_ingest_with_stats(str(path), workers=workers)
        assert s.records == 30 and s.skipped == 0
        np.testing.assert_array_equal(g.src, g0.src)
        np.testing.assert_array_equal(g.w, g0.w)
    gz = tmp_path / "u.ndjson.gz"
    with open(path, "rb") as f, gzip.open(gz, "wb") as z:
        z.write(f.read())
    g, s = dist_ingest_with_stats(str(gz), workers=3)
    assert s.records == 30 and s.skipped == 0
    np.testing.assert_array_equal(g.src, g0.src)


def test_more_workers_than_lines(tmp_path):
    path = tmp_path / "tiny.ndjson"
    path.write_text(json.dumps({"fn": "f", "bb": "b", "op": "add",
                                "def": "v0", "uses": []}) + "\n")
    g, s = dist_ingest_with_stats(str(path), workers=16)
    assert g.n == 1 and s.records == 1


def test_dist_ingest_rejects_non_paths():
    with pytest.raises(TypeError):
        dist_ingest_with_stats(["{}"], workers=2)
    with pytest.raises(ValueError):
        dist_ingest_with_stats("x.ndjson", pool="threads")


# ---------------------------------------------------------------------- #
# path inputs + pipeline plumbing
# ---------------------------------------------------------------------- #
def test_dist_cut_from_trace_path(trace_path):
    # pipeline=False two-phases the path input: ingest + cut must match
    # handing over the pre-ingested graph exactly
    g = dist_ingest(trace_path, workers=2)
    a = dist_vertex_cut(trace_path, 16, workers=2, merge_period=4000,
                        pipeline=False)
    b = dist_vertex_cut(g, 16, workers=2, merge_period=4000)
    np.testing.assert_array_equal(a.assignment, b.assignment)


def test_dist_cut_from_trace_path_pipelined(trace_path):
    # the auto-pipelined path is deterministic and a valid cut, but its
    # prefix-snapshot swap/bound legitimately differs from two-phase
    g = dist_ingest(trace_path, workers=2)
    tl = {}
    a = dist_vertex_cut(trace_path, 16, workers=2, merge_period=4000,
                        timeline=tl)
    b = dist_vertex_cut(trace_path, 16, workers=2, merge_period=4000)
    np.testing.assert_array_equal(a.assignment, b.assignment)
    assert tl["mode"] == "pipelined" and len(tl["rounds"]) >= 1
    assert a.p == 16 and len(a.assignment) == g.num_edges
    # replica CSR must agree with the assignment-derived sets
    from repro.core._arrayops import replica_csr
    indptr, flat = replica_csr(g.n, 16, g.src, g.dst, a.assignment)
    np.testing.assert_array_equal(a.replica_indptr, indptr)
    np.testing.assert_array_equal(a.replica_flat, flat)


def test_dist_cut_from_npz_path(tmp_path, graph):
    npz = tmp_path / "g.npz"
    graph.save_npz(str(npz))
    a = dist_vertex_cut(str(npz), 8, workers=1)
    b = vertex_cut(graph, 8, backend="fast")
    np.testing.assert_array_equal(a.assignment, b.assignment)


def test_run_pipeline_dist_trace_path(trace_path):
    part, mapping, rep = run_pipeline(trace_path, 8, "wb_libra",
                                      backend="dist", workers=2)
    assert part.p == 8 and rep.exec_time > 0


def test_cli_partition_workers(trace_path, capsys):
    from repro.trace.__main__ import main
    assert main(["partition", trace_path, "-p", "4", "--workers", "2"]) == 0
    out = json.loads(capsys.readouterr().out)
    assert out["p"] == 4 and out["replication_factor"] >= 1.0


def test_empty_graph_dist():
    g = IRGraph(n=3, src=np.zeros(0, np.int32), dst=np.zeros(0, np.int32),
                w=np.zeros(0), name="empty")
    r = dist_vertex_cut(g, 4, workers=2)
    assert len(r.assignment) == 0
    assert r.replication_factor == 0.0


# ---------------------------------------------------------------------- #
# worker pools, pipelined dataflow, adaptive merges
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("pool", ("serial", "thread", "process"))
def test_two_phase_pool_equivalence(graph, pool):
    """The pool choice never affects the result (serial is the oracle)."""
    ref = dist_vertex_cut(graph, 16, workers=3, merge_period=1000,
                          pool="serial")
    tl = {}
    got = dist_vertex_cut(graph, 16, workers=3, merge_period=1000,
                          pool=pool, timeline=tl)
    np.testing.assert_array_equal(got.assignment, ref.assignment)
    assert tl["mode"] == "two-phase"
    from repro.core._native import native_available
    expect = pool if (pool != "thread" or native_available()) else "thread"
    assert tl["pool"] == expect


@pytest.mark.parametrize("pool", ("serial", "thread", "process"))
def test_pipelined_pool_equivalence(trace_path, pool):
    ref = dist_vertex_cut(trace_path, 16, workers=3, merge_period=700,
                          pool="serial")
    tl = {}
    got = dist_vertex_cut(trace_path, 16, workers=3, merge_period=700,
                          pool=pool, timeline=tl)
    np.testing.assert_array_equal(got.assignment, ref.assignment)
    assert tl["mode"] == "pipelined" and tl["pool"] == pool


def test_pipelined_determinism_tiny_rounds(trace_path):
    """Racy interleavings (tiny rounds, many merges) must not leak into
    the output: repeated runs are bit-identical for a fixed config."""
    runs = [dist_vertex_cut(trace_path, 8, workers=4, merge_period=97)
            for _ in range(3)]
    for r in runs[1:]:
        np.testing.assert_array_equal(runs[0].assignment,
                                      r.assignment)


def test_pipelined_independent_of_parse_workers(trace_path):
    """Shard-count of the parse side must not affect the cut (round
    boundaries are global edge offsets, not parse-shard boundaries)."""
    a = dist_vertex_cut(trace_path, 8, workers=2, merge_period=1500)
    for pw in (1, 3, 7):
        b = dist_vertex_cut(trace_path, 8, workers=2, merge_period=1500,
                            parse_workers=pw)
        np.testing.assert_array_equal(a.assignment, b.assignment)


def test_auto_pool_matches_engine(trace_path):
    """auto routes native -> threads, pure-Python -> processes, so the
    no-native CI job exercises the process pool end to end."""
    from repro.core._native import native_available
    tl = {}
    dist_vertex_cut(trace_path, 8, workers=2, merge_period=4000,
                    timeline=tl)
    if native_available():
        assert tl["engine"] == "native" and tl["pool"] == "thread"
    else:
        assert tl["engine"] == "python" and tl["pool"] == "process"


def test_thread_pool_python_engine_warns(graph):
    with pytest.warns(RuntimeWarning, match="GIL"):
        r = dist_vertex_cut(graph, 8, workers=2, backend="python",
                            pool="thread", merge_period=4000)
    ref = dist_vertex_cut(graph, 8, workers=2, backend="python",
                          pool="serial", merge_period=4000)
    np.testing.assert_array_equal(r.assignment, ref.assignment)


def test_pipeline_forced_ineligible_raises(graph, trace_path):
    with pytest.raises(ValueError, match="pipeline=True"):
        dist_vertex_cut(graph, 8, workers=2, pipeline=True)   # not a path
    with pytest.raises(ValueError, match="pipeline=True"):
        dist_vertex_cut(trace_path, 8, workers=1, pipeline=True)
    with pytest.raises(ValueError, match="pipeline=True"):
        dist_vertex_cut(trace_path, 8, workers=2, method="pg",
                        pipeline=True)                        # PG rule
    with pytest.raises(ValueError, match="pipeline"):
        dist_vertex_cut(trace_path, 8, workers=2, pipeline="sometimes")


def test_adaptive_merge_determinism_and_savings(trace_path):
    """divergence defers full merges deterministically; divergence=None
    reproduces the fixed every-round schedule."""
    tl_fixed, tl_adapt = {}, {}
    fixed = dist_vertex_cut(trace_path, 16, workers=3, merge_period=500,
                            timeline=tl_fixed)
    a1 = dist_vertex_cut(trace_path, 16, workers=3, merge_period=500,
                         divergence=1.0, timeline=tl_adapt)
    a2 = dist_vertex_cut(trace_path, 16, workers=3, merge_period=500,
                         divergence=1.0)
    np.testing.assert_array_equal(a1.assignment, a2.assignment)
    assert tl_fixed["full_merges"] == tl_fixed["round_merges"]
    assert tl_adapt["full_merges"] < tl_adapt["round_merges"]
    # a loose bound still ends with a valid cut of comparable quality
    assert len(a1.assignment) == len(fixed.assignment)
    assert a1.replication_factor <= fixed.replication_factor * 1.25


def test_adaptive_merge_quality_sweep(graph):
    """Adaptive merges (tight bound) must not degrade cut quality vs the
    fixed every-round schedule beyond tolerance, across a (p, W) sweep."""
    for p, w in ((8, 2), (32, 4)):
        fixed = dist_vertex_cut(graph, p, workers=w, merge_period=2000)
        adapt = dist_vertex_cut(graph, p, workers=w, merge_period=2000,
                                divergence=0.05)
        assert (adapt.replication_factor
                <= fixed.replication_factor * 1.05), (p, w)


def test_divergence_validation(graph):
    with pytest.raises(ValueError, match="divergence"):
        dist_vertex_cut(graph, 8, workers=2, divergence=-0.1)


def test_shard_state_grow_and_adopt_loads():
    st = ShardCutState.create(4, 128, np.zeros(4, np.int64), np.inf,
                              True, "python")
    st.masks[: 4 * st.limbs] = 7
    st.rem[:] = 5
    st.grow(9)
    assert len(st.rem) == 9 and len(st.masks) == 9 * st.limbs
    assert (st.masks[: 4 * st.limbs] == 7).all()
    assert (st.masks[4 * st.limbs:] == 0).all()
    assert (st.rem[:4] == 5).all() and (st.rem[4:] == 0).all()
    st.grow(3)                      # shrink is a no-op
    assert len(st.rem) == 9
    st2 = ShardCutState.create(3, 8, np.zeros(3, np.int64), np.inf,
                               True, "python")
    assert st2.fresh
    st2.adopt_loads(np.arange(8, dtype=np.float64))
    assert not st2.fresh and st2.loads[7] == 7.0
    # adopt with rem=None leaves rem untouched (Libra never reads it)
    st2.rem[:] = 9
    st2.adopt(np.zeros(8), None, np.zeros(3 * st2.limbs, np.uint64))
    assert (st2.rem == 9).all()


def test_masks_to_replica_csr_matches_sort_based(graph):
    from concurrent.futures import ThreadPoolExecutor
    from repro.core._arrayops import masks_to_replica_csr, replica_csr

    for p in (3, 64, 130):
        cut = vertex_cut(graph, p, method="wb_libra", backend="fast")
        limbs = (p + 63) // 64
        masks = np.zeros(graph.n * limbs, dtype=np.uint64)
        for arrs, v in ((graph.src, None), (graph.dst, None)):
            idx = arrs.astype(np.int64) * limbs + cut.assignment // 64
            np.bitwise_or.at(masks, idx,
                             np.uint64(1) << (cut.assignment % 64
                                              ).astype(np.uint64))
        want = replica_csr(graph.n, p, graph.src, graph.dst, cut.assignment)
        got = masks_to_replica_csr(masks, graph.n, limbs, p)
        np.testing.assert_array_equal(got[0], want[0])
        np.testing.assert_array_equal(got[1], want[1])
        with ThreadPoolExecutor(max_workers=4) as ex:
            sharded = masks_to_replica_csr(masks, graph.n, limbs, p,
                                           executor=ex, shards=7)
        np.testing.assert_array_equal(sharded[0], want[0])
        np.testing.assert_array_equal(sharded[1], want[1])
        # short masks pad as empty rows
        trunc = masks_to_replica_csr(masks[: (graph.n - 2) * limbs],
                                     graph.n, limbs, p)
        assert trunc[0][-1] <= want[0][-1]


def test_timeline_shape(graph):
    tl = {}
    dist_vertex_cut(graph, 8, workers=2, merge_period=3000, timeline=tl)
    assert tl["mode"] == "two-phase" and tl["workers"] == 2
    assert tl["finalize_us"] >= 0 and len(tl["rounds"]) >= 1
    r0 = tl["rounds"][0]
    assert len(r0["cut_us"]) == 2 and "merge_us" in r0
