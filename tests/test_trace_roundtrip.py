"""Round-trip oracle: `repro.trace.record` output re-ingested must equal
`jaxpr_to_graph` **bit-identically** in vertex count and `src`/`dst`,
with `w` matching to rtol 1e-12 under the `bytes` weight model, and
`src`/`dst` staying identical under every other weight model.

This is the tier-1 guarantee that the NDJSON front end builds the same
dynamic dependence graph the jaxpr tracer does — any divergence in the
def-table/const/live-in creation order breaks it immediately.
"""
import io

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.jaxpr_graph import jaxpr_to_graph, trace_to_graph
from repro.trace import (DEMO_PROGRAMS, WEIGHT_MODELS, demo_program,
                         ingest_trace, record_graph)

try:        # the randomized search deepens when the [test] extra exists
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def roundtrip(g):
    buf = io.StringIO()
    lines = record_graph(g, buf)
    assert lines >= 1
    buf.seek(0)
    return ingest_trace(buf, weight_model="bytes", keep_labels=True)


def assert_bit_identical(g, g2, check_w=True):
    assert g2.n == g.n
    assert np.array_equal(g.src, g2.src)
    assert np.array_equal(g.dst, g2.dst)
    if check_w:
        assert np.allclose(g.w, g2.w, rtol=1e-12, atol=0.0)


@pytest.mark.parametrize("name", sorted(DEMO_PROGRAMS))
def test_demo_program_roundtrip(name):
    fn, args = demo_program(name)
    g = trace_to_graph(fn, *args, name=name)
    assert_bit_identical(g, roundtrip(g))


@pytest.mark.parametrize("name", sorted(DEMO_PROGRAMS))
@pytest.mark.parametrize("model", sorted(WEIGHT_MODELS))
def test_roundtrip_edges_identical_across_weight_models(name, model):
    fn, args = demo_program(name)
    g = trace_to_graph(fn, *args, name=name)
    buf = io.StringIO()
    record_graph(g, buf)
    buf.seek(0)
    g2 = ingest_trace(buf, weight_model=model)
    # src/dst are weight-model independent; w is exact for "bytes"
    assert_bit_identical(g, g2, check_w=(model == "bytes"))


def test_jit_wrapped_roundtrip():
    """pjit inlining creates boundary const vertices — the trickiest
    creation-order case for the serializer."""
    @jax.jit
    def f(x, w):
        h = jnp.tanh(x @ w + 1.5)
        return (h * 2.0).sum()

    g = trace_to_graph(f, jnp.ones((4, 8)), jnp.ones((8, 4)), name="jit")
    assert_bit_identical(g, roundtrip(g))


def test_scan_roundtrip_unroll_depths():
    def rnn(xs, w):
        def step(h, x):
            h = jnp.tanh(h @ w + x)
            return h, h
        _, ys = jax.lax.scan(step, jnp.zeros((4,), xs.dtype), xs)
        return ys.sum()

    cj = jax.make_jaxpr(rnn)(jnp.ones((6, 4)), jnp.ones((4, 4)))
    for unroll in (1, 3, 8):
        g = jaxpr_to_graph(cj, name="rnn", max_scan_unroll=unroll)
        assert_bit_identical(g, roundtrip(g))


def _mlp_roundtrip(depth, width, batch, residual, reduce_op):
    def fwd(x, ws):
        for w in ws:
            h = jnp.tanh(x @ w)
            x = x + h if residual else h
        return getattr(jnp, reduce_op)(x)

    ws = [jnp.ones((width, width), jnp.float32) for _ in range(depth)]
    g = trace_to_graph(fwd, jnp.ones((batch, width), jnp.float32), ws,
                       name="mlp_prop")
    assert_bit_identical(g, roundtrip(g))


def _op_soup_roundtrip(seed, n_eqns):
    """Random elementwise/matmul op soups over a shared pool of values —
    stresses literal-heavy and fan-out-heavy graphs."""
    rng = np.random.default_rng(seed)
    ops = rng.integers(0, 4, n_eqns)
    picks = rng.integers(0, 1 << 30, (n_eqns, 2))

    def soup(x, y):
        pool = [x, y]
        for k in range(n_eqns):
            a = pool[picks[k, 0] % len(pool)]
            b = pool[picks[k, 1] % len(pool)]
            if ops[k] == 0:
                r = a + b
            elif ops[k] == 1:
                r = a * 0.5 + b
            elif ops[k] == 2:
                r = jnp.maximum(a, b) + 1.0
            else:
                r = jnp.tanh(a) * b
            pool.append(r)
        return sum(p.sum() for p in pool[2:])

    g = trace_to_graph(soup, jnp.ones((3, 3)), jnp.ones((3, 3)),
                       name="soup")
    assert_bit_identical(g, roundtrip(g))


# seeded sweeps always run (tier-1 must enforce the oracle even without
# the [test] extra); hypothesis widens the same search when present
@pytest.mark.parametrize("depth,width,batch,residual,reduce_op", [
    (1, 2, 1, False, "sum"), (2, 5, 3, True, "max"), (3, 8, 4, True, "mean"),
])
def test_mlp_roundtrip_seeded(depth, width, batch, residual, reduce_op):
    _mlp_roundtrip(depth, width, batch, residual, reduce_op)


@pytest.mark.parametrize("seed,n_eqns", [(0, 2), (7, 12), (1234, 24)])
def test_op_soup_roundtrip_seeded(seed, n_eqns):
    _op_soup_roundtrip(seed, n_eqns)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(
        depth=st.integers(1, 3),
        width=st.sampled_from([2, 3, 5, 8]),
        batch=st.integers(1, 4),
        residual=st.booleans(),
        reduce_op=st.sampled_from(["sum", "max", "mean"]),
    )
    def test_random_mlp_roundtrip(depth, width, batch, residual, reduce_op):
        """Property: every traceable program round-trips bit-identically."""
        _mlp_roundtrip(depth, width, batch, residual, reduce_op)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16), n_eqns=st.integers(2, 24))
    def test_random_op_soup_roundtrip(seed, n_eqns):
        _op_soup_roundtrip(seed, n_eqns)
