"""Metrics registry (`repro.obs.metrics`) + its consumers: histogram
math and merging, the module-level `obs.observe` contract, dist
worker-metric folding across pool kinds, the live `PlanService.metrics`
snapshot, LRU plan-cache eviction accounting, the round-timeline
Perfetto exporter, and the `check_regression --attribute` phase blame.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import obs
from repro.obs.export import events_from_chrome, timeline_trace
from repro.obs.metrics import DEFAULT_BUCKETS_US, Histogram, MetricsRegistry
from repro.serve import PlanRequest, PlanService
from repro.serve.cache import PlanBundle, PlanCache
from repro.trace import synthesize_trace

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)        # benchmarks/ is a repo-root package
from benchmarks import check_regression  # noqa: E402


@pytest.fixture(autouse=True)
def _obs_clean():
    obs.disable()
    yield
    obs.disable()


@pytest.fixture(scope="module")
def trace_path(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("metrics") / "synth.ndjson")
    synthesize_trace(path, 20_000, seed=0)
    return path


# ---------------------------------------------------------------------- #
# histogram math
# ---------------------------------------------------------------------- #
def test_histogram_single_sample_reports_the_sample():
    h = Histogram()
    h.observe(3.7)
    assert h.count == 1 and h.sum == 3.7
    # interpolation is clamped to the observed min/max
    assert h.percentile(50) == 3.7
    assert h.percentile(99) == 3.7


def test_histogram_percentile_interpolates():
    h = Histogram(bounds=(10.0, 20.0, 30.0))
    for v in (5.0, 15.0, 25.0, 28.0):
        h.observe(v)
    assert h.min == 5.0 and h.max == 28.0
    assert 0.0 < h.percentile(10) <= 10.0
    assert h.percentile(100) == 28.0
    assert Histogram().percentile(50) == 0.0          # empty -> 0


def test_histogram_overflow_bucket():
    h = Histogram(bounds=(1.0, 2.0))
    h.observe(100.0)
    assert h.counts == [0, 0, 1]
    assert h.percentile(99) == 100.0                  # clamped to max


def test_histogram_merge_adds_counts():
    a, b = Histogram(), Histogram()
    for v in (1.0, 10.0, 100.0):
        a.observe(v)
    for v in (2.0, 20.0):
        b.observe(v)
    a.merge(b)
    assert a.count == 5
    assert a.sum == pytest.approx(133.0)
    assert a.min == 1.0 and a.max == 100.0
    # merging mismatched bucket grids is a hard error, not silent skew
    with pytest.raises(ValueError, match="buckets"):
        a.merge(Histogram(bounds=(1.0, 2.0)))
    with pytest.raises(ValueError, match="sorted"):
        Histogram(bounds=(2.0, 1.0))


def test_histogram_snapshot_roundtrip():
    h = Histogram()
    for v in (3.0, 30.0, 300.0, 3000.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["bounds"] == list(DEFAULT_BUCKETS_US)
    assert snap["count"] == 4 and snap["p50"] == h.percentile(50)
    h2 = Histogram.from_snapshot(json.loads(json.dumps(snap)))
    assert h2.counts == h.counts
    assert h2.percentile(99) == h.percentile(99)
    assert (h2.min, h2.max, h2.sum) == (h.min, h.max, h.sum)


# ---------------------------------------------------------------------- #
# registry
# ---------------------------------------------------------------------- #
def test_registry_instruments_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("hits")
    reg.counter("hits", 2)
    reg.gauge("depth", 7)
    reg.observe("lat_us", 12.0)
    reg.observe("lat_us", 24.0)
    assert len(reg) == 3
    snap = reg.snapshot()
    assert snap["counters"]["hits"] == 3.0
    assert snap["gauges"]["depth"] == 7
    assert snap["histograms"]["lat_us"]["count"] == 2
    assert reg.percentile("lat_us", 50) > 0
    assert reg.percentile("never_observed", 50) == 0.0
    reg.reset()
    assert len(reg) == 0


def test_registry_merge_registry_and_snapshot_dict():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("c", 1)
    a.observe("h", 10.0)
    b.counter("c", 2)
    b.gauge("g", 5)
    b.observe("h", 20.0)
    b.observe("h2", 1.0)
    a.merge(b)                                   # live registry
    a.merge(json.loads(json.dumps(b.snapshot())))  # crossed-process dict
    snap = a.snapshot()
    assert snap["counters"]["c"] == 5.0
    assert snap["gauges"]["g"] == 5
    assert snap["histograms"]["h"]["count"] == 3
    assert snap["histograms"]["h2"]["count"] == 2


def test_module_observe_zero_cost_and_scoped_merge():
    assert not obs.enabled()
    obs.observe("lat", 1.0)                      # disabled: pure no-op
    with obs.scoped(merge=False) as outer:
        obs.observe("lat", 5.0)
        with obs.scoped() as inner:              # merge=True default
            obs.observe("lat", 7.0)
            obs.observe("inner_only", 1.0)
        assert inner.metrics.snapshot()["histograms"]["lat"]["count"] == 1
    snap = outer.metrics.snapshot()["histograms"]
    assert snap["lat"]["count"] == 2             # child folded into outer
    assert snap["inner_only"]["count"] == 1
    assert obs.current() is None


# ---------------------------------------------------------------------- #
# dist: worker metrics fold identically across pool kinds
# ---------------------------------------------------------------------- #
def _dist_metrics(trace_path, pool):
    from repro.dist import dist_vertex_cut
    with obs.scoped(merge=False) as col:
        dist_vertex_cut(trace_path, 8, workers=4, merge_period=2000,
                        pool=pool)
    return col.metrics.snapshot()["histograms"]


def test_dist_metrics_serial_vs_process(trace_path):
    """Worker durations ship home over the result channels and the
    coordinator observes them — so the merged histograms exist without
    shared memory, and the deterministic ones (round edge counts) are
    bit-identical between a serial and a process-pool run."""
    serial = _dist_metrics(trace_path, "serial")
    proc = _dist_metrics(trace_path, "process")
    for snap in (serial, proc):
        assert {"dist.round_edges", "dist.cut_us", "dist.parse_wait_us",
                "dist.finalize_us"} <= set(snap)
    # round partitioning is a pure function of the input: exact equality
    assert serial["dist.round_edges"] == proc["dist.round_edges"]
    # timings differ run to run, but the *sample counts* cannot
    assert serial["dist.cut_us"]["count"] == proc["dist.cut_us"]["count"]
    assert serial["dist.cut_us"]["count"] > 0


def test_repro_profile_process_pool_keeps_coordinator_profile(
        tmp_path, trace_path):
    """REPRO_PROFILE + a process-pool dist run: worker processes must
    not clobber the coordinator's profile, and the dump carries the
    merged worker metrics (the registry rides in repro.metrics)."""
    out = tmp_path / "prof.json"
    code = ("from repro.dist import dist_vertex_cut; "
            f"dist_vertex_cut({trace_path!r}, 8, workers=4, "
            "merge_period=2000, pool='process')")
    env = dict(os.environ, REPRO_PROFILE=str(out), PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], env=env, cwd=REPO,
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    doc = json.loads(out.read_text())
    names = {e.get("name") for e in doc["traceEvents"]}
    assert "dist.finalize" in names              # the coordinator's dump
    hists = doc["repro"]["metrics"]["histograms"]
    assert hists["dist.cut_us"]["count"] > 0
    assert hists["dist.round_edges"]["count"] > 0


# ---------------------------------------------------------------------- #
# LRU plan cache
# ---------------------------------------------------------------------- #
def _bundle(tag: int) -> PlanBundle:
    return PlanBundle(
        assignment=np.full(16, tag, np.int32),
        loads=np.ones(4), edge_counts=np.full(4, 4, np.int64),
        replica_indptr=np.arange(9, dtype=np.int64),
        replica_flat=np.zeros(8, np.int32),
        core_of=np.arange(4), core_times=np.ones(4),
        exec_time=1.0, comm_bytes=2.0, graph_name=f"g{tag}",
        n_vertices=8, total_weight=16.0, p=4, method="wb_libra", lam=1.0)


def test_plan_cache_lru_eviction_counts(tmp_path):
    reg = MetricsRegistry()
    cache = PlanCache(str(tmp_path / "plans"), max_entries=2, metrics=reg)
    for i in range(3):
        cache.put(f"fp{i}", _bundle(i))
    # fp0 was least recently used -> evicted; fp1/fp2 resident
    assert list(cache._hot) == ["fp1", "fp2"]
    assert cache.evictions == 1
    assert reg.snapshot()["counters"]["serve.cache.evictions"] == 1
    # an evicted bundle is never lost: disk restore re-promotes it and
    # pushes out the new LRU tail
    got = cache.get("fp0")
    assert got is not None and got.graph_name == "g0"
    assert list(cache._hot) == ["fp2", "fp0"]
    assert cache.evictions == 2
    # hot hits refresh recency: fp2 touched -> fp0 becomes the tail
    cache.get("fp2")
    cache.put("fp3", _bundle(3))
    assert list(cache._hot) == ["fp2", "fp3"]
    assert cache.hot_bytes == sum(
        cache._bundle_nbytes(b) for b in cache._hot.values())


def test_plan_cache_byte_bound(tmp_path):
    one = PlanCache._bundle_nbytes(_bundle(0))
    cache = PlanCache(str(tmp_path / "plans"), max_bytes=2 * one)
    for i in range(3):
        cache.put(f"fp{i}", _bundle(i))
    assert len(cache._hot) == 2
    assert cache.hot_bytes <= 2 * one
    assert cache.evictions == 1


# ---------------------------------------------------------------------- #
# live service metrics
# ---------------------------------------------------------------------- #
def test_service_metrics_live_snapshot(tmp_path, trace_path):
    svc = PlanService(cache_dir=str(tmp_path / "plans"))
    req = PlanRequest(source=trace_path, p=8, lam=1.1)
    svc.plan(req)
    svc.plan(req)
    svc.plan(req)
    m = svc.metrics()
    assert m["plans"] == 3 and m["hits"] == 2 and m["misses"] == 1
    assert m["hit_rate"] == round(2 / 3, 4)
    assert m["tiers"]["cold"]["count"] == 1
    assert m["tiers"]["memory"]["count"] == 2
    assert m["plan_latency_p99_us"] >= m["plan_latency_p50_us"] > 0
    # hits resolve in the hot map: far cheaper than the cold plan
    assert m["tiers"]["memory"]["p99_us"] < m["tiers"]["cold"]["p50_us"]
    assert m["plans_per_s"] > 0 and m["uptime_s"] > 0
    assert m["evictions"] == 0
    # the registry is always on — no obs collector was ever active
    assert obs.current() is None


def test_service_bounded_hot_map_evicts_and_recovers(tmp_path, trace_path):
    other = str(tmp_path / "other.ndjson")
    synthesize_trace(other, 8_000, seed=3)
    svc = PlanService(cache_dir=str(tmp_path / "plans"),
                      max_hot_entries=1)
    r_a = svc.plan(PlanRequest(source=trace_path, p=8, lam=1.1))
    svc.plan(PlanRequest(source=other, p=8, lam=1.1))  # evicts the first
    m = svc.metrics()
    assert m["evictions"] == 1 and m["hot_entries"] == 1
    # the evicted plan comes back from disk as a hit, not a re-plan
    r2 = svc.plan(PlanRequest(source=trace_path, p=8, lam=1.1))
    assert r2.cache == "disk"
    np.testing.assert_array_equal(r2.bundle.assignment,
                                  r_a.bundle.assignment)
    m = svc.metrics()
    assert m["misses"] == 2 and m["tiers"]["disk"]["count"] == 1
    assert svc.registry.snapshot()["counters"]["serve.plans.disk"] == 1


def test_cli_metrics_subcommand(tmp_path, trace_path, capsys):
    from repro.serve.__main__ import main
    reqs = str(tmp_path / "reqs.json")
    with open(reqs, "w") as f:
        json.dump([{"source": trace_path, "p": 8, "lam": 1.1},
                   {"source": trace_path, "p": 8, "lam": 1.1}], f)
    rc = main(["--cache-dir", str(tmp_path / "plans"), "metrics", reqs])
    assert rc == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["plans"] == 2 and doc["hits"] == 1
    assert doc["hit_rate"] == 0.5
    assert doc["tiers"]["cold"]["count"] == 1
    # without a replay file: an empty but well-formed snapshot
    rc = main(["--cache-dir", str(tmp_path / "plans"), "metrics"])
    assert rc == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["plans"] == 0 and doc["hit_rate"] == 0.0


# ---------------------------------------------------------------------- #
# round-timeline Perfetto export
# ---------------------------------------------------------------------- #
def _sample_timeline() -> dict:
    return {"workers": 2, "merge_period": 100, "full_merges": 1,
            "round_merges": 2, "finalize_us": 500.0,
            "rounds": [
                {"round": 0, "edges": 200, "parse_wait_us": 50.0,
                 "cut_us": [100.0, 120.0], "merge_us": 30.0,
                 "full_merge": True},
                {"round": 1, "edges": 150, "parse_wait_us": 10.0,
                 "cut_us": [90.0, 80.0], "merge_us": 0.0},
            ]}


def test_timeline_trace_synthetic_tracks():
    doc = timeline_trace(_sample_timeline())
    events = events_from_chrome(doc)
    assert {e["lane"] for e in events} == {"coord", "cut/w0", "cut/w1"}
    by_name: dict = {}
    for e in events:
        by_name.setdefault(e["name"], []).append(e)
    assert len(by_name["dist.parse_wait"]) == 2
    assert len(by_name["dist.cut"]) == 4
    assert len(by_name["dist.merge"]) == 1       # merge_us=0 is skipped
    assert len(by_name["dist.finalize"]) == 1
    # round 0 dataflow on the synthetic clock: parse_wait, then the two
    # cut spans in parallel, then the merge after the slowest cut
    cuts0 = [e for e in by_name["dist.cut"] if e["args"]["round"] == 0]
    assert all(e["ts"] == pytest.approx(50.0) for e in cuts0)
    assert by_name["dist.merge"][0]["ts"] == pytest.approx(50.0 + 120.0)
    # waits stay cat=wait so the summarizer never counts them busy
    assert by_name["dist.parse_wait"][0]["cat"] == "wait"
    assert doc["repro"]["gauges"]["timeline.workers"] == 2


def test_timeline_cli_from_bench_json(tmp_path, trace_path, capsys):
    """End to end: a real engine timeline lands in a bench-style JSON
    meta and the `python -m repro.obs timeline` subcommand exports it."""
    from repro.dist import dist_vertex_cut
    from repro.obs.__main__ import main
    tl: dict = {}
    dist_vertex_cut(trace_path, 8, workers=2, merge_period=4000,
                    pool="serial", timeline=tl)
    assert tl["rounds"]
    bench = tmp_path / "BENCH_fake.json"
    bench.write_text(json.dumps(
        {"suite": "dist_scaling", "rows": [], "meta": {"timeline_w4": tl}}))
    out = tmp_path / "tl_trace.json"
    rc = main(["timeline", str(bench), "-o", str(out)])
    assert rc == 0
    assert "perfetto" in capsys.readouterr().out
    doc = json.loads(out.read_text())
    lanes = {e["args"]["name"] for e in doc["traceEvents"]
             if e.get("ph") == "M"}
    assert "coord" in lanes
    assert any(ln.startswith("cut/w") for ln in lanes)
    # a bench JSON without the timeline key fails loudly
    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"rows": [], "meta": {}}))
    assert main(["timeline", str(empty), "-o", str(out)]) == 1


# ---------------------------------------------------------------------- #
# check_regression --attribute: the guilty phase is named
# ---------------------------------------------------------------------- #
def _write(path, rows):
    with open(path, "w") as f:
        json.dump({"suite": "t", "rows": rows, "meta": {}}, f)
    return str(path)


def test_attribute_names_regressing_phase(tmp_path, capsys):
    base = _write(tmp_path / "base.json", [
        {"backend": "reference", "case": "r", "us_per_edge": 10.0},
        {"backend": "fast", "case": "a", "us_per_edge": 10.0,
         "phases": {"parse": 40.0, "cut": 60.0}},
        {"backend": "fast", "case": "b", "us_per_edge": 12.0,
         "phases": {"parse": 50.0, "cut": 70.0}},
    ])
    run = _write(tmp_path / "run.json", [
        {"backend": "reference", "case": "r", "us_per_edge": 10.0},
        {"backend": "fast", "case": "a", "us_per_edge": 50.0,
         "phases": {"parse": 42.0, "cut": 458.0}},
        {"backend": "fast", "case": "b", "us_per_edge": 60.0,
         "phases": {"parse": 52.0, "cut": 548.0}},
    ])
    rc = check_regression.main([run, base, "--factor", "2.0",
                                "--attribute"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "phase attribution for backend=fast" in out
    assert "regressing phase: cut" in out
    # the blamed phase leads the delta table (worst first)
    table = out.split("phase attribution")[1].splitlines()
    assert table[1].split()[0] == "cut"


def test_speedup_gate_skips_on_one_core_host(tmp_path, capsys):
    """A 1-core host can't demonstrate a W-way speedup: the ratio check
    is skipped (W time-sliced workers measure the scheduler, not the
    code), but the meta key must still be present, and a multi-core
    host still gates the scaled floor."""
    rows = [{"backend": "reference", "case": "r", "us_per_edge": 10.0}]
    base = _write(tmp_path / "base.json", rows)
    gate = ["--min-speedup", "3.0", "--speedup-key", "speedup_w4",
            "--speedup-cores", "4"]
    run = tmp_path / "run.json"
    run.write_text(json.dumps({"suite": "t", "rows": rows,
                               "meta": {"host_cores": 1,
                                        "speedup_w4": 0.42}}))
    assert check_regression.main([str(run), base, *gate]) == 0
    assert "SKIP      speedup_w4" in capsys.readouterr().out
    # the same ratio on a 4-core host fails the scaled floor
    run.write_text(json.dumps({"suite": "t", "rows": rows,
                               "meta": {"host_cores": 4,
                                        "speedup_w4": 0.42}}))
    assert check_regression.main([str(run), base, *gate]) == 1
    capsys.readouterr()
    # a missing key is lost coverage even on a 1-core host
    run.write_text(json.dumps({"suite": "t", "rows": rows,
                               "meta": {"host_cores": 1}}))
    assert check_regression.main([str(run), base, *gate]) == 1


def test_attribute_silent_when_gate_passes(tmp_path, capsys):
    rows = [{"backend": "reference", "case": "r", "us_per_edge": 10.0},
            {"backend": "fast", "case": "a", "us_per_edge": 10.0,
             "phases": {"parse": 40.0, "cut": 60.0}}]
    base = _write(tmp_path / "base.json", rows)
    run = _write(tmp_path / "run.json", rows)
    rc = check_regression.main([run, base, "--factor", "2.0",
                                "--attribute"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "phase attribution" not in out
