"""Per-architecture smoke tests (assignment: reduced same-family configs,
one forward/train step on CPU, output shapes + no NaNs) + decode
equivalence."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import models
from repro.configs import ARCHS, reduced_config
from repro.configs.base import ParallelConfig
from repro.launch.steps import make_serve_step, make_train_step
from repro.optim import AdamWConfig, adamw_init

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, B=2, S=24, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, 4, cfg.d_model)), jnp.float32)
    if cfg.n_encoder_layers:
        batch["frame_embeds"] = jnp.asarray(
            rng.standard_normal((B, 8, cfg.d_model)), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def setups():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = reduced_config(ARCHS[name])
            params = models.init_params(cfg, jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_shapes_and_finite(setups, name):
    cfg, params = setups(name)
    batch = _batch(cfg)
    logits, aux = models.forward(cfg, params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), name
    assert bool(jnp.isfinite(aux)), name


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step_improves_nothing_nan(setups, name):
    cfg, params = setups(name)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    par = ParallelConfig(fsdp=False, tp=False, microbatches=1,
                         remat="none")
    step = make_train_step(cfg, opt_cfg, par)
    opt = adamw_init(params, opt_cfg)
    batch = _batch(cfg, S=16)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_opt["step"]) == 1
    # params actually changed
    diffs = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), params, new_params)
    assert max(jax.tree.leaves(diffs)) > 0, name


@pytest.mark.parametrize("name", ["granite-3-2b", "gemma2-27b",
                                  "recurrentgemma-9b", "rwkv6-7b",
                                  "qwen2-vl-2b"])
def test_decode_matches_forward(setups, name):
    cfg, params = setups(name)
    B, S = 2, 10
    batch = _batch(cfg, B=B, S=S)
    if cfg.frontend == "vision":
        batch.pop("patch_embeds")  # text-only decode comparison
    ref, _ = models.forward(cfg, params, batch)
    cache = models.init_cache(cfg, B, max_len=S)
    errs = []
    for t in range(S):
        logits, cache = models.decode_step(
            cfg, params, cache, batch["tokens"][:, t], jnp.int32(t))
        errs.append(float(jnp.abs(logits - ref[:, t]).max()))
    assert max(errs) < 1e-4, (name, errs)


def test_decode_matches_forward_moe_dropless(setups):
    cfg, params = setups("dbrx-132b")
    cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    B, S = 2, 8
    batch = _batch(cfg, B=B, S=S)
    ref, _ = models.forward(cfg, params, batch)
    cache = models.init_cache(cfg, B, max_len=S)
    for t in range(S):
        logits, cache = models.decode_step(
            cfg, params, cache, batch["tokens"][:, t], jnp.int32(t))
        assert float(jnp.abs(logits - ref[:, t]).max()) < 1e-4


def test_windowed_ring_buffer_cache(setups):
    """gemma2 local layers keep only `window` positions — decoding past
    the window must still match the windowed forward."""
    cfg, params = setups("gemma2-27b")
    assert cfg.local_window == 32  # reduced config window
    B, S = 1, 40                   # exceeds the window
    batch = _batch(cfg, B=B, S=S)
    ref, _ = models.forward(cfg, params, batch)
    cache = models.init_cache(cfg, B, max_len=S)
    errs = []
    for t in range(S):
        logits, cache = models.decode_step(
            cfg, params, cache, batch["tokens"][:, t], jnp.int32(t))
        errs.append(float(jnp.abs(logits - ref[:, t]).max()))
    assert max(errs) < 1e-4, errs


def test_serve_step_greedy(setups):
    cfg, params = setups("smollm-360m")
    step = make_serve_step(cfg)
    cache = models.init_cache(cfg, 2, max_len=8)
    tok = jnp.zeros((2,), jnp.int32)
    nxt, cache = step(params, cache, tok, jnp.int32(0))
    assert nxt.shape == (2,)
    assert nxt.dtype == jnp.int32


def test_remat_matches_no_remat(setups):
    cfg, params = setups("smollm-360m")
    batch = _batch(cfg, S=12)
    a, _ = models.forward(cfg, params, batch, remat=False)
    b, _ = models.forward(cfg, params, batch, remat=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_mtp_loss_larger_than_plain(setups):
    """DeepSeek MTP adds an auxiliary term: loss(mtp) > plain CE."""
    cfg, params = setups("deepseek-v3-671b")
    batch = _batch(cfg, S=16)
    full = models.loss_fn(cfg, params, batch)
    plain = models.loss_fn(cfg, params, batch, mtp_weight=0.0,
                           aux_weight=0.0)
    assert float(full) > float(plain)


def test_param_count_formula_matches_init():
    """Analytic param_count (used for MODEL_FLOPS) tracks real init."""
    for name in ("smollm-360m", "granite-3-2b", "rwkv6-7b"):
        cfg = ARCHS[name]
        small = reduced_config(cfg)
        params = models.init_params(small, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        predicted = small.param_count()
        assert abs(actual - predicted) / actual < 0.25, \
            (name, actual, predicted)
