"""Pallas kernel validation: shape/dtype sweeps vs. the ref.py oracles
(assignment requirement: per-kernel allclose against the pure-jnp ref)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import attention_ref, rglru_ref, rwkv6_ref
from repro.kernels.rglru import rglru_scan
from repro.kernels.rwkv6 import rwkv6_scan


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype)


# ---------------------------------------------------------------------- #
# flash attention
# ---------------------------------------------------------------------- #
FA_CASES = [
    # (B, Sq, Sk, Hq, Hkv, D, causal, window, softcap, dtype)
    (2, 128, 128, 4, 2, 64, True, None, None, jnp.float32),
    (1, 256, 256, 8, 1, 64, True, 64, None, jnp.float32),    # MQA + window
    (2, 64, 64, 4, 4, 128, True, None, 50.0, jnp.float32),   # softcap
    (1, 100, 100, 2, 2, 64, False, None, None, jnp.float32), # non-divisible
    (1, 192, 320, 4, 2, 64, True, None, None, jnp.float32),  # Sq != Sk
    (2, 128, 128, 4, 2, 64, True, None, None, jnp.bfloat16),
    (1, 128, 128, 6, 3, 32, True, 32, 30.0, jnp.float32),    # all features
]


@pytest.mark.parametrize("case", FA_CASES)
def test_flash_attention_vs_ref(case):
    B, Sq, Sk, Hq, Hkv, D, causal, window, cap, dt = case
    rng = np.random.default_rng(0)
    q = _rand(rng, (B, Sq, Hq, D), dt)
    k = _rand(rng, (B, Sk, Hkv, D), dt)
    v = _rand(rng, (B, Sk, Hkv, D), dt)
    ref = attention_ref(q, k, v, causal=causal, window=window, softcap=cap)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=cap, block_q=64, block_k=64)
    tol = 2e-5 if dt == jnp.float32 else 2e-2
    err = float(jnp.abs(out.astype(jnp.float32)
                        - ref.astype(jnp.float32)).max())
    assert err < tol, (case, err)


def test_chunked_attention_vs_ref_decode_path():
    rng = np.random.default_rng(1)
    q = _rand(rng, (2, 4, 4, 32), jnp.float32)
    k = _rand(rng, (2, 1500, 2, 32), jnp.float32)
    v = _rand(rng, (2, 1500, 2, 32), jnp.float32)
    ref = attention_ref(q, k, v, causal=True, q_offset=900,
                        kv_len=jnp.int32(1000))
    out = ops.attention(q, k, v, causal=True, q_offset=900,
                        kv_len=jnp.int32(1000), impl="chunked")
    assert float(jnp.abs(out - ref).max()) < 1e-5


def test_chunked_attention_mla_head_dims():
    """MLA: qk head dim 192 != v head dim 128."""
    rng = np.random.default_rng(2)
    q = _rand(rng, (1, 80, 4, 24), jnp.float32)
    k = _rand(rng, (1, 80, 4, 24), jnp.float32)
    v = _rand(rng, (1, 80, 4, 16), jnp.float32)
    ref = attention_ref(q, k, v, causal=True, scale=24 ** -0.5)
    out = ops.attention(q, k, v, causal=True, scale=24 ** -0.5,
                        impl="chunked")
    assert float(jnp.abs(out - ref).max()) < 1e-5


def test_attention_grad_finite():
    rng = np.random.default_rng(3)
    q = _rand(rng, (1, 32, 2, 16), jnp.float32)
    k = _rand(rng, (1, 32, 2, 16), jnp.float32)
    v = _rand(rng, (1, 32, 2, 16), jnp.float32)

    def f(q, k, v):
        return ops.attention(q, k, v, causal=True, impl="ref").sum()

    grads = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for g in grads:
        assert bool(jnp.isfinite(g).all())


# ---------------------------------------------------------------------- #
# RG-LRU
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("B,S,D,bd,dt", [
    (2, 64, 128, 64, jnp.float32),
    (1, 33, 96, 128, jnp.float32),      # non-divisible feature block
    (2, 64, 128, 64, jnp.bfloat16),
])
def test_rglru_kernel_vs_ref(B, S, D, bd, dt):
    rng = np.random.default_rng(0)
    x = _rand(rng, (B, S, D), dt)
    a = jnp.asarray(rng.uniform(0.05, 0.99, (B, S, D)), dt)
    h_ref, hl_ref = rglru_ref(x, a)
    h_k, hl_k = rglru_scan(x, a, block_d=bd)
    tol = 1e-5 if dt == jnp.float32 else 3e-2
    assert float(jnp.abs(h_ref.astype(jnp.float32)
                         - h_k.astype(jnp.float32)).max()) < tol
    assert float(jnp.abs(hl_ref.astype(jnp.float32)
                         - hl_k.astype(jnp.float32)).max()) < tol


def test_rglru_carries_state():
    rng = np.random.default_rng(1)
    x = _rand(rng, (1, 16, 8), jnp.float32)
    a = jnp.asarray(rng.uniform(0.5, 0.9, (1, 16, 8)), jnp.float32)
    full, hl = rglru_ref(x, a)
    # split into two halves with state carry
    h1, s1 = rglru_ref(x[:, :8], a[:, :8])
    h2, s2 = rglru_ref(x[:, 8:], a[:, 8:], h0=s1)
    np.testing.assert_allclose(np.asarray(full),
                               np.concatenate([h1, h2], axis=1),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(s2), atol=1e-6)


# ---------------------------------------------------------------------- #
# RWKV6
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("B,S,H,Dk,Dv", [
    (2, 32, 2, 16, 16),
    (1, 48, 4, 32, 32),
    (1, 16, 1, 8, 24),     # Dk != Dv
])
def test_rwkv6_kernel_vs_ref(B, S, H, Dk, Dv):
    rng = np.random.default_rng(0)
    r = _rand(rng, (B, S, H, Dk), jnp.float32)
    k = _rand(rng, (B, S, H, Dk), jnp.float32) * 0.3
    v = _rand(rng, (B, S, H, Dv), jnp.float32)
    w = jnp.asarray(rng.uniform(0.4, 0.99, (B, S, H, Dk)), jnp.float32)
    u = _rand(rng, (H, Dk), jnp.float32) * 0.1
    o_ref, s_ref = rwkv6_ref(r, k, v, w, u)
    o_k, s_k = rwkv6_scan(r, k, v, w, u)
    assert float(jnp.abs(o_ref - o_k).max()) < 1e-5
    assert float(jnp.abs(s_ref - s_k).max()) < 1e-5


def test_rwkv6_state_carry():
    rng = np.random.default_rng(1)
    B, S, H, D = 1, 20, 2, 8
    r = _rand(rng, (B, S, H, D), jnp.float32)
    k = _rand(rng, (B, S, H, D), jnp.float32) * 0.3
    v = _rand(rng, (B, S, H, D), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 0.95, (B, S, H, D)), jnp.float32)
    u = _rand(rng, (H, D), jnp.float32) * 0.1
    full, s_full = rwkv6_ref(r, k, v, w, u)
    o1, s1 = rwkv6_ref(r[:, :10], k[:, :10], v[:, :10], w[:, :10], u)
    o2, s2 = rwkv6_ref(r[:, 10:], k[:, 10:], v[:, 10:], w[:, 10:], u,
                       s0=s1)
    np.testing.assert_allclose(np.asarray(full),
                               np.concatenate([o1, o2], axis=1), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                               atol=1e-5)


# ---------------------------------------------------------------------- #
# chunk-parallel WKV6 (the production training path — §Perf iteration)
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("B,S,H,D,chunk", [
    (2, 128, 2, 16, 32), (1, 256, 4, 32, 64), (1, 64, 2, 16, 64),
])
def test_rwkv6_chunked_vs_ref(B, S, H, D, chunk):
    from repro.kernels.ref import rwkv6_chunked
    rng = np.random.default_rng(0)
    r = _rand(rng, (B, S, H, D), jnp.float32)
    k = _rand(rng, (B, S, H, D), jnp.float32) * 0.3
    v = _rand(rng, (B, S, H, D), jnp.float32)
    w = jnp.asarray(np.exp(-np.exp(rng.uniform(-6, 1.5, (B, S, H, D)))),
                    jnp.float32)
    u = _rand(rng, (H, D), jnp.float32) * 0.1
    s0 = _rand(rng, (B, H, D, D), jnp.float32) * 0.1
    o_ref, s_ref = rwkv6_ref(r, k, v, w, u, s0=s0)
    o_ch, s_ch = rwkv6_chunked(r, k, v, w, u, s0=s0, chunk=chunk)
    assert float(jnp.abs(o_ref - o_ch).max()) < 5e-4
    assert float(jnp.abs(s_ref - s_ch).max()) < 5e-4


def test_rwkv6_chunked_adversarial_decay():
    """Harsh constant decay channel: the two-level factorisation must not
    overflow (the failure mode of a single-level log-space split)."""
    from repro.kernels.ref import rwkv6_chunked
    rng = np.random.default_rng(1)
    B, S, H, D = 1, 128, 2, 16
    r = _rand(rng, (B, S, H, D), jnp.float32)
    k = _rand(rng, (B, S, H, D), jnp.float32) * 0.3
    v = _rand(rng, (B, S, H, D), jnp.float32)
    wnp = np.exp(-np.exp(rng.uniform(-6, 1.5, (B, S, H, D))))
    wnp[..., 0] = np.exp(-np.exp(2.3))    # ~1e-4 decay every step
    w = jnp.asarray(wnp, jnp.float32)
    u = _rand(rng, (H, D), jnp.float32) * 0.1
    o_ref, s_ref = rwkv6_ref(r, k, v, w, u)
    o_ch, s_ch = rwkv6_chunked(r, k, v, w, u, chunk=64)
    assert bool(jnp.isfinite(o_ch).all())
    assert float(jnp.abs(o_ref - o_ch).max()) < 5e-4


def test_rwkv6_chunked_grad_finite():
    from repro.kernels.ref import rwkv6_chunked
    rng = np.random.default_rng(2)
    B, S, H, D = 1, 64, 2, 8
    r = _rand(rng, (B, S, H, D), jnp.float32)
    k = _rand(rng, (B, S, H, D), jnp.float32) * 0.3
    v = _rand(rng, (B, S, H, D), jnp.float32)
    w = jnp.asarray(np.exp(-np.exp(rng.uniform(-4, 1, (B, S, H, D)))),
                    jnp.float32)
    u = _rand(rng, (H, D), jnp.float32) * 0.1

    def f(r, k, v, w):
        out, _ = rwkv6_chunked(r, k, v, w, u, chunk=32)
        return (out ** 2).mean()

    grads = jax.grad(f, argnums=(0, 1, 2, 3))(r, k, v, w)
    for g in grads:
        assert bool(jnp.isfinite(g).all())
