"""Fast engines must be bit-identical to the reference oracle.

The array-native engines (pure-Python bitmask loop and the optional C
kernel) implement the same streaming case rules as the reference loop
with the same deterministic (load, cluster-id) tie-breaking — so for
every method, p, and λ they must produce the *identical* assignment,
hence identical replication factor, loads, and λ-bound compliance.
Constant-weight and unweighted runs stress the tie-breaking paths.
"""
import numpy as np
import pytest

from repro.core import ALGORITHMS, IRGraph, resolve_backend, vertex_cut
from repro.core._native import native_available

P_VALUES = (2, 8, 64, 512)

FAST_BACKENDS = [
    "python",
    pytest.param("native", marks=pytest.mark.skipif(
        not native_available(), reason="no C compiler available")),
]


def _graphs():
    rng = np.random.default_rng(7)
    out = []
    # weighted, lognormal (generic)
    n, m = 120, 700
    out.append(IRGraph(n=n, src=rng.integers(0, n, m),
                       dst=rng.integers(0, n, m),
                       w=rng.lognormal(size=m), name="lognormal"))
    # constant weights: every load comparison can tie
    n, m = 60, 500
    out.append(IRGraph(n=n, src=rng.integers(0, n, m),
                       dst=rng.integers(0, n, m),
                       w=np.full(m, 0.5), name="ties"))
    # hub-heavy with self-loops: exercises big replica sets + case 1
    n, m = 200, 800
    hub = rng.integers(0, 6, m)
    leaf = rng.integers(0, n, m)
    out.append(IRGraph(n=n, src=hub, dst=leaf,
                       w=rng.lognormal(size=m), name="hubs"))
    return out


GRAPHS = _graphs()


@pytest.mark.parametrize("backend", FAST_BACKENDS)
@pytest.mark.parametrize("p", P_VALUES)
@pytest.mark.parametrize("method", ALGORITHMS)
def test_fast_backends_match_reference(method, p, backend):
    for g in GRAPHS:
        for lam in (1.0, 1.25):
            ref = vertex_cut(g, p, method=method, lam=lam, seed=3,
                             backend="reference")
            got = vertex_cut(g, p, method=method, lam=lam, seed=3,
                             backend=backend)
            np.testing.assert_array_equal(got.assignment, ref.assignment,
                                          err_msg=f"{g.name} lam={lam}")
            np.testing.assert_array_equal(got.loads, ref.loads)
            assert got.replication_factor == ref.replication_factor
            assert (got.edge_weight_imbalance
                    == ref.edge_weight_imbalance)
            if method in ("wb_pg", "wb_libra"):
                bound = lam * g.total_weight / p
                cushion = g.w.max() if g.num_edges else 0.0
                assert got.loads.max() <= bound + cushion + 1e-9


@pytest.mark.parametrize("backend", FAST_BACKENDS)
def test_edge_cases_match_reference(backend):
    cases = [
        IRGraph(n=3, src=np.array([], dtype=int), dst=np.array([], dtype=int),
                w=np.array([]), name="empty"),
        IRGraph(n=2, src=np.array([0]), dst=np.array([1]),
                w=np.array([2.0]), name="one_edge"),
        IRGraph(n=4, src=np.array([0, 1, 2, 2]), dst=np.array([0, 1, 2, 3]),
                w=np.ones(4), name="self_loops"),
        IRGraph(n=4, src=np.array([0, 1]), dst=np.array([1, 2]),
                w=np.zeros(2), name="zero_weights"),
    ]
    for g in cases:
        for p in (1, 2, 512):
            for method in ALGORITHMS:
                ref = vertex_cut(g, p, method=method, backend="reference")
                got = vertex_cut(g, p, method=method, backend=backend)
                np.testing.assert_array_equal(got.assignment, ref.assignment,
                                              err_msg=f"{g.name} p={p}")


def test_replica_csr_matches_bruteforce():
    g = GRAPHS[0]
    r = vertex_cut(g, 8, method="wb_libra")
    expect = [set() for _ in range(g.n)]
    for e in range(g.num_edges):
        expect[int(g.src[e])].add(int(r.assignment[e]))
        expect[int(g.dst[e])].add(int(r.assignment[e]))
    for v in range(g.n):
        got = r.replicas[v] or set()
        assert got == expect[v]
    assert len(r.replica_flat) == sum(len(s) for s in expect)


def test_negative_weights_rejected():
    g = IRGraph(n=3, src=np.array([0, 1]), dst=np.array([1, 2]),
                w=np.array([1.0, -0.5]), name="neg")
    for backend in ("fast", "python", "reference"):
        with pytest.raises(ValueError, match="weights"):
            vertex_cut(g, 4, method="wb_libra", backend=backend)
    # unweighted methods ignore weights and must still work
    r = vertex_cut(g, 4, method="libra")
    assert len(r.assignment) == 2


def test_backend_validation():
    g = GRAPHS[0]
    with pytest.raises(ValueError):
        vertex_cut(g, 4, backend="bogus")
    with pytest.raises(ValueError):
        resolve_backend("bogus")
    assert resolve_backend("fast") in ("native", "python")
    assert resolve_backend("reference") == "reference"


def test_monkeypatched_no_native_falls_back(monkeypatch):
    import sys
    vc = sys.modules["repro.core.vertex_cut"]
    monkeypatch.setattr(vc, "native_engine", lambda: None)
    monkeypatch.setattr(vc, "native_available", lambda: False)
    g = GRAPHS[1]
    ref = vertex_cut(g, 8, backend="reference")
    got = vertex_cut(g, 8, backend="fast")   # resolves to python engine
    np.testing.assert_array_equal(got.assignment, ref.assignment)
    with pytest.raises(RuntimeError):
        vertex_cut(g, 8, backend="native")


# deeper randomized search when the [test] extra is installed ----------- #
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @st.composite
    def small_graphs(draw):
        n = draw(st.integers(min_value=2, max_value=40))
        m = draw(st.integers(min_value=1, max_value=120))
        src = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
        dst = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
        # coarse weights make load ties likely
        w = draw(st.lists(st.sampled_from([0.5, 1.0, 2.0]),
                          min_size=m, max_size=m))
        return IRGraph(n=n, src=np.array(src), dst=np.array(dst),
                       w=np.array(w), name="hyp")

    @given(g=small_graphs(), p=st.sampled_from([2, 8, 64, 512]),
           method=st.sampled_from([m for m in ALGORITHMS if m != "random"]),
           lam=st.sampled_from([1.0, 1.5]))
    @settings(max_examples=40, deadline=None)
    def test_property_fast_matches_reference(g, p, method, lam):
        ref = vertex_cut(g, p, method=method, lam=lam, backend="reference")
        for backend in ("python", "fast"):
            got = vertex_cut(g, p, method=method, lam=lam, backend=backend)
            np.testing.assert_array_equal(got.assignment, ref.assignment)
            np.testing.assert_array_equal(got.loads, ref.loads)
