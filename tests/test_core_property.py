"""Hypothesis property tests on the system's invariants (paper §4.2)."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the [test] extra: pip install -e .[test]")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import IRGraph, vertex_cut  # noqa: E402
from repro.core.powerlaw import expected_replication_random_empirical  # noqa: E402


@st.composite
def small_graphs(draw):
    n = draw(st.integers(min_value=2, max_value=40))
    m = draw(st.integers(min_value=1, max_value=120))
    src = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    dst = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    w = draw(st.lists(st.floats(0.1, 100.0), min_size=m, max_size=m))
    return IRGraph(n=n, src=np.array(src), dst=np.array(dst),
                   w=np.array(w), name="hyp")


@given(g=small_graphs(),
       p=st.integers(2, 8),
       method=st.sampled_from(["pg", "libra", "w_pg", "wb_pg",
                               "w_libra", "wb_libra"]))
@settings(max_examples=60, deadline=None)
def test_partition_invariants(g, p, method):
    r = vertex_cut(g, p=p, method=method)
    # every edge exactly once, in range
    assert len(r.assignment) == g.num_edges
    assert (r.assignment >= 0).all() and (r.assignment < p).all()
    # total weight conserved
    assert np.isclose(r.loads.sum(), g.total_weight)
    # replica sets consistent: edge cluster ∈ A(u) ∩ A(v)
    for e in range(g.num_edges):
        c = r.assignment[e]
        assert c in r.replicas[g.src[e]]
        assert c in r.replicas[g.dst[e]]
    # A(v) only contains clusters that actually host an adjacent edge
    host = [set() for _ in range(g.n)]
    for e in range(g.num_edges):
        host[g.src[e]].add(int(r.assignment[e]))
        host[g.dst[e]].add(int(r.assignment[e]))
    for v in range(g.n):
        got = r.replicas[v] or set()
        assert got == host[v]
    # replication factor bounded by min(degree, p)
    deg = g.degrees()
    for v in range(g.n):
        got = r.replicas[v] or set()
        assert len(got) <= min(max(deg[v], 1), p)


@given(g=small_graphs(), p=st.integers(2, 8))
@settings(max_examples=40, deadline=None)
def test_wb_bound_soft(g, p):
    """λ-bounded variants never exceed bound + max single edge weight."""
    r = vertex_cut(g, p=p, method="wb_libra", lam=1.0)
    bound = g.total_weight / p
    assert r.loads.max() <= bound + g.w.max() + 1e-9


@given(st.integers(2, 64), st.floats(1.5, 3.0))
@settings(max_examples=40, deadline=None)
def test_eq6_bounds(p, alpha):
    """Eq. (6) expectation lies in [1, p] for any degree sequence."""
    rng = np.random.default_rng(0)
    deg = rng.zipf(alpha, size=200).clip(max=199)
    e = expected_replication_random_empirical(deg, p)
    assert 1.0 <= e <= p


def test_partition_invariants_pallas_backend():
    """The §4.2 invariants hold verbatim on the Pallas finalize path,
    and its outputs equal the numpy backends' exactly (two seeded graphs
    keep the interpret-mode jit cache footprint small; the exhaustive
    end-to-end sweep lives in tests/test_pallas_pipeline.py)."""
    pytest.importorskip("jax", reason="pallas layer needs jax")
    from repro.core.pallas import pallas_available
    if not pallas_available():
        pytest.skip("pallas segment-sum probe failed on this jax install")
    rng = np.random.default_rng(11)
    for n, m, p in ((25, 90, 4), (40, 120, 8)):
        g = IRGraph(n=n, src=rng.integers(0, n, m),
                    dst=rng.integers(0, n, m),
                    w=rng.lognormal(size=m), name="pallas_inv")
        r = vertex_cut(g, p=p, method="wb_libra", backend="pallas")
        ref = vertex_cut(g, p=p, method="wb_libra", backend="fast")
        np.testing.assert_array_equal(r.assignment, ref.assignment)
        np.testing.assert_array_equal(r.loads, ref.loads)
        np.testing.assert_array_equal(r.replica_indptr, ref.replica_indptr)
        np.testing.assert_array_equal(r.replica_flat, ref.replica_flat)
        assert np.isclose(r.loads.sum(), g.total_weight)
        for e in range(g.num_edges):
            c = r.assignment[e]
            assert c in r.replicas[g.src[e]]
            assert c in r.replicas[g.dst[e]]


def test_submodularity_modularity_identity():
    """Paper Thm 4.2: f(X)+f(Y) = f(X∩Y)+f(X∪Y) for assignment sets —
    the objective is modular (hence submodular) over replica-set unions."""
    rng = np.random.default_rng(0)
    n, p = 30, 6
    for _ in range(20):
        X = [set(rng.choice(p, size=rng.integers(0, 4), replace=False))
             for _ in range(n)]
        Y = [set(rng.choice(p, size=rng.integers(0, 4), replace=False))
             for _ in range(n)]

        def f(sets):
            return sum(len(s) for s in sets) / n

        inter = [x & y for x, y in zip(X, Y)]
        union = [x | y for x, y in zip(X, Y)]
        lhs = f(X) + f(Y)
        rhs = f(inter) + f(union)
        assert np.isclose(lhs, rhs)


def test_monotonicity():
    """Paper Thm 4.3: adding an assignment never decreases f."""
    rng = np.random.default_rng(1)
    n, p = 20, 5
    A = [set(rng.choice(p, size=rng.integers(0, 3), replace=False))
         for _ in range(n)]

    def f(sets):
        return sum(len(s) for s in sets) / n

    base = f(A)
    for v in range(n):
        for c in range(p):
            grown = [set(s) for s in A]
            grown[v].add(c)
            assert f(grown) >= base - 1e-12
