"""Trace ingestion: schema handling, malformed-input determinism,
streaming invariance, CFG replay, weight models, and pipeline threading.

Malformed traces must raise (with the line number) or skip *atomically*
— a rejected record leaves no vertices, edges, or def-table entries
behind, so the edge stream can never be corrupted by bad input.
"""
import json

import numpy as np
import pytest

from repro.core import run_pipeline
from repro.trace import (TraceFormatError, WEIGHT_MODELS, ingest_trace,
                         ingest_trace_with_stats, iter_synthetic_trace,
                         load_graph, replay_trace, type_bytes)


def rec(**kw) -> str:
    base = {"fn": "f", "bb": "b0", "op": "add", "def": None, "uses": []}
    base.update(kw)
    return json.dumps(base)


# ---------------------------------------------------------------------- #
# basic construction semantics
# ---------------------------------------------------------------------- #
def test_basic_edges_and_weights():
    lines = [
        rec(op="load", **{"def": "v0"}, uses=["arg0"], use_tys=["ptr"]),
        rec(op="mul", **{"def": "v1"}, uses=["v0", "v0"],
            use_tys=["i32", "i32"]),
        rec(op="store", uses=["v1", "arg0"], use_tys=["<4 x float>", "ptr"]),
    ]
    g, st = ingest_trace_with_stats(lines, keep_labels=True)
    # vertices: load, arg0 live-in, mul, store
    assert g.n == 4 and g.num_edges == 5
    assert list(g.node_labels) == ["load", "arg0", "mul", "store"]
    assert g.src.tolist() == [1, 0, 0, 2, 1]
    assert g.dst.tolist() == [0, 2, 2, 3, 3]
    assert g.w.tolist() == [8.0, 4.0, 4.0, 16.0, 8.0]
    assert st.records == 3 and st.livein_uses == 1 and st.void_defs == 1


def test_const_uses_materialise_fresh_vertices():
    lines = [
        rec(op="add", **{"def": "v0"},
            uses=["const:i32:7", "const:i32:7"], use_tys=["i32", "i32"]),
        rec(op="add", pp=None, **{"def": "v1"}, uses=["const:i32:7", "v0"]),
    ]
    g, st = ingest_trace_with_stats(lines)
    # the same const id never interns: 3 uses -> 3 fresh vertices
    assert st.const_uses == 3 and g.n == 5
    assert g.src.tolist() == [1, 2, 4, 0]


def test_def_ty_fallback_and_default_weight():
    lines = [
        rec(op="load", **{"def": "v0"}, def_ty="i16", uses=[]),
        rec(op="add", **{"def": "v1"}, uses=["v0", "v9"]),  # no use_tys
    ]
    g = ingest_trace(lines)
    # without use_tys the weight falls back to the producer's def_ty
    # (2 bytes for i16), then to the 8-byte default for the live-in
    assert g.w.tolist() == [2.0, 8.0]


def test_rolling_def_table_rebinds():
    lines = [
        rec(op="add", **{"def": "v0"}, uses=[]),
        rec(op="mul", **{"def": "v0"}, uses=["v0"]),   # self-redefinition
        rec(op="sub", **{"def": "v1"}, uses=["v0"]),
    ]
    g = ingest_trace(lines)
    # mul's use binds to the OLD v0 (node 0); sub binds to mul's def
    assert g.src.tolist() == [0, 1] and g.dst.tolist() == [1, 2]


def test_def_tables_are_per_function():
    lines = [
        rec(fn="a", op="add", **{"def": "v0"}, uses=[]),
        rec(fn="b", op="mul", **{"def": "v9"}, uses=["v0"]),
    ]
    g, st = ingest_trace_with_stats(lines)
    # fn b's v0 is a live-in, NOT fn a's def
    assert st.livein_uses == 1 and g.src.tolist() == [2]
    assert st.functions == 2


def test_unknown_opcodes_ingest_fine():
    lines = [rec(op="frobnicate", **{"def": "v0"}, uses=[]),
             rec(op="quux", uses=["v0"], use_tys=["i64"])]
    for model in WEIGHT_MODELS:
        g = ingest_trace(lines, weight_model=model)
        assert g.num_edges == 1
    assert ingest_trace(lines, weight_model="memop-latency").w.tolist() == [1.0]


def test_memop_latency_classes():
    lines = [rec(op="add", **{"def": "v0"}, uses=[]),
             rec(op="load", **{"def": "v1"}, uses=["v0"]),
             rec(op="store", uses=["v1"]),
             rec(op="call", **{"def": "v2"}, uses=["v1"])]
    g = ingest_trace(lines, weight_model="memop-latency")
    assert g.w.tolist() == [200.0, 100.0, 250.0]


# ---------------------------------------------------------------------- #
# malformed input: raise with line numbers, or skip atomically
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("bad", [
    '{"fn":"f","bb":"b0","op":"tru',            # truncated JSON
    '["not","an","object"]',                    # non-object
    '{"kind":"wat","fn":"f"}',                  # unknown kind
    '{"fn":"f","bb":"b0","uses":[]}',           # missing op
    '{"fn":"f","bb":"b0","op":"a","uses":"v0"}',        # uses not a list
    '{"fn":"f","bb":"b0","op":"a","uses":[1,2]}',       # non-string ids
    '{"fn":"f","bb":"b0","op":"a","def":5,"uses":[]}',  # non-string def
    '{"fn":"f","bb":"b0","op":"a","uses":["v0"],"use_tys":[]}',  # mismatch
    '{"fn":"f","bb":"b0","op":"a","uses":[],"pp":"g:b9:i0"}',    # pp vs fn/bb
    '{"fn":"f","bb":"b0","op":"a","uses":[],"pp":"f:b0:ix"}',    # bad index
])
def test_malformed_raise_and_skip(bad):
    ok = [rec(op="load", **{"def": "v0"}, uses=[]),
          rec(op="add", pp=None, **{"def": "v1"}, uses=["v0"])]
    lines = [ok[0], bad, ok[1]]
    with pytest.raises(TraceFormatError, match="line 2"):
        ingest_trace(lines)
    g, st = ingest_trace_with_stats(lines, on_error="skip")
    assert st.skipped == 1 and st.records == 2
    # atomic skip: identical to the trace without the bad line
    g_ref = ingest_trace(ok)
    assert g.n == g_ref.n
    assert np.array_equal(g.src, g_ref.src)
    assert np.array_equal(g.dst, g_ref.dst)
    assert np.array_equal(g.w, g_ref.w)


def test_out_of_order_pp():
    lines = [rec(pp="f:b0:i0", **{"def": "v0"}),
             rec(pp="f:b0:i5", **{"def": "v1"}),
             rec(pp="f:b0:i3", **{"def": "v2"}),     # rewinds inside the run
             rec(pp="f:b0:i6", **{"def": "v3"})]
    with pytest.raises(TraceFormatError, match="out-of-order"):
        ingest_trace(lines)
    g, st = ingest_trace_with_stats(lines, on_error="skip")
    assert st.skipped == 1 and g.n == 3
    # a block *change* resets the index legally (loop re-entry)
    lines2 = [rec(pp="f:b0:i0", **{"def": "v0"}),
              rec(bb="b1", pp="f:b1:i0", **{"def": "v1"}),
              rec(pp="f:b0:i0", **{"def": "v2"})]
    assert ingest_trace(lines2).n == 3


def test_self_looping_block_reentry():
    """A single-block loop executed back-to-back re-enters the block: the
    pp index rewinds to the run's first index, which is legal (real
    dynamic traces of self-looping blocks look exactly like this)."""
    lines = [rec(bb="loop", pp="f:loop:i0", op="add", **{"def": "v0"}),
             rec(bb="loop", pp="f:loop:i1", op="icmp", **{"def": "v1"},
                 uses=["v0"]),
             rec(bb="loop", pp="f:loop:i0", op="add", **{"def": "v0"},
                 uses=["v0"]),
             rec(bb="loop", pp="f:loop:i1", op="icmp", **{"def": "v1"},
                 uses=["v0"])]
    g, st = ingest_trace_with_stats(lines)
    assert st.records == 4 and st.skipped == 0
    # iteration 2's add uses iteration 1's def (rolling def-table)
    assert (0, 2) in set(zip(g.src.tolist(), g.dst.tolist()))
    # a CFG with a loop self-edge allows it; one without flags it
    with_self = ['{"kind":"block","fn":"f","bb":"loop",'
                 '"succs":["loop","exit"]}']
    assert ingest_trace(lines, cfg=with_self).n == 4
    no_self = ['{"kind":"block","fn":"f","bb":"loop","succs":["exit"]}']
    with pytest.raises(TraceFormatError, match="not a CFG edge"):
        ingest_trace(lines, cfg=no_self)
    # a rewind that is NOT a restart from the first index stays an error
    bad = lines[:2] + [rec(bb="loop", pp="f:loop:i1", op="x", uses=[])]
    with pytest.raises(TraceFormatError, match="out-of-order"):
        ingest_trace(bad)


def test_use_tys_elements_validated():
    bad = [rec(op="add", **{"def": "v0"}, uses=["x"], use_tys=[7])]
    with pytest.raises(TraceFormatError, match="use_tys"):
        ingest_trace(bad)
    g, st = ingest_trace_with_stats(bad, on_error="skip")
    assert st.skipped == 1 and g.n == 0      # atomic: nothing half-added
    # null elements are legal: fall through to the default weight
    ok = [rec(op="add", **{"def": "v0"}, uses=["x", "y"],
              use_tys=[None, "i32"])]
    assert ingest_trace(ok).w.tolist() == [8.0, 4.0]


def test_cfg_missing_field_reports_line():
    from repro.trace import load_cfg
    with pytest.raises(TraceFormatError, match="line 2.*missing field"):
        load_cfg(['{"kind":"block","fn":"f","bb":"b0","succs":[]}',
                  '{"kind":"edge","fn":"f","to":"b1"}'])


def test_blank_lines_and_cfg_records_skipped():
    lines = ["", "   ",
             '{"kind":"block","fn":"f","bb":"b0","succs":["b1"]}',
             rec(**{"def": "v0"})]
    g, st = ingest_trace_with_stats(lines)
    assert g.n == 1 and st.cfg_records == 1 and st.skipped == 0


def test_cfg_block_ordering_validation():
    cfg = ['{"kind":"block","fn":"f","bb":"b0","succs":["b1"]}',
           '{"kind":"block","fn":"f","bb":"b1","succs":["b0","b2"]}']
    ok = [rec(bb="b0", pp="f:b0:i0", **{"def": "v0"}),
          rec(bb="b1", pp="f:b1:i0", **{"def": "v1"}),
          rec(bb="b0", pp="f:b0:i0", **{"def": "v2"})]
    assert ingest_trace(ok, cfg=cfg).n == 3
    bad = [ok[0], rec(bb="b2", pp="f:b2:i0", **{"def": "v1"})]
    with pytest.raises(TraceFormatError, match="not a CFG edge"):
        ingest_trace(bad, cfg=cfg)
    g, st = ingest_trace_with_stats(bad, cfg=cfg, on_error="skip")
    assert st.cfg_violations == 1 and g.n == 1


# ---------------------------------------------------------------------- #
# streaming invariance (chunking must never change the graph)
# ---------------------------------------------------------------------- #
def test_chunk_invariance_and_buffer_bound():
    lines = list(iter_synthetic_trace(3000, seed=7))
    ref = ingest_trace(lines, chunk_edges=1 << 30)
    for chunk in (1, 64, 1023):
        g, st = ingest_trace_with_stats(lines, chunk_edges=chunk)
        assert g.n == ref.n
        assert np.array_equal(g.src, ref.src)
        assert np.array_equal(g.dst, ref.dst)
        assert np.array_equal(g.w, ref.w)
        # the Python buffer never grows past chunk + one record's uses
        assert st.peak_chunk_edges <= chunk + 8


def test_synthetic_trace_deterministic_and_powerlaw():
    a = list(iter_synthetic_trace(2000, seed=1))
    b = list(iter_synthetic_trace(2000, seed=1))
    assert a == b
    g = ingest_trace(a)
    assert g.num_edges > 2000          # ~1.85 uses/record
    assert 1.1 < g.power_law_alpha() < 4.0


# ---------------------------------------------------------------------- #
# CFG replay: static listing -> dynamic graph
# ---------------------------------------------------------------------- #
STATIC = [
    rec(bb="entry", pp="f:entry:i0", op="load", **{"def": "v0"},
        uses=["arg0"], use_tys=["ptr"]),
    rec(bb="loop", pp="f:loop:i0", op="add", **{"def": "v1"},
        uses=["v0", "v1"], use_tys=["i32", "i32"]),
    rec(bb="exit", pp="f:exit:i0", op="ret", uses=["v1"], use_tys=["i32"]),
]
CFG_LINES = [
    '{"kind":"block","fn":"f","bb":"entry","succs":["loop"]}',
    '{"kind":"block","fn":"f","bb":"loop","succs":["loop","exit"]}',
    '{"kind":"path","fn":"f","path_id":0,'
    '"bbs":["entry","loop","loop","loop","exit"]}',
]


def test_replay_expands_loop_iterations():
    g, st = replay_trace(STATIC, CFG_LINES, keep_labels=True)
    # load + 3 loop adds + ret + liveins (arg0, first-iteration v1)
    assert st.records == 5 and g.n == 7
    labels = list(g.node_labels)
    adds = [i for i, lb in enumerate(labels) if lb == "add"]
    assert len(adds) == 3
    # loop-carried dependency: add_k uses add_{k-1}'s def
    edges = set(zip(g.src.tolist(), g.dst.tolist()))
    assert (adds[0], adds[1]) in edges and (adds[1], adds[2]) in edges
    # first iteration's v1 use is a live-in vertex, not a future def
    assert (labels.index("v1"), adds[0]) in edges


def test_replay_repeat_and_filters():
    g1, st1 = replay_trace(STATIC, CFG_LINES)
    g2, st2 = replay_trace(STATIC, CFG_LINES, repeat=3)
    assert st2.records == 3 * st1.records
    g3, st3 = replay_trace(STATIC, CFG_LINES, fn="other")
    assert st3.records == 0 and g3.n == 0
    g4, st4 = replay_trace(STATIC, CFG_LINES, path_ids=[99])
    assert st4.records == 0


# ---------------------------------------------------------------------- #
# type parsing + pipeline threading + CLI
# ---------------------------------------------------------------------- #
def test_type_bytes_palette():
    assert type_bytes("i1") == 1.0 and type_bytes("i32") == 4.0
    assert type_bytes("double") == 8.0 and type_bytes("float") == 4.0
    assert type_bytes("ptr") == 8.0 and type_bytes("i8*") == 8.0
    assert type_bytes("<4 x float>") == 16.0
    assert type_bytes("[16 x i8]") == 16.0
    assert type_bytes("[2 x <4 x i32>]") == 32.0
    assert type_bytes("%struct.opaque") == 8.0      # default
    assert type_bytes(None) == 8.0


def test_load_graph_and_run_pipeline_paths(tmp_path):
    trace = tmp_path / "t.ndjson"
    trace.write_text("\n".join(iter_synthetic_trace(500, seed=2)) + "\n")
    g = load_graph(str(trace))
    npz = tmp_path / "t.npz"
    g.save_npz(str(npz))
    for source in (str(trace), str(npz)):
        part, mapping, rep = run_pipeline(source, 4, "wb_libra")
        assert rep.p == 4 and rep.exec_time > 0
    with pytest.raises(TypeError):
        run_pipeline(123, 4, "wb_libra")


def test_gzip_source_round_trips(tmp_path):
    """A .ndjson.gz path must ingest identically to the plain text file
    (transparent decompression, same stats), end to end through
    `load_graph` and the CLI."""
    import gzip
    text = "\n".join(iter_synthetic_trace(800, seed=5)) + "\n"
    plain = tmp_path / "t.ndjson"
    plain.write_text(text)
    gz = tmp_path / "t.ndjson.gz"
    with gzip.open(gz, "wt", encoding="utf-8") as f:
        f.write(text)
    g_plain, st_plain = ingest_trace_with_stats(str(plain))
    g_gz, st_gz = ingest_trace_with_stats(str(gz))
    assert st_gz.summary() == st_plain.summary()
    assert g_gz.n == g_plain.n
    assert np.array_equal(g_gz.src, g_plain.src)
    assert np.array_equal(g_gz.dst, g_plain.dst)
    assert np.array_equal(g_gz.w, g_plain.w)
    # the pipeline path dispatch accepts the gzipped trace too
    part, mapping, rep = run_pipeline(str(gz), 4, "wb_libra")
    assert rep.p == 4 and rep.exec_time > 0
    from repro.trace.__main__ import main
    assert main(["inspect", str(gz)]) == 0


def test_zstd_source_round_trips(tmp_path):
    """A .ndjson.zst path must ingest identically to the plain text file,
    mirroring the gzip path (soft dep: skipped without `zstandard`)."""
    zstandard = pytest.importorskip(
        "zstandard", reason="zstd line source needs the zstandard package")
    text = "\n".join(iter_synthetic_trace(800, seed=5)) + "\n"
    plain = tmp_path / "t.ndjson"
    plain.write_text(text)
    zst = tmp_path / "t.ndjson.zst"
    with open(zst, "wb") as f:
        f.write(zstandard.ZstdCompressor().compress(text.encode("utf-8")))
    g_plain, st_plain = ingest_trace_with_stats(str(plain))
    g_zst, st_zst = ingest_trace_with_stats(str(zst))
    assert st_zst.summary() == st_plain.summary()
    assert g_zst.n == g_plain.n
    assert np.array_equal(g_zst.src, g_plain.src)
    assert np.array_equal(g_zst.dst, g_plain.dst)
    assert np.array_equal(g_zst.w, g_plain.w)
    # the pipeline path dispatch and the CLI accept the zstd trace too
    part, mapping, rep = run_pipeline(str(zst), 4, "wb_libra")
    assert rep.p == 4 and rep.exec_time > 0
    from repro.trace.__main__ import main
    assert main(["inspect", str(zst)]) == 0
    # and the sharded parallel parse decompresses it transparently
    from repro.dist import dist_ingest_with_stats
    g_dist, _ = dist_ingest_with_stats(str(zst), workers=3, pool="serial")
    assert np.array_equal(g_dist.src, g_plain.src)
    assert np.array_equal(g_dist.w, g_plain.w)


def test_zstd_missing_dependency_error(tmp_path, monkeypatch):
    """Without `zstandard`, a .zst path fails with an actionable message
    instead of deep inside the stream loop."""
    import builtins
    import sys
    if "zstandard" in sys.modules:      # pragma: no cover - env dependent
        pytest.skip("zstandard installed; error path not reachable")
    real_import = builtins.__import__

    def no_zstd(name, *a, **kw):
        if name == "zstandard":
            raise ImportError("No module named 'zstandard'")
        return real_import(name, *a, **kw)

    monkeypatch.setattr(builtins, "__import__", no_zstd)
    path = tmp_path / "t.ndjson.zst"
    path.write_bytes(b"")
    with pytest.raises(ImportError, match="zstandard"):
        ingest_trace_with_stats(str(path))


def test_committed_example_traces():
    import pathlib
    tdir = pathlib.Path(__file__).resolve().parent.parent / "examples/traces"
    trace, cfg = tdir / "toy_loop.ndjson", tdir / "toy_loop.cfg.ndjson"
    g, st = ingest_trace_with_stats(str(trace), cfg=str(cfg))
    assert st.records == 10 and st.cfg_violations == 0
    g2, st2 = replay_trace(str(trace), str(cfg))
    assert st2.records == 31          # entry + 4 loop iterations + exit
    # the recorded jaxpr example must round-trip against the live tracer
    from repro.core.jaxpr_graph import trace_to_graph
    from repro.trace import demo_program
    fn, args = demo_program("mlp")
    ref = trace_to_graph(fn, *args, name="mlp")
    g3 = ingest_trace(str(tdir / "mlp_jaxpr.ndjson"))
    assert g3.n == ref.n
    assert np.array_equal(g3.src, ref.src)
    assert np.array_equal(g3.dst, ref.dst)
    assert np.allclose(g3.w, ref.w, rtol=1e-12, atol=0.0)


def test_cli_subcommands(tmp_path, capsys):
    from repro.trace.__main__ import main
    trace = tmp_path / "t.ndjson"
    assert main(["synth", str(trace), "--lines", "400"]) == 0
    assert main(["inspect", str(trace)]) == 0
    out = capsys.readouterr().out
    assert '"records": 400' in out
    npz = tmp_path / "t.npz"
    assert main(["convert", str(trace), str(npz)]) == 0
    assert load_graph(str(npz)).n > 0
    assert main(["partition", str(trace), "-p", "4"]) == 0
    assert '"replication_factor"' in capsys.readouterr().out
