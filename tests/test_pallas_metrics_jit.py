"""Compile-count probes for the jitted pallas metrics glue.

The metrics layer pads every traced core to power-of-two shape buckets
precisely so that novel graph shapes stop paying op-by-op compiles.
These tests hold it to that: same-bucket inputs must be pure cache hits
(zero new traces), probed through `metrics.trace_count()` — a counter
bumped only while jax traces a core.
"""
import numpy as np
import pytest

pytest.importorskip("jax", reason="pallas layer needs jax")

from repro.core import synthesize_powerlaw_graph, vertex_cut  # noqa: E402
from repro.core.mapping import cluster_interaction_graphs  # noqa: E402
from repro.core.pallas import metrics, pallas_available  # noqa: E402
from repro.core.simulator import vertex_bytes_model  # noqa: E402

pytestmark = pytest.mark.skipif(
    not pallas_available(), reason="pallas segment-sum layer unavailable")

P = 16


def test_replica_csr_cache_hits_across_same_bucket_graphs():
    # n in (900, 950) shares the 1024 vertex bucket; edge counts land in
    # the same padded stream bucket too
    g1 = synthesize_powerlaw_graph(n=900, alpha=2.2, seed=0)
    g2 = synthesize_powerlaw_graph(n=950, alpha=2.2, seed=7)
    r1 = vertex_cut(g1, P, backend="pallas")        # warm the cache
    before = metrics.trace_count("replica_csr")
    assert before >= 1
    r2 = vertex_cut(g2, P, backend="pallas")
    assert metrics.trace_count("replica_csr") == before, \
        "same-bucket graph re-traced replica_csr (padding regressed)"
    # and the cached result still matches the numpy oracle
    for g, r in ((g1, r1), (g2, r2)):
        ref = vertex_cut(g, P, backend="fast")
        np.testing.assert_array_equal(r.assignment, ref.assignment)
        np.testing.assert_array_equal(r.replica_indptr, ref.replica_indptr)
        np.testing.assert_array_equal(r.replica_flat, ref.replica_flat)
        np.testing.assert_array_equal(r.loads, ref.loads)


def test_star_and_interaction_cache_hits_on_repeat():
    g = synthesize_powerlaw_graph(n=700, alpha=2.2, seed=3)
    cut = vertex_cut(g, P, backend="pallas")
    vb = vertex_bytes_model(g)
    c1, s1 = cluster_interaction_graphs(cut, P, vb, backend="pallas")
    before = metrics.trace_count()
    c2, s2 = cluster_interaction_graphs(cut, P, vb, backend="pallas")
    assert metrics.trace_count() == before, \
        "identical interaction inputs re-traced a metrics core"
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    # oracle equality (bit-identical contract of the pallas layer)
    cf, sf = cluster_interaction_graphs(cut, P, vb, backend="fast")
    np.testing.assert_array_equal(np.asarray(c1), cf)
    np.testing.assert_array_equal(np.asarray(s1), sf)


def test_star_triples_bucketed_cache():
    g1 = synthesize_powerlaw_graph(n=500, alpha=2.2, seed=1)
    g2 = synthesize_powerlaw_graph(n=480, alpha=2.2, seed=9)
    cut1 = vertex_cut(g1, P, backend="fast")
    cut2 = vertex_cut(g2, P, backend="fast")
    metrics.star_triples(*cut1.replica_csr(),
                         vertex_bytes_model(g1))     # warm
    before = metrics.trace_count("star_triples")
    o, r, b = metrics.star_triples(*cut2.replica_csr(),
                                   vertex_bytes_model(g2))
    assert metrics.trace_count("star_triples") == before
    from repro.core._arrayops import star_triples as np_star
    on, rn, bn = np_star(*cut2.replica_csr(), vertex_bytes_model(g2))
    np.testing.assert_array_equal(np.asarray(o), on)
    np.testing.assert_array_equal(np.asarray(r), rn)
    np.testing.assert_array_equal(np.asarray(b), bn)


def test_trace_count_monotone_and_queryable():
    assert metrics.trace_count() >= metrics.trace_count("replica_csr") >= 0
    assert metrics.trace_count("no_such_core") == 0
