"""End-to-end `backend="pallas"` equivalence: partition→metrics→mapping.

The Pallas engine must be indistinguishable from the numpy backends at
the pipeline's observable outputs: identical cut (assignment, loads,
replica CSR), bit-identical `core_of`, and a `SimReport` within rtol
1e-12 of the reference oracle (core_times are bit-identical to the fast
engine — only the total-bytes reduction may reassociate).  Runs over
the seeded sweep graphs from the backend-equivalence suite plus one
real ingested NDJSON trace from `examples/traces/`.
"""
import os

import numpy as np
import pytest

pytest.importorskip("jax", reason="pallas layer needs jax")
from repro.core.pallas import pallas_available  # noqa: E402

if not pallas_available():
    pytest.skip("pallas segment-sum probe failed on this jax install",
                allow_module_level=True)

from repro.core import run_pipeline, synthesize_powerlaw_graph  # noqa: E402
from test_backend_equivalence import GRAPHS  # noqa: E402

TRACES = os.path.join(os.path.dirname(__file__), "..", "examples", "traces")
SWEEP_GRAPHS = GRAPHS + [synthesize_powerlaw_graph(n=3000, alpha=2.2, seed=1)]


def _assert_pipeline_equivalent(g, p, method="wb_libra", lam=1.0):
    ref_part, ref_map, ref_rep = run_pipeline(g, p, method, lam=lam,
                                              backend="reference")
    fast_part, fast_map, fast_rep = run_pipeline(g, p, method, lam=lam,
                                                 backend="fast")
    pal_part, pal_map, pal_rep = run_pipeline(g, p, method, lam=lam,
                                              backend="pallas")
    # cut: identical to both numpy engines
    np.testing.assert_array_equal(pal_part.assignment, ref_part.assignment)
    np.testing.assert_array_equal(pal_part.loads, ref_part.loads)
    np.testing.assert_array_equal(pal_part.edge_counts,
                                  ref_part.edge_counts)
    np.testing.assert_array_equal(pal_part.replica_indptr,
                                  fast_part.replica_indptr)
    np.testing.assert_array_equal(pal_part.replica_flat,
                                  fast_part.replica_flat)
    # mapping: bit-identical core_of
    np.testing.assert_array_equal(pal_map.core_of, ref_map.core_of)
    np.testing.assert_array_equal(pal_map.core_of, fast_map.core_of)
    # simulator: rtol 1e-12 vs the oracle, bit-identical vs fast
    for field in ("exec_time", "data_comm_bytes", "sync_time", "sync_bytes"):
        np.testing.assert_allclose(getattr(pal_rep, field),
                                   getattr(ref_rep, field),
                                   rtol=1e-12, err_msg=field)
    np.testing.assert_allclose(pal_rep.core_times, ref_rep.core_times,
                               rtol=1e-12)
    np.testing.assert_array_equal(pal_rep.core_times, fast_rep.core_times)


def test_sweep_graphs_pallas_equivalent_p8():
    for g in SWEEP_GRAPHS:
        _assert_pipeline_equivalent(g, 8)


def test_sweep_graphs_pallas_equivalent_p64():
    # two shapes at the larger p keep the jit-cache footprint (and the
    # tier-1 wall clock) bounded: the hub-heavy graph stresses big
    # replica sets, the power-law graph the realistic degree tail
    for g in (SWEEP_GRAPHS[2], SWEEP_GRAPHS[-1]):
        _assert_pipeline_equivalent(g, 64)


def test_methods_and_lambda_pallas_equivalent():
    g = SWEEP_GRAPHS[0]
    for method, lam in (("w_pg", 1.0), ("libra", 1.0), ("wb_libra", 1.25)):
        _assert_pipeline_equivalent(g, 8, method=method, lam=lam)


def test_ingested_trace_pallas_equivalent():
    """One real NDJSON trace through the full path, all three backends."""
    trace = os.path.join(TRACES, "toy_loop.ndjson")
    _assert_pipeline_equivalent(trace, 8)


def test_pallas_backend_validation():
    from repro.core import resolve_backend, resolve_mapping_backend
    assert resolve_backend("pallas") == "pallas"
    assert resolve_mapping_backend("pallas") == "pallas"
    assert resolve_mapping_backend("native") == "fast"
