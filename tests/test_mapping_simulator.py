"""Memory-centric mapping (Algorithm 2) + NUMA simulator behaviour."""
import numpy as np
import pytest

from repro.core import (IRGraph, Machine, MappingResult, build_graph,
                        cluster_interaction_graphs, edge_cut,
                        memory_centric_mapping, round_robin_mapping,
                        run_pipeline, simulate, synthesize_powerlaw_graph,
                        vertex_bytes_model, vertex_cut)
from repro.core.edge_cut import EdgeCutResult
from repro.core.simulator import (CACHE_LINE, INSTR_COST, SYNC_BASE,
                                  SYNC_MSG_BYTES, WEIGHT_TO_SECONDS)


@pytest.fixture(scope="module")
def g():
    return build_graph("kmeans", scale="reduced", cache_dir=None)


def test_machine_geometry():
    m = Machine(rows=4, cols=4)
    assert m.n_cores == 16
    assert m.hops(0, 15) == 6  # (0,0) -> (3,3) XY route
    assert m.hops(5, 5) == 0
    regions = {m.region_of(c) for c in range(16)}
    assert len(regions) == 4  # quadrant decomposition


def test_machine_vectorized_views_match_scalar():
    for rows, cols, nr in [(4, 4, 4), (2, 3, 6), (5, 2, 5), (1, 7, 3)]:
        m = Machine(rows=rows, cols=cols, n_regions=nr)
        hops = m.hop_matrix()
        regs = m.region_array()
        for a in range(m.n_cores):
            assert regs[a] == m.region_of(a)
            for b in range(m.n_cores):
                assert hops[a, b] == m.hops(a, b)


def test_region_of_non_square_meshes():
    """Non-perfect-square n_regions must not drop region ids (the old
    rr·cc grid lost regions, e.g. n_regions=5 -> 2x2 = 4 ids)."""
    cases = [(2, 3, 6), (3, 2, 6), (5, 1, 5), (1, 5, 5), (4, 2, 8),
             (3, 4, 6), (4, 3, 12), (7, 1, 7)]
    for rows, cols, nr in cases:
        m = Machine(rows=rows, cols=cols, n_regions=nr)
        regions = {m.region_of(c) for c in range(m.n_cores)}
        assert regions == set(range(nr)), (rows, cols, nr, regions)
    # meshes smaller than the region grid still produce valid, in-range ids
    m = Machine(rows=2, cols=2, n_regions=16)
    assert all(0 <= m.region_of(c) < 16 for c in range(4))


def test_machine_for_clusters_caps_cores():
    m = Machine.for_clusters(1024, max_cores=64)
    assert m.n_cores == 64
    assert m.cluster_threshold >= 16  # 1024 clusters must fit


def test_mapping_spreads_when_cores_available(g):
    p = 8
    cut = vertex_cut(g, p, method="wb_libra")
    comm, shared = cluster_interaction_graphs(cut.replicas, p,
                                              vertex_bytes_model(g))
    mapping = memory_centric_mapping(comm, shared, Machine.for_clusters(p))
    # with >= p cores, parallelism should not collapse
    assert mapping.cores_used >= p // 2
    assert len(mapping.core_of) == p
    counts = np.bincount(mapping.core_of,
                         minlength=mapping.machine.n_cores)
    assert counts.max() <= mapping.machine.cluster_threshold


def test_mapping_respects_threshold(g):
    p = 32
    cut = vertex_cut(g, p, method="wb_libra")
    comm, shared = cluster_interaction_graphs(cut.replicas, p)
    mach = Machine(rows=2, cols=2, cluster_threshold=8)
    mapping = memory_centric_mapping(comm, shared, mach)
    counts = np.bincount(mapping.core_of, minlength=4)
    assert counts.max() <= 8


def test_memory_centric_beats_round_robin_on_comm(g):
    """Factor-2 adjacency should reduce average message distance."""
    p = 16
    cut = vertex_cut(g, p, method="wb_libra")
    comm, shared = cluster_interaction_graphs(cut.replicas, p,
                                              vertex_bytes_model(g))
    mach = Machine(rows=4, cols=4)
    smart = memory_centric_mapping(comm, shared, mach)
    naive = round_robin_mapping(p, mach)

    def weighted_hops(mapping):
        tot = 0.0
        for i in range(p):
            for j in range(p):
                if comm[i, j] > 0:
                    tot += comm[i, j] * mach.hops(
                        int(mapping.core_of[i]), int(mapping.core_of[j]))
        return tot

    assert weighted_hops(smart) <= weighted_hops(naive) * 1.05


def test_simulator_parallel_speedup(g):
    """More clusters -> shorter simulated time (up to core budget)."""
    _, _, r2 = run_pipeline(g, 2, "wb_libra")
    _, _, r16 = run_pipeline(g, 16, "wb_libra")
    assert r16.exec_time < r2.exec_time


def test_simulator_vertex_cut_comm_less_than_edge_cut(g):
    """§6.2.4 headline: vertex-cut traffic (replica sync) is lower than
    edge-cut traffic (all cut edges) on power-law trace graphs."""
    p = 8
    _, _, vc = run_pipeline(g, p, "wb_libra")
    _, _, ec = run_pipeline(g, p, "compnet")
    assert vc.data_comm_bytes < ec.data_comm_bytes


def test_simulate_type_dispatch(g):
    p = 4
    cut = vertex_cut(g, p, method="wb_libra")
    comm, shared = cluster_interaction_graphs(cut.replicas, p)
    mapping = memory_centric_mapping(comm, shared, Machine.for_clusters(p))
    rep = simulate(g, cut, mapping)
    assert rep.exec_time > 0
    ec = edge_cut(g, p, method="metis")
    rep2 = simulate(g, ec, mapping)
    assert rep2.exec_time > 0
    with pytest.raises(TypeError):
        simulate(g, "not a partition", mapping)


def test_edge_cut_methods(g):
    for method in ("compnet", "metis"):
        r = edge_cut(g, 8, method=method)
        assert len(r.parts) == g.n
        assert r.parts.min() >= 0 and r.parts.max() < 8
        assert 0 <= r.cut_weight <= g.total_weight
    with pytest.raises(ValueError):
        edge_cut(g, 8, method="nope")


# ---------------------------------------------------------------------- #
# factor-3 region avoidance (the formerly dead `avoid` branch)
# ---------------------------------------------------------------------- #
def test_factor3_avoids_strongest_peer_region():
    """An independent cluster with a weak (sub-colocation) interaction
    peer must land in a different mesh region than that peer."""
    p = 5
    mach = Machine(rows=4, cols=4, n_regions=4, cluster_threshold=4)
    comm = np.zeros((p, p))
    shared = np.zeros((p, p))
    # cluster 4 weakly shares data with cluster 0: below the colocation
    # threshold (0.4 < 0.5 * min(own)=1), zero comm -> factor 3 applies
    shared[4, 0] = shared[0, 4] = 0.4
    order = np.arange(p)
    for backend in ("fast", "reference"):
        mapping = memory_centric_mapping(comm, shared, mach,
                                         cluster_order=order,
                                         backend=backend)
        reg = [mach.region_of(int(c)) for c in mapping.core_of]
        # clusters 0-3 are fully independent: round-robin across regions
        assert sorted(reg[:4]) == [0, 1, 2, 3]
        # cluster 4 is placed when the round-robin cursor is back at
        # cluster 0's region — only the avoidance keeps them apart
        assert reg[4] != reg[0]


# ---------------------------------------------------------------------- #
# golden-value simulator tests (hand-checked small graphs)
# ---------------------------------------------------------------------- #
def _two_edge_cut():
    """Path 0->1->2 cut into clusters {e01}->0, {e12}->1 (wb_libra in
    trace order: the lambda bound forces edge 2 into a fresh cluster)."""
    g = IRGraph(n=3, src=np.array([0, 1]), dst=np.array([1, 2]),
                w=np.array([1.0, 1.0]), name="path3")
    cut = vertex_cut(g, 2, method="wb_libra", edge_order="trace")
    np.testing.assert_array_equal(cut.assignment, [0, 1])
    return g, cut


@pytest.mark.parametrize("backend", ["fast", "reference"])
def test_simulator_golden_replica_sync(backend):
    """One cut vertex (1), owner on core 0, replica on core 1, 1 hop."""
    g, cut = _two_edge_cut()
    mach = Machine(rows=1, cols=2, n_regions=2)
    mapping = MappingResult(machine=mach,
                            core_of=np.array([0, 1], dtype=np.int32), p=2)
    rep = simulate(g, cut, mapping, backend=backend)

    sync_rounds = 2 * 1.0                      # p log2 p, p=2
    sync_bytes = sync_rounds * SYNC_MSG_BYTES  # p/256 < 1 -> factor 1
    sync_time = sync_rounds * SYNC_BASE / 2
    assert rep.sync_bytes == pytest.approx(sync_bytes)
    assert rep.sync_time == pytest.approx(sync_time)
    # replica sync: vertex 1 is in both clusters -> one 64B line moves
    assert rep.data_comm_bytes == pytest.approx(CACHE_LINE + sync_bytes)
    per_cluster = 1.0 * WEIGHT_TO_SECONDS + INSTR_COST
    lat = 1 * mach.hop_latency + mach.coherence_penalty
    wait = lat / mach.mshr_overlap + CACHE_LINE / mach.link_bw
    assert rep.core_times == pytest.approx([per_cluster, per_cluster + wait])
    assert rep.exec_time == pytest.approx(per_cluster + wait + sync_time)


@pytest.mark.parametrize("backend", ["fast", "reference"])
def test_simulator_golden_colocation_zeroes_replica_traffic(backend):
    """Same cut, both clusters on one core: no replica bytes move, but
    the clusters serialize (factor-1 trade-off made explicit)."""
    g, cut = _two_edge_cut()
    mach = Machine(rows=1, cols=2, n_regions=2)
    mapping = MappingResult(machine=mach,
                            core_of=np.array([0, 0], dtype=np.int32), p=2)
    rep = simulate(g, cut, mapping, backend=backend)
    assert rep.data_comm_bytes == pytest.approx(rep.sync_bytes)
    per_cluster = 1.0 * WEIGHT_TO_SECONDS + INSTR_COST
    assert rep.core_times == pytest.approx([2 * per_cluster, 0.0])
    assert rep.exec_time == pytest.approx(2 * per_cluster + rep.sync_time)


def test_simulator_golden_edge_cut():
    """One cut edge between adjacent cores moves one cache line."""
    g = IRGraph(n=2, src=np.array([0]), dst=np.array([1]),
                w=np.array([2.0]), name="one_edge")
    part = EdgeCutResult(graph_name="one_edge", method="manual", p=2,
                         parts=np.array([0, 1], dtype=np.int32),
                         loads=np.array([0.0, 2.0]), cut_weight=2.0,
                         cut_edges=1, total_weight=2.0)
    mach = Machine(rows=1, cols=2, n_regions=2)
    mapping = MappingResult(machine=mach,
                            core_of=np.array([0, 1], dtype=np.int32), p=2)
    rep = simulate(g, part, mapping)
    assert rep.data_comm_bytes == pytest.approx(CACHE_LINE + rep.sync_bytes)
    lat = 1 * mach.hop_latency + mach.coherence_penalty
    wait = lat / mach.mshr_overlap + CACHE_LINE / mach.link_bw
    per_edge = 2.0 * WEIGHT_TO_SECONDS + INSTR_COST
    # the edge executes at its consumer's cluster (core 1)
    assert rep.core_times == pytest.approx([0.0, per_edge + wait])


# ---------------------------------------------------------------------- #
# fast-vs-reference equivalence on the full pipeline
# ---------------------------------------------------------------------- #
def _sim_reports_close(a, b):
    assert np.isclose(a.exec_time, b.exec_time, rtol=1e-12)
    assert np.isclose(a.data_comm_bytes, b.data_comm_bytes, rtol=1e-12)
    assert np.isclose(a.sync_time, b.sync_time, rtol=1e-12)
    assert np.isclose(a.sync_bytes, b.sync_bytes, rtol=1e-12)
    np.testing.assert_allclose(a.core_times, b.core_times, rtol=1e-12)


@pytest.mark.parametrize("p", [4, 16, 64])
def test_interaction_graphs_backends_agree(g, p):
    cut = vertex_cut(g, p, method="wb_libra")
    vb = vertex_bytes_model(g)
    cf, sf = cluster_interaction_graphs(cut, p, vb, backend="fast")
    cr, sr = cluster_interaction_graphs(cut.replicas, p, vb,
                                        backend="reference")
    np.testing.assert_allclose(cf, cr, rtol=1e-12)
    np.testing.assert_array_equal(sf, sr)   # integer counts: exact
    # the legacy list-of-sets input feeds the fast path too
    cl, sl = cluster_interaction_graphs(cut.replicas, p, vb, backend="fast")
    np.testing.assert_allclose(cl, cf, rtol=1e-12)
    np.testing.assert_array_equal(sl, sf)


@pytest.mark.parametrize("method", ["wb_libra", "w_pg", "compnet"])
def test_pipeline_backends_agree(g, method):
    """Fast and reference pipelines produce identical mapping + report
    for vertex- and edge-cut partitions."""
    _, mf, rf = run_pipeline(g, 16, method, backend="fast")
    _, mr, rr = run_pipeline(g, 16, method, backend="reference")
    np.testing.assert_array_equal(mf.core_of, mr.core_of)
    _sim_reports_close(rf, rr)


def test_simulate_backend_validation(g):
    cut = vertex_cut(g, 4, method="wb_libra")
    mapping = memory_centric_mapping(
        *cluster_interaction_graphs(cut, 4), Machine.for_clusters(4))
    with pytest.raises(ValueError):
        simulate(g, cut, mapping, backend="bogus")
    with pytest.raises(ValueError):
        memory_centric_mapping(np.zeros((2, 2)), np.zeros((2, 2)),
                               backend="bogus")
    with pytest.raises(ValueError):
        cluster_interaction_graphs(cut, 4, backend="bogus")


# ---------------------------------------------------------------------- #
# quality regression: algorithmic wins must not silently rot
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("p", [8, 64])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_wb_libra_beats_round_robin_and_random(p, seed):
    """WB-Libra + memory-centric mapping must beat (a) the same cut on a
    locality-oblivious round-robin mapping and (b) a random edge
    placement, on power-law graphs at p in {8, 64} — a deterministic
    floor under the paper's Tables 6-9 claims.  Fully seeded, so a
    failure is an algorithmic regression, not flakiness."""
    pg = synthesize_powerlaw_graph(n=4000, alpha=2.2, seed=seed)
    cut, mapping, rep = run_pipeline(pg, p, "wb_libra")
    naive = simulate(pg, cut, round_robin_mapping(p, mapping.machine))
    assert rep.exec_time <= naive.exec_time
    _, _, rnd = run_pipeline(pg, p, "random", seed=seed)
    assert rep.exec_time < rnd.exec_time
    assert rep.data_comm_bytes <= rnd.data_comm_bytes
