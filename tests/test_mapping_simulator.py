"""Memory-centric mapping (Algorithm 2) + NUMA simulator behaviour."""
import numpy as np
import pytest

from repro.core import (Machine, build_graph, cluster_interaction_graphs,
                        edge_cut, memory_centric_mapping,
                        round_robin_mapping, run_pipeline, simulate,
                        vertex_bytes_model, vertex_cut)


@pytest.fixture(scope="module")
def g():
    return build_graph("kmeans", scale="reduced", cache_dir=None)


def test_machine_geometry():
    m = Machine(rows=4, cols=4)
    assert m.n_cores == 16
    assert m.hops(0, 15) == 6  # (0,0) -> (3,3) XY route
    assert m.hops(5, 5) == 0
    regions = {m.region_of(c) for c in range(16)}
    assert len(regions) == 4  # quadrant decomposition


def test_machine_for_clusters_caps_cores():
    m = Machine.for_clusters(1024, max_cores=64)
    assert m.n_cores == 64
    assert m.cluster_threshold >= 16  # 1024 clusters must fit


def test_mapping_spreads_when_cores_available(g):
    p = 8
    cut = vertex_cut(g, p, method="wb_libra")
    comm, shared = cluster_interaction_graphs(cut.replicas, p,
                                              vertex_bytes_model(g))
    mapping = memory_centric_mapping(comm, shared, Machine.for_clusters(p))
    # with >= p cores, parallelism should not collapse
    assert mapping.cores_used >= p // 2
    assert len(mapping.core_of) == p
    counts = np.bincount(mapping.core_of,
                         minlength=mapping.machine.n_cores)
    assert counts.max() <= mapping.machine.cluster_threshold


def test_mapping_respects_threshold(g):
    p = 32
    cut = vertex_cut(g, p, method="wb_libra")
    comm, shared = cluster_interaction_graphs(cut.replicas, p)
    mach = Machine(rows=2, cols=2, cluster_threshold=8)
    mapping = memory_centric_mapping(comm, shared, mach)
    counts = np.bincount(mapping.core_of, minlength=4)
    assert counts.max() <= 8


def test_memory_centric_beats_round_robin_on_comm(g):
    """Factor-2 adjacency should reduce average message distance."""
    p = 16
    cut = vertex_cut(g, p, method="wb_libra")
    comm, shared = cluster_interaction_graphs(cut.replicas, p,
                                              vertex_bytes_model(g))
    mach = Machine(rows=4, cols=4)
    smart = memory_centric_mapping(comm, shared, mach)
    naive = round_robin_mapping(p, mach)

    def weighted_hops(mapping):
        tot = 0.0
        for i in range(p):
            for j in range(p):
                if comm[i, j] > 0:
                    tot += comm[i, j] * mach.hops(
                        int(mapping.core_of[i]), int(mapping.core_of[j]))
        return tot

    assert weighted_hops(smart) <= weighted_hops(naive) * 1.05


def test_simulator_parallel_speedup(g):
    """More clusters -> shorter simulated time (up to core budget)."""
    _, _, r2 = run_pipeline(g, 2, "wb_libra")
    _, _, r16 = run_pipeline(g, 16, "wb_libra")
    assert r16.exec_time < r2.exec_time


def test_simulator_vertex_cut_comm_less_than_edge_cut(g):
    """§6.2.4 headline: vertex-cut traffic (replica sync) is lower than
    edge-cut traffic (all cut edges) on power-law trace graphs."""
    p = 8
    _, _, vc = run_pipeline(g, p, "wb_libra")
    _, _, ec = run_pipeline(g, p, "compnet")
    assert vc.data_comm_bytes < ec.data_comm_bytes


def test_simulate_type_dispatch(g):
    p = 4
    cut = vertex_cut(g, p, method="wb_libra")
    comm, shared = cluster_interaction_graphs(cut.replicas, p)
    mapping = memory_centric_mapping(comm, shared, Machine.for_clusters(p))
    rep = simulate(g, cut, mapping)
    assert rep.exec_time > 0
    ec = edge_cut(g, p, method="metis")
    rep2 = simulate(g, ec, mapping)
    assert rep2.exec_time > 0
    with pytest.raises(TypeError):
        simulate(g, "not a partition", mapping)


def test_edge_cut_methods(g):
    for method in ("compnet", "metis"):
        r = edge_cut(g, 8, method=method)
        assert len(r.parts) == g.n
        assert r.parts.min() >= 0 and r.parts.max() < 8
        assert 0 <= r.cut_weight <= g.total_weight
    with pytest.raises(ValueError):
        edge_cut(g, 8, method="nope")
