"""Launch-layer + HLO-analysis tests: cells enumeration, parallel plans,
sharded lowering on a small in-process mesh, loop-aware cost analysis."""
import subprocess
import sys

import jax
import jax.numpy as jnp

from repro.analysis import analyze_hlo
from repro.configs import ARCHS, SHAPES, reduced_config
from repro.configs.base import ParallelConfig
from repro.launch.cells import (Cell, cell_skip_reason, enumerate_cells,
                                parallel_plan)
from repro.optim import AdamWConfig


def test_cell_enumeration_covers_assignment():
    all_cells = enumerate_cells(include_skipped=True)
    assert len(all_cells) == len(ARCHS) * len(SHAPES) == 40
    runnable = enumerate_cells()
    skipped = [c for c in all_cells if cell_skip_reason(c)]
    # long_500k runs only for ssm + hybrid (2 archs), skipped for 8
    assert len(skipped) == 8
    assert all(c.shape == "long_500k" for c in skipped)
    assert {c.arch for c in runnable if c.shape == "long_500k"} == \
        {"rwkv6-7b", "recurrentgemma-9b"}


def test_parallel_plan_bounds_tokens():
    par, opt = parallel_plan(Cell("deepseek-v3-671b", "train_4k"))
    assert par.microbatches >= 8
    assert par.remat != "none"
    assert opt.moment_dtype == jnp.bfloat16  # >100B params
    par2, opt2 = parallel_plan(Cell("smollm-360m", "decode_32k"))
    assert par2.microbatches == 1


def test_sharded_lowering_small_mesh():
    """Compile a reduced train step on an in-process (1,2) mesh — covers
    param/batch/cache sharding rules + mesh context end-to-end."""
    from repro import models
    from repro.launch.steps import make_train_step
    from repro.optim import adamw_init
    from repro.parallel.sharding import (batch_specs, param_specs,
                                         sanitize_specs)
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = reduced_config(ARCHS["granite-3-2b"])
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    par = ParallelConfig(fsdp=True, tp=True, microbatches=1, remat="block")
    opt_cfg = AdamWConfig()
    params = jax.eval_shape(lambda k: models.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    opt = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params)
    batch = {"tokens": jax.ShapeDtypeStruct((2, 16), jnp.int32)}
    p_specs = sanitize_specs(param_specs(params, cfg, par), params, mesh)
    sh = lambda t: jax.tree.map(  # noqa: E731
        lambda s: NamedSharding(mesh, s), t,
        is_leaf=lambda x: isinstance(x, P))
    b_specs = batch_specs(cfg, batch, ("data",))
    step = make_train_step(cfg, opt_cfg, par)
    from repro.launch.mesh import mesh_context
    with mesh_context(mesh):
        lowered = jax.jit(
            step, in_shardings=(sh(p_specs),
                                sh({"m": p_specs, "v": p_specs,
                                    "step": P()}),
                                sh(b_specs))).lower(params, opt, batch)
        compiled = lowered.compile()
    assert compiled.cost_analysis() is not None


# ----------------------------- analysis ------------------------------- #
def test_analyze_hlo_scan_flops_exact():
    def f(w, x):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=8)
        return h

    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 256), jnp.float32)
    cost = analyze_hlo(jax.jit(f).lower(w, x).compile().as_text())
    expect = 8 * 2 * 32 * 256 * 256
    assert abs(cost.flops - expect) / expect < 0.05


def test_analyze_hlo_bytes_scale_with_scan():
    def make(n):
        def f(w, x):
            def body(h, _):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, x, None, length=n)
            return h
        return f

    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 256), jnp.float32)
    b4 = analyze_hlo(jax.jit(make(4)).lower(w, x).compile().as_text())
    b16 = analyze_hlo(jax.jit(make(16)).lower(w, x).compile().as_text())
    assert b16.hbm_bytes > 2.5 * b4.hbm_bytes  # ~4x expected


def test_analyze_hlo_slice_not_full_array():
    """A scan that slices a big constant per step must NOT charge the
    full array per iteration (the dynamic-slice fix)."""
    def f(big, x):
        def body(h, t):
            sl = jax.lax.dynamic_slice_in_dim(big, t * 0, 32)
            return h + sl.sum(), None
        h, _ = jax.lax.scan(body, x, jnp.arange(64), length=64)
        return h

    big = jax.ShapeDtypeStruct((maxdim := 32 * 1024, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((), jnp.float32)
    cost = analyze_hlo(jax.jit(f).lower(big, x).compile().as_text())
    full_per_iter = 64 * maxdim * 32 * 4
    assert cost.hbm_bytes < full_per_iter / 4


def test_analyze_hlo_collectives_in_loop():
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("model",))

    def g(w, x):
        def body(h, _):
            return h @ w, None
        h, _ = jax.lax.scan(body, x, None, length=5)
        return h.sum()

    w_sh = NamedSharding(mesh, P("model", None))
    x_sh = NamedSharding(mesh, P(None, "model"))
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    c = jax.jit(g, in_shardings=(w_sh, x_sh)).lower(w, x).compile()
    cost = analyze_hlo(c.as_text())
    # single-device mesh: no collectives required
    assert cost.total_collective_bytes >= 0.0


def test_mesh_with_vertex_cut_device_order():
    """Algorithm-2 device ordering: the mesh builder accepts a shard-comm
    matrix and produces a valid permuted mesh (subprocess: needs 512
    placeholder devices, which must not leak into this test process)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import numpy as np
from repro.launch.mesh import make_mesh_with_order, make_production_mesh
rng = np.random.default_rng(0)
comm = rng.random((16, 16)); comm = comm + comm.T
m1 = make_production_mesh(multi_pod=False)
m2 = make_mesh_with_order(comm, multi_pod=False)
assert m1.devices.shape == m2.devices.shape == (16, 16)
ids1 = sorted(d.id for d in m1.devices.flat)
ids2 = sorted(d.id for d in m2.devices.flat)
assert ids1 == ids2          # same device set, permuted order
m3 = make_mesh_with_order(None, multi_pod=True)
assert m3.devices.shape == (2, 16, 16)
print("MESH_ORDER_OK")
"""
    import subprocess
    import sys
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd=__import__("os").path.dirname(
            __import__("os").path.dirname(__file__)),
        timeout=300)
    assert "MESH_ORDER_OK" in out.stdout, out.stderr[-2000:]
