"""Plan service tests: content-addressed cache, incremental
repartitioning bit-identity, batched serving, CLI."""
import io
import json

import numpy as np
import pytest

from repro.core.graph import IRGraph
from repro.core.vertex_cut import vertex_cut
from repro.serve import (IncrementalPlanner, PlanRequest, PlanService,
                         plan_fingerprint)
from repro.serve.fingerprint import clear_stat_memo, content_digest
from repro.trace.ingest import TraceSession, ingest_trace
from repro.trace.synth import synthesize_trace

P = 16
LAM = 1.1


@pytest.fixture(scope="module")
def trace_path(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("serve") / "trace.ndjson")
    synthesize_trace(path, 12_000, seed=0)
    return path


# ----------------------------- fingerprint ---------------------------- #
def test_fingerprint_stable_and_knob_sensitive(trace_path):
    fp1 = plan_fingerprint(trace_path, P, "wb_libra", LAM)
    assert fp1 == plan_fingerprint(trace_path, P, "wb_libra", LAM)
    assert fp1 != plan_fingerprint(trace_path, P + 1, "wb_libra", LAM)
    assert fp1 != plan_fingerprint(trace_path, P, "w_libra", LAM)
    assert fp1 != plan_fingerprint(trace_path, P, "wb_libra", LAM + 0.1)
    assert fp1 != plan_fingerprint(trace_path, P, "wb_libra", LAM, seed=1)


def test_fingerprint_tracks_content(tmp_path, trace_path):
    other = str(tmp_path / "other.ndjson")
    synthesize_trace(other, 12_000, seed=1)
    assert (plan_fingerprint(trace_path, P, "wb_libra", LAM)
            != plan_fingerprint(other, P, "wb_libra", LAM))
    # graph-object fingerprints hash the canonical edge arrays
    g = IRGraph(n=4, src=np.array([0, 1]), dst=np.array([2, 3]),
                w=np.array([1.0, 2.0]), name="a")
    g2 = IRGraph(n=4, src=np.array([0, 1]), dst=np.array([2, 3]),
                 w=np.array([1.0, 2.5]), name="a")
    assert content_digest(g) != content_digest(g2)


def test_fingerprint_stat_memo_skips_rehash(tmp_path):
    path = str(tmp_path / "t.ndjson")
    synthesize_trace(path, 1_000, seed=0)
    clear_stat_memo()
    d1 = content_digest(path)
    assert content_digest(path) == d1          # memo hit, same digest
    d_cold = content_digest(path, use_stat_memo=False)
    assert d_cold == d1


# -------------------------- service / cache --------------------------- #
def test_service_cold_then_memory_then_disk(tmp_path, trace_path):
    cache = str(tmp_path / "plans")
    svc = PlanService(cache_dir=cache)
    req = PlanRequest(source=trace_path, p=P, lam=LAM)
    r1 = svc.plan(req)
    assert r1.cache == "cold"
    r2 = svc.plan(req)
    assert r2.cache == "memory"
    np.testing.assert_array_equal(r1.bundle.assignment,
                                  r2.bundle.assignment)
    # warm restart: a fresh service over the same cache dir loads from
    # the checkpoint store without planning
    svc2 = PlanService(cache_dir=cache)
    r3 = svc2.plan(req)
    assert r3.cache == "disk"
    np.testing.assert_array_equal(r1.bundle.assignment,
                                  r3.bundle.assignment)
    np.testing.assert_array_equal(r1.bundle.replica_flat,
                                  r3.bundle.replica_flat)
    np.testing.assert_array_equal(r1.bundle.core_of, r3.bundle.core_of)
    assert r3.bundle.exec_time == r1.bundle.exec_time
    assert r3.bundle.comm_bytes == r1.bundle.comm_bytes
    assert svc2.stats()["disk_entries"] == 1


def test_service_bundle_matches_direct_pipeline(tmp_path, trace_path):
    svc = PlanService(cache_dir=str(tmp_path / "plans"))
    r = svc.plan(PlanRequest(source=trace_path, p=P, lam=LAM))
    g = ingest_trace(trace_path)
    cut = vertex_cut(g, P, method="wb_libra", lam=LAM, backend="fast")
    np.testing.assert_array_equal(r.bundle.assignment, cut.assignment)
    assert r.bundle.replication_factor == pytest.approx(
        cut.replication_factor)


def test_plan_many_dedups_and_serves(tmp_path, trace_path):
    other = str(tmp_path / "other.ndjson")
    synthesize_trace(other, 4_000, seed=2)
    svc = PlanService(cache_dir=str(tmp_path / "plans"))
    reqs = [PlanRequest(source=trace_path, p=P, lam=LAM),
            PlanRequest(source=other, p=P, lam=LAM),
            PlanRequest(source=trace_path, p=P, lam=LAM)]  # duplicate
    out = svc.plan_many(reqs)
    assert [r.cache for r in out] == ["cold", "cold", "memory"]
    assert out[0].fingerprint == out[2].fingerprint
    assert out[0].fingerprint != out[1].fingerprint
    np.testing.assert_array_equal(out[0].bundle.assignment,
                                  out[2].bundle.assignment)
    assert svc.stats() == {**svc.stats(), "hits": 1, "misses": 2}


# ------------------------ incremental planner ------------------------- #
def test_trace_session_matches_one_shot(trace_path):
    lines = open(trace_path).read().splitlines(keepends=True)
    sess = TraceSession()
    sess.feed(io.StringIO("".join(lines[:5_000])))
    sess.feed(io.StringIO("".join(lines[5_000:])))
    g_inc = sess.graph("t")
    g_one = ingest_trace(trace_path, name="t")
    assert g_inc.n == g_one.n
    np.testing.assert_array_equal(g_inc.src, g_one.src)
    np.testing.assert_array_equal(g_inc.dst, g_one.dst)
    np.testing.assert_array_equal(g_inc.w, g_one.w)


def test_incremental_single_quantum_matches_vertex_cut(trace_path):
    pl = IncrementalPlanner(p=P, method="wb_libra", lam=LAM,
                            quantum=1 << 22)
    pl.append(trace_path)
    g, cut, mapping, rep = pl.plan()
    ref = vertex_cut(ingest_trace(trace_path), P, method="wb_libra",
                     lam=LAM, edge_order="trace", backend="fast")
    np.testing.assert_array_equal(cut.assignment, ref.assignment)
    np.testing.assert_array_equal(cut.replica_indptr, ref.replica_indptr)
    np.testing.assert_array_equal(cut.replica_flat, ref.replica_flat)
    np.testing.assert_array_equal(cut.loads, ref.loads)
    np.testing.assert_array_equal(cut.edge_counts, ref.edge_counts)


@pytest.mark.parametrize("method", ["libra", "w_libra", "wb_libra"])
def test_incremental_window_invariance(trace_path, method):
    """Warm incremental == cold over the concatenated trace, bit for
    bit — the incremental-repartition contract (window boundaries and
    interleaved plan() calls never change the output)."""
    lines = open(trace_path).read().splitlines(keepends=True)
    cuts = []
    windows = [[len(lines)],                       # one shot (the cold cut)
               [7_000, len(lines)],                # two windows
               [2_000, 5_000, 9_000, len(lines)]]  # four, plan mid-way
    for bounds in windows:
        pl = IncrementalPlanner(p=P, method=method, lam=LAM, quantum=2048)
        start = 0
        for end in bounds:
            pl.append(io.StringIO("".join(lines[start:end])))
            start = end
            pl.plan()        # interleaved plans must not perturb state
        _, cut, _, rep = pl.plan()
        cuts.append((cut, rep))
    cold, cold_rep = cuts[0]
    for cut, rep in cuts[1:]:
        np.testing.assert_array_equal(cut.assignment, cold.assignment)
        np.testing.assert_array_equal(cut.replica_indptr,
                                      cold.replica_indptr)
        np.testing.assert_array_equal(cut.replica_flat, cold.replica_flat)
        np.testing.assert_array_equal(cut.loads, cold.loads)
        assert rep.exec_time == cold_rep.exec_time
        assert rep.data_comm_bytes == cold_rep.data_comm_bytes


def test_incremental_rejects_pg_methods():
    with pytest.raises(ValueError, match="Libra-rule"):
        IncrementalPlanner(p=4, method="wb_pg")
    with pytest.raises(ValueError, match="lambda"):
        IncrementalPlanner(p=4, lam=0.5)


# -------------------------------- CLI --------------------------------- #
def test_cli_plan_and_cache(tmp_path, trace_path, capsys):
    from repro.serve.__main__ import main
    cache = str(tmp_path / "plans")
    rc = main(["--cache-dir", cache, "plan", trace_path, "-p", str(P),
               "--lam", str(LAM)])
    assert rc == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["cache"] == "cold" and doc["p"] == P
    rc = main(["--cache-dir", cache, "plan", trace_path, "-p", str(P),
               "--lam", str(LAM)])
    assert rc == 0
    assert json.loads(capsys.readouterr().out)["cache"] == "disk"
    rc = main(["--cache-dir", cache, "cache"])
    assert rc == 0
    assert doc["fingerprint"] in capsys.readouterr().out


def test_cli_batch(tmp_path, trace_path, capsys):
    from repro.serve.__main__ import main
    reqs = str(tmp_path / "reqs.json")
    with open(reqs, "w") as f:
        json.dump([{"source": trace_path, "p": P, "lam": LAM},
                   {"source": trace_path, "p": P, "lam": LAM}], f)
    rc = main(["--cache-dir", str(tmp_path / "plans"), "batch", reqs])
    assert rc == 0
    doc = json.loads(capsys.readouterr().out)
    assert [r["cache"] for r in doc["responses"]] == ["cold", "memory"]
    assert doc["stats"]["hits"] == 1 and doc["stats"]["misses"] == 1
