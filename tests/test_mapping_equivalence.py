"""Mapping invariants + fast-vs-reference backend equivalence.

The array-native mapping engine must be *bit-identical* to the reference
oracle (same greedy decisions, same lowest-index tie-breaking), and both
must uphold Algorithm 2's invariants: every cluster placed, the per-core
cluster threshold respected whenever capacity exists, and deterministic
output for a fixed input.  Seeded randomized sweeps run everywhere; the
hypothesis section digs deeper when the [test] extra is installed.
"""
import numpy as np
import pytest

from repro.core import (IRGraph, Machine, cluster_interaction_graphs,
                        memory_centric_mapping, vertex_bytes_model,
                        vertex_cut)

MACHINES = [
    Machine(rows=4, cols=4),
    Machine(rows=2, cols=3, n_regions=6, cluster_threshold=8),
    Machine(rows=5, cols=2, n_regions=5, cluster_threshold=2),
    Machine(rows=1, cols=8, n_regions=4, cluster_threshold=16),
]


def _random_interaction(rng, p):
    """Random symmetric (comm, shared) pair shaped like real cut output."""
    comm = rng.random((p, p)) * (rng.random((p, p)) < 0.3)
    comm = np.triu(comm, 1)
    comm = comm + comm.T
    shared = np.floor(rng.random((p, p)) * 6) * (rng.random((p, p)) < 0.4)
    shared = np.triu(shared, 1)
    shared = shared + shared.T
    np.fill_diagonal(shared, np.floor(rng.random(p) * 20))
    return comm, shared


def _check_invariants(mapping, machine, p):
    assert len(mapping.core_of) == p
    assert (mapping.core_of >= 0).all()                 # every cluster placed
    assert (mapping.core_of < machine.n_cores).all()
    counts = np.bincount(mapping.core_of, minlength=machine.n_cores)
    if machine.n_cores * machine.cluster_threshold >= p:
        # threshold respected whenever capacity exists
        assert counts.max() <= machine.cluster_threshold
    else:
        # oversubscribed machine: still as balanced as the threshold allows
        assert counts.max() <= p


@pytest.mark.parametrize("machine", MACHINES)
@pytest.mark.parametrize("p", [1, 2, 7, 16, 40, 130])
def test_random_interactions_fast_matches_reference(machine, p):
    rng = np.random.default_rng(p * 31 + machine.n_cores)
    for trial in range(3):
        comm, shared = _random_interaction(rng, p)
        ref = memory_centric_mapping(comm, shared, machine,
                                     backend="reference")
        fast = memory_centric_mapping(comm, shared, machine, backend="fast")
        np.testing.assert_array_equal(fast.core_of, ref.core_of,
                                      err_msg=f"p={p} trial={trial}")
        _check_invariants(fast, machine, p)
        # deterministic for a fixed input
        again = memory_centric_mapping(comm, shared, machine, backend="fast")
        np.testing.assert_array_equal(fast.core_of, again.core_of)


def _pallas_ready() -> bool:
    try:
        from repro.core.pallas import pallas_available
    except ImportError:
        return False
    return pallas_available()


@pytest.mark.parametrize("p", [2, 8, 64])
def test_real_cut_interactions_fast_matches_reference(p):
    """End-to-end over real vertex-cut replica sets, all machines."""
    rng = np.random.default_rng(7)
    n, m = 300, 1500
    g = IRGraph(n=n, src=rng.integers(0, n, m), dst=rng.integers(0, n, m),
                w=rng.lognormal(size=m), name="rand")
    cut = vertex_cut(g, p, method="wb_libra")
    vb = vertex_bytes_model(g)
    cf, sf = cluster_interaction_graphs(cut, p, vb, backend="fast")
    cr, sr = cluster_interaction_graphs(cut.replicas, p, vb,
                                        backend="reference")
    np.testing.assert_allclose(cf, cr, rtol=1e-12)
    np.testing.assert_array_equal(sf, sr)
    if _pallas_ready():
        # the Pallas segment-sum port must match the fast path bit for
        # bit (same key sets, same accumulation order)
        cp, sp_ = cluster_interaction_graphs(cut, p, vb, backend="pallas")
        np.testing.assert_array_equal(cp, cf)
        np.testing.assert_array_equal(sp_, sf)
    for machine in MACHINES:
        ref = memory_centric_mapping(cr, sr, machine, backend="reference")
        fast = memory_centric_mapping(cf, sf, machine, backend="fast")
        np.testing.assert_array_equal(fast.core_of, ref.core_of)
        _check_invariants(fast, machine, p)


def test_explicit_cluster_order_respected():
    p = 6
    comm, shared = _random_interaction(np.random.default_rng(0), p)
    order = np.array([5, 3, 1, 0, 2, 4])
    a = memory_centric_mapping(comm, shared, MACHINES[0],
                               cluster_order=order, backend="fast")
    b = memory_centric_mapping(comm, shared, MACHINES[0],
                               cluster_order=order, backend="reference")
    np.testing.assert_array_equal(a.core_of, b.core_of)


# deeper randomized search when the [test] extra is installed ----------- #
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @st.composite
    def interactions(draw):
        p = draw(st.integers(min_value=1, max_value=40))
        seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
        rng = np.random.default_rng(seed)
        return _random_interaction(rng, p) + (p,)

    @st.composite
    def machines(draw):
        rows = draw(st.integers(min_value=1, max_value=6))
        cols = draw(st.integers(min_value=1, max_value=6))
        n_regions = draw(st.integers(min_value=1, max_value=8))
        thr = draw(st.integers(min_value=1, max_value=8))
        return Machine(rows=rows, cols=cols, n_regions=n_regions,
                       cluster_threshold=thr)

    @given(ip=interactions(), machine=machines())
    @settings(max_examples=60, deadline=None)
    def test_property_mapping_invariants_and_equivalence(ip, machine):
        comm, shared, p = ip
        ref = memory_centric_mapping(comm, shared, machine,
                                     backend="reference")
        fast = memory_centric_mapping(comm, shared, machine, backend="fast")
        np.testing.assert_array_equal(fast.core_of, ref.core_of)
        _check_invariants(fast, machine, p)
        again = memory_centric_mapping(comm, shared, machine,
                                       backend="fast")
        np.testing.assert_array_equal(fast.core_of, again.core_of)

    @given(st.integers(1, 6), st.integers(1, 6), st.integers(1, 12))
    @settings(max_examples=60, deadline=None)
    def test_property_region_ids_complete(rows, cols, n_regions):
        """Every region id in [0, n_regions) appears when the mesh has
        room for the region grid; ids never leave the valid range."""
        m = Machine(rows=rows, cols=cols, n_regions=n_regions)
        regs = {m.region_of(c) for c in range(m.n_cores)}
        assert all(0 <= r < n_regions for r in regs)
        rb, cb = m.region_grid()
        assert rb * cb == max(1, n_regions)
        if rb <= rows and cb <= cols:
            assert regs == set(range(n_regions))
