"""Telemetry layer (`repro.obs`): zero-cost-when-disabled contract,
Perfetto export schema, process-pool event merging, summarize math,
and the warning-origin contract of the dist engine's fallbacks.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
import warnings

import numpy as np
import pytest

from repro import obs
from repro.core import run_pipeline, synthesize_powerlaw_graph
from repro.dist import dist_vertex_cut
from repro.obs.export import (chrome_trace, events_from_chrome,
                              load_profile, write_profile)
from repro.obs.summarize import render_summary, summarize_events
from repro.trace import synthesize_trace


@pytest.fixture(scope="module")
def trace_path(tmp_path_factory):
    path = tmp_path_factory.mktemp("obs_traces") / "synth.ndjson"
    synthesize_trace(str(path), 20_000, seed=0)
    return str(path)


@pytest.fixture(autouse=True)
def _obs_clean():
    """Every test starts and ends with telemetry disabled."""
    obs.disable()
    yield
    obs.disable()


# ---------------------------------------------------------------------- #
# disabled contract
# ---------------------------------------------------------------------- #
def test_disabled_is_noop_and_cheap():
    assert not obs.enabled()
    # the disabled span is a shared singleton — no allocation per call
    assert obs.span("a") is obs.span("b", lane="x", big=1)
    t0 = time.perf_counter()
    for _ in range(100_000):
        with obs.span("hot", lane="w", n=1) as sp:
            sp.set(k=2)
        obs.counter("c")
        obs.event("e")
    dt = time.perf_counter() - t0
    # budget: ~10us/iteration would already be pathological; the
    # measured cost is ~0.5us.  Generous bound for shared CI runners.
    assert dt < 1.0, f"100k disabled spans took {dt:.3f}s"


def test_disabled_records_nothing(trace_path):
    cut = dist_vertex_cut(trace_path, 8, workers=2, merge_period=4000)
    assert cut.assignment is not None
    assert obs.current() is None


# ---------------------------------------------------------------------- #
# collection + Perfetto export schema
# ---------------------------------------------------------------------- #
def _collect_sample():
    with obs.scoped(merge=False) as col:
        with obs.span("outer", lane="main", cat="section"):
            with obs.span("work", lane="main", n=3):
                time.sleep(0.001)
            t = time.perf_counter()
            obs.complete("remote", t - 0.002, t, lane="w1")
        obs.event("blip", lane="main", reason="test")
        obs.counter("edges", 42)
        obs.counter("edges", 8)
        obs.gauge("depth", 7)
    return col


def test_perfetto_export_schema():
    col = _collect_sample()
    doc = chrome_trace(col)
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    meta = [e for e in evs if e["ph"] == "M"]
    body = [e for e in evs if e["ph"] != "M"]
    # one thread_name metadata record per lane, unique tids
    assert {m["name"] for m in meta} == {"thread_name"}
    lanes = {m["args"]["name"] for m in meta}
    assert lanes == {"main", "w1"}
    assert len({m["tid"] for m in meta}) == len(meta)
    for e in body:
        assert e["ph"] in ("X", "i")
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        assert e["ts"] >= 0
        if e["ph"] == "X":
            assert e["dur"] >= 0
        else:
            assert e["s"] == "t"
    # timestamps monotone non-decreasing per lane (exporter sorts)
    by_tid: dict = {}
    for e in body:
        assert e["ts"] >= by_tid.get(e["tid"], 0)
        by_tid[e["tid"]] = e["ts"]
    # counters/gauges ride along under the repro key
    assert doc["repro"]["counters"]["edges"] == 50
    assert doc["repro"]["gauges"]["depth"] == 7


def test_export_roundtrip(tmp_path):
    col = _collect_sample()
    path = tmp_path / "prof.json"
    write_profile(str(path), col)
    doc = load_profile(str(path))
    events = events_from_chrome(doc)
    assert {e["lane"] for e in events} == {"main", "w1"}
    names = {e["name"] for e in events}
    assert {"outer", "work", "remote", "blip"} <= names
    # lanes recovered by name, not tid — summarize works on the rehydrated
    # events exactly as on the live ones
    s = summarize_events(events)
    assert s["wall_us"] > 0
    assert render_summary(s, doc["repro"]["counters"])


# ---------------------------------------------------------------------- #
# summarize math
# ---------------------------------------------------------------------- #
def test_summary_decomposition_sums_to_wall():
    with obs.scoped(merge=False) as col:
        t = time.perf_counter()
        # lane a: [0, 10ms]; lane b: [5ms, 15ms] -> 5 serial + 5 parallel
        # + 5 serial, wall 15ms, no idle
        obs.complete("a", t, t + 0.010, lane="a")
        obs.complete("b", t + 0.005, t + 0.015, lane="b")
    s = summarize_events(col.events)
    assert s["wall_us"] == pytest.approx(15_000, rel=1e-6)
    assert s["parallel_us"] == pytest.approx(5_000, rel=1e-6)
    assert s["serial_us"] == pytest.approx(10_000, rel=1e-6)
    assert s["idle_us"] == pytest.approx(0, abs=1e-6)
    assert (s["serial_us"] + s["parallel_us"] + s["idle_us"]
            == pytest.approx(s["wall_us"], rel=1e-6))
    assert s["serial_fraction"] == pytest.approx(2 / 3, rel=1e-6)
    # waits and sections never count as busy time
    with obs.scoped(merge=False) as col2:
        t = time.perf_counter()
        obs.complete("env", t, t + 0.010, lane="a", cat="section")
        obs.complete("stall", t, t + 0.010, lane="b", cat="wait")
        obs.complete("real", t, t + 0.002, lane="b")
    s2 = summarize_events(col2.events)
    assert s2["serial_us"] == pytest.approx(2_000, rel=1e-6)
    assert s2["parallel_us"] == pytest.approx(0, abs=1e-6)


# ---------------------------------------------------------------------- #
# process-pool event merging
# ---------------------------------------------------------------------- #
def _pipelined_events(trace_path):
    with obs.scoped(merge=False) as col:
        dist_vertex_cut(trace_path, 8, workers=4, merge_period=2000,
                        pool="process")
    return col.events


def test_process_pool_event_merge_deterministic(trace_path):
    """W=4 pipelined run over a process pool: worker timings ship home
    over the result channel and merge into the coordinator's collector.
    The event *structure* (names, lanes, per-phase counts) is a pure
    function of the input — only timestamps may differ between runs."""
    runs = [_pipelined_events(trace_path) for _ in range(2)]
    shapes = [sorted((e["name"], e["lane"]) for e in evs) for evs in runs]
    assert shapes[0] == shapes[1]
    lanes = {e["lane"] for e in runs[0]}
    assert {"coord"} <= lanes
    assert any(ln.startswith("cut/w") for ln in lanes)
    assert any(ln.startswith("parse/p") for ln in lanes)
    names = {e["name"] for e in runs[0]}
    assert {"dist.cut", "parse.shard", "dist.parse_wait",
            "dist.finalize"} <= names
    # every event survived the export path with its lane intact
    doc = chrome_trace_from_events(runs[0])
    back = events_from_chrome(doc)
    assert sorted((e["name"], e["lane"]) for e in back) == shapes[0]


def chrome_trace_from_events(events):
    col = obs.Collector()
    col.events.extend(events)
    return chrome_trace(col)


# ---------------------------------------------------------------------- #
# profile hooks: run_pipeline(profile=) and REPRO_PROFILE
# ---------------------------------------------------------------------- #
def test_run_pipeline_profile_writes_trace(tmp_path):
    g = synthesize_powerlaw_graph(300, 2.0, seed=0)
    out = tmp_path / "pipe.json"
    run_pipeline(g, 4, "wb_libra", profile=str(out))
    doc = json.loads(out.read_text())
    names = {e.get("name") for e in doc["traceEvents"]}
    assert {"pipeline.partition", "pipeline.map",
            "pipeline.simulate"} <= names
    # the collector died with the context — nothing leaks into the test
    assert obs.current() is None


def test_repro_profile_env(tmp_path, trace_path):
    out = tmp_path / "env.json"
    code = ("from repro.dist import dist_vertex_cut; "
            f"dist_vertex_cut({trace_path!r}, 8, workers=2, "
            "merge_period=4000)")
    env = dict(os.environ, REPRO_PROFILE=str(out),
               PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))),
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    doc = json.loads(out.read_text())
    assert any(e.get("name") == "dist.finalize"
               for e in doc["traceEvents"])
    # and the summarize CLI renders it
    r = subprocess.run([sys.executable, "-m", "repro.obs", "summarize",
                        str(out)], env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))),
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    assert "serial fraction" in r.stdout


# ---------------------------------------------------------------------- #
# warning origins (stacklevel contract)
# ---------------------------------------------------------------------- #
def test_gil_warning_points_at_caller():
    g = synthesize_powerlaw_graph(200, 2.0, seed=1)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        dist_vertex_cut(g, 8, workers=2, backend="python",
                        pool="thread", merge_period=4000)
    gil = [w for w in rec if "GIL" in str(w.message)]
    assert gil and gil[0].filename == __file__


def test_process_fallback_warning_points_at_caller(monkeypatch,
                                                   trace_path):
    from repro.dist import engine

    class Boom:
        def __init__(self, *a, **kw):
            raise ImportError("no pipes here")

    monkeypatch.setattr(engine, "_ProcessPool", Boom)
    g = synthesize_powerlaw_graph(200, 2.0, seed=1)
    # two-phase route: dist_vertex_cut -> _make_pool (stacklevel 3)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        a = dist_vertex_cut(g, 8, workers=2, pool="process",
                            merge_period=4000)
    fb = [w for w in rec if "falling back to serial" in str(w.message)]
    assert fb and fb[0].filename == __file__
    # pipelined route is one frame deeper:
    # dist_vertex_cut -> _pipelined_cut -> _make_pool (stacklevel 4)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        b = dist_vertex_cut(trace_path, 8, workers=2, pool="process",
                            merge_period=4000)
    fb = [w for w in rec if "falling back to serial" in str(w.message)]
    assert fb and fb[0].filename == __file__
    # the fallback still computes the right answer
    ref = dist_vertex_cut(g, 8, workers=2, pool="serial",
                          merge_period=4000)
    np.testing.assert_array_equal(a.assignment, ref.assignment)
    assert b.assignment is not None
