"""Invariants of the Weight Balanced p-way Vertex Cut (paper §4)."""
import numpy as np
import pytest

from repro.core import (ALGORITHMS, build_graph,
                        expected_replication_random,
                        expected_replication_random_empirical,
                        synthesize_powerlaw_graph, vertex_cut)


@pytest.fixture(scope="module")
def fft_graph():
    return build_graph("fft", scale="reduced", cache_dir=None)


@pytest.fixture(scope="module")
def pl_graph():
    return synthesize_powerlaw_graph(n=2000, alpha=2.2, seed=1)


@pytest.mark.parametrize("backend", ("fast", "python", "reference"))
@pytest.mark.parametrize("method", ALGORITHMS)
def test_every_edge_assigned_exactly_once(fft_graph, method, backend):
    r = vertex_cut(fft_graph, p=8, method=method, backend=backend)
    assert len(r.assignment) == fft_graph.num_edges
    assert r.assignment.min() >= 0 and r.assignment.max() < 8
    # loads/counts are consistent with the assignment
    counts = np.bincount(r.assignment, minlength=8)
    np.testing.assert_array_equal(counts, r.edge_counts)
    assert np.isclose(r.loads.sum(), fft_graph.total_weight)


@pytest.mark.parametrize("method", ALGORITHMS)
def test_replica_sets_cover_assignments(fft_graph, method):
    r = vertex_cut(fft_graph, p=4, method=method)
    for e in range(fft_graph.num_edges):
        c = r.assignment[e]
        assert c in r.replicas[fft_graph.src[e]]
        assert c in r.replicas[fft_graph.dst[e]]


def test_wb_libra_respects_lambda_bound(pl_graph):
    """Paper Eq. (3): max cluster weight < λ·Σw/p (+ one edge overshoot,
    since the check precedes the placement)."""
    for lam in (1.0, 1.01, 1.1):
        r = vertex_cut(pl_graph, p=8, method="wb_libra", lam=lam)
        bound = lam * pl_graph.total_weight / 8
        assert r.loads.max() <= bound + pl_graph.w.max() + 1e-9


def test_wb_beats_w_on_imbalance(pl_graph):
    """§4.4: the explicit constraint improves edge-weight balance."""
    for fam in ("pg", "libra"):
        w = vertex_cut(pl_graph, p=8, method=f"w_{fam}")
        wb = vertex_cut(pl_graph, p=8, method=f"wb_{fam}")
        assert wb.edge_weight_imbalance <= w.edge_weight_imbalance + 1e-9


def test_wb_near_ideal_balance(pl_graph):
    """§4.4: λ=1 gives imbalance 1+ε for small ε."""
    r = vertex_cut(pl_graph, p=8, method="wb_libra", lam=1.0)
    assert r.edge_weight_imbalance < 1.05


def test_greedy_beats_random_theory(pl_graph):
    """Fig. 8: greedy replication factors sit below the Eq. (10) bound.
    (Bound computed over active vertices, matching the measured factor.)"""
    deg = pl_graph.degrees()
    deg = deg[deg > 0]
    for p in (4, 16, 64):
        bound_emp = expected_replication_random_empirical(deg, p)
        for method in ("w_pg", "wb_pg", "w_libra", "wb_libra"):
            r = vertex_cut(pl_graph, p=p, method=method)
            assert r.replication_factor_active <= bound_emp + 1e-6, \
                f"{method} p={p}"


def test_random_cut_matches_eq10(pl_graph):
    """Random placement empirically matches Eq. (6) within a few %."""
    p = 8
    r = vertex_cut(pl_graph, p=p, method="random", seed=3)
    deg = pl_graph.degrees()
    expected = expected_replication_random_empirical(deg[deg > 0], p)
    measured = r.replication_factor_active
    assert abs(measured - expected) / expected < 0.05


def test_eq10_closed_form_monotone_in_p():
    vals = [expected_replication_random(5000, 2.2, p) for p in (2, 4, 8, 16)]
    assert all(b > a for a, b in zip(vals, vals[1:]))
    assert all(1.0 <= v <= p for v, p in zip(vals, (2, 4, 8, 16)))


def test_libra_cuts_high_degree_vertices(pl_graph):
    """Libra's rule: high-degree vertices are the replicated ones."""
    r = vertex_cut(pl_graph, p=16, method="wb_libra")
    deg = pl_graph.degrees()
    sizes = np.array([len(a) if a else 0 for a in r.replicas])
    hubs = deg >= np.percentile(deg[deg > 0], 99)
    leaves = (deg > 0) & (deg <= 2)
    assert sizes[hubs].mean() > sizes[leaves].mean()


def test_single_cluster_degenerate(fft_graph):
    r = vertex_cut(fft_graph, p=1, method="wb_libra")
    assert r.replication_factor_active == 1.0
    assert r.edge_weight_imbalance == pytest.approx(1.0)


def test_edge_order_modes(pl_graph):
    a = vertex_cut(pl_graph, p=8, method="wb_libra", edge_order="trace")
    b = vertex_cut(pl_graph, p=8, method="wb_libra", edge_order="shuffled")
    for r in (a, b):
        assert np.isclose(r.loads.sum(), pl_graph.total_weight)
    with pytest.raises(ValueError):
        vertex_cut(pl_graph, p=8, edge_order="bogus")


def test_invalid_args(fft_graph):
    with pytest.raises(ValueError):
        vertex_cut(fft_graph, p=8, method="nope")
    with pytest.raises(ValueError):
        vertex_cut(fft_graph, p=0)
    with pytest.raises(ValueError):
        vertex_cut(fft_graph, p=8, lam=0.5)
