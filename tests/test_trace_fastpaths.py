"""The two trace fast paths: the vectorized NDJSON scanner (`trace.scan`)
and the `.rtb` binary columnar container (`trace.binfmt`).

Both are *transparent accelerators*: every test here is a differential
against the sequential streaming interpreter, which remains the semantic
reference.  The scanner must be bit-identical where it engages and fall
back (whole-file) everywhere else; `.rtb` containers must round-trip the
exact arrays `convert` serialized and be accepted anywhere an NDJSON
path is.
"""
import gzip
import json
import os
import struct

import numpy as np
import pytest

from repro.core import run_pipeline
from repro.core.graph import IRGraph
from repro.trace import (BINARY_MAGIC, BINARY_VERSION, BinaryFormatError,
                         SCANNER_ENV, TraceFormatError, ingest_trace_with_stats,
                         is_binary_trace_path, iter_synthetic_trace,
                         iter_trace_bin_chunks, load_graph, read_trace_bin,
                         read_trace_bin_header, scanner_enabled,
                         try_scan_ingest, write_trace_bin)


def _write_synth(tmp_path, lines=1500, seed=11, name="t.ndjson"):
    p = tmp_path / name
    p.write_text("\n".join(iter_synthetic_trace(lines, seed=seed)) + "\n")
    return str(p)


def _seq(monkeypatch, source, **kw):
    """Sequential-reference ingest: scanner forced off via the env knob."""
    monkeypatch.setenv(SCANNER_ENV, "0")
    try:
        return ingest_trace_with_stats(source, **kw)
    finally:
        monkeypatch.delenv(SCANNER_ENV)


def _assert_graphs_identical(a: IRGraph, b: IRGraph):
    assert a.n == b.n
    assert np.array_equal(a.src, b.src)
    assert np.array_equal(a.dst, b.dst)
    assert np.array_equal(a.w, b.w)          # exact: bit-identity, no tol
    assert a.node_labels == b.node_labels


# ---------------------------------------------------------------------- #
# scanner: bit-identity where it engages
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("model", ["bytes", "memop-latency"])
def test_scanner_matches_sequential_synth(tmp_path, monkeypatch, model):
    path = _write_synth(tmp_path, 2500, seed=3)
    g_ref, st_ref = _seq(monkeypatch, path, weight_model=model,
                         keep_labels=True)
    g, st = ingest_trace_with_stats(path, weight_model=model,
                                    keep_labels=True)
    assert st_ref.engine == "stream" and st.engine == "scan"
    _assert_graphs_identical(g, g_ref)
    # every semantic stat matches; engine/peak are engine provenance
    sa, sb = st.summary(), st_ref.summary()
    for k in ("engine", "peak_chunk_edges"):
        sa.pop(k), sb.pop(k)
    assert sa == sb


def test_scanner_matches_on_committed_fixtures(monkeypatch):
    import pathlib
    tdir = pathlib.Path(__file__).resolve().parent.parent / "examples/traces"
    for fixture in ("toy_loop.ndjson", "mlp_jaxpr.ndjson"):
        path = str(tdir / fixture)
        g_ref, _ = _seq(monkeypatch, path, keep_labels=True)
        g, st = ingest_trace_with_stats(path, keep_labels=True)
        assert st.engine == "scan", fixture
        _assert_graphs_identical(g, g_ref)


def test_scanner_gzip_source(tmp_path, monkeypatch):
    text = "\n".join(iter_synthetic_trace(900, seed=5)) + "\n"
    gz = tmp_path / "t.ndjson.gz"
    with gzip.open(gz, "wt", encoding="utf-8") as f:
        f.write(text)
    g_ref, _ = _seq(monkeypatch, str(gz))
    g, st = ingest_trace_with_stats(str(gz))
    assert st.engine == "scan"
    _assert_graphs_identical(g, g_ref)


def test_scanner_env_override(tmp_path, monkeypatch):
    path = _write_synth(tmp_path, 300)
    for off in ("0", "off", "FALSE", "no"):
        monkeypatch.setenv(SCANNER_ENV, off)
        assert not scanner_enabled()
        assert try_scan_ingest(path) is None
        _, st = ingest_trace_with_stats(path)
        assert st.engine == "stream"
    monkeypatch.setenv(SCANNER_ENV, "1")
    assert scanner_enabled()
    _, st = ingest_trace_with_stats(path)
    assert st.engine == "scan"


def test_scanner_size_heuristic(tmp_path, monkeypatch):
    """Auto mode falls back to the stream engine past the size budget;
    force mode scans regardless; results stay bit-identical."""
    from repro.trace import SCAN_MAX_MB_ENV, scanner_mode

    path = _write_synth(tmp_path, 400, seed=11)
    size_mb = os.path.getsize(path) / (1 << 20)

    monkeypatch.delenv(SCANNER_ENV, raising=False)
    assert scanner_mode() == "auto"
    # budget above the file: the scanner engages
    monkeypatch.setenv(SCAN_MAX_MB_ENV, str(size_mb * 2))
    g_scan, st = ingest_trace_with_stats(path)
    assert st.engine == "scan"
    # budget below the file: auto falls back to the stream engine
    monkeypatch.setenv(SCAN_MAX_MB_ENV, str(size_mb / 2))
    g_stream, st = ingest_trace_with_stats(path)
    assert st.engine == "stream"
    _assert_graphs_identical(g_scan, g_stream)
    # force overrides the budget
    monkeypatch.setenv(SCANNER_ENV, "1")
    assert scanner_mode() == "force"
    g_forced, st = ingest_trace_with_stats(path)
    assert st.engine == "scan"
    _assert_graphs_identical(g_forced, g_stream)
    # off overrides everything
    monkeypatch.setenv(SCANNER_ENV, "off")
    assert scanner_mode() == "off"
    # garbage budget falls back to the default instead of crashing
    monkeypatch.setenv(SCANNER_ENV, "")
    monkeypatch.setenv(SCAN_MAX_MB_ENV, "not-a-number")
    _, st = ingest_trace_with_stats(path)
    assert st.engine == "scan"


def test_scanner_fallback_cases(tmp_path):
    """Everything outside the scanner's strict subset runs sequentially
    — same graph, `engine="stream"`, sequential diagnostics."""
    path = _write_synth(tmp_path, 300, seed=9)
    lines = open(path).read().splitlines()
    # iterable sources never scan
    _, st = ingest_trace_with_stats(lines)
    assert st.engine == "stream"
    # on_error="skip" and cfg validation are sequential-only
    _, st = ingest_trace_with_stats(path, on_error="skip")
    assert st.engine == "stream"
    # callable weight models may be stateful: per-unique eval is unsound
    _, st = ingest_trace_with_stats(path, weight_model=lambda o, t, b: 1.0)
    assert st.engine == "stream"
    # pretty-printed JSON (whitespace outside strings) falls back, and
    # the sequential interpreter accepts it
    pretty = tmp_path / "pretty.ndjson"
    pretty.write_text('{"fn": "f", "bb": "b0", "op": "add", '
                      '"def": "v0", "uses": []}\n')
    g, st = ingest_trace_with_stats(str(pretty))
    assert st.engine == "stream" and g.n == 1
    # malformed input: the scanner falls back whole-file, so the error
    # (and its line number) is exactly the sequential interpreter's
    bad = tmp_path / "bad.ndjson"
    bad.write_text(lines[0] + "\n" + '{"fn":"f","bb":"b0","uses":[]}\n')
    with pytest.raises(TraceFormatError, match="line 2"):
        ingest_trace_with_stats(str(bad))


# ---------------------------------------------------------------------- #
# binary container: round trip + universal acceptance
# ---------------------------------------------------------------------- #
def test_binary_round_trip_multichunk(tmp_path, monkeypatch):
    path = _write_synth(tmp_path, 2000, seed=1)
    g0, st0 = _seq(monkeypatch, path, keep_labels=True)
    rtb = tmp_path / "t.rtb"
    nchunks = write_trace_bin(rtb, g0, st0, chunk_edges=500)
    assert nchunks == -(-g0.num_edges // 500) and nchunks > 1
    g, st = read_trace_bin(rtb, keep_labels=True)
    _assert_graphs_identical(g, g0)
    assert g.name == g0.name
    assert st.engine == "binary"
    assert st.records == st0.records and st.functions == st0.functions
    # header inspect + chunk iteration agree with the full read
    hdr = read_trace_bin_header(rtb)
    assert hdr["n"] == g0.n and hdr["edges"] == g0.num_edges
    assert [c["edges"] for c in hdr["chunks"]] == \
        [500] * (nchunks - 1) + [g0.num_edges - 500 * (nchunks - 1)]
    parts = list(iter_trace_bin_chunks(rtb))
    assert len(parts) == nchunks
    assert np.array_equal(np.concatenate([p[1] for p in parts]), g0.src)
    assert np.array_equal(np.concatenate([p[3] for p in parts]), g0.w)


def test_binary_empty_trace_round_trips(tmp_path):
    g0 = IRGraph(n=0, src=[], dst=[], w=[], name="empty")
    rtb = tmp_path / "e.rtb"
    assert write_trace_bin(rtb, g0) == 0
    g, st = read_trace_bin(rtb)
    assert g.n == 0 and g.num_edges == 0 and st.engine == "binary"
    (hdr, s, d, w), = iter_trace_bin_chunks(rtb)
    assert hdr["edges"] == 0 and len(s) == len(d) == len(w) == 0


def test_binary_gzip_container(tmp_path, monkeypatch):
    path = _write_synth(tmp_path, 600, seed=4)
    g0, st0 = _seq(monkeypatch, path)
    rtb = tmp_path / "t.rtb.gz"
    assert is_binary_trace_path(rtb) and is_binary_trace_path("x.rtb.zst")
    assert not is_binary_trace_path("x.ndjson.gz")
    write_trace_bin(rtb, g0, st0)
    g, st = read_trace_bin(rtb)
    _assert_graphs_identical(g, g0)
    assert st.engine == "binary"


def test_binary_accepted_everywhere(tmp_path, capsys):
    """`.rtb` paths work wherever NDJSON paths do: ingest, load_graph,
    coerce_graph / run_pipeline, the CLI, and `repro.dist`."""
    from repro.trace.__main__ import main
    path = _write_synth(tmp_path, 800, seed=2)
    rtb = str(tmp_path / "t.rtb")
    assert main(["convert", path, rtb]) == 0
    g0, _ = ingest_trace_with_stats(path)
    g, st = ingest_trace_with_stats(rtb)
    assert st.engine == "binary"
    _assert_graphs_identical(g, g0)
    _assert_graphs_identical(load_graph(rtb), g0)
    part_j, _, rep_j = run_pipeline(path, 4, "wb_libra")
    part_b, _, rep_b = run_pipeline(rtb, 4, "wb_libra")
    assert np.array_equal(part_j.assignment, part_b.assignment)
    assert rep_j.exec_time == rep_b.exec_time
    assert main(["inspect", rtb]) == 0
    out = capsys.readouterr().out
    assert '"engine": "binary"' in out
    assert main(["partition", rtb, "-p", "4"]) == 0


def test_binary_dist_workers_identical(tmp_path):
    """`backend="dist"` on a `.rtb` source loads the conversion-time graph
    for any worker count, so workers=1 is bit-identical to "fast"."""
    from repro.dist import dist_ingest_with_stats
    path = _write_synth(tmp_path, 700, seed=6)
    rtb = str(tmp_path / "t.rtb")
    g0, st0 = ingest_trace_with_stats(path)
    write_trace_bin(rtb, g0, st0)
    for workers in (1, 3):
        gd, sd = dist_ingest_with_stats(rtb, workers=workers)
        assert sd.engine == "binary"
        _assert_graphs_identical(gd, g0)
    part_f, _, rep_f = run_pipeline(rtb, 8, "wb_libra", backend="fast")
    part_d, _, rep_d = run_pipeline(rtb, 8, "wb_libra", backend="dist",
                                    workers=1)
    assert np.array_equal(part_f.assignment, part_d.assignment)
    assert rep_f.exec_time == rep_d.exec_time


def test_binary_rejects_cfg(tmp_path):
    from repro.dist import dist_ingest_with_stats
    g0 = IRGraph(n=2, src=[0], dst=[1], w=[1.0])
    rtb = str(tmp_path / "t.rtb")
    write_trace_bin(rtb, g0)
    cfg = ['{"kind":"block","fn":"f","bb":"b0","succs":[]}']
    with pytest.raises(ValueError, match="cfg validation"):
        ingest_trace_with_stats(rtb, cfg=cfg)
    with pytest.raises(ValueError, match="cfg validation"):
        dist_ingest_with_stats(rtb, workers=2, cfg=cfg)


# ---------------------------------------------------------------------- #
# binary container: malformed inputs raise BinaryFormatError
# ---------------------------------------------------------------------- #
def _make_rtb(tmp_path, name="m.rtb", labels=False):
    g = IRGraph(n=3, src=[0, 1, 2, 0], dst=[1, 2, 0, 2],
                w=[1.0, 2.5, 3.0, 0.5],
                node_labels=["a", "b", "a"] if labels else None)
    p = tmp_path / name
    write_trace_bin(p, g, chunk_edges=3)
    return p, p.read_bytes()


def _rewrite_header(raw: bytes, mutate) -> bytes:
    """Re-serialize `raw` with its JSON header passed through `mutate`."""
    version, hlen = struct.unpack("<HI", raw[8:14])
    header = json.loads(raw[14:14 + hlen])
    mutate(header)
    hdr = json.dumps(header, separators=(",", ":")).encode()
    return raw[:8] + struct.pack("<HI", version, len(hdr)) + hdr \
        + raw[14 + hlen:]


def test_binary_bad_magic(tmp_path):
    p, raw = _make_rtb(tmp_path)
    p.write_bytes(b"NOTMAGIC" + raw[8:])
    with pytest.raises(BinaryFormatError, match="bad magic"):
        read_trace_bin(p)
    # an empty file is also "bad magic", not an index error
    p.write_bytes(b"")
    with pytest.raises(BinaryFormatError, match="bad magic"):
        read_trace_bin_header(p)


def test_binary_unsupported_version(tmp_path):
    p, raw = _make_rtb(tmp_path)
    p.write_bytes(raw[:8] + struct.pack("<H", BINARY_VERSION + 1) + raw[10:])
    with pytest.raises(BinaryFormatError, match="unsupported format version"):
        read_trace_bin(p)


def test_binary_truncated_chunk(tmp_path):
    p, raw = _make_rtb(tmp_path)
    p.write_bytes(raw[:-5])
    with pytest.raises(BinaryFormatError, match="truncated chunk"):
        read_trace_bin(p)
    # truncation inside the header is caught too
    p.write_bytes(raw[:20])
    with pytest.raises(BinaryFormatError, match="truncated header"):
        read_trace_bin(p)


def test_binary_dtype_mismatch(tmp_path):
    p, raw = _make_rtb(tmp_path)

    def swap(h):
        h["dtypes"]["w"] = "<f4"
    p.write_bytes(_rewrite_header(raw, swap))
    with pytest.raises(BinaryFormatError, match="dtype mismatch.*'w'"):
        read_trace_bin(p)


def test_binary_header_integrity(tmp_path):
    p, raw = _make_rtb(tmp_path)

    def lie(h):
        h["chunks"][0]["edges"] += 1
    p.write_bytes(_rewrite_header(raw, lie))
    with pytest.raises(BinaryFormatError, match="chunk table sums"):
        read_trace_bin(p)

    def drop(h):
        del h["edges"]
    p.write_bytes(_rewrite_header(raw, drop))
    with pytest.raises(BinaryFormatError, match="missing field 'edges'"):
        read_trace_bin(p)
    _, hlen = struct.unpack("<HI", raw[8:14])
    p.write_bytes(raw[:14] + b"x" * hlen + raw[14 + hlen:])
    with pytest.raises(BinaryFormatError, match="not valid JSON"):
        read_trace_bin(p)


def test_binary_label_id_out_of_range(tmp_path):
    p, raw = _make_rtb(tmp_path, labels=True)
    p.write_bytes(raw[:-4] + struct.pack("<i", 999))
    with pytest.raises(BinaryFormatError, match="label id 999 outside"):
        read_trace_bin(p, keep_labels=True)


def test_binary_endpoint_out_of_range(tmp_path):
    p, raw = _make_rtb(tmp_path)

    def shrink(h):
        h["n"] = 1
    p.write_bytes(_rewrite_header(raw, shrink))
    with pytest.raises(BinaryFormatError, match="endpoint exceeds"):
        read_trace_bin(p)


# ---------------------------------------------------------------------- #
# property test: convert -> ingest round trip (hypothesis, soft dep)
# ---------------------------------------------------------------------- #
def test_binary_round_trip_property(tmp_path):
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property test needs the hypothesis package")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.function_scoped_fixture])
    @given(data=st.data())
    def round_trip(data):
        n = data.draw(st.integers(min_value=1, max_value=50))
        m = data.draw(st.integers(min_value=0, max_value=200))
        ids = st.integers(min_value=0, max_value=n - 1)
        src = data.draw(st.lists(ids, min_size=m, max_size=m))
        dst = data.draw(st.lists(ids, min_size=m, max_size=m))
        w = data.draw(st.lists(
            st.floats(allow_nan=False, allow_infinity=False, width=64),
            min_size=m, max_size=m))
        labels = data.draw(st.one_of(st.none(), st.lists(
            st.text(max_size=6), min_size=n, max_size=n)))
        chunk = data.draw(st.integers(min_value=1, max_value=64))
        g0 = IRGraph(n=n, src=src, dst=dst, w=w, name="prop",
                     node_labels=list(labels) if labels else None)
        p = tmp_path / "prop.rtb"
        write_trace_bin(p, g0, chunk_edges=chunk)
        g1, st1 = read_trace_bin(p, keep_labels=True)
        assert st1.engine == "binary"
        assert g1.n == n and g1.name == "prop"
        assert np.array_equal(g1.src, g0.src)
        assert np.array_equal(g1.dst, g0.dst)
        assert np.array_equal(g1.w, g0.w)      # exact float64 round trip
        assert (g1.node_labels == (list(labels) if labels else None))

    round_trip()


# ---------------------------------------------------------------------- #
# the 10x ingestion gate (binary fast path vs streaming JSON)
# ---------------------------------------------------------------------- #
def test_binary_read_is_10x_faster_than_json(tmp_path, monkeypatch):
    """The tentpole's acceptance gate, asserted in-tree on a small trace:
    reading the converted `.rtb` must beat sequential JSON ingestion by
    >= 10x edges/s on identical output.  (benchmarks/trace_ingest.py
    gates the full 1M-line version; binary loads are ~100x+ even here,
    so the margin absorbs machine noise.)"""
    import time
    path = _write_synth(tmp_path, 20_000, seed=0)
    t0 = time.perf_counter()
    g_json, _ = _seq(monkeypatch, path)
    t_json = time.perf_counter() - t0
    rtb = tmp_path / "t.rtb"
    write_trace_bin(rtb, g_json)
    t_bin = min(_timed(read_trace_bin, rtb) for _ in range(3))
    g_bin, _ = read_trace_bin(rtb)
    _assert_graphs_identical(g_bin, g_json)
    assert t_json / t_bin >= 10.0, \
        f"binary speedup {t_json / t_bin:.1f}x < 10x gate"


def _timed(fn, *args):
    import time
    t0 = time.perf_counter()
    fn(*args)
    return time.perf_counter() - t0
