"""Pallas segment-sum kernel vs the `np.add.reduceat` oracle.

The kernel's contract (see `repro.core.pallas.segsum`): over a sorted
segment-id stream it equals the strict left-to-right per-segment
reduction — *bit-identical* to the sequential numpy oracles
(`np.add.at` / `np.bincount`, the accumulation the pipeline's reference
backends use) for ints and floats alike.  `np.add.reduceat` reduces
pairwise instead, so floats match it to rtol 1e-12 with an
eps-scaled atol for segments that cancel to ~0 (ints are exact against
both).  Layouts are stressed where tiled kernels break: empty
segments, one giant segment spanning many blocks, non-divisible tails,
and block-boundary straddles.  The jitted call must match the op-by-op
interpreter (compiled-vs-interpret parity runs when a real accelerator
is present).
"""
import numpy as np
import pytest

pytest.importorskip("jax", reason="pallas layer needs jax")
from repro.core.pallas import pallas_available  # noqa: E402

if not pallas_available():          # foreign jax/pallas API: skip the file
    pytest.skip("pallas segment-sum probe failed on this jax install",
                allow_module_level=True)

from repro.core.pallas import keyed_sum, segment_sum  # noqa: E402


def _oracle_reduceat(data, sids, nseg):
    """np.add.reduceat over the segment runs, empty segments = 0.
    (reduceat reduces *pairwise* for floats — the documented tolerance.)
    """
    out = np.zeros(nseg, dtype=data.dtype)
    if len(data) == 0:
        return out
    present, starts = np.unique(sids, return_index=True)
    out[present] = np.add.reduceat(data, starts)
    return out


def _oracle_sequential(data, sids, nseg):
    """Strict in-order accumulation — np.add.at is unbuffered/sequential,
    the order the kernel's carry chain reproduces bit for bit."""
    out = np.zeros(nseg, dtype=data.dtype)
    np.add.at(out, sids, data)
    return out


def _check(data, sids, nseg, block):
    got = np.asarray(segment_sum(data, sids, nseg, block_size=block))
    want_seq = _oracle_sequential(data, sids, nseg)
    want_ra = _oracle_reduceat(data, sids, nseg)
    assert got.dtype == want_seq.dtype
    # bit-identical to the sequential oracle, ints and floats alike
    np.testing.assert_array_equal(got, want_seq)
    if np.issubdtype(data.dtype, np.integer):
        np.testing.assert_array_equal(got, want_ra)
    else:
        # eps-scaled atol covers segments whose true sum cancels to ~0,
        # where a pure rtol bound is vacuous for *any* reassociation
        atol = 1e-12 * max(1.0, float(np.abs(data).sum()))
        np.testing.assert_allclose(got, want_ra, rtol=1e-12, atol=atol)


LAYOUTS = [
    # (m, nseg, block, layout) — handcrafted block-boundary stress
    (0, 5, 8, "empty-stream"),
    (7, 1, 4, "single-segment-tail"),
    (64, 1, 8, "one-giant-segment-8-blocks"),
    (33, 50, 8, "non-divisible-tail"),
    (24, 200, 8, "mostly-empty-segments"),
    (48, 3, 16, "segment-spanning-3-blocks"),
]


@pytest.mark.parametrize("m,nseg,block,layout", LAYOUTS)
@pytest.mark.parametrize("dtype", [np.float64, np.int64])
def test_handcrafted_layouts(m, nseg, block, layout, dtype):
    import zlib
    rng = np.random.default_rng(zlib.crc32(layout.encode()))
    if layout == "one-giant-segment-8-blocks":
        sids = np.zeros(m, np.int64)
    elif layout == "segment-spanning-3-blocks":
        # middle segment covers >= 3 full blocks; neighbours are slivers
        sids = np.r_[np.zeros(4), np.ones(40), np.full(4, 2)].astype(np.int64)
    else:
        sids = np.sort(rng.integers(0, nseg, m))
    data = rng.integers(-50, 50, m).astype(dtype)
    if dtype is np.float64:
        data *= np.pi                      # inexact values: rounding matters
    _check(data, sids, nseg, block)


def test_int_weights_bit_identical_large():
    rng = np.random.default_rng(3)
    m, nseg = 20_000, 511
    sids = np.sort(rng.integers(0, nseg, m))
    data = rng.integers(-10**9, 10**9, m)
    got = np.asarray(segment_sum(data, sids, nseg))
    np.testing.assert_array_equal(got, _oracle_reduceat(data, sids, nseg))


def test_keyed_sum_matches_bincount_bit_for_bit():
    """Stable sort + sequential kernel == np.bincount accumulation order."""
    rng = np.random.default_rng(5)
    m, nkeys = 30_000, 777
    keys = rng.integers(0, nkeys, m)
    vals = rng.lognormal(size=m)
    got = np.asarray(keyed_sum(keys, vals, nkeys))
    want = np.bincount(keys, weights=vals, minlength=nkeys)
    np.testing.assert_array_equal(got, want)


def test_interpret_modes_parity():
    """Jitted interpreter vs the same call — parity across cache entries
    and dtypes; on TPU/GPU this also exercises compiled-vs-interpret."""
    import jax
    rng = np.random.default_rng(9)
    m, nseg = 1000, 37
    sids = np.sort(rng.integers(0, nseg, m))
    data = rng.standard_normal(m)
    a = np.asarray(segment_sum(data, sids, nseg, interpret=True))
    b = np.asarray(segment_sum(data, sids, nseg))  # auto mode
    np.testing.assert_array_equal(a, b)
    if jax.default_backend() in ("tpu", "gpu"):    # pragma: no cover - accel
        c = np.asarray(segment_sum(data, sids, nseg, interpret=False))
        np.testing.assert_allclose(c, a, rtol=1e-12)


def test_validate_flags_bad_contracts():
    data = np.ones(4)
    with pytest.raises(ValueError, match="sorted"):
        segment_sum(data, np.array([0, 2, 1, 3]), 4, validate=True)
    with pytest.raises(ValueError, match="lie in"):
        segment_sum(data, np.array([0, 1, 2, 9]), 4, validate=True)
    with pytest.raises(ValueError, match="parallel"):
        segment_sum(data, np.array([0, 1]), 4)


# deeper randomized search when the [test] extra is installed ----------- #
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @st.composite
    def segment_layouts(draw):
        """Random sorted layouts biased toward the nasty shapes: empty
        segments, giant runs, and tails not divisible by the block."""
        nseg = draw(st.integers(1, 64))
        runs = draw(st.lists(
            st.tuples(st.integers(0, nseg - 1), st.integers(1, 70)),
            min_size=0, max_size=12))
        sids = np.sort(np.concatenate(
            [np.full(ln, s, np.int64) for s, ln in runs]
            or [np.empty(0, np.int64)]))
        block = draw(st.sampled_from([2, 8, 32, 4096]))
        return sids, nseg, block

    @given(layout=segment_layouts(),
           dtype=st.sampled_from([np.float64, np.int64]),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_property_matches_reduceat(layout, dtype, seed):
        sids, nseg, block = layout
        rng = np.random.default_rng(seed)
        data = rng.integers(-100, 100, len(sids)).astype(dtype)
        if dtype is np.float64:
            data *= np.e
        _check(data, sids, nseg, block)
