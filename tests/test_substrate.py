"""Substrate tests: data pipeline, optimizer, compression, checkpointing,
fault tolerance, sharding rules."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLM, host_shard
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, compressed_bytes,
                         cosine_schedule, ef_compress_cycle,
                         init_error_feedback)
from repro.runtime import ElasticMesh, StragglerDetector, TrainSupervisor


# ------------------------------ data ---------------------------------- #
def test_data_deterministic_resume():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8)
    d1 = SyntheticLM(cfg)
    d2 = SyntheticLM(cfg)
    np.testing.assert_array_equal(d1.batch(7)["tokens"],
                                  d2.batch(7)["tokens"])
    assert not np.array_equal(d1.batch(7)["tokens"],
                              d1.batch(8)["tokens"])


def test_data_host_sharding_partitions_batch():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    full = SyntheticLM(cfg, shard_id=0, num_shards=1).batch(3)["tokens"]
    parts = [SyntheticLM(cfg, shard_id=i, num_shards=4).batch(3)["tokens"]
             for i in range(4)]
    np.testing.assert_array_equal(full, np.concatenate(parts))
    with pytest.raises(AssertionError):
        host_shard(10, 0, 3)


def test_data_microbatch_split():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8)
    b = SyntheticLM(cfg).batch(0, n_micro=4)
    assert b["tokens"].shape == (4, 2, 8)


# ------------------------------ optim --------------------------------- #
def test_adamw_reduces_quadratic_loss():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                      weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw_init(params, cfg)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_schedule(cfg, jnp.int32(0))) == 0.0
    assert float(cosine_schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(cosine_schedule(cfg, jnp.int32(100))) == pytest.approx(
        0.0, abs=1e-6)


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(grads, 1.0)
    assert float(gn) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0,
                                                                 rel=1e-5)


def test_bf16_moments_supported():
    cfg = AdamWConfig(moment_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    state = adamw_init(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    p2, s2, _ = adamw_update(params, {"w": jnp.ones((8,), jnp.bfloat16)},
                             state, cfg)
    assert p2["w"].dtype == jnp.bfloat16


# --------------------------- compression ------------------------------ #
def test_error_feedback_compression_unbiased_over_time():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal(1000), jnp.float32)}
    ef = init_error_feedback(g)
    applied = jnp.zeros(1000)
    for _ in range(20):
        out, ef = ef_compress_cycle(g, ef)
        applied = applied + out["w"]
    # mean applied converges to the true gradient
    err = float(jnp.abs(applied / 20 - g["w"]).max())
    assert err < 0.05


def test_compression_ratio_about_4x():
    g = {"w": jnp.zeros((10_000,), jnp.float32)}
    raw, comp = compressed_bytes(g)
    assert raw / comp > 3.5


# --------------------------- checkpointing ---------------------------- #
def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "step": jnp.int32(0)}
    for s in (10, 20, 30):
        mgr.save(s, state, meta={"loss": 1.0})
    assert mgr.all_steps() == [20, 30]  # keep=2
    restored, meta = mgr.restore(state)
    assert meta["step"] == 30
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_checkpoint_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = {"w": jnp.ones((4,))}
    mgr.save(5, state, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_crash_between_commit_and_rename(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = {"w": jnp.arange(4.0)}
    mgr.save(5, state)
    # simulate a crash after COMMIT is written but before the atomic
    # rename: a fully-committed .tmp staging dir is left behind
    stale = tmp_path / "step_00000010.tmp"
    stale.mkdir()
    (stale / "COMMIT").touch()
    # the stale dir must not corrupt enumeration, restore, or saves
    assert mgr.all_steps() == [5]
    restored, meta = mgr.restore(state)
    assert meta["step"] == 5
    mgr.save(7, state)
    assert mgr.all_steps() == [5, 7]
    # a fresh manager over the same dir GCs the stale staging dir
    mgr2 = CheckpointManager(str(tmp_path), keep=3)
    assert not stale.exists()
    mgr2.save(10, state)
    assert mgr2.all_steps() == [5, 7, 10]


def test_checkpoint_async_save_error_propagates(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=3)
    # point the manager at a plain file: the writer thread's makedirs
    # fails, and wait() must re-raise instead of reporting success
    mgr.dir = str(tmp_path / "blocked")
    open(mgr.dir, "w").close()
    mgr.save(1, {"w": jnp.ones((2,))}, blocking=False)
    with pytest.raises(OSError):
        mgr.wait()
    # the error is consumed: the manager stays usable afterwards
    mgr.dir = str(tmp_path / "ck")
    mgr.save(2, {"w": jnp.ones((2,))}, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 2


def test_checkpoint_restore_flat(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, {"a": np.arange(5), "b": np.ones((2, 2))},
             meta={"tag": "x"})
    flat, meta = mgr.restore_flat()
    assert meta["step"] == 3 and meta["tag"] == "x"
    np.testing.assert_array_equal(flat["a"], np.arange(5))
    np.testing.assert_array_equal(flat["b"], np.ones((2, 2)))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.ones((5,))})


# --------------------------- fault tolerance -------------------------- #
def test_straggler_detector_flags_outlier():
    det = StragglerDetector(threshold_sigma=3.0, warmup=3)
    for i in range(20):
        det.observe(i, 1.0 + 0.01 * (i % 3))
    assert det.observe(20, 10.0) is True
    assert 20 in det.flagged


def test_elastic_mesh_replan():
    em = ElasticMesh(model_parallel=16)
    full = em.plan(512)
    assert full == {"pod": 2, "data": 16, "model": 16,
                    "devices_used": 512, "devices_idle": 0}
    degraded = em.plan(480)   # lost 2 hosts = 32 chips
    assert degraded["devices_used"] <= 480
    assert degraded["model"] == 16
    assert em.rebatch(256, old_data=32, new_data=degraded["pod"]
                      * degraded["data"]) > 0
    with pytest.raises(RuntimeError):
        em.plan(8)


def test_supervisor_recovers_from_failures(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    sup = TrainSupervisor(mgr, save_every=2, max_restarts=5)
    fail_at = {5}

    def fail_hook(step):
        if step in fail_at:
            fail_at.discard(step)
            raise RuntimeError("simulated host failure")

    def run_step(state, step):
        return {"count": state["count"] + 1}

    state, step = sup.run({"count": jnp.int32(0)}, run_step, n_steps=10,
                          fail_hook=fail_hook)
    assert step == 10
    assert sup.restarts == 1
    # resumed from the last checkpoint, so total increments >= 10
    assert int(state["count"]) >= 10


def test_supervisor_restarts_through_async_save_failure(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    real_write = mgr._write
    armed = {"on": True}

    def flaky_write(step, state, meta):
        if step == 4 and armed["on"]:
            armed["on"] = False
            raise OSError("simulated disk failure")
        real_write(step, state, meta)

    mgr._write = flaky_write
    sup = TrainSupervisor(mgr, save_every=2, max_restarts=5,
                          save_blocking=False)
    state, step = sup.run({"count": jnp.int32(0)},
                          lambda s, i: {"count": s["count"] + 1},
                          n_steps=8)
    # the step-4 async write failed; the error surfaced at the next
    # save's wait(), the supervisor restarted from step 2 and re-saved
    assert step == 8
    assert sup.restarts == 1
    assert int(state["count"]) >= 8
    assert mgr.latest_step() == 8


# --------------------------- sharding rules --------------------------- #
def test_param_specs_cover_tree():
    from repro.configs import ARCHS, reduced_config
    from repro.configs.base import ParallelConfig
    from repro.parallel import param_specs
    from repro import models
    from jax.sharding import PartitionSpec as P

    for name in ("deepseek-v3-671b", "rwkv6-7b", "gemma2-27b"):
        cfg = reduced_config(ARCHS[name])
        params = jax.eval_shape(
            lambda k, c=cfg: models.init_params(c, k),
            jax.random.PRNGKey(0))
        specs = param_specs(params, cfg, ParallelConfig())
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(specs,
                                 is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for p_, s_ in zip(flat_p, flat_s):
            assert len(s_) <= len(p_.shape)


def test_sanitize_specs_drops_nondivisible():
    from repro.parallel.sharding import sanitize_specs
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("model",))
    spec = sanitize_specs(P("model"), jax.ShapeDtypeStruct((7,), jnp.float32),
                          mesh)
    assert spec == P("model")  # 7 % 1 == 0
