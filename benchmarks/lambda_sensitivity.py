"""Paper Fig. 11: execution time of WB-Libra / WB-PG as λ grows from 1.
The W-* variants (no bound) are the asymptote; the paper recommends λ=1.

`exec_time` (per λ) and `w_variant_time` (the unbounded asymptote) are
deterministic model outputs; the committed baseline gates both in CI
via `check_regression.py` so the λ-sensitivity curve cannot silently
reshape."""
from __future__ import annotations

from repro.core import run_pipeline

from .common import emit, graphs, timed_phases

LAMBDAS = (1.0, 1.0004, 1.0008, 1.0012, 1.01, 1.1, 2.0)


def run(scale: str = "reduced", names=None, p: int = 8) -> list[dict]:
    rows = []
    names = names or ["mandel", "md", "nn", "neuron", "strassen16"]
    for g in graphs(scale, names):
        for fam in ("libra", "pg"):
            # unbounded asymptote
            (_, _, w_rep), _us, _ph = timed_phases(run_pipeline, g, p,
                                                   f"w_{fam}")
            times = []
            for lam in LAMBDAS:
                (part, mapping, rep), us, phases = timed_phases(
                    run_pipeline, g, p, f"wb_{fam}", lam=lam)
                times.append(rep.exec_time)
                rows.append({"graph": g.name, "family": fam, "lam": lam,
                             "phases": phases,
                             "exec_time": rep.exec_time,
                             "w_variant_time": w_rep.exec_time})
                emit(f"lambda_sensitivity/{g.name}/wb_{fam}/lam{lam}", us,
                     f"exec_s={rep.exec_time:.3e};"
                     f"w_variant_s={w_rep.exec_time:.3e}")
            trend_up = times[-1] >= times[0] - 1e-12
            emit(f"lambda_sensitivity/{g.name}/wb_{fam}/trend", 0.0,
                 f"lam1_s={times[0]:.3e};lam_max_s={times[-1]:.3e};"
                 f"degrades_with_lambda={trend_up}")
    return rows


if __name__ == "__main__":
    run()
