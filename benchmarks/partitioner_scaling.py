"""Paper §4.4 complexity claim: Weight Balanced Libra is O(|E|·|C|) —
measured here as near-linear edge throughput across |E| and mild growth
in |C| (our lazy-heap engine is O(|E| log |C|), a better constant)."""
from __future__ import annotations

from repro.core import synthesize_powerlaw_graph, vertex_cut

from .common import emit, timed


def run() -> list[dict]:
    rows = []
    for n in (2_000, 8_000, 32_000):
        g = synthesize_powerlaw_graph(n=n, alpha=2.2, seed=0)
        for p in (8, 64, 512):
            r, us = timed(vertex_cut, g, p, method="wb_libra")
            per_edge = us / max(g.num_edges, 1)
            rows.append({"edges": g.num_edges, "p": p,
                         "us_per_edge": per_edge})
            emit(f"partitioner_scaling/E{g.num_edges}/p{p}", us,
                 f"us_per_edge={per_edge:.3f}")
    return rows


if __name__ == "__main__":
    run()
