"""Paper §4.4 complexity claim: Weight Balanced Libra is O(|E|·|C|) —
measured as edge throughput across |E| and |C| for both streaming
engines.  The fast backend (array-native; C kernel when a compiler is
present) is benchmarked against the reference oracle loop at the paper's
1024-cluster scale and on a >=500k-edge power-law graph; the reference
is swept only at the 32k-vertex scale where it finishes in seconds.

Emits the usual CSV rows plus machine-readable
`BENCH_partitioner_scaling.json` (see benchmarks/check_regression.py for
the CI perf gate against the committed baseline).
"""
from __future__ import annotations

from repro.core import resolve_backend, synthesize_powerlaw_graph, vertex_cut
from repro.core.pallas import require_pallas
from repro.core.pallas.cost import partitioner_finalize_cost

from .common import emit, timed_phases, write_bench_json
from .roofline import roofline_fraction

# (n, p sweep, backends); the reference oracle only runs at <=32k vertices
SMALL_NS = (2_000, 8_000, 32_000)
SMALL_PS = (8, 64, 512)
BIG_N = 300_000          # >=500k edges at alpha=2.2 (paper §4.4 scale)
BIG_PS = (512, 1024)
REPEATS = 5
# pallas rows get an untimed warmup (jax compiles must never score —
# the reference-probe calibration cannot track compile-cache state)
BACKEND_REPEATS = {"fast": REPEATS, "reference": 2, "pallas": 3}


def _row(g, n, p, backend, repeats=REPEATS):
    if backend == "pallas":
        vertex_cut(g, p, method="wb_libra", backend=backend)  # warm compiles
    r, us, phases = timed_phases(vertex_cut, g, p, method="wb_libra",
                                 backend=backend, repeats=repeats)
    per_edge = us / max(g.num_edges, 1)
    row = {"n": n, "edges": g.num_edges, "p": p, "backend": backend,
           "us_per_edge": round(per_edge, 4), "us_total": round(us, 1),
           "replication_factor": round(r.replication_factor, 4),
           "phases": phases}
    if backend == "pallas":
        # lowered-HLO cost of the on-accelerator finalize, judged against
        # the roofline over its measured (finalize-phase) time
        cost = partitioner_finalize_cost(n, g.num_edges, p)
        row["hlo_flops"] = cost["flops"]
        row["hlo_hbm_bytes"] = cost["hbm_bytes"]
        row["roofline_fraction"] = round(roofline_fraction(
            cost["flops"], cost["hbm_bytes"],
            phases.get("finalize") or us), 6)
    emit(f"partitioner_scaling/E{g.num_edges}/p{p}/{backend}", us,
         f"us_per_edge={per_edge:.3f}")
    return row


def run() -> list[dict]:
    engine = resolve_backend("fast")
    rows = []
    by_key = {}
    # the pallas column (fast stream + on-accelerator finalize; interpret
    # mode on CPU) runs the small sweep only — same rows as the reference
    # calibration probe, gated against its own baseline.  Its rows are
    # committed baseline coverage, so a broken pallas layer fails loudly
    # here rather than as a misleading "coverage lost" gate message.
    require_pallas()
    backends = ("fast", "reference", "pallas")
    for n in SMALL_NS:
        g = synthesize_powerlaw_graph(n=n, alpha=2.2, seed=0)
        for p in SMALL_PS:
            for backend in backends:
                # reference rows double as the machine-speed calibration
                # probe in check_regression.py — keep them best-of-2
                row = _row(g, n, p, backend,
                           repeats=BACKEND_REPEATS[backend])
                rows.append(row)
                by_key[(n, p, backend)] = row

    # headline ratio at the paper's scaling point (32k vertices, p=512)
    fast = by_key[(32_000, 512, "fast")]
    ref = by_key[(32_000, 512, "reference")]
    speedup = ref["us_per_edge"] / max(fast["us_per_edge"], 1e-9)
    emit("partitioner_scaling/speedup_E32k_p512", fast["us_total"],
         f"fast_vs_reference={speedup:.1f}x")

    # paper §4.4 scale: >=500k edges, up to 1024 clusters (fast only —
    # the reference loop needs minutes here); best-of-2 so one scheduler
    # hiccup cannot bake a ~5x-loose row into a committed baseline
    g = synthesize_powerlaw_graph(n=BIG_N, alpha=2.2, seed=0)
    for p in BIG_PS:
        rows.append(_row(g, BIG_N, p, "fast", repeats=2))

    write_bench_json("partitioner_scaling", rows,
                     meta={"engine": engine,
                           "speedup_E32k_p512": round(speedup, 2)})
    return rows


if __name__ == "__main__":
    run()
