"""Re-run the loop-aware HLO analysis over cached dry-run HLO dumps
(dryrun_hlo/*.hlo.gz) and refresh the metrics in dryrun_results.json —
lets the cost model iterate without recompiling 64 cells."""
from __future__ import annotations

import gzip
import json
import os
import sys

from repro.analysis import analyze_hlo


def main(results="dryrun_results.json", hlo_dir="dryrun_hlo") -> None:
    with open(results) as f:
        recs = json.load(f)
    n = 0
    for rec in recs:
        if not rec.get("ok"):
            continue
        tag = rec["cell"].replace("/", "_") + "_" + rec["mesh"]
        path = os.path.join(hlo_dir, tag + ".hlo.gz")
        if not os.path.exists(path):
            continue
        with gzip.open(path, "rt") as f:
            la = analyze_hlo(f.read())
        rec["hlo_flops"] = la.flops
        rec["hlo_hbm_bytes"] = la.hbm_bytes
        rec["hlo_collective_bytes"] = la.collective_bytes
        rec["hlo_collective_bytes_bf16eq"] = la.collective_bytes_bf16eq
        rec["hlo_collective_counts"] = la.collective_counts
        n += 1
    with open(results, "w") as f:
        json.dump(recs, f, indent=1)
    print(f"reanalyzed {n} records")


if __name__ == "__main__":
    main(*sys.argv[1:])
