"""Mapping/simulator pipeline scaling: the Fig. 1 path *after* the cut.

Times the array-native map-and-score stage — `cluster_interaction_graphs`
(replica-CSR segment ops) + `memory_centric_mapping` (masked-argmin
placement) + `simulate` (CSR replica-sync triples) — against the
reference oracle loops on a power-law graph at the paper's cluster
scales, p in {8, 64, 256, 1024}.  The partition itself is computed once
per p with the fast engine and shared by all backends, so the rows
isolate the mapping/simulator layer this suite gates.

A third `pallas` column runs the same stage through the on-accelerator
segment-sum kernel; it is committed baseline coverage, so the suite
*requires* a working Pallas layer and fails loudly with the probe's
error otherwise.  On CPU CI that column measures *interpret mode* (the
honest number for the container target — expect it well above the
numpy fast path; the gate only holds it to its own baseline, and its
quality fields pin the model outputs to the other backends').

Rows carry both throughput (`us_per_cluster`) and the pipeline's quality
outputs (`exec_time`, `data_comm_bytes` — Tables 6-9 quantities), so the
CI gate catches algorithmic regressions as well as slowdowns.  Emits the
usual CSV rows plus machine-readable `BENCH_mapping_pipeline.json`
(see benchmarks/check_regression.py).
"""
from __future__ import annotations

from repro.core import (Machine, cluster_interaction_graphs,
                        memory_centric_mapping, simulate,
                        synthesize_powerlaw_graph, vertex_bytes_model,
                        vertex_cut)
from repro.core.pallas import require_pallas
from repro.core.pallas.cost import interaction_cost, keyed_sum_cost

from .common import emit, timed_phases, write_bench_json
from .roofline import roofline_fraction

N = 100_000              # >=170k edges at alpha=2.2
PS = (8, 64, 256, 1024)
REPEATS = 5
# repeats per backend: the reference rows double as the machine-speed
# calibration probe in check_regression.py (best-of-2); the pallas rows
# get an untimed warmup call first (jax compiles op-by-op per novel
# shape — the reference-probe calibration cannot track compile-cache
# state, so compiles must never score) and then best-of-3
BACKEND_REPEATS = {"fast": REPEATS, "reference": 2, "pallas": 3}


def _merge_costs(*costs: dict) -> dict:
    return {"flops": sum(c["flops"] for c in costs),
            "hbm_bytes": sum(c["hbm_bytes"] for c in costs)}


def _map_and_score(g, cut, vb, machine, backend):
    comm, shared = cluster_interaction_graphs(cut, cut.p, vb,
                                              backend=backend)
    mapping = memory_centric_mapping(comm, shared, machine, backend=backend)
    return simulate(g, cut, mapping, backend=backend)


def run() -> list[dict]:
    g = synthesize_powerlaw_graph(n=N, alpha=2.2, seed=0)
    vb = vertex_bytes_model(g)
    rows = []
    by_key = {}
    # the pallas column is *gated coverage* (its rows live in the
    # committed baseline), so a broken pallas layer must fail here with
    # the probe's error — silently dropping the column would surface as
    # a misleading "baseline coverage lost" in check_regression.py
    require_pallas()
    backends = ("fast", "reference", "pallas")
    for p in PS:
        cut = vertex_cut(g, p, method="wb_libra")
        machine = Machine.for_clusters(p)
        for backend in backends:
            if backend == "pallas":
                _map_and_score(g, cut, vb, machine, backend)  # warm compiles
            rep, us, phases = timed_phases(_map_and_score, g, cut, vb,
                                           machine, backend,
                                           repeats=BACKEND_REPEATS[backend])
            per_cluster = us / p
            row = {"n": N, "edges": g.num_edges, "p": p, "backend": backend,
                   "us_per_cluster": round(per_cluster, 3),
                   "us_total": round(us, 1),
                   "exec_time": rep.exec_time,
                   "data_comm_bytes": rep.data_comm_bytes,
                   "phases": phases}
            if backend == "pallas":
                # device work: interaction reductions + the simulator's
                # three keyed sums (per-cluster compute, per-core fold,
                # replica-sync wait — the triple stream is ~|members|)
                members = len(cut.replica_csr()[1])
                cost = _merge_costs(
                    interaction_cost(members, p),
                    keyed_sum_cost(g.num_edges, p),
                    keyed_sum_cost(p, machine.n_cores),
                    keyed_sum_cost(members, machine.n_cores))
                row["hlo_flops"] = cost["flops"]
                row["hlo_hbm_bytes"] = cost["hbm_bytes"]
                row["roofline_fraction"] = round(roofline_fraction(
                    cost["flops"], cost["hbm_bytes"], us), 6)
            rows.append(row)
            by_key[(p, backend)] = row
            emit(f"mapping_pipeline/p{p}/{backend}", us,
                 f"us_per_cluster={per_cluster:.2f}")

    # headline ratio at the paper's extreme scale (p=1024 planning)
    fast = by_key[(1024, "fast")]
    ref = by_key[(1024, "reference")]
    speedup = ref["us_total"] / max(fast["us_total"], 1e-9)
    emit("mapping_pipeline/speedup_p1024", fast["us_total"],
         f"fast_vs_reference={speedup:.1f}x")

    write_bench_json("mapping_pipeline", rows,
                     meta={"speedup_p1024": round(speedup, 2)})
    return rows


if __name__ == "__main__":
    run()
