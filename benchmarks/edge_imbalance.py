"""Paper Table 5: edge-weight imbalance of the six vertex-cut methods
(λ=1 for the WB variants, to match the paper's setting)."""
from __future__ import annotations

from repro.core import vertex_cut

from .common import VERTEX_METHODS, emit, graphs, timed


def run(scale: str = "reduced", p: int = 8, names=None) -> list[dict]:
    rows = []
    for g in graphs(scale, names):
        row = {"graph": g.name}
        for m in VERTEX_METHODS:
            r, us = timed(vertex_cut, g, p, method=m, lam=1.0)
            row[m] = r.edge_weight_imbalance
            emit(f"edge_imbalance/{g.name}/{m}", us,
                 f"imbalance={r.edge_weight_imbalance:.5f}")
        # the paper's two key orderings
        row["wb_beats_w_libra"] = row["wb_libra"] <= row["w_libra"] + 1e-9
        row["wb_beats_w_pg"] = row["wb_pg"] <= row["w_pg"] + 1e-9
        rows.append(row)
    return rows


if __name__ == "__main__":
    run()
