"""Paper Table 5: edge-weight imbalance of the six vertex-cut methods
(λ=1 for the WB variants, to match the paper's setting).

One row per (graph, method) with the deterministic `imbalance` output,
so `check_regression.py` pins every cell of the table against the
committed baseline in CI.  The WB rows additionally carry
`excess_vs_unbounded` = max(0, wb - w): the paper's key ordering
(each bounded variant at or below its unbounded sibling) holds exactly
when it is 0, and since the committed baseline is 0 everywhere, *any*
positive excess blows past the 1% quality gate — the ordering itself is
CI-gated, not just the individual cells."""
from __future__ import annotations

from repro.core import vertex_cut

from .common import VERTEX_METHODS, emit, graphs, timed_phases


def run(scale: str = "reduced", p: int = 8, names=None) -> list[dict]:
    rows = []
    for g in graphs(scale, names):
        by_method = {}
        for m in VERTEX_METHODS:
            r, us, phases = timed_phases(vertex_cut, g, p, method=m,
                                         lam=1.0)
            by_method[m] = {"graph": g.name, "method": m,
                            "phases": phases,
                            "imbalance": r.edge_weight_imbalance}
            rows.append(by_method[m])
            emit(f"edge_imbalance/{g.name}/{m}", us,
                 f"imbalance={r.edge_weight_imbalance:.5f}")
        # the paper's two key orderings, as a gated quality field on the
        # WB rows (0 == ordering holds; see module docstring).  The 1e-9
        # cushion matches the historical tolerance so a last-ulp rounding
        # shift in a future numpy can't explode the zero-baseline ratio
        for fam in ("libra", "pg"):
            excess = max(0.0, by_method[f"wb_{fam}"]["imbalance"]
                         - by_method[f"w_{fam}"]["imbalance"] - 1e-9)
            by_method[f"wb_{fam}"]["excess_vs_unbounded"] = excess
            emit(f"edge_imbalance/{g.name}/wb_{fam}/ordering", 0.0,
                 f"excess_vs_unbounded={excess:.3e};holds={excess == 0.0}")
    return rows


if __name__ == "__main__":
    run()
