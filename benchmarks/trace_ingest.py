"""Trace-ingestion front end: parse throughput + downstream cut quality.

Streams synthetic TRACE_SCHEMA v0 NDJSON (>=1M lines at the headline
point) through `repro.trace.ingest_trace` and reports edges/second, then
partitions the ingested graph with WB-Libra and reports the replication
factor — so a regression in either the parser or the graph it builds
fails CI (`benchmarks/baselines/trace_ingest.json`).

The `reference` backend is a deliberately naive ingester (materialise
every record dict, single unchunked pass) kept both as the readable
oracle — the bench asserts graph equality against the streaming engine —
and as the host-speed calibration probe for `check_regression.py`.
Streaming-mode discipline is asserted outright: the peak Python edge
buffer must stay bounded by the chunk size, not the trace length.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core import vertex_cut
from repro.core.graph import IRGraph
from repro.trace import (ingest_trace_with_stats, resolve_weight_model,
                         synthesize_trace, type_bytes)

from .common import emit, timed, write_bench_json

CACHE_DIR = ".cache/traces"
SMALL_LINES = 100_000
BIG_LINES = 1_000_000
CHUNK_EDGES = 1 << 16
CUT_P = 64


def reference_ingest(path: str, weight_model: str = "bytes") -> IRGraph:
    """Naive oracle: all records as dicts, one unchunked pass."""
    weight_fn = resolve_weight_model(weight_model)
    with open(path, "r", encoding="utf-8") as f:
        records = [json.loads(line) for line in f if line.strip()]
    defs: dict = {}
    src, dst, w, n = [], [], [], 0
    for rec in records:
        if "kind" in rec:
            continue
        fn = rec.get("fn", "?")
        nid = n
        n += 1
        use_tys = rec.get("use_tys")
        for i, u in enumerate(rec.get("uses", [])):
            if (fn, u) in defs:
                pid, pbytes = defs[(fn, u)]
            elif u.startswith("const:"):
                pid, pbytes, n = n, None, n + 1
            else:
                pid, pbytes, n = n, None, n + 1
                defs[(fn, u)] = (pid, None)
            src.append(pid)
            dst.append(nid)
            w.append(weight_fn(rec["op"],
                               use_tys[i] if use_tys is not None else None,
                               pbytes))
        if rec.get("def") is not None:
            ty = rec.get("def_ty")
            defs[(fn, rec["def"])] = (
                nid, type_bytes(ty) if isinstance(ty, str) else None)
    return IRGraph(n=n, src=np.asarray(src, np.int32),
                   dst=np.asarray(dst, np.int32),
                   w=np.asarray(w, np.float64), name="reference")


def _trace_path(lines: int) -> str:
    os.makedirs(CACHE_DIR, exist_ok=True)
    path = os.path.join(CACHE_DIR, f"synth_{lines}_seed0.ndjson")
    if not os.path.exists(path):
        synthesize_trace(path, lines, seed=0)
    return path


def _row(lines: int, model: str, backend: str, with_quality: bool) -> dict:
    path = _trace_path(lines)
    if backend == "fast":
        (g, stats), us = timed(ingest_trace_with_stats, path,
                               weight_model=model, chunk_edges=CHUNK_EDGES)
        # streaming discipline: buffer bounded by chunk, not trace size
        assert stats.peak_chunk_edges <= CHUNK_EDGES + 8, \
            f"edge buffer {stats.peak_chunk_edges} exceeds chunk bound"
    else:
        g, us = timed(reference_ingest, path, model)
    row = {"lines": lines, "model": model, "backend": backend,
           "edges": g.num_edges,
           "us_per_edge": round(us / max(g.num_edges, 1), 4),
           "us_total": round(us, 1),
           "edges_per_s": round(g.num_edges / (us / 1e6), 1)}
    if with_quality:
        cut = vertex_cut(g, CUT_P, method="wb_libra", backend="fast")
        row["replication_factor"] = round(cut.replication_factor, 4)
    emit(f"trace_ingest/L{lines}/{model}/{backend}", us,
         f"edges_per_s={row['edges_per_s']:.0f}")
    return row, g


def run() -> list[dict]:
    rows = []
    small, g_fast = _row(SMALL_LINES, "bytes", "fast", with_quality=True)
    rows.append(small)
    ref, g_ref = _row(SMALL_LINES, "bytes", "reference", with_quality=False)
    rows.append(ref)
    # the naive oracle must agree with the streaming engine bit-for-bit
    assert g_fast.n == g_ref.n, (g_fast.n, g_ref.n)
    assert np.array_equal(g_fast.src, g_ref.src)
    assert np.array_equal(g_fast.dst, g_ref.dst)
    assert np.array_equal(g_fast.w, g_ref.w)
    rows.append(_row(SMALL_LINES, "memop-latency", "fast",
                     with_quality=False)[0])
    big, _ = _row(BIG_LINES, "bytes", "fast", with_quality=True)
    rows.append(big)

    speedup = ref["us_per_edge"] / max(small["us_per_edge"], 1e-9)
    emit("trace_ingest/speedup_L100k", small["us_total"],
         f"fast_vs_reference={speedup:.2f}x")
    write_bench_json("trace_ingest", rows,
                     meta={"chunk_edges": CHUNK_EDGES, "cut_p": CUT_P,
                           "edges_per_s_1M": big["edges_per_s"],
                           "speedup_L100k": round(speedup, 2)})
    return rows


if __name__ == "__main__":
    run()
