"""Trace-ingestion front end: parse throughput + downstream cut quality.

Streams synthetic TRACE_SCHEMA v0 NDJSON (>=1M lines at the headline
point) through every ingestion engine and reports edges/second, then
partitions the ingested graph with WB-Libra and reports the replication
factor — so a regression in either the parsers or the graph they build
fails CI (`benchmarks/baselines/trace_ingest.json`).

Engines benchmarked (the `backend` column; see docs/trace-format.md):

  * ``fast``      — the sequential streaming interpreter (scanner forced
                    off via `REPRO_TRACE_SCANNER=0`), the semantic
                    reference for both fast paths;
  * ``auto``      — the default dispatch (`REPRO_TRACE_SCANNER` unset):
                    the scanner engages only within its size budget
                    (`REPRO_TRACE_SCAN_MAX_MB`), else the stream engine
                    runs — whichever wins at that scale;
  * ``scan``      — the vectorized structural-index scanner
                    (`repro.trace.scan`), forced on regardless of size
                    (the diagnostic row that shows *why* the budget
                    exists: it loses past the cache-friendly regime);
  * ``binary``    — reading the `.rtb` columnar container produced by
                    one-time conversion (`repro.trace.binfmt`);
  * ``reference`` — a deliberately naive ingester (materialise every
                    record dict, single unchunked pass) kept both as the
                    readable oracle — the bench asserts graph equality
                    against the streaming engine — and as the host-speed
                    calibration probe for `check_regression.py`.

Every engine's graph is asserted bit-identical to the ``fast`` graph
before its row is emitted.  The ingestion-wall gate lives in the meta:
``speedup_binary_1M`` (binary vs fast edges/s, same run, same machine)
must stay >= 10x — asserted here and re-checked in CI via
``check_regression.py --min-speedup 10 --speedup-key speedup_binary_1M``.
Streaming-mode discipline is asserted outright: the peak Python edge
buffer must stay bounded by the chunk size, not the trace length.
"""
from __future__ import annotations

import contextlib
import json
import os

import numpy as np

from repro import obs
from repro.core import vertex_cut
from repro.core.graph import IRGraph
from repro.trace import (SCANNER_ENV, ingest_trace_with_stats, read_trace_bin,
                         resolve_weight_model, synthesize_trace, type_bytes,
                         write_trace_bin)

from .common import emit, timed, timed_phases, write_bench_json

CACHE_DIR = ".cache/traces"
SMALL_LINES = 100_000
BIG_LINES = 1_000_000
CHUNK_EDGES = 1 << 16
CUT_P = 64
MIN_BINARY_SPEEDUP = 10.0       # the tentpole's ingestion-wall gate

_convert_us: dict = {}          # lines -> one-time .rtb conversion cost


@contextlib.contextmanager
def _scanner(state: str):
    """Pin the NDJSON scanner on ("1"), off ("0"), or default dispatch
    ("auto" — env unset, the size heuristic decides) for one timing."""
    old = os.environ.get(SCANNER_ENV)
    if state == "auto":
        os.environ.pop(SCANNER_ENV, None)
    else:
        os.environ[SCANNER_ENV] = state
    try:
        yield
    finally:
        if old is None:
            os.environ.pop(SCANNER_ENV, None)
        else:
            os.environ[SCANNER_ENV] = old


def reference_ingest(path: str, weight_model: str = "bytes") -> IRGraph:
    """Naive oracle: all records as dicts, one unchunked pass."""
    weight_fn = resolve_weight_model(weight_model)
    with open(path, "r", encoding="utf-8") as f:
        records = [json.loads(line) for line in f if line.strip()]
    defs: dict = {}
    src, dst, w, n = [], [], [], 0
    for rec in records:
        if "kind" in rec:
            continue
        fn = rec.get("fn", "?")
        nid = n
        n += 1
        use_tys = rec.get("use_tys")
        for i, u in enumerate(rec.get("uses", [])):
            if (fn, u) in defs:
                pid, pbytes = defs[(fn, u)]
            elif u.startswith("const:"):
                pid, pbytes, n = n, None, n + 1
            else:
                pid, pbytes, n = n, None, n + 1
                defs[(fn, u)] = (pid, None)
            src.append(pid)
            dst.append(nid)
            w.append(weight_fn(rec["op"],
                               use_tys[i] if use_tys is not None else None,
                               pbytes))
        if rec.get("def") is not None:
            ty = rec.get("def_ty")
            defs[(fn, rec["def"])] = (
                nid, type_bytes(ty) if isinstance(ty, str) else None)
    return IRGraph(n=n, src=np.asarray(src, np.int32),
                   dst=np.asarray(dst, np.int32),
                   w=np.asarray(w, np.float64), name="reference")


def _trace_path(lines: int) -> str:
    os.makedirs(CACHE_DIR, exist_ok=True)
    path = os.path.join(CACHE_DIR, f"synth_{lines}_seed0.ndjson")
    if not os.path.exists(path):
        synthesize_trace(path, lines, seed=0)
    return path


def _bin_path(lines: int, model: str) -> str:
    """One-time NDJSON -> .rtb conversion (the cost `convert` amortises)."""
    path = os.path.join(CACHE_DIR, f"synth_{lines}_seed0_{model}.rtb")
    if not os.path.exists(path):
        g, stats = ingest_trace_with_stats(_trace_path(lines),
                                           weight_model=model,
                                           chunk_edges=CHUNK_EDGES)
        _, us = timed(write_trace_bin, path, g, stats)
        _convert_us[lines] = round(us, 1)
    return path


def _reference_spanned(path: str, model: str) -> IRGraph:
    # the naive oracle has no internal telemetry; the bench wraps it so
    # its rows still carry a parse-phase breakdown
    with obs.span("trace.ingest", engine="reference"):
        return reference_ingest(path, model)


def _row(lines: int, model: str, backend: str, with_quality: bool):
    path = _trace_path(lines)
    if backend == "fast":
        with _scanner("0"):
            (g, stats), us, phases = timed_phases(
                ingest_trace_with_stats, path, weight_model=model,
                chunk_edges=CHUNK_EDGES)
        assert stats.engine == "stream", stats.engine
        # streaming discipline: buffer bounded by chunk, not trace size
        assert stats.peak_chunk_edges <= CHUNK_EDGES + 8, \
            f"edge buffer {stats.peak_chunk_edges} exceeds chunk bound"
    elif backend == "scan":
        with _scanner("1"):
            (g, stats), us, phases = timed_phases(
                ingest_trace_with_stats, path, weight_model=model,
                chunk_edges=CHUNK_EDGES)
        assert stats.engine == "scan", \
            f"scanner fell back to {stats.engine!r} on {path}"
    elif backend == "auto":
        with _scanner("auto"):
            (g, stats), us, phases = timed_phases(
                ingest_trace_with_stats, path, weight_model=model,
                chunk_edges=CHUNK_EDGES)
        engine_used = stats.engine
    elif backend == "binary":
        bpath = _bin_path(lines, model)
        (g, stats), us, phases = timed_phases(read_trace_bin, bpath,
                                              repeats=3)
        assert stats.engine == "binary", stats.engine
    else:
        g, us, phases = timed_phases(_reference_spanned, path, model)
    row = {"lines": lines, "model": model, "backend": backend,
           "edges": g.num_edges,
           "us_per_edge": round(us / max(g.num_edges, 1), 4),
           "us_total": round(us, 1),
           "edges_per_s": round(g.num_edges / (us / 1e6), 1),
           "phases": phases}
    if backend == "auto":
        row["engine"] = engine_used
    if with_quality:
        cut = vertex_cut(g, CUT_P, method="wb_libra", backend="fast")
        row["replication_factor"] = round(cut.replication_factor, 4)
    emit(f"trace_ingest/L{lines}/{model}/{backend}", us,
         f"edges_per_s={row['edges_per_s']:.0f}")
    return row, g


def _assert_identical(g: IRGraph, ref: IRGraph, what: str) -> None:
    assert g.n == ref.n, (what, g.n, ref.n)
    assert np.array_equal(g.src, ref.src), what
    assert np.array_equal(g.dst, ref.dst), what
    assert np.array_equal(g.w, ref.w), what


def run() -> list[dict]:
    rows = []
    small, g_fast = _row(SMALL_LINES, "bytes", "fast", with_quality=True)
    rows.append(small)
    ref, g_ref = _row(SMALL_LINES, "bytes", "reference", with_quality=False)
    rows.append(ref)
    # the naive oracle must agree with the streaming engine bit-for-bit
    _assert_identical(g_fast, g_ref, "fast-vs-reference L100k")
    rows.append(_row(SMALL_LINES, "memop-latency", "fast",
                     with_quality=False)[0])
    for backend in ("scan", "binary"):
        r, g = _row(SMALL_LINES, "bytes", backend, with_quality=False)
        _assert_identical(g, g_fast, f"{backend} L100k")
        rows.append(r)
    auto_small, g = _row(SMALL_LINES, "bytes", "auto", with_quality=False)
    _assert_identical(g, g_fast, "auto L100k")
    # ~10 MB is inside the scanner's size budget: auto must pick it
    assert auto_small["engine"] == "scan", auto_small["engine"]
    rows.append(auto_small)
    big, g_big = _row(BIG_LINES, "bytes", "fast", with_quality=True)
    rows.append(big)
    scan_big, g = _row(BIG_LINES, "bytes", "scan", with_quality=False)
    _assert_identical(g, g_big, "scan L1M")
    rows.append(scan_big)
    auto_big, g = _row(BIG_LINES, "bytes", "auto", with_quality=False)
    _assert_identical(g, g_big, "auto L1M")
    # ~100 MB is past the budget: auto must fall back to the stream
    # engine the forced-scan row just lost to
    assert auto_big["engine"] == "stream", auto_big["engine"]
    rows.append(auto_big)
    bin_big, g = _row(BIG_LINES, "bytes", "binary", with_quality=False)
    _assert_identical(g, g_big, "binary L1M")
    rows.append(bin_big)

    speedup = ref["us_per_edge"] / max(small["us_per_edge"], 1e-9)
    sp_forced = scan_big["edges_per_s"] / max(big["edges_per_s"], 1e-9)
    # the default-dispatch gate: when auto resolves to the stream engine
    # the ratio is 1.0 *by definition* (same code ran; re-timing it would
    # only measure noise), else it is the measured auto-vs-stream ratio
    sp_scan = (1.0 if auto_big["engine"] == "stream"
               else auto_big["edges_per_s"] / max(big["edges_per_s"], 1e-9))
    sp_bin = bin_big["edges_per_s"] / max(big["edges_per_s"], 1e-9)
    emit("trace_ingest/speedup_L100k", small["us_total"],
         f"fast_vs_reference={speedup:.2f}x")
    emit("trace_ingest/speedup_1M", big["us_total"],
         f"auto={sp_scan:.2f}x forced_scan={sp_forced:.2f}x "
         f"binary={sp_bin:.2f}x")
    # the default dispatch must never lose to the stream engine
    assert sp_scan >= 1.0, \
        f"auto ingest dispatch {sp_scan:.2f}x loses to the stream engine"
    # the ingestion-wall gate: convert-once must beat re-parsing 10x
    assert sp_bin >= MIN_BINARY_SPEEDUP, \
        f"binary ingest speedup {sp_bin:.1f}x < {MIN_BINARY_SPEEDUP}x gate"
    write_bench_json("trace_ingest", rows,
                     meta={"chunk_edges": CHUNK_EDGES, "cut_p": CUT_P,
                           "edges_per_s_1M": bin_big["edges_per_s"],
                           "edges_per_s_stream_1M": big["edges_per_s"],
                           "speedup_L100k": round(speedup, 2),
                           "speedup_scan_1M": round(sp_scan, 2),
                           "speedup_scan_forced_1M": round(sp_forced, 2),
                           "speedup_binary_1M": round(sp_bin, 2),
                           "convert_us_1M": _convert_us.get(BIG_LINES)})
    return rows


if __name__ == "__main__":
    run()
