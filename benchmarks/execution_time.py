"""Paper Tables 6-7 / Fig. 9: simulated execution time per method and
cluster count on the NUMA machine model, normalised to CompNet (the
paper's headline: WB-Libra 1.56x / 1.86x over CompNet at 8 / 1024)."""
from __future__ import annotations

from repro.core import run_pipeline

from .common import ALL_METHODS, emit, graphs, timed_phases

P_VALUES = (8, 64, 1024)


def run(scale: str = "reduced", names=None,
        p_values=P_VALUES) -> list[dict]:
    rows = []
    for g in graphs(scale, names):
        for p in p_values:
            base = None
            for m in ALL_METHODS:
                (part, mapping, rep), us, phases = timed_phases(
                    run_pipeline, g, p, m)
                if m == "compnet":
                    base = rep
                speed = base.exec_time / rep.exec_time
                rows.append({"graph": g.name, "p": p, "method": m,
                             "phases": phases,
                             "exec_time": rep.exec_time,
                             "speedup_vs_compnet": speed})
                emit(f"execution_time/{g.name}/p{p}/{m}", us,
                     f"exec_s={rep.exec_time:.3e};"
                     f"speedup_vs_compnet={speed:.2f}x")
    return rows


if __name__ == "__main__":
    run()
