"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import json
import os
import platform
import time

import numpy as np

from repro import obs
from repro.core import all_benchmark_names, build_graph

VERTEX_METHODS = ("pg", "libra", "w_pg", "wb_pg", "w_libra", "wb_libra")
EDGE_METHODS = ("compnet", "metis")
ALL_METHODS = EDGE_METHODS + VERTEX_METHODS

CACHE_DIR = ".cache/benchgraphs"

# Span-name -> phase attribution for BENCH row "phases" dicts.  Only
# cat=="op" spans are summed — "section" envelopes (pipeline.*) and
# "wait" spans wrap or overlap the ops and would double-count.
PHASE_OF = {
    "trace.ingest": "parse",
    "parse.shard": "parse",
    "parse.merge": "parse",
    "cut.stream": "cut",
    "dist.cut": "cut",
    "dist.merge": "merge",
    "cut.finalize": "finalize",
    "dist.finalize": "finalize",
    "map.place": "map",
    "map.cluster_graphs": "map",
    "sim.run": "simulate",
    "serve.fingerprint": "fingerprint",
    "serve.cache_load": "cache",
    "serve.cache_store": "cache",
}


def phases_of(events) -> dict:
    """Fold a collector's op spans into {phase: total_us} via PHASE_OF."""
    out: dict = {}
    for ev in events:
        if ev.get("ph") != "X" or ev.get("cat", "op") != "op":
            continue
        phase = PHASE_OF.get(ev["name"])
        if phase is not None:
            out[phase] = round(out.get(phase, 0.0) + ev.get("dur", 0.0), 1)
    return out


def graphs(scale: str = "reduced", names=None):
    for name in (names or all_benchmark_names()):
        yield build_graph(name, scale=scale, cache_dir=CACHE_DIR)


def timed(fn, *args, **kw):
    # perf_counter, not time.time(): the gated rows need a monotonic
    # clock — wall time can step backwards under NTP adjustment
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6  # us


def timed_best(fn, *args, repeats: int = 1, **kw):
    """Best-of-N timing — the robust estimator for perf-gated rows."""
    best_us, out = float("inf"), None
    for _ in range(max(1, repeats)):
        o, us = timed(fn, *args, **kw)
        if us < best_us:
            best_us, out = us, o
    return out, best_us


def timed_phases(fn, *args, repeats: int = 1, **kw):
    """Best-of-N timing with phase attribution.

    Each repeat runs under a scoped collector; returns
    ``(out, best_us, phases)`` where ``phases`` maps phase name to
    total op-span microseconds for the *best* repeat, so the breakdown
    is consistent with the gated number.
    """
    best_us, out, phases = float("inf"), None, {}
    for _ in range(max(1, repeats)):
        with obs.scoped() as col:
            o, us = timed(fn, *args, **kw)
        if us < best_us:
            best_us, out, phases = us, o, phases_of(col.events)
    return out, best_us, phases


def emit(name: str, us: float, derived: str) -> None:
    """Assignment-required CSV line: name,us_per_call,derived."""
    print(f"{name},{us:.1f},{derived}")


def bench_output_path(suite: str) -> str:
    out_dir = os.environ.get("BENCH_OUTPUT_DIR", ".")
    return os.path.join(out_dir, f"BENCH_{suite}.json")


def write_bench_json(suite: str, rows: list, meta: dict | None = None) -> str:
    """Machine-readable benchmark emission consumed by the CI perf gate."""
    doc = {
        "suite": suite,
        "meta": {
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
            "python": platform.python_version(),
            "numpy": np.__version__,
            "machine": platform.machine(),
            **(meta or {}),
        },
        "rows": rows,
    }
    path = bench_output_path(suite)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, default=float)
        f.write("\n")
    return path
