"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import time

from repro.core import all_benchmark_names, build_graph

VERTEX_METHODS = ("pg", "libra", "w_pg", "wb_pg", "w_libra", "wb_libra")
EDGE_METHODS = ("compnet", "metis")
ALL_METHODS = EDGE_METHODS + VERTEX_METHODS

CACHE_DIR = ".cache/benchgraphs"


def graphs(scale: str = "reduced", names=None):
    for name in (names or all_benchmark_names()):
        yield build_graph(name, scale=scale, cache_dir=CACHE_DIR)


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6  # us


def emit(name: str, us: float, derived: str) -> None:
    """Assignment-required CSV line: name,us_per_call,derived."""
    print(f"{name},{us:.1f},{derived}")
