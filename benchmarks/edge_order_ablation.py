"""Edge-stream-order ablation (referenced from core/vertex_cut.py):
program-order vs loader-shuffled streams for bounded and unbounded
greedy variants.  Quantifies the finding recorded in DESIGN.md §2 —
a connected program-order trace funnels unbounded greedy cuts into one
cluster, while the λ bound (WB-*) is robust to either order."""
from __future__ import annotations

from repro.core import vertex_cut

from .common import emit, graphs, timed

METHODS = ("w_libra", "wb_libra")
ORDERS = ("trace", "shuffled")


def run(scale: str = "reduced", names=None, p: int = 8) -> list[dict]:
    rows = []
    for g in graphs(scale, names or ["dijkstra", "fft", "nn"]):
        for m in METHODS:
            for order in ORDERS:
                r, us = timed(vertex_cut, g, p, method=m,
                              edge_order=order)
                rows.append({"graph": g.name, "method": m, "order": order,
                             "imbalance": r.edge_weight_imbalance,
                             "rf": r.replication_factor_active})
                emit(f"edge_order/{g.name}/{m}/{order}", us,
                     f"imbalance={r.edge_weight_imbalance:.4f};"
                     f"rf={r.replication_factor_active:.3f}")
        # the headline: WB bounded under trace order, W unbounded blows up
        wb = [r for r in rows if r["graph"] == g.name
              and r["method"] == "wb_libra" and r["order"] == "trace"][0]
        w = [r for r in rows if r["graph"] == g.name
             and r["method"] == "w_libra" and r["order"] == "trace"][0]
        emit(f"edge_order/{g.name}/lambda_bound_robustness", 0.0,
             f"wb_trace_imb={wb['imbalance']:.3f};"
             f"w_trace_imb={w['imbalance']:.3f};"
             f"bound_protects={wb['imbalance'] < w['imbalance']}")
    return rows


if __name__ == "__main__":
    run()
