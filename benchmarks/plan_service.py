"""Plan service: content-addressed cache + incremental repartitioning.

Serving-regime throughput of `repro.serve` on the 276k-line trace
(>= 510k edges, the partitioner_scaling headline scale):

  * ``cold`` — first request through `PlanService`: parse + cut + map +
    simulate + persist.  Tagged ``backend=reference`` so it doubles as
    the host-speed calibration probe for `check_regression.py` (it is
    the plain sequential pipeline; its engine rarely changes).
  * ``cache_hit`` — the same request again on the same service: the
    fingerprint resolves in the hot map, nothing is parsed or cut.
  * ``warm_restart`` — a *fresh* service over the same cache directory:
    the bundle is reloaded from the `checkpoint.store` files on disk.
  * ``incremental_cold`` — `IncrementalPlanner` fed the whole trace in
    one window, then `plan()`.
  * ``incremental_warm`` — a planner pre-fed the first 90% of the trace
    (state warm, durable CSR built); timed portion appends the last 10%
    window and re-plans.  Only dirty replica-CSR rows are re-decoded.

  * ``zipf_mix`` — the production request mix: many requests over few
    distinct programs, source popularity Zipf-skewed, served by an
    LRU-*bounded* service (`max_hot_entries` < distinct sources) so the
    hot map churns: head sources stay resident, tail sources evict and
    reload from disk.  The row reports sustained ``plans_per_s``, the
    deterministic ``hit_rate``, and the live latency ``p50_us``/
    ``p99_us`` straight from `PlanService.metrics()`.

Gates (`benchmarks/baselines/plan_service.json` + CI):
  * meta.speedup_cache_hit = cold / cache_hit >= 50x (a hit must cost
    dictionary-lookup time, not pipeline time);
  * meta.speedup_incremental = incremental_cold / incremental_warm >=
    3x (re-planning a 10% window must not pay the full-recut price);
  * meta.zipf_hit_rate >= 0.9 (checked in CI via
    ``--min-speedup 0.9 --speedup-key zipf_hit_rate``: the hit rate of
    the fixed request sequence is deterministic, so a drop means the
    cache or fingerprint layer broke);
  * replication_factor per row at quality factor 1.01 — every stage is
    deterministic, so any drift means the algorithm changed.

Bit-identity is asserted outright, not gated: the cache-hit and
warm-restart bundles must equal the cold bundle array-for-array, and
the warm incremental plan must equal the cold incremental plan over the
concatenated trace (the `repro.serve` window-invariance contract).
So are the live-metrics invariants: `PlanService.metrics()` must agree
with the request history (tier counts, hit rate, evictions), and the
memory-tier p99 must sit far below the cold-tier p50.
"""
from __future__ import annotations

import io
import os
import shutil

import numpy as np

from repro.serve import IncrementalPlanner, PlanRequest, PlanService

from .common import emit, timed_phases, write_bench_json

CACHE_DIR = ".cache/traces"
PLAN_CACHE = ".cache/plans_bench"
LINES = 276_000          # ingests to >= 510k edges (headline scale)
CUT_P = 64
LAM = 1.1
WARM_FRACTION = 0.9      # pre-fed share for the incremental_warm stage
HIT_REPEATS = 5          # hits are cheap and idempotent: best-of-5

# ----- the zipf_mix serving scenario ----- #
ZIPF_CACHE = ".cache/plans_bench_zipf"
ZIPF_LINES = 2_000       # small programs: the mix is about cache traffic
ZIPF_SOURCES = 8         # distinct programs in the request universe
ZIPF_REQUESTS = 1_000
ZIPF_EXPONENT = 1.2      # popularity ~ 1/rank^1.2
ZIPF_HOT_ENTRIES = 4     # < ZIPF_SOURCES: the LRU bound must churn
ZIPF_P = 16


def _trace_path(lines: int) -> str:
    os.makedirs(CACHE_DIR, exist_ok=True)
    path = os.path.join(CACHE_DIR, f"synth_{lines}_seed0.ndjson")
    if not os.path.exists(path):
        from repro.trace import synthesize_trace
        synthesize_trace(path, lines, seed=0)
    return path


def _row(stage: str, backend: str, edges: int, us: float,
         rf: float, phases: "dict | None" = None) -> dict:
    row = {"lines": LINES, "stage": stage, "backend": backend,
           "edges": edges, "us_total": round(us, 1),
           "replication_factor": round(rf, 4),
           "phases": phases or {}}
    emit(f"plan_service/{stage}", us, f"rf={rf:.4f}")
    return row


def _zipf_mix() -> "tuple[dict, dict]":
    """Serve the skewed request mix through an LRU-bounded service;
    returns (bench row, live metrics snapshot)."""
    from repro.trace import synthesize_trace
    paths = []
    for i in range(ZIPF_SOURCES):
        p = os.path.join(CACHE_DIR,
                         f"synth_{ZIPF_LINES}_seed{100 + i}.ndjson")
        if not os.path.exists(p):
            synthesize_trace(p, ZIPF_LINES, seed=100 + i)
        paths.append(p)
    pop = 1.0 / np.arange(1, ZIPF_SOURCES + 1) ** ZIPF_EXPONENT
    picks = np.random.default_rng(0).choice(
        ZIPF_SOURCES, size=ZIPF_REQUESTS, p=pop / pop.sum())
    reqs = [PlanRequest(source=paths[i], p=ZIPF_P, method="wb_libra",
                        lam=LAM) for i in picks]

    shutil.rmtree(ZIPF_CACHE, ignore_errors=True)   # cold universe
    svc = PlanService(cache_dir=ZIPF_CACHE,
                      max_hot_entries=ZIPF_HOT_ENTRIES)

    def serve():
        for req in reqs:
            svc.plan(req)

    _, us, phases = timed_phases(serve)
    m = svc.metrics()

    # the request sequence is fixed, so the traffic split is too: first
    # sight of each source is the only miss — an evicted bundle comes
    # back from disk as a (slower) *hit*, never as a re-plan
    expect_hits = ZIPF_REQUESTS - ZIPF_SOURCES
    assert m["plans"] == ZIPF_REQUESTS and m["hits"] == expect_hits, \
        (m["plans"], m["hits"])
    assert m["hit_rate"] == round(expect_hits / ZIPF_REQUESTS, 4), \
        m["hit_rate"]
    assert m["tiers"]["cold"]["count"] == ZIPF_SOURCES, m["tiers"]
    assert m["evictions"] > 0, \
        "LRU bound below the source count produced no evictions"
    assert m["hot_entries"] <= ZIPF_HOT_ENTRIES, m["hot_entries"]
    assert m["tiers"]["disk"]["count"] > 0, \
        "evicted bundles never reloaded from disk"
    # hits must stay in dictionary-lookup territory: the memory-tier
    # p99 far below the cold-tier median
    assert m["tiers"]["memory"]["p99_us"] * 5 \
        < m["tiers"]["cold"]["p50_us"], m["tiers"]
    assert m["plan_latency_p99_us"] > 0 and m["plans_per_s"] > 0, m

    row = {"lines": ZIPF_LINES, "stage": "zipf_mix", "backend": "serve",
           "requests": ZIPF_REQUESTS, "distinct": ZIPF_SOURCES,
           "hot_entries": ZIPF_HOT_ENTRIES,
           "us_total": round(us, 1),
           "hit_rate": m["hit_rate"],
           "plans_per_s": m["plans_per_s"],
           "p50_us": m["plan_latency_p50_us"],
           "p99_us": m["plan_latency_p99_us"],
           "phases": phases}
    emit("plan_service/zipf_mix", us,
         f"plans_per_s={m['plans_per_s']:.0f} hit_rate={m['hit_rate']} "
         f"evictions={m['evictions']} p99_us={m['plan_latency_p99_us']}")
    return row, m


def _assert_same_bundle(a, b, what: str) -> None:
    for field in ("assignment", "loads", "replica_indptr", "replica_flat",
                  "core_of"):
        assert np.array_equal(getattr(a, field), getattr(b, field)), \
            f"{what}: bundle field {field} diverged from the cold plan"
    assert a.exec_time == b.exec_time and a.comm_bytes == b.comm_bytes, \
        f"{what}: simulated cost diverged from the cold plan"


def run() -> list[dict]:
    path = _trace_path(LINES)
    shutil.rmtree(PLAN_CACHE, ignore_errors=True)  # cold must be cold
    rows = []
    req = PlanRequest(source=path, p=CUT_P, method="wb_libra", lam=LAM)

    svc = PlanService(cache_dir=PLAN_CACHE)
    cold, us_cold, ph_cold = timed_phases(lambda: svc.plan(req))
    assert cold.cache == "cold"
    m = int(cold.bundle.edge_counts.sum())
    rows.append(_row("cold", "reference", m, us_cold,
                     cold.bundle.replication_factor, ph_cold))

    hit, us_hit, ph_hit = timed_phases(lambda: svc.plan(req),
                                       repeats=HIT_REPEATS)
    assert hit.cache == "memory"
    _assert_same_bundle(hit.bundle, cold.bundle, "cache_hit")
    rows.append(_row("cache_hit", "serve", m, us_hit,
                     hit.bundle.replication_factor, ph_hit))

    # the always-on registry must agree with the request history
    live = svc.metrics()
    assert live["misses"] == 1 and live["hits"] == HIT_REPEATS, live
    assert live["tiers"]["cold"]["count"] == 1, live["tiers"]
    assert live["tiers"]["memory"]["count"] == HIT_REPEATS, live["tiers"]
    assert live["plan_latency_p99_us"] > 0, live

    def restart():
        return PlanService(cache_dir=PLAN_CACHE).plan(req)

    warm, us_warm, ph_warm = timed_phases(restart, repeats=HIT_REPEATS)
    assert warm.cache == "disk"
    _assert_same_bundle(warm.bundle, cold.bundle, "warm_restart")
    rows.append(_row("warm_restart", "serve", m, us_warm,
                     warm.bundle.replication_factor, ph_warm))

    # ----- incremental repartitioning: 10% appended window ----- #
    def inc_cold():
        pl = IncrementalPlanner(p=CUT_P, method="wb_libra", lam=LAM)
        pl.append(path)
        return pl.plan()

    (_, cut_c, _, rep_c), us_inc_cold, ph_inc_c = timed_phases(inc_cold)
    rows.append(_row("incremental_cold", "serve", m, us_inc_cold,
                     cut_c.replication_factor, ph_inc_c))

    with open(path) as f:
        lines = f.read().splitlines(keepends=True)
    split = int(len(lines) * WARM_FRACTION)
    pl = IncrementalPlanner(p=CUT_P, method="wb_libra", lam=LAM)
    pl.append(io.StringIO("".join(lines[:split])))
    pl.plan()                       # builds the durable CSR (untimed)

    def inc_warm():
        pl.append(io.StringIO("".join(lines[split:])))
        return pl.plan()

    (_, cut_w, _, rep_w), us_inc_warm, ph_inc_w = timed_phases(inc_warm)
    rows.append(_row("incremental_warm", "serve", m, us_inc_warm,
                     cut_w.replication_factor, ph_inc_w))
    # the window-invariance contract: warm == cold recut, bit for bit
    for field in ("assignment", "loads", "edge_counts", "replica_indptr",
                  "replica_flat"):
        assert np.array_equal(getattr(cut_w, field),
                              getattr(cut_c, field)), \
            f"incremental_warm: {field} diverged from the cold recut"
    assert rep_w.exec_time == rep_c.exec_time, \
        "incremental_warm: simulated cost diverged from the cold recut"

    # ----- the skewed serving mix over an LRU-bounded service ----- #
    zipf_row, zipf_metrics = _zipf_mix()
    rows.append(zipf_row)

    speedup_hit = us_cold / max(us_hit, 1e-9)
    speedup_restart = us_cold / max(us_warm, 1e-9)
    speedup_inc = us_inc_cold / max(us_inc_warm, 1e-9)
    emit("plan_service/speedup_cache_hit", us_hit,
         f"vs_cold={speedup_hit:.0f}x")
    emit("plan_service/speedup_incremental", us_inc_warm,
         f"vs_cold={speedup_inc:.2f}x")
    write_bench_json("plan_service", rows,
                     meta={"lines": LINES, "cut_p": CUT_P, "lam": LAM,
                           "warm_fraction": WARM_FRACTION,
                           "edges": m,
                           "speedup_cache_hit": round(speedup_hit, 1),
                           "speedup_warm_restart": round(speedup_restart, 1),
                           "speedup_incremental": round(speedup_inc, 2),
                           "hit_p50_us": live["tiers"]["memory"]["p50_us"],
                           "hit_p99_us": live["tiers"]["memory"]["p99_us"],
                           "zipf_hit_rate": zipf_metrics["hit_rate"],
                           "zipf_plans_per_s": zipf_metrics["plans_per_s"],
                           "zipf_evictions": zipf_metrics["evictions"],
                           "zipf_p99_us":
                               zipf_metrics["plan_latency_p99_us"]})
    return rows


if __name__ == "__main__":
    run()
