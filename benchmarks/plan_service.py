"""Plan service: content-addressed cache + incremental repartitioning.

Serving-regime throughput of `repro.serve` on the 276k-line trace
(>= 510k edges, the partitioner_scaling headline scale):

  * ``cold`` — first request through `PlanService`: parse + cut + map +
    simulate + persist.  Tagged ``backend=reference`` so it doubles as
    the host-speed calibration probe for `check_regression.py` (it is
    the plain sequential pipeline; its engine rarely changes).
  * ``cache_hit`` — the same request again on the same service: the
    fingerprint resolves in the hot map, nothing is parsed or cut.
  * ``warm_restart`` — a *fresh* service over the same cache directory:
    the bundle is reloaded from the `checkpoint.store` files on disk.
  * ``incremental_cold`` — `IncrementalPlanner` fed the whole trace in
    one window, then `plan()`.
  * ``incremental_warm`` — a planner pre-fed the first 90% of the trace
    (state warm, durable CSR built); timed portion appends the last 10%
    window and re-plans.  Only dirty replica-CSR rows are re-decoded.

Gates (`benchmarks/baselines/plan_service.json` + CI):
  * meta.speedup_cache_hit = cold / cache_hit >= 50x (a hit must cost
    dictionary-lookup time, not pipeline time);
  * meta.speedup_incremental = incremental_cold / incremental_warm >=
    3x (re-planning a 10% window must not pay the full-recut price);
  * replication_factor per row at quality factor 1.01 — every stage is
    deterministic, so any drift means the algorithm changed.

Bit-identity is asserted outright, not gated: the cache-hit and
warm-restart bundles must equal the cold bundle array-for-array, and
the warm incremental plan must equal the cold incremental plan over the
concatenated trace (the `repro.serve` window-invariance contract).
"""
from __future__ import annotations

import io
import os
import shutil

import numpy as np

from repro.serve import IncrementalPlanner, PlanRequest, PlanService

from .common import emit, timed_best, write_bench_json

CACHE_DIR = ".cache/traces"
PLAN_CACHE = ".cache/plans_bench"
LINES = 276_000          # ingests to >= 510k edges (headline scale)
CUT_P = 64
LAM = 1.1
WARM_FRACTION = 0.9      # pre-fed share for the incremental_warm stage
HIT_REPEATS = 5          # hits are cheap and idempotent: best-of-5


def _trace_path(lines: int) -> str:
    os.makedirs(CACHE_DIR, exist_ok=True)
    path = os.path.join(CACHE_DIR, f"synth_{lines}_seed0.ndjson")
    if not os.path.exists(path):
        from repro.trace import synthesize_trace
        synthesize_trace(path, lines, seed=0)
    return path


def _row(stage: str, backend: str, edges: int, us: float,
         rf: float) -> dict:
    row = {"lines": LINES, "stage": stage, "backend": backend,
           "edges": edges, "us_total": round(us, 1),
           "replication_factor": round(rf, 4)}
    emit(f"plan_service/{stage}", us, f"rf={rf:.4f}")
    return row


def _assert_same_bundle(a, b, what: str) -> None:
    for field in ("assignment", "loads", "replica_indptr", "replica_flat",
                  "core_of"):
        assert np.array_equal(getattr(a, field), getattr(b, field)), \
            f"{what}: bundle field {field} diverged from the cold plan"
    assert a.exec_time == b.exec_time and a.comm_bytes == b.comm_bytes, \
        f"{what}: simulated cost diverged from the cold plan"


def run() -> list[dict]:
    path = _trace_path(LINES)
    shutil.rmtree(PLAN_CACHE, ignore_errors=True)  # cold must be cold
    rows = []
    req = PlanRequest(source=path, p=CUT_P, method="wb_libra", lam=LAM)

    svc = PlanService(cache_dir=PLAN_CACHE)
    cold, us_cold = timed_best(lambda: svc.plan(req), repeats=1)
    assert cold.cache == "cold"
    m = int(cold.bundle.edge_counts.sum())
    rows.append(_row("cold", "reference", m, us_cold,
                     cold.bundle.replication_factor))

    hit, us_hit = timed_best(lambda: svc.plan(req), repeats=HIT_REPEATS)
    assert hit.cache == "memory"
    _assert_same_bundle(hit.bundle, cold.bundle, "cache_hit")
    rows.append(_row("cache_hit", "serve", m, us_hit,
                     hit.bundle.replication_factor))

    def restart():
        return PlanService(cache_dir=PLAN_CACHE).plan(req)

    warm, us_warm = timed_best(restart, repeats=HIT_REPEATS)
    assert warm.cache == "disk"
    _assert_same_bundle(warm.bundle, cold.bundle, "warm_restart")
    rows.append(_row("warm_restart", "serve", m, us_warm,
                     warm.bundle.replication_factor))

    # ----- incremental repartitioning: 10% appended window ----- #
    def inc_cold():
        pl = IncrementalPlanner(p=CUT_P, method="wb_libra", lam=LAM)
        pl.append(path)
        return pl.plan()

    (_, cut_c, _, rep_c), us_inc_cold = timed_best(inc_cold, repeats=1)
    rows.append(_row("incremental_cold", "serve", m, us_inc_cold,
                     cut_c.replication_factor))

    with open(path) as f:
        lines = f.read().splitlines(keepends=True)
    split = int(len(lines) * WARM_FRACTION)
    pl = IncrementalPlanner(p=CUT_P, method="wb_libra", lam=LAM)
    pl.append(io.StringIO("".join(lines[:split])))
    pl.plan()                       # builds the durable CSR (untimed)

    def inc_warm():
        pl.append(io.StringIO("".join(lines[split:])))
        return pl.plan()

    (_, cut_w, _, rep_w), us_inc_warm = timed_best(inc_warm, repeats=1)
    rows.append(_row("incremental_warm", "serve", m, us_inc_warm,
                     cut_w.replication_factor))
    # the window-invariance contract: warm == cold recut, bit for bit
    for field in ("assignment", "loads", "edge_counts", "replica_indptr",
                  "replica_flat"):
        assert np.array_equal(getattr(cut_w, field),
                              getattr(cut_c, field)), \
            f"incremental_warm: {field} diverged from the cold recut"
    assert rep_w.exec_time == rep_c.exec_time, \
        "incremental_warm: simulated cost diverged from the cold recut"

    speedup_hit = us_cold / max(us_hit, 1e-9)
    speedup_restart = us_cold / max(us_warm, 1e-9)
    speedup_inc = us_inc_cold / max(us_inc_warm, 1e-9)
    emit("plan_service/speedup_cache_hit", us_hit,
         f"vs_cold={speedup_hit:.0f}x")
    emit("plan_service/speedup_incremental", us_inc_warm,
         f"vs_cold={speedup_inc:.2f}x")
    write_bench_json("plan_service", rows,
                     meta={"lines": LINES, "cut_p": CUT_P, "lam": LAM,
                           "warm_fraction": WARM_FRACTION,
                           "edges": m,
                           "speedup_cache_hit": round(speedup_hit, 1),
                           "speedup_warm_restart": round(speedup_restart, 1),
                           "speedup_incremental": round(speedup_inc, 2)})
    return rows


if __name__ == "__main__":
    run()
