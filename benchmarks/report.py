"""Render EXPERIMENTS.md tables from dryrun_results.json + the paper-scale
benchmark CSV.  Usage:

    PYTHONPATH=src python -m benchmarks.report [--dryrun FILE] [--bench FILE]
"""
from __future__ import annotations

import argparse
import json
import os

from .roofline import ICI_BW, analyze_record

HBM_PER_CHIP = 16e9


def roofline_table(path: str) -> str:
    with open(path) as f:
        results = json.load(f)
    latest, skips = {}, []
    for r in results:
        if r.get("ok"):
            latest[(r["cell"], r["mesh"])] = r
        elif r.get("ok") is None:
            skips.append((r["cell"], r["mesh"]))
    lines = ["| cell | mesh | compute s | memory s | collective s (bf16-eq) "
             "| dominant | useful | roofline | fits 16GB |",
             "|---|---|---|---|---|---|---|---|---|"]
    for (cell, mesh), rec in sorted(latest.items()):
        a = analyze_record(rec)
        eq = rec.get("hlo_collective_bytes_bf16eq") or rec.get(
            "hlo_collective_bytes", {})
        t_coll_eq = sum(eq.values()) / ICI_BW
        mem = rec.get("memory", {})
        static = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0))
        fits = "yes" if static <= HBM_PER_CHIP else \
            f"NO ({static/1e9:.0f}GB)"
        lines.append(
            f"| {cell} | {mesh} | {a['t_compute_s']:.2e} "
            f"| {a['t_memory_s']:.2e} | {a['t_collective_s']:.2e} "
            f"({t_coll_eq:.2e}) | {a['dominant']} "
            f"| {a['useful_ratio']:.2f} | {100*a['roofline_fraction']:.1f}% "
            f"| {fits} |")
    n_ok = len(latest)
    n_skip = len(set(skips))
    head = (f"{n_ok} cells compiled OK; {n_skip} skipped per assignment "
            "(long_500k on full-attention archs).\n\n")
    return head + "\n".join(lines)


def repro_summary(path: str) -> str:
    if not os.path.exists(path):
        return "(paper-scale benchmark output not found)"
    rows = [ln.strip() for ln in open(path) if "," in ln]
    out = []
    ub = [ln for ln in rows if "under_bound=" in ln]
    if ub:
        good = sum(1 for ln in ub if "under_bound=True" in ln)
        out.append(f"- Fig. 8 replication factor: {good}/{len(ub)} "
                   "greedy results under the Eq. (10) bound.")
    sp = [ln for ln in rows if ln.startswith("execution_time/") and
          "wb_libra" in ln]
    if sp:
        import re
        by_p: dict = {}
        for ln in sp:
            m = re.search(r"/p(\d+)/", ln)
            v = re.search(r"speedup_vs_compnet=([\d.]+)x", ln)
            if m and v:
                by_p.setdefault(int(m.group(1)), []).append(
                    float(v.group(1)))
        for p in sorted(by_p):
            vs = by_p[p]
            out.append(f"- WB-Libra speedup vs CompNet at p={p}: "
                       f"mean {sum(vs)/len(vs):.2f}x "
                       f"(range {min(vs):.2f}-{max(vs):.2f}x) "
                       f"over {len(vs)} graphs.")
    dc = [ln for ln in rows if ln.startswith("data_comm/") and
          ("wb_libra" in ln or "/metis" in ln)]
    if dc:
        import re
        for meth in ("wb_libra", "metis"):
            vs = [float(re.search(r"pct_of_compnet=([\d.]+)%", ln).group(1))
                  for ln in dc if f"/{meth}" in ln and "pct_of_compnet" in ln]
            if vs:
                out.append(f"- {meth} data communication vs CompNet=100%: "
                           f"mean {sum(vs)/len(vs):.0f}% over {len(vs)} "
                           "cells.")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="dryrun_results.json")
    ap.add_argument("--bench", default="bench_paper_output.txt")
    args = ap.parse_args()
    print("## Roofline table\n")
    print(roofline_table(args.dryrun))
    print("\n## Reproduction summary\n")
    print(repro_summary(args.bench))


if __name__ == "__main__":
    main()
