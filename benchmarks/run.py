"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes each suite's
rows to machine-readable ``BENCH_<suite>.json`` (``BENCH_OUTPUT_DIR``
overrides the target directory) so CI can track the perf trajectory —
see ``benchmarks/check_regression.py``.  Default runs at reduced graph
scale (CI-friendly); ``--paper`` uses the paper's Table 3 input sizes;
``--graphs`` limits to a comma list.

    PYTHONPATH=src python -m benchmarks.run
    PYTHONPATH=src python -m benchmarks.run --paper --only execution_time
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (cluster_sweep, data_comm, dist_scaling, edge_imbalance,
               edge_order_ablation, exec_and_comm, execution_time,
               expert_placement, lambda_sensitivity, mapping_pipeline,
               partitioner_scaling, plan_service, replication_factor,
               roofline, trace_ingest)
from .common import write_bench_json

# suites that write their own BENCH_*.json with extra metadata
SELF_WRITING = {"partitioner_scaling", "mapping_pipeline", "trace_ingest",
                "dist_scaling", "plan_service"}
# opt-in suites skipped by a default (no --only) run: their rows are a
# re-sweep of exec_and_comm's combined pass
OPT_IN = {"execution_time", "data_comm"}

SUITES = {
    "replication_factor": lambda a: replication_factor.run(
        scale=a.scale, names=a.names),            # paper Fig. 8
    "edge_imbalance": lambda a: edge_imbalance.run(
        scale=a.scale, names=a.names),            # paper Table 5
    "exec_and_comm": lambda a: exec_and_comm.run(
        scale=a.scale, names=a.names),  # paper Tables 6-9 in one pass
    # the split Table 6-7 / 8-9 suites repeat exec_and_comm's sweep, so
    # they are opt-in (--only) rather than part of the default run
    "execution_time": lambda a: execution_time.run(
        scale=a.scale, names=a.names),            # paper Tables 6-7
    "data_comm": lambda a: data_comm.run(
        scale=a.scale, names=a.names),            # paper Tables 8-9
    "lambda_sensitivity": lambda a: lambda_sensitivity.run(
        scale=a.scale, names=a.names),            # paper Fig. 11
    "partitioner_scaling": lambda a: partitioner_scaling.run(),  # §4.4
    "mapping_pipeline": lambda a: mapping_pipeline.run(),  # §5-§6 fast path
    "trace_ingest": lambda a: trace_ingest.run(),  # NDJSON front end
    "dist_scaling": lambda a: dist_scaling.run(),  # sharded workers sweep
    "plan_service": lambda a: plan_service.run(),  # serve cache + increm.
    "edge_order_ablation": lambda a: edge_order_ablation.run(
        scale=a.scale, names=a.names),            # DESIGN §2 finding
    "cluster_sweep": lambda a: cluster_sweep.run(
        scale=a.scale, names=a.names),            # paper Figs 9-10 sweep
    "expert_placement": lambda a: expert_placement.run(),  # beyond-paper EP
    "roofline": lambda a: roofline.run(a.roofline_json),  # bench HLO costs
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--paper", action="store_true",
                    help="paper-scale (Table 3) benchmark inputs")
    ap.add_argument("--only", default=None,
                    help="comma list of suites to run")
    ap.add_argument("--graphs", default=None,
                    help="comma list of benchmark graphs")
    ap.add_argument("--roofline-json", default=None, dest="roofline_json",
                    help="roofline input: a BENCH_*.json (bench mode) or "
                         "a dryrun_results.json (legacy TPU mode); "
                         "default scans the bench outputs")
    args = ap.parse_args()
    args.scale = "paper" if args.paper else "reduced"
    args.names = args.graphs.split(",") if args.graphs else None

    only = set(args.only.split(",")) if args.only else None
    if only and not only <= set(SUITES):
        sys.exit(f"unknown suite(s): {sorted(only - set(SUITES))}; "
                 f"choose from {sorted(SUITES)}")
    print("name,us_per_call,derived")
    for name, fn in SUITES.items():
        if (only and name not in only) or (not only and name in OPT_IN):
            continue
        t0 = time.time()
        try:
            rows = fn(args)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/SUITE_ERROR,0.0,{type(e).__name__}:{e}",
                  file=sys.stderr)
            raise
        if rows and name not in SELF_WRITING:
            write_bench_json(name, rows)
        print(f"# suite {name} done in {time.time() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
