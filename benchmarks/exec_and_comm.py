"""Paper Tables 6-9 in ONE pass: execution time and data communication
come from the same (partition, mapping, simulate) pipeline, so computing
them together halves the cost of the full-scale runs."""
from __future__ import annotations

from repro.core import run_pipeline

from .common import ALL_METHODS, emit, graphs, timed_phases

P_VALUES = (8, 64, 1024)


def run(scale: str = "reduced", names=None, p_values=P_VALUES):
    rows = []
    for g in graphs(scale, names):
        for p in p_values:
            base = None
            for m in ALL_METHODS:
                (part, mapping, rep), us, phases = timed_phases(
                    run_pipeline, g, p, m)
                if m == "compnet":
                    base = rep
                speed = base.exec_time / rep.exec_time
                pct = 100.0 * rep.data_comm_bytes / base.data_comm_bytes
                rows.append({"graph": g.name, "p": p, "method": m,
                             "phases": phases,
                             "exec_time": rep.exec_time,
                             "speedup_vs_compnet": speed,
                             "pct_of_compnet": pct})
                emit(f"execution_time/{g.name}/p{p}/{m}", us,
                     f"exec_s={rep.exec_time:.3e};"
                     f"speedup_vs_compnet={speed:.2f}x")
                emit(f"data_comm/{g.name}/p{p}/{m}", 0.0,
                     f"bytes={rep.data_comm_bytes:.3e};"
                     f"pct_of_compnet={pct:.1f}%")
    return rows


if __name__ == "__main__":
    run()
