"""Paper Figs. 9-10 full cluster sweep: execution time and communication
across p = 8..1024 (the U-shaped communication trend beyond 128 clusters
from §6.2.4).

Rows carry the deterministic model outputs (`exec_time`,
`data_comm_bytes`) under the names `check_regression.py` treats as
measured, so the committed baseline gates the sweep in CI: any drift in
these quantities means the partition/mapping/simulation algorithms
changed, not the machine."""
from __future__ import annotations

from repro.core import run_pipeline

from .common import emit, graphs, timed_phases

P_SWEEP = (8, 16, 32, 64, 128, 256, 512, 1024)


def run(scale: str = "reduced", names=None) -> list[dict]:
    rows = []
    for g in graphs(scale, names or ["fft", "kmeans"]):
        for m in ("compnet", "wb_libra"):
            times, comms = [], []
            for p in P_SWEEP:
                (part, mapping, rep), us, phases = timed_phases(
                    run_pipeline, g, p, m)
                times.append(rep.exec_time)
                comms.append(rep.data_comm_bytes)
                rows.append({"graph": g.name, "method": m, "p": p,
                             "phases": phases,
                             "exec_time": rep.exec_time,
                             "data_comm_bytes": rep.data_comm_bytes})
                emit(f"cluster_sweep/{g.name}/{m}/p{p}", us,
                     f"exec_s={rep.exec_time:.3e};"
                     f"comm_bytes={rep.data_comm_bytes:.3e}")
            # §6.2.4 trend: comm eventually turns up (sync takes over)
            emit(f"cluster_sweep/{g.name}/{m}/comm_trend", 0.0,
                 f"comm_p8={comms[0]:.3e};comm_min={min(comms):.3e};"
                 f"comm_p1024={comms[-1]:.3e};"
                 f"u_shape={comms[-1] > min(comms)}")
    return rows


if __name__ == "__main__":
    run()
