"""Roofline analysis: judge measured kernel rows against an analytic bound.

Two input modes, auto-detected from the JSON shape:

* **Bench mode** (default) — a ``BENCH_*.json`` suite document whose
  pallas rows carry ``hlo_flops`` / ``hlo_hbm_bytes`` (lowered-HLO costs
  from `repro.core.pallas.cost`).  Each row is scored against the CPU
  roofline: ``ideal_us = max(flops/peak, bytes/bw)`` and
  ``roofline_fraction = ideal_us / measured_us``.  With no explicit
  path, every default bench JSON that exists is scanned.
* **Dry-run mode** (legacy) — a ``dryrun_results.json`` list of compiled
  (arch × shape × mesh) records, scored against TPU v5e constants.

CPU constants are deliberately conservative single-core numbers (the
timed kernels run interpret-mode Pallas on one core) and overridable:
``REPRO_ROOFLINE_PEAK_FLOPS`` / ``REPRO_ROOFLINE_MEM_BW``.
"""
from __future__ import annotations

import json
import os

from repro.configs import ARCHS, SHAPES

from .common import bench_output_path

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e, dry-run mode)
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link
DCN_BW = 25e9                # B/s per host (pod axis)

# CPU roofline for the bench rows: single-core scalar-ish throughput
# (the interpret-mode kernels don't vectorize) and one core's share of
# memory bandwidth.  Environment-overridable for calibrated hosts.
CPU_PEAK_FLOPS = float(os.environ.get("REPRO_ROOFLINE_PEAK_FLOPS", 5e10))
CPU_MEM_BW = float(os.environ.get("REPRO_ROOFLINE_MEM_BW", 2e10))

# suites whose pallas rows carry lowered-HLO cost fields
BENCH_SUITES = ("partitioner_scaling", "mapping_pipeline")


def ideal_us(flops: float, hbm_bytes: float) -> float:
    """Roofline-ideal time for a kernel on the CPU model: bound by
    whichever of compute and memory traffic dominates."""
    return max(flops / CPU_PEAK_FLOPS, hbm_bytes / CPU_MEM_BW) * 1e6


def roofline_fraction(flops: float, hbm_bytes: float,
                      measured_us: float) -> float:
    """ideal/measured in (0, 1]-ish — how close the measured kernel ran
    to its analytic bound (interpret mode sits far below 1)."""
    return ideal_us(flops, hbm_bytes) / max(measured_us, 1e-9)


def analyze_bench_rows(doc: dict) -> list[dict]:
    """Score a bench suite document's HLO-costed rows."""
    out = []
    for row in doc.get("rows", []):
        flops = row.get("hlo_flops")
        hbm = row.get("hlo_hbm_bytes")
        if flops is None or hbm is None:
            continue
        us = row.get("us_total", 0.0)
        frac = row.get("roofline_fraction",
                       roofline_fraction(flops, hbm, us))
        tag = "/".join(str(row[k]) for k in ("backend", "p") if k in row)
        out.append({"suite": doc.get("suite", "?"), "row": tag,
                    "hlo_flops": flops, "hlo_hbm_bytes": hbm,
                    "us_total": us, "ideal_us": ideal_us(flops, hbm),
                    "roofline_fraction": frac})
    return out


def _attention_flops(cfg, sc) -> float:
    """Quadratic attention term (2 matmuls of S×S per head), window-
    limited for local layers — dominates MODEL_FLOPS at 32k context."""
    if cfg.attention_free:
        return 0.0
    B, S = sc.global_batch, sc.seq_len
    pattern = list(cfg.layer_pattern)
    per_pos = 0.0
    for i in range(cfg.n_layers):
        kind = pattern[i % len(pattern)]
        if kind in ("rec", "rwkv"):
            continue
        window = cfg.local_window if (
            kind == "local" or (kind == "attn" and cfg.family == "hybrid")
        ) else None
        if sc.kind == "decode":
            ctx = min(window, S) if window else S
        else:
            ctx = min(window, S) if window else S / 2  # causal average
        hd = cfg.head_dim
        if cfg.use_mla:
            hd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim \
                + cfg.v_head_dim
        per_pos += 2.0 * 2.0 * cfg.n_heads * hd * ctx
    n_q = B if sc.kind == "decode" else B * S
    total = per_pos * n_q
    if cfg.n_encoder_layers and sc.kind != "decode":
        total += (2.0 * 2.0 * cfg.n_heads * cfg.head_dim * S / 2
                  * B * S * cfg.n_encoder_layers)
    return total


def model_flops(arch: str, shape: str) -> float:
    cfg = ARCHS[arch]
    sc = SHAPES[shape]
    n_active = cfg.active_param_count()
    tokens = sc.global_batch * sc.seq_len
    attn = _attention_flops(cfg, sc)
    if sc.kind == "train":
        return 6.0 * n_active * tokens + 3.0 * attn
    if sc.kind == "prefill":
        return 2.0 * n_active * tokens + attn
    # decode: one token per sequence in the batch
    return 2.0 * n_active * sc.global_batch + attn


def analyze_record(rec: dict) -> dict | None:
    if not rec.get("ok"):
        return None
    arch, shape = rec["cell"].split("/")
    n_chips = 512 if rec["mesh"] == "2x16x16" else 256
    flops = rec.get("hlo_flops", 0.0)
    hbm = rec.get("hlo_hbm_bytes", 0.0)
    coll = rec.get("hlo_collective_bytes", {}) or {}
    coll_total = sum(coll.values())
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm / HBM_BW
    t_coll = coll_total / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(arch, shape) / n_chips
    useful = mf / flops if flops > 0 else 0.0
    bound = max(terms.values())
    # roofline fraction: useful model FLOP-time over the bounding term
    frac = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        "cell": rec["cell"], "mesh": rec["mesh"], "kind": rec["kind"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_per_chip": mf, "hlo_flops": flops,
        "useful_ratio": useful, "roofline_fraction": frac,
        "collective_breakdown": coll,
        "temp_bytes_per_dev": rec.get("memory", {}).get("temp_bytes", -1),
        "arg_bytes_per_dev": rec.get("memory", {}).get("argument_bytes", -1),
    }


def load_results(path: str) -> list[dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        results = json.load(f)
    # last ok record wins per (cell, mesh)
    latest: dict = {}
    for r in results:
        if r.get("ok"):
            latest[(r["cell"], r["mesh"])] = r
    return [analyze_record(r) for r in latest.values()]


def _run_dryrun(path: str) -> list[dict]:
    rows = [r for r in load_results(path) if r]
    rows.sort(key=lambda r: (r["mesh"], r["cell"]))
    for r in rows:
        print(f"roofline/{r['cell']}/{r['mesh']},0.0,"
              f"dominant={r['dominant']};"
              f"compute_s={r['t_compute_s']:.3e};"
              f"memory_s={r['t_memory_s']:.3e};"
              f"collective_s={r['t_collective_s']:.3e};"
              f"useful_ratio={r['useful_ratio']:.3f};"
              f"roofline_fraction={r['roofline_fraction']:.3f}")
    return rows


def _run_bench(docs: list[dict]) -> list[dict]:
    rows = []
    for doc in docs:
        rows.extend(analyze_bench_rows(doc))
    rows.sort(key=lambda r: (r["suite"], r["row"]))
    for r in rows:
        print(f"roofline/{r['suite']}/{r['row']},{r['us_total']:.1f},"
              f"ideal_us={r['ideal_us']:.1f};"
              f"flops={r['hlo_flops']:.3e};"
              f"hbm_bytes={r['hlo_hbm_bytes']:.3e};"
              f"roofline_fraction={r['roofline_fraction']:.4f}")
    return rows


def run(path: str | None = None) -> list[dict]:
    """Score roofline rows from ``path``, auto-detecting the format; with
    no path, scan the default bench outputs (and fall back to a legacy
    ``dryrun_results.json`` if that is all that exists)."""
    if path is not None:
        with open(path) as f:
            doc = json.load(f)
        if isinstance(doc, dict) and "suite" in doc:
            return _run_bench([doc])
        return _run_dryrun(path)

    docs = []
    for suite in BENCH_SUITES:
        p = bench_output_path(suite)
        if os.path.exists(p):
            with open(p) as f:
                docs.append(json.load(f))
    if docs:
        return _run_bench(docs)
    if os.path.exists("dryrun_results.json"):
        return _run_dryrun("dryrun_results.json")
    print("roofline: no bench JSON found (run partitioner_scaling / "
          "mapping_pipeline first)")
    return []


if __name__ == "__main__":
    run()
