"""Roofline analysis per (arch × shape × mesh) from the dry-run artifacts.

Terms (per chip, per step; TPU v5e constants):
  compute    = HLO_FLOPs / peak_FLOPs           (197 TFLOP/s bf16)
  memory     = HLO_bytes / HBM_bw               (819 GB/s)
  collective = collective_bytes / link_bw       (~50 GB/s/link ICI;
               the 'pod' axis share rides DCN at ~25 GB/s/host)

HLO_FLOPs/bytes come from the loop-aware analyzer (repro.analysis) over
the SPMD-partitioned module — i.e. already per-device; collective bytes
likewise.  MODEL_FLOPS = 6·N·D (training, dense) or 6·N_active·D (MoE);
2·N·D for single-token decode; the ratio MODEL_FLOPS/HLO_FLOPs measures
how much compiled compute is useful (remat/dispatch waste shows up here).
"""
from __future__ import annotations

import json
import os

from repro.configs import ARCHS, SHAPES

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link
DCN_BW = 25e9                # B/s per host (pod axis)


def _attention_flops(cfg, sc) -> float:
    """Quadratic attention term (2 matmuls of S×S per head), window-
    limited for local layers — dominates MODEL_FLOPS at 32k context."""
    if cfg.attention_free:
        return 0.0
    B, S = sc.global_batch, sc.seq_len
    pattern = list(cfg.layer_pattern)
    per_pos = 0.0
    for i in range(cfg.n_layers):
        kind = pattern[i % len(pattern)]
        if kind in ("rec", "rwkv"):
            continue
        window = cfg.local_window if (
            kind == "local" or (kind == "attn" and cfg.family == "hybrid")
        ) else None
        if sc.kind == "decode":
            ctx = min(window, S) if window else S
        else:
            ctx = min(window, S) if window else S / 2  # causal average
        hd = cfg.head_dim
        if cfg.use_mla:
            hd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim \
                + cfg.v_head_dim
        per_pos += 2.0 * 2.0 * cfg.n_heads * hd * ctx
    n_q = B if sc.kind == "decode" else B * S
    total = per_pos * n_q
    if cfg.n_encoder_layers and sc.kind != "decode":
        total += (2.0 * 2.0 * cfg.n_heads * cfg.head_dim * S / 2
                  * B * S * cfg.n_encoder_layers)
    return total


def model_flops(arch: str, shape: str) -> float:
    cfg = ARCHS[arch]
    sc = SHAPES[shape]
    n_active = cfg.active_param_count()
    tokens = sc.global_batch * sc.seq_len
    attn = _attention_flops(cfg, sc)
    if sc.kind == "train":
        return 6.0 * n_active * tokens + 3.0 * attn
    if sc.kind == "prefill":
        return 2.0 * n_active * tokens + attn
    # decode: one token per sequence in the batch
    return 2.0 * n_active * sc.global_batch + attn


def analyze_record(rec: dict) -> dict | None:
    if not rec.get("ok"):
        return None
    arch, shape = rec["cell"].split("/")
    n_chips = 512 if rec["mesh"] == "2x16x16" else 256
    flops = rec.get("hlo_flops", 0.0)
    hbm = rec.get("hlo_hbm_bytes", 0.0)
    coll = rec.get("hlo_collective_bytes", {}) or {}
    coll_total = sum(coll.values())
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm / HBM_BW
    t_coll = coll_total / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(arch, shape) / n_chips
    useful = mf / flops if flops > 0 else 0.0
    bound = max(terms.values())
    # roofline fraction: useful model FLOP-time over the bounding term
    frac = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        "cell": rec["cell"], "mesh": rec["mesh"], "kind": rec["kind"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_per_chip": mf, "hlo_flops": flops,
        "useful_ratio": useful, "roofline_fraction": frac,
        "collective_breakdown": coll,
        "temp_bytes_per_dev": rec.get("memory", {}).get("temp_bytes", -1),
        "arg_bytes_per_dev": rec.get("memory", {}).get("argument_bytes", -1),
    }


def load_results(path: str = "dryrun_results.json") -> list[dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        results = json.load(f)
    # last ok record wins per (cell, mesh)
    latest: dict = {}
    for r in results:
        if r.get("ok"):
            latest[(r["cell"], r["mesh"])] = r
    return [analyze_record(r) for r in latest.values()]


def run(path: str = "dryrun_results.json") -> list[dict]:
    rows = [r for r in load_results(path) if r]
    rows.sort(key=lambda r: (r["mesh"], r["cell"]))
    for r in rows:
        print(f"roofline/{r['cell']}/{r['mesh']},0.0,"
              f"dominant={r['dominant']};"
              f"compute_s={r['t_compute_s']:.3e};"
              f"memory_s={r['t_memory_s']:.3e};"
              f"collective_s={r['t_collective_s']:.3e};"
              f"useful_ratio={r['useful_ratio']:.3f};"
              f"roofline_fraction={r['roofline_fraction']:.3f}")
    return rows


if __name__ == "__main__":
    run()
