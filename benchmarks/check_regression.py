"""CI perf gate: compare a BENCH_*.json run against a committed baseline.

    python benchmarks/check_regression.py BENCH_partitioner_scaling.json \
        benchmarks/baselines/partitioner_scaling.json --factor 2.0
    python benchmarks/check_regression.py BENCH_mapping_pipeline.json \
        benchmarks/baselines/mapping_pipeline.json --metric us_per_cluster \
        --factor 2.0 --min-speedup 5 --speedup-key speedup_p1024

Rows are matched on their identity keys (every key except the measured
ones) and compared after machine calibration: the reference-backend rows
act as a speed probe of the host (their engine never changes), so every
ratio is divided by ``median(run_ref / baseline_ref)``.  The gate then
fails a *backend* whose geometric-mean calibrated ratio exceeds
``factor`` — a real engine regression shifts every row, while scheduler
noise on a sub-millisecond row only perturbs one, so aggregating keeps
a 2x gate usable on shared CI runners.  Baseline rows missing from the
run are reported (coverage must not silently shrink); new rows pass
(they have no baseline yet).  Exits 1 on any regression or lost
coverage.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

MEASURED = {"us_per_edge", "us_total", "replication_factor",
            "us_per_cluster", "exec_time", "data_comm_bytes",
            "edges_per_s", "comm_bytes", "pct_of_compnet",
            "speedup_vs_compnet", "imbalance", "w_variant_time",
            "excess_vs_unbounded", "phases", "hlo_flops",
            "hlo_hbm_bytes", "roofline_fraction", "hit_rate",
            "plans_per_s", "p50_us", "p99_us"}


def _key(row: dict) -> tuple:
    return tuple(sorted((k, v) for k, v in row.items() if k not in MEASURED))


def _load_rows(path: str, metric: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    rows = doc["rows"] if isinstance(doc, dict) else doc
    return {_key(r): r for r in rows if metric in r}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("run_json")
    ap.add_argument("baseline_json")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="allowed slowdown vs baseline (default 2.0)")
    ap.add_argument("--metric", default="us_per_edge",
                    help="measured column the gate compares "
                         "(default us_per_edge)")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="also require meta.<speedup-key> >= this")
    ap.add_argument("--speedup-key", default="speedup_E32k_p512",
                    help="meta key checked by --min-speedup")
    ap.add_argument("--speedup-cores", type=int, default=None,
                    help="cores the --min-speedup target assumes: the "
                         "effective gate is scaled by min(host, N)/N "
                         "(host cores from meta.host_cores, falling back "
                         "to os.cpu_count()) with 20%% parallel-overhead "
                         "slack and a 0.75 floor; a 1-core host skips "
                         "the ratio check entirely (the key must still "
                         "be present) — W time-sliced workers on one "
                         "core measure the scheduler, not the code, and "
                         "an uncalibrated gate that no measured baseline "
                         "can meet gates nothing")
    ap.add_argument("--max-serial-fraction", type=float, default=None,
                    help="bound the measured non-parallel share of the "
                         "run: require meta.<serial-fraction-key> <= this "
                         "(host-aware: on hosts with fewer cores than "
                         "--speedup-cores the bound relaxes toward 1.0, "
                         "and a 1-core host auto-passes — there is no "
                         "parallelism to measure)")
    ap.add_argument("--serial-fraction-key", default="serial_fraction_w4",
                    help="meta key checked by --max-serial-fraction")
    ap.add_argument("--quality-fields", default=None,
                    help="comma list of lower-is-better row fields (e.g. "
                         "exec_time,data_comm_bytes) gated at "
                         "--quality-factor; these are deterministic model "
                         "outputs, so drift means the algorithm changed")
    ap.add_argument("--quality-factor", type=float, default=1.01,
                    help="allowed quality-field growth vs baseline "
                         "(default 1.01)")
    ap.add_argument("--attribute", action="store_true",
                    help="on a backend geomean failure, break the "
                         "regression down by pipeline phase: sum each "
                         "row's 'phases' dict across the backend's "
                         "matched rows, compare against the calibrated "
                         "baseline sums, and print the per-phase deltas "
                         "worst-first so the guilty phase is named")
    args = ap.parse_args(argv)
    METRIC = args.metric
    quality = [f.strip() for f in (args.quality_fields or "").split(",")
               if f.strip()]
    quality_checks = dict.fromkeys(quality, 0)

    run = _load_rows(args.run_json, METRIC)
    base = _load_rows(args.baseline_json, METRIC)

    # host-speed calibration from the reference-backend rows
    ref_ratios = sorted(
        run[k][METRIC] / max(base[k][METRIC], 1e-12)
        for k in set(run) & set(base)
        if dict(k).get("backend") == "reference")
    calib = ref_ratios[len(ref_ratios) // 2] if ref_ratios else 1.0
    print(f"machine calibration: x{calib:.2f} "
          f"({len(ref_ratios)} reference rows)")

    failures = []
    by_backend: dict = {}
    phase_sums: dict = {}       # backend -> {phase: [run_us, base_us]}
    for key, brow in sorted(base.items()):
        rrow = run.get(key)
        tag = "/".join(f"{k}={v}" for k, v in key)
        if rrow is None:
            failures.append(f"MISSING  {tag} (baseline coverage lost)")
            continue
        ratio = rrow[METRIC] / max(brow[METRIC] * calib, 1e-12)
        backend = dict(key).get("backend", "?")
        by_backend.setdefault(backend, []).append(ratio)
        sums = phase_sums.setdefault(backend, {})
        for src, col in ((rrow, 0), (brow, 1)):
            for phase, us in (src.get("phases") or {}).items():
                sums.setdefault(phase, [0.0, 0.0])[col] += us
        flag = " " if ratio <= args.factor else "*"
        print(f"{flag} {tag}: {rrow[METRIC]:.3f} {METRIC} "
              f"(baseline {brow[METRIC]:.3f}, x{ratio:.2f})")
        for field in quality:
            if field not in brow:
                continue            # baseline never tracked this field
            if field not in rrow:
                failures.append(f"QUALITY  {tag}: {field} missing from run "
                                "(quality coverage lost)")
                continue
            quality_checks[field] += 1
            qratio = rrow[field] / max(brow[field], 1e-30)
            if qratio > args.quality_factor:
                failures.append(f"QUALITY  {tag}: {field} {rrow[field]:.6g} "
                                f"vs baseline {brow[field]:.6g} "
                                f"(x{qratio:.3f} > x{args.quality_factor})")
    for backend, ratios in sorted(by_backend.items()):
        gmean = math.exp(sum(math.log(max(r, 1e-12)) for r in ratios)
                         / len(ratios))
        status = "OK" if gmean <= args.factor else "REGRESSED"
        print(f"{status:9} backend={backend}: geomean x{gmean:.2f} "
              f"over {len(ratios)} rows (gate x{args.factor})")
        if gmean > args.factor:
            failures.append(f"backend={backend}: geomean x{gmean:.2f} "
                            f"> x{args.factor}")
            if args.attribute and phase_sums.get(backend):
                deltas = sorted(
                    ((run_us - base_us * calib, phase, run_us, base_us)
                     for phase, (run_us, base_us)
                     in phase_sums[backend].items()),
                    reverse=True)
                print(f"  phase attribution for backend={backend} "
                      f"(run vs calibrated baseline, worst first):")
                for delta, phase, run_us, base_us in deltas:
                    cal = base_us * calib
                    pratio = run_us / max(cal, 1e-12)
                    print(f"    {phase:10} {run_us:12.1f}us vs "
                          f"{cal:12.1f}us  x{pratio:5.2f}  "
                          f"({delta:+12.1f}us)")
                worst = deltas[0][1]
                print(f"  regressing phase: {worst}")
    for key in sorted(set(run) - set(base)):
        print(f"NEW       {'/'.join(f'{k}={v}' for k, v in key)}: "
              f"{run[key][METRIC]:.3f} {METRIC} (no baseline)")
    for field, n_checked in quality_checks.items():
        # a requested field that never matched is a typo or lost coverage
        if n_checked == 0:
            failures.append(f"quality field {field!r}: 0 rows compared")
        else:
            print(f"QUALITY   {field}: checked {n_checked} rows "
                  f"(gate x{args.quality_factor})")

    if args.min_speedup is not None:
        with open(args.run_json) as f:
            meta = json.load(f).get("meta", {})
        sp = meta.get(args.speedup_key)
        gate = args.min_speedup
        if args.speedup_cores:
            host = meta.get("host_cores") or os.cpu_count() or 1
            if min(host, args.speedup_cores) <= 1:
                # A 1-core host can't run even 2-way parallel: W worker
                # processes are pure time-sliced overhead there, so the
                # ratio measures the scheduler, not the code.  The key
                # must still exist (coverage), but its value is not
                # gated; the geomean rows still gate absolute W-way
                # throughput against the calibrated baseline.
                if sp is None:
                    failures.append(
                        f"meta {args.speedup_key} missing from run "
                        "(speedup coverage lost)")
                else:
                    print(f"SKIP      {args.speedup_key} = {sp}x "
                          f"(1 host core: a {args.speedup_cores}-way "
                          "speedup is unmeasurable)")
                sp = None
                gate = None
            else:
                gate = max(0.75, args.min_speedup
                           * min(host, args.speedup_cores)
                           / args.speedup_cores * 0.8)
                print(f"speedup gate scaled for {host} host cores "
                      f"(target {args.min_speedup}x @ "
                      f"{args.speedup_cores} cores -> {gate:.2f}x)")
        if gate is None:
            pass
        elif sp is None or sp < gate:
            failures.append(
                f"meta speedup {args.speedup_key} {sp} < {gate:.2f}")
        else:
            print(f"OK        {args.speedup_key} = {sp}x "
                  f"(gate {gate:.2f}x)")

    if args.max_serial_fraction is not None:
        with open(args.run_json) as f:
            meta = json.load(f).get("meta", {})
        sf = meta.get(args.serial_fraction_key)
        host = meta.get("host_cores") or os.cpu_count() or 1
        cores = args.speedup_cores or 1
        # Amdahl in reverse: a W-core serial-fraction target is only
        # measurable when W cores exist.  Interpolate the bound from
        # 1.0 (1 host core: everything is serial, nothing to gate)
        # down to the requested max at full core count, with the same
        # 20% overhead slack the speedup gate uses.
        if cores > 1:
            frac = (min(host, cores) - 1) / (cores - 1)
            allowed = min(1.0, 1 - (1 - args.max_serial_fraction)
                          * 0.8 * frac)
        else:
            allowed = 1.0
        print(f"serial-fraction gate scaled for {host} host cores "
              f"(target <= {args.max_serial_fraction} @ {cores} cores "
              f"-> <= {allowed:.3f})")
        if sf is None:
            failures.append(f"meta {args.serial_fraction_key} missing "
                            "from run (serial-fraction coverage lost)")
        elif sf > allowed:
            failures.append(
                f"meta {args.serial_fraction_key} {sf:.3f} "
                f"> {allowed:.3f} (serial share too large)")
        else:
            print(f"OK        {args.serial_fraction_key} = {sf:.3f} "
                  f"(gate <= {allowed:.3f})")

    if failures:
        print("\nPERF GATE FAILED:", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        return 1
    print(f"\nperf gate passed: {len(by_backend)} backend groups "
          f"({len(base)} baseline rows) within geomean x{args.factor}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
