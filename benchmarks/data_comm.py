"""Paper Tables 8-9 / Fig. 10: inter-core data communication per method,
normalised to CompNet = 100% (vertex cuts land well below 100%, METIS
above — the paper's §6.2.4 finding)."""
from __future__ import annotations

from repro.core import run_pipeline

from .common import ALL_METHODS, emit, graphs, timed_phases

P_VALUES = (8, 64, 1024)


def run(scale: str = "reduced", names=None,
        p_values=P_VALUES) -> list[dict]:
    rows = []
    for g in graphs(scale, names):
        for p in p_values:
            base = None
            for m in ALL_METHODS:
                (part, mapping, rep), us, phases = timed_phases(
                    run_pipeline, g, p, m)
                if m == "compnet":
                    base = rep
                pct = 100.0 * rep.data_comm_bytes / base.data_comm_bytes
                rows.append({"graph": g.name, "p": p, "method": m,
                             "phases": phases,
                             "comm_bytes": rep.data_comm_bytes,
                             "pct_of_compnet": pct})
                emit(f"data_comm/{g.name}/p{p}/{m}", us,
                     f"bytes={rep.data_comm_bytes:.3e};"
                     f"pct_of_compnet={pct:.1f}%")
    return rows


if __name__ == "__main__":
    run()
