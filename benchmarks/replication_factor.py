"""Paper Fig. 8: replication factor of the four greedy vertex cuts vs.
the Eq. (10) random-cut theoretical upper bound, across cluster counts."""
from __future__ import annotations

from repro.core import vertex_cut
from repro.core.powerlaw import expected_replication_random_empirical

from .common import emit, graphs, timed

P_VALUES = (8, 32, 128)
METHODS = ("w_pg", "wb_pg", "w_libra", "wb_libra")


def run(scale: str = "reduced", names=None) -> list[dict]:
    rows = []
    for g in graphs(scale, names):
        deg = g.degrees()
        active = deg[deg > 0]
        for p in P_VALUES:
            bound = expected_replication_random_empirical(active, p)
            for m in METHODS:
                r, us = timed(vertex_cut, g, p, method=m)
                rf = r.replication_factor_active
                rows.append({"graph": g.name, "p": p, "method": m,
                             "rf": rf, "bound": bound})
                emit(f"replication_factor/{g.name}/p{p}/{m}", us,
                     f"rf={rf:.3f};eq10_bound={bound:.3f};"
                     f"under_bound={rf <= bound + 1e-9}")
    return rows


if __name__ == "__main__":
    run()
