"""Shim: the loop-aware HLO cost analyzer lives in repro.analysis."""
from repro.analysis.hlo_cost import HLOCost, analyze_hlo  # noqa: F401
