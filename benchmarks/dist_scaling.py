"""Distributed sharded partitioner: ingest+partition scaling over workers.

End-to-end throughput of the `repro.dist` subsystem — parallel
byte-sharded NDJSON parse followed by the W-worker sharded vertex cut —
at W ∈ {1, 2, 4, 8} on a synthetic dynamic trace whose ingested graph
matches the partitioner_scaling headline scale (>= 510k edges), plus a
sequential `reference` row (plain streaming ingester + single-stream
fast cut) that doubles as the host-speed calibration probe for
`check_regression.py`.

Gates (`benchmarks/baselines/dist_scaling.json` + CI):
  * throughput per row (us_per_edge, calibrated geomean factor 2.0);
  * replication_factor per row — the W>1 cut is deterministic for a
    fixed (W, seed, merge_period), so any drift means the algorithm
    changed (quality factor 1.01);
  * meta.speedup_w4 >= 2x on CI runners (--min-speedup 2.0): the
    parallel front end must actually pay for itself at W=4.

The W=1 bit-identity contract is asserted outright: same assignment as
`vertex_cut(..., backend="fast")` on the ingested graph, hence the same
replication factor.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import vertex_cut
from repro.dist import dist_ingest, dist_vertex_cut
from repro.trace import ingest_trace, synthesize_trace

from .common import emit, timed_best, write_bench_json

CACHE_DIR = ".cache/traces"
LINES = 276_000          # ingests to >= 510k edges (partitioner headline)
CUT_P = 64
WORKERS = (1, 2, 4, 8)
MERGE_PERIOD = 1 << 16
# best-of-N timing: the W=4/W=1 speedup is a wall-clock ratio gated in
# CI, so one scheduler hiccup must not be able to sink (or inflate) it
REPEATS = 2


def _trace_path() -> str:
    os.makedirs(CACHE_DIR, exist_ok=True)
    path = os.path.join(CACHE_DIR, f"synth_{LINES}_seed0.ndjson")
    if not os.path.exists(path):
        synthesize_trace(path, LINES, seed=0)
    return path


def _row(backend: str, workers: int, edges: int, us: float,
         rf: float) -> dict:
    row = {"backend": backend, "workers": workers, "edges": edges,
           "us_per_edge": round(us / max(edges, 1), 4),
           "us_total": round(us, 1),
           "edges_per_s": round(edges / (us / 1e6), 1),
           "replication_factor": round(rf, 4)}
    emit(f"dist_scaling/W{workers}/{backend}", us,
         f"edges_per_s={row['edges_per_s']:.0f}")
    return row


def run() -> list[dict]:
    path = _trace_path()
    rows = []

    # sequential oracle + host calibration probe
    def seq_pipeline():
        g = ingest_trace(path)
        return g, vertex_cut(g, CUT_P, method="wb_libra", backend="fast")

    (g_ref, cut_ref), us_ref = timed_best(seq_pipeline, repeats=REPEATS)
    rows.append(_row("reference", 1, g_ref.num_edges, us_ref,
                     cut_ref.replication_factor))

    by_w = {}
    for w in WORKERS:
        def dist_pipeline(w=w):
            g = dist_ingest(path, workers=w)
            return g, dist_vertex_cut(g, CUT_P, method="wb_libra",
                                      workers=w,
                                      merge_period=MERGE_PERIOD)

        (g, cut), us = timed_best(dist_pipeline, repeats=REPEATS)
        row = _row("dist", w, g.num_edges, us, cut.replication_factor)
        rows.append(row)
        by_w[w] = row
        if w == 1:
            # the W=1 contract: bit-identical to the stream engine
            assert np.array_equal(cut.assignment, cut_ref.assignment), \
                "dist workers=1 diverged from the fast streaming engine"
            assert np.array_equal(g.src, g_ref.src), \
                "sharded parse (W=1) diverged from the sequential ingester"

    speedup_w4 = by_w[1]["us_total"] / max(by_w[4]["us_total"], 1e-9)
    rf_ratio_w4 = (by_w[4]["replication_factor"]
                   / max(by_w[1]["replication_factor"], 1e-9))
    emit("dist_scaling/speedup_W4", by_w[4]["us_total"],
         f"vs_W1={speedup_w4:.2f}x rf_ratio={rf_ratio_w4:.3f}")
    write_bench_json("dist_scaling", rows,
                     meta={"lines": LINES, "cut_p": CUT_P,
                           "merge_period": MERGE_PERIOD,
                           "speedup_w4": round(speedup_w4, 2),
                           "rf_ratio_w4": round(rf_ratio_w4, 4)})
    return rows


if __name__ == "__main__":
    run()
