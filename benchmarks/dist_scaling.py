"""Distributed sharded partitioner: ingest+partition scaling over workers.

End-to-end throughput of the `repro.dist` subsystem at two scales:

  * the 276k-line trace (>= 510k edges, the partitioner_scaling
    headline scale) runs the classic two-phase path at W ∈ {1, 2, 4, 8}
    plus a sequential `reference` row (plain streaming ingester +
    single-stream fast cut) that doubles as the host-speed calibration
    probe for `check_regression.py`;
  * the 2.76M-line trace (~5.1M edges) is the scaling headline: trace
    *paths* go straight into `dist_vertex_cut`, so W > 1 runs the
    pipelined parse→cut dataflow (parse shards stream into resident
    cut workers — no parse barrier) and W=1 is the two-phase wall the
    speedups are measured against.

Gates (`benchmarks/baselines/dist_scaling.json` + CI):
  * throughput per row (us_per_edge, calibrated geomean factor 2.0);
  * replication_factor per row — the W>1 cut is deterministic for a
    fixed (W, seed, merge_period), so any drift means the algorithm
    changed (quality factor 1.01);
  * meta.speedup_w4 >= 3x at the 5.1M-edge scale, host-aware
    (`--min-speedup 3.0 --speedup-cores 4`: the gate scales by
    min(host_cores, 4)/4 with 20% slack, so a 1-core runner gates at
    the 0.75 no-pathology floor while a 4-core runner must show real
    scaling), and speedup_w8 must not fall below speedup_w4 (monotone
    through W=8, asserted here on hosts with >= 8 cores).

The W=1 bit-identity contract is asserted outright: same assignment as
`vertex_cut(..., backend="fast")` on the ingested graph, hence the same
replication factor.  Phase timings of the big pipelined runs flow
through the `repro.obs` telemetry layer: each W runs inside a scoped
collector, and the per-phase totals, per-lane utilization, and the
measured serial fraction (the Amdahl `s` the `--max-serial-fraction`
gate bounds) land in ``meta.phases_w{4,8}`` /
``meta.serial_fraction_w4``.  Running the suite under
``REPRO_PROFILE=out.json`` additionally exports the full per-worker
Perfetto trace (the scoped collectors merge into the env collector).
"""
from __future__ import annotations

import os

import numpy as np

from repro import obs
from repro.core import vertex_cut
from repro.dist import dist_ingest, dist_vertex_cut
from repro.obs.summarize import summarize_events
from repro.trace import ingest_trace, synthesize_trace

from .common import emit, phases_of, timed_best, timed_phases, \
    write_bench_json

CACHE_DIR = ".cache/traces"
LINES = 276_000          # ingests to >= 510k edges (partitioner headline)
BIG_LINES = 2_760_000    # ~5.1M edges: the pipelined-scaling headline
CUT_P = 64
WORKERS = (1, 2, 4, 8)
BIG_WORKERS = (1, 4, 8)
MERGE_PERIOD = 1 << 16
# best-of-N timing: the W=4/W=1 speedup is a wall-clock ratio gated in
# CI, so one scheduler hiccup must not be able to sink (or inflate) it
REPEATS = 2
BIG_REPEATS = 1          # ~5.1M edges/run: one pass per W is plenty
                         # (also keeps one obs collector per W run)


def _trace_path(lines: int) -> str:
    os.makedirs(CACHE_DIR, exist_ok=True)
    path = os.path.join(CACHE_DIR, f"synth_{lines}_seed0.ndjson")
    if not os.path.exists(path):
        synthesize_trace(path, lines, seed=0)
    return path


def _row(lines: int, backend: str, workers: int, edges: int, us: float,
         rf: float, phases: dict | None = None) -> dict:
    row = {"lines": lines, "backend": backend, "workers": workers,
           "edges": edges,
           "us_per_edge": round(us / max(edges, 1), 4),
           "us_total": round(us, 1),
           "edges_per_s": round(edges / (us / 1e6), 1),
           "replication_factor": round(rf, 4),
           "phases": phases or {}}
    emit(f"dist_scaling/L{lines}/W{workers}/{backend}", us,
         f"edges_per_s={row['edges_per_s']:.0f}")
    return row


def _phase_meta(summary: dict) -> dict:
    """Meta-sized view of an obs summary: phase totals, utilization,
    and the wall decomposition the serial-fraction gate reads."""
    return {
        "wall_us": round(summary["wall_us"], 1),
        "parallel_us": round(summary["parallel_us"], 1),
        "serial_us": round(summary["serial_us"], 1),
        "idle_us": round(summary["idle_us"], 1),
        "serial_fraction": round(summary["serial_fraction"], 4),
        "phases": {name: {"count": int(ph["count"]),
                          "total_us": round(ph["total_us"], 1)}
                   for name, ph in sorted(summary["phases"].items())},
        "lane_utilization": {lane: round(st["utilization"], 4)
                             for lane, st in summary["lanes"].items()},
    }


def run() -> list[dict]:
    path = _trace_path(LINES)
    rows = []

    # sequential oracle + host calibration probe
    def seq_pipeline():
        g = ingest_trace(path)
        return g, vertex_cut(g, CUT_P, method="wb_libra", backend="fast")

    (g_ref, cut_ref), us_ref, ph_ref = timed_phases(seq_pipeline,
                                                    repeats=REPEATS)
    rows.append(_row(LINES, "reference", 1, g_ref.num_edges, us_ref,
                     cut_ref.replication_factor, ph_ref))

    for w in WORKERS:
        def dist_pipeline(w=w):
            g = dist_ingest(path, workers=w)
            return g, dist_vertex_cut(g, CUT_P, method="wb_libra",
                                      workers=w,
                                      merge_period=MERGE_PERIOD)

        (g, cut), us, ph = timed_phases(dist_pipeline, repeats=REPEATS)
        rows.append(_row(LINES, "dist", w, g.num_edges, us,
                         cut.replication_factor, ph))
        if w == 1:
            # the W=1 contract: bit-identical to the stream engine
            assert np.array_equal(cut.assignment, cut_ref.assignment), \
                "dist workers=1 diverged from the fast streaming engine"
            assert np.array_equal(g.src, g_ref.src), \
                "sharded parse (W=1) diverged from the sequential ingester"

    # ----- the 5.1M-edge pipelined-scaling headline ----- #
    big_path = _trace_path(BIG_LINES)
    by_w: dict = {}
    summaries: dict = {}
    timeline_w4: dict = {}
    for w in BIG_WORKERS:
        def big_pipeline(w=w):
            # trace path straight into the cut: W>1 pipelines parse→cut;
            # the W=4 run also records the engine's round timeline, which
            # lands in meta as the Perfetto-exportable track source
            # (python -m repro.obs timeline BENCH_dist_scaling.json)
            return dist_vertex_cut(big_path, CUT_P, method="wb_libra",
                                   workers=w, merge_period=MERGE_PERIOD,
                                   timeline=timeline_w4 if w == 4 else None)

        # scoped collector: the engine's telemetry spans become the
        # per-round timeline (merged upward into REPRO_PROFILE if set)
        with obs.scoped() as prof:
            cut, us = timed_best(big_pipeline, repeats=BIG_REPEATS)
        rows.append(_row(BIG_LINES, "dist", w, len(cut.assignment), us,
                         cut.replication_factor, phases_of(prof.events)))
        by_w[w] = rows[-1]
        if w > 1:
            assert any(ev["name"] == "dist.parse_wait"
                       for ev in prof.events), \
                f"W={w} trace-path cut did not pipeline (no parse/cut " \
                "dataflow spans recorded)"
            summaries[w] = _phase_meta(summarize_events(prof.events))

    speedup_w4 = by_w[1]["us_total"] / max(by_w[4]["us_total"], 1e-9)
    speedup_w8 = by_w[1]["us_total"] / max(by_w[8]["us_total"], 1e-9)
    rf_ratio_w4 = (by_w[4]["replication_factor"]
                   / max(by_w[1]["replication_factor"], 1e-9))
    serial_fraction_w4 = summaries[4]["serial_fraction"]
    emit("dist_scaling/speedup_W4", by_w[4]["us_total"],
         f"vs_W1={speedup_w4:.2f}x rf_ratio={rf_ratio_w4:.3f} "
         f"serial_fraction={serial_fraction_w4:.3f}")
    emit("dist_scaling/speedup_W8", by_w[8]["us_total"],
         f"vs_W1={speedup_w8:.2f}x")
    host_cores = (len(os.sched_getaffinity(0))
                  if hasattr(os, "sched_getaffinity") else os.cpu_count())
    # monotone scaling through W=8: W=8 must never lose to W=4 (10%
    # wall-clock noise allowance; both are single-shot timings).  Only
    # enforceable where 8 workers have 8 cores to scale onto — on a
    # smaller host the extra workers are pure scheduling overhead.
    if host_cores >= 8:
        assert speedup_w8 >= speedup_w4 * 0.9, \
            f"W=8 ({speedup_w8:.2f}x) fell behind W=4 ({speedup_w4:.2f}x)"
    write_bench_json("dist_scaling", rows,
                     meta={"lines": LINES, "big_lines": BIG_LINES,
                           "cut_p": CUT_P,
                           "merge_period": MERGE_PERIOD,
                           "host_cores": host_cores,
                           "speedup_w4": round(speedup_w4, 2),
                           "speedup_w8": round(speedup_w8, 2),
                           "rf_ratio_w4": round(rf_ratio_w4, 4),
                           "serial_fraction_w4": serial_fraction_w4,
                           "phases_w4": summaries.get(4),
                           "phases_w8": summaries.get(8),
                           "timeline_w4": timeline_w4 or None})
    return rows


if __name__ == "__main__":
    run()
