"""Beyond-paper integration benchmark: WB-Libra expert placement for MoE
EP shards vs. the standard contiguous layout.

Vertices = experts, edges = co-activation (top-k co-routing), weights =
routed-token counts: the vertex cut replicates hot experts (the paper's
'cut the high-degree vertex') and balances per-shard token load — the
quantities that set the MoE all-to-all and expert-compute roofline terms
for deepseek-v3-671b / dbrx-132b."""
from __future__ import annotations

import numpy as np

from repro.core.planner import expert_placement, naive_expert_placement

from .common import emit, timed


def synth_routing(n_experts: int, zipf_a: float = 1.2, seed: int = 0,
                  k: int = 8, n_tokens: int = 100_000):
    """Zipf expert popularity + correlated co-activation counts."""
    rng = np.random.default_rng(seed)
    pop = (np.arange(1, n_experts + 1, dtype=np.float64) ** -zipf_a)
    pop = pop[rng.permutation(n_experts)]
    pop /= pop.sum()
    load = pop * n_tokens * k
    co = np.zeros((n_experts, n_experts))
    draws = rng.choice(n_experts, size=(n_tokens // 50, k), p=pop)
    for row in draws:
        for i in range(k):
            for j in range(i + 1, k):
                co[row[i], row[j]] += 1
                co[row[j], row[i]] += 1
    return load, co


def run() -> list[dict]:
    rows = []
    for (E, k, devs, label) in ((256, 8, 16, "deepseek-v3"),
                                (16, 4, 8, "dbrx")):
        load, co = synth_routing(E, k=k)
        ep, us = timed(expert_placement, load, co, n_devices=devs)
        nv = naive_expert_placement(load, devs)
        imb_ep = float(ep.device_load.max() / ep.device_load.mean())
        imb_nv = float(nv.device_load.max() / nv.device_load.mean())
        rows.append({"arch": label, "imb_vertex_cut": imb_ep,
                     "imb_naive": imb_nv,
                     "a2a_vertex_cut": ep.all_to_all_fraction,
                     "a2a_naive": nv.all_to_all_fraction,
                     "replication": ep.replication_factor})
        emit(f"expert_placement/{label}", us,
             f"load_imb={imb_ep:.3f}_vs_naive_{imb_nv:.3f};"
             f"a2a_frac={ep.all_to_all_fraction:.3f}_vs_naive_"
             f"{nv.all_to_all_fraction:.3f};"
             f"replicas_per_expert={ep.replication_factor:.2f}")
    return rows


if __name__ == "__main__":
    run()
